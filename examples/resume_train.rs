//! Resumable training end to end, in one process (the §4.2.4 recovery
//! story at library level; see `rust/tests/integration_recovery.rs` for the
//! same drill with real SIGKILLed child processes):
//!
//! 1. run A trains 30 steps straight through — the reference;
//! 2. run B trains the identical config while cutting a coordinated
//!    checkpoint epoch every 10 steps (PS snapshot + global manifest);
//! 3. run C starts FRESH, restores epoch 20 (dense + optimizer from the
//!    manifest, embedding PS from the epoch files, loader streams by
//!    fast-forward) and trains only steps 20..30.
//!
//! C must finish **bit-identical** to A: resuming from a committed epoch is
//! indistinguishable from never having died.

use anyhow::Result;
use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::{ResumeState, Trainer};
use persia::recovery::{latest_epoch, load_manifest, EpochConfig};

fn trainer(steps: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 1000,
        shard_capacity: 8192,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster = ClusterConfig {
        n_nn_workers: 1,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: 32,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: 5,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, 1000, 1.05, 5);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.eval_rows = 1024;
    // Deterministic FullSync: the configuration under which resume is
    // provably EXACT, not just statistically equivalent.
    t.deterministic = true;
    t
}

fn main() -> Result<()> {
    let steps = 30;
    let dir = std::env::temp_dir().join(format!("persia_resume_demo_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    println!("== run A: 30 steps, no checkpoints (reference) ==");
    let a = trainer(steps).run_rust()?;
    a.report.print_row();

    println!("\n== run B: 30 steps, checkpoint epoch every 10 ==");
    let mut b = trainer(steps);
    b.checkpoint = Some(EpochConfig { dir: dir.clone(), every: 10 });
    let b = b.run_rust()?;
    b.report.print_row();
    anyhow::ensure!(
        a.final_params == b.final_params,
        "checkpointing must be pure observation"
    );
    let newest = latest_epoch(&dir);
    println!("committed epochs present; newest = {newest:?}");
    anyhow::ensure!(newest == Some(30), "expected epoch 30 committed");

    println!("\n== run C: fresh process, --resume-from epoch 20 ==");
    let manifest = load_manifest(&dir, 20)?;
    let mut c = trainer(steps);
    c.start_step = manifest.step as usize;
    c.resume = Some(ResumeState::from_manifest(&manifest, Some(dir.clone())));
    let c = c.run_rust()?;
    c.report.print_row();

    anyhow::ensure!(
        c.final_params == a.final_params,
        "resumed run diverged from the uninterrupted reference"
    );
    anyhow::ensure!(
        c.tracker.aucs == a.tracker.aucs,
        "resumed AUC trajectory diverged: {:?} vs {:?}",
        c.tracker.aucs,
        a.tracker.aucs
    );
    let suffix: Vec<(u64, f32)> =
        a.tracker.losses.iter().filter(|(s, _)| *s >= 20).cloned().collect();
    anyhow::ensure!(c.tracker.losses == suffix, "resumed loss curve != reference suffix");
    println!("\nPARITY OK: resume from epoch 20 is bit-identical to the uninterrupted run");

    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
