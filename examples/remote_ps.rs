//! TCP service mode demo: the embedding PS as a standalone server, a hybrid
//! trainer talking to it over loopback, and an in-process control run that
//! must match it exactly.
//!
//! ```bash
//! cargo run --release --example remote_ps
//! ```
//!
//! This is the single-process version of the two-process deployment
//! (`persia serve-ps` + `persia train --remote-ps`); it spawns the server on
//! an ephemeral port so it needs no free well-known port.

use std::sync::Arc;

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, ServiceConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::Trainer;
use persia::service::{PsBackend, PsServer, RemotePs};

fn trainer() -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 1000,
        shard_capacity: 4096,
        n_nodes: 2,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster =
        ClusterConfig { n_nn_workers: 1, n_emb_workers: 2, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::Hybrid,
        batch_size: 64,
        lr: 0.1,
        staleness_bound: 4,
        steps: 200,
        eval_every: 100,
        seed: 17,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 1000, 1.05, 17);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    // Inline gradient application: bit-reproducible, so remote == local.
    t.deterministic = true;
    t
}

fn main() -> anyhow::Result<()> {
    let base = trainer();

    // 1. Embedding PS as a TCP service on an ephemeral loopback port.
    let ps =
        Arc::new(EmbeddingPs::new(&base.emb_cfg, base.model.emb_dim_per_group, base.train.seed));
    let server = PsServer::bind(ps, "127.0.0.1:0", &base.emb_cfg, base.train.seed)?;
    let addr = server.local_addr()?;
    let handle = server.spawn()?;
    println!("embedding PS serving on {addr}");

    // 2. Hybrid training against the remote PS.
    let remote = Arc::new(RemotePs::connect(&ServiceConfig::at(addr.to_string()))?);
    println!(
        "connected: dim={} nodes={} shards/node={}",
        PsBackend::dim(remote.as_ref()),
        remote.n_nodes(),
        remote.shards_per_node()
    );
    let mut remote_trainer = trainer();
    remote_trainer.ps_backend = Some(remote.clone());
    let remote_out = remote_trainer.run_rust()?;
    print!("remote-PS  ");
    remote_out.report.print_row();
    let stats = PsBackend::stats(remote.as_ref())?;
    println!(
        "remote PS stats: rows={} evictions={} imbalance={:.2}",
        stats.total_rows, stats.total_evictions, stats.imbalance
    );

    // 3. In-process control run with the same seed.
    let local_out = trainer().run_rust()?;
    print!("in-process ");
    local_out.report.print_row();

    let auc_gap =
        (remote_out.report.final_auc.unwrap() - local_out.report.final_auc.unwrap()).abs();
    println!("AUC gap remote vs in-process: {auc_gap:.2e}");
    anyhow::ensure!(auc_gap < 1e-6, "remote PS diverged from in-process PS");

    // 4. Graceful shutdown: drop the client pool, then drain the server.
    drop(remote_trainer);
    remote.shutdown_server()?;
    drop(remote);
    handle.shutdown()?;
    println!("server drained and stopped; service mode OK");
    Ok(())
}
