//! Live resharding demo (ISSUE 9): grow a 2-shard PS deployment to 3
//! shards mid-train, every role its own OS process on loopback.
//!
//! Two `persia serve-ps` shards own an intentionally lopsided split of the
//! PS node space (4 nodes vs 2), a third starts as a `--join` spare that
//! owns nothing, and `persia train` runs with the reshard probe armed.
//! Under the preset's Zipf traffic the probe sees the ≈1.33 per-process
//! imbalance at the first cadence boundary, streams the hot shard's tail
//! nodes onto the spare behind the PREPARE/MIGRATE/COMMIT barrier, and
//! commits routing epoch 1 — while the deterministic FullSync run keeps
//! bitwise parity (≤ 1e-6) with an unresharded single-process reference.
//!
//! ```bash
//! cargo build --release            # builds the `persia` binary it spawns
//! cargo run --release --example reshard_live
//! ```

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;
use persia::service::reshard::load_routing;

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: &str = "65536";
const SEED: &str = "42";
const STEPS: usize = 30;
const BATCH: usize = 16;
/// A finer node grid than the preset default so the planner has split
/// points: ps0 serves 0..4, ps1 serves 4..6.
const N_NODES: usize = 6;

/// The `persia` binary next to this example's executable
/// (`target/<profile>/examples/reshard_live` → `target/<profile>/persia`).
fn persia_bin() -> Result<PathBuf> {
    let exe = std::env::current_exe().context("current_exe")?;
    let dir = exe
        .parent()
        .and_then(|p| p.parent())
        .context("example executable has no target dir")?;
    let bin = dir.join(format!("persia{}", std::env::consts::EXE_SUFFIX));
    anyhow::ensure!(
        bin.exists(),
        "persia binary not found at {} — run `cargo build --release` first",
        bin.display()
    );
    Ok(bin)
}

/// A child with stdout AND stderr streamed to our stdout (prefixed) while
/// scanning for marker lines. Killed on drop.
struct Proc {
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    readers: Vec<std::thread::JoinHandle<()>>,
}

impl Proc {
    /// Spawn and return a channel yielding every output line as it arrives.
    fn spawn(
        tag: &'static str,
        args: &[String],
    ) -> Result<(Proc, std::sync::mpsc::Receiver<String>)> {
        let mut child = Command::new(persia_bin()?)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning {tag}"))?;
        let stdout = child.stdout.take().context("stdout piped")?;
        let stderr = child.stderr.take().context("stderr piped")?;
        let lines = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel();
        let mut readers = Vec::new();
        for reader in [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)] {
            let lines = lines.clone();
            let tx = tx.clone();
            readers.push(std::thread::spawn(move || {
                for line in std::io::BufReader::new(reader).lines() {
                    let Ok(line) = line else { break };
                    println!("[{tag}] {line}");
                    lines.lock().unwrap().push(line.clone());
                    let _ = tx.send(line);
                }
            }));
        }
        Ok((Proc { child, lines, readers }, rx))
    }

    fn wait_success(&mut self, tag: &str) -> Result<Vec<String>> {
        let status = self.child.wait().with_context(|| format!("waiting for {tag}"))?;
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        let lines = self.lines.lock().unwrap().clone();
        anyhow::ensure!(status.success(), "{tag} failed with {status}");
        Ok(lines)
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

/// Wait (bounded) for the first line containing `pat`; returns the suffix
/// after `pat`'s first whitespace-delimited token.
fn await_line(
    rx: &std::sync::mpsc::Receiver<String>,
    pat: &str,
    what: &str,
) -> Result<String> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(240);
    loop {
        let remaining = deadline.saturating_duration_since(std::time::Instant::now());
        anyhow::ensure!(!remaining.is_zero(), "timed out waiting for {what}");
        match rx.recv_timeout(remaining) {
            Ok(line) if line.contains(pat) => return Ok(line),
            Ok(_) => continue,
            Err(_) => anyhow::bail!("stream ended before {what}"),
        }
    }
}

fn await_addr(rx: &std::sync::mpsc::Receiver<String>, pat: &str, what: &str) -> Result<String> {
    let line = await_line(rx, pat, what)?;
    line.split(pat)
        .nth(1)
        .and_then(|r| r.split_whitespace().next())
        .map(|s| s.to_string())
        .with_context(|| format!("no address in {what} line"))
}

/// The train-loop flags every process of the deployment shares verbatim.
fn shared_flags() -> Vec<String> {
    [
        "--preset", PRESET, "--dense", DENSE, "--engine", "rust", "--mode", "sync",
        "--deterministic", "true", "--shard-capacity", CAPACITY, "--seed", SEED, "--lr",
        "0.05", "--tau", "4", "--emb-workers", "1", "--nn-workers", "1", "--netsim",
        "false", "--compress", "false",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--nodes".to_string(),
        N_NODES.to_string(),
        "--batch".to_string(),
        BATCH.to_string(),
        "--steps".to_string(),
        STEPS.to_string(),
        "--eval-every".to_string(),
        STEPS.to_string(),
    ])
    .collect()
}

fn serve_ps_args(disposition: &[&str], ckpt_dir: &str) -> Vec<String> {
    let mut args = vec!["serve-ps".to_string()];
    args.extend(shared_flags());
    args.extend(["--addr".to_string(), "127.0.0.1:0".to_string()]);
    args.extend(disposition.iter().map(|s| s.to_string()));
    args.extend(["--checkpoint-dir".to_string(), ckpt_dir.to_string()]);
    args
}

/// The threaded single-process reference with the exact same preset knobs
/// and node grid — the unresharded ground truth.
fn threaded_reference() -> Result<(f32, f64)> {
    let preset = BenchPreset::by_name(PRESET).context("preset")?;
    let model = preset.model(DENSE);
    let mut emb_cfg = preset.embedding(&model, CAPACITY.parse()?);
    emb_cfg.n_nodes = N_NODES;
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster =
        ClusterConfig { n_nn_workers: 1, n_emb_workers: 1, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps: STEPS,
        eval_every: STEPS,
        seed: SEED.parse()?,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED.parse()?);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    let out = t.run_rust()?;
    Ok((out.report.final_loss, out.report.final_auc.context("reference AUC")?))
}

fn main() -> Result<()> {
    let ckpt_dir = std::env::temp_dir()
        .join(format!("persia_reshard_live_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    std::fs::create_dir_all(&ckpt_dir)?;
    let ckpt = ckpt_dir.display().to_string();

    // 1. Two owning shards with a lopsided 4:2 node split, plus a `--join`
    //    spare that materializes the full range but owns nothing.
    let (ps0, ps0_rx) =
        Proc::spawn("ps0", &serve_ps_args(&["--node-range", "0..4"], &ckpt))?;
    let (ps1, ps1_rx) =
        Proc::spawn("ps1", &serve_ps_args(&["--node-range", "4..6"], &ckpt))?;
    let (spare, spare_rx) = Proc::spawn("spare", &serve_ps_args(&["--join", "true"], &ckpt))?;
    let addr0 = await_addr(&ps0_rx, "listening on ", "ps0 address")?;
    let addr1 = await_addr(&ps1_rx, "listening on ", "ps1 address")?;
    let addr2 = await_addr(&spare_rx, "listening on ", "spare address")?;
    println!("== fleet up: owners at {addr0},{addr1}; --join spare at {addr2}");

    // 2. Train against the fleet with the reshard probe armed: cadence 10,
    //    threshold 1.1, checkpoints at every migration boundary.
    let mut args = vec![
        "train".to_string(),
        "--parity-lines".to_string(),
        "true".to_string(),
        "--remote-ps".to_string(),
        format!("{addr0},{addr1},{addr2}"), // spare listed LAST: epoch-0 routing is list-ordered
    ];
    args.extend(shared_flags());
    args.extend(
        ["--checkpoint-dir", &ckpt, "--checkpoint-every", "5", "--reshard-every", "10",
         "--reshard-threshold", "1.1"]
        .iter()
        .map(|s| s.to_string()),
    );
    let (mut tr, tr_rx) = Proc::spawn("train", &args)?;

    // 3. The probe fires at step 10, splits the hot shard onto the spare,
    //    and commits epoch 1 mid-run.
    await_line(&tr_rx, "RESHARD epoch 1 committed", "the reshard commit")?;
    println!("== routing epoch 1 committed mid-train (2 shards -> 3)");

    // 4. The run still finishes — and matches the unresharded reference.
    let lines = tr.wait_success("train")?;
    let parity = lines
        .iter()
        .find(|l| l.starts_with("PARITY "))
        .context("train printed no PARITY line")?;
    let mut final_loss = f32::NAN;
    let mut final_auc = f64::NAN;
    for field in parity["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            final_loss = v.parse()?;
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            final_auc = v.parse()?;
        }
    }

    // 5. The committed layout survived to disk and the spare now owns the
    //    migrated nodes.
    let table = load_routing(&ckpt_dir)?.context("commit persisted no ROUTING table")?;
    anyhow::ensure!(table.epoch >= 1, "ROUTING still at epoch {}", table.epoch);
    anyhow::ensure!(table.owned_count(2) > 0, "spare owns nothing after the split");
    println!(
        "== persisted ROUTING epoch {}: per-shard node counts {:?}",
        table.epoch,
        (0..3).map(|s| table.owned_count(s)).collect::<Vec<_>>()
    );

    let (ref_loss, ref_auc) = threaded_reference()?;
    let loss_gap = (ref_loss - final_loss).abs();
    let auc_gap = (ref_auc - final_auc).abs();
    println!(
        "== parity: loss {final_loss:.6} vs unresharded {ref_loss:.6} (gap {loss_gap:.2e}), \
         AUC {final_auc:.6} vs {ref_auc:.6} (gap {auc_gap:.2e})"
    );
    anyhow::ensure!(loss_gap <= 1e-6, "loss diverged across the live split");
    anyhow::ensure!(auc_gap <= 1e-6, "AUC diverged across the live split");

    // 6. Teardown: the fleet is killed by Drop.
    drop(ps0_rx);
    drop(ps1_rx);
    drop(spare_rx);
    drop(tr_rx);
    drop(spare);
    drop(ps1);
    drop(ps0);
    std::fs::remove_dir_all(&ckpt_dir).ok();
    println!(
        "== live resharding OK: 2 -> 3 shards mid-train behind the \
         PREPARE/MIGRATE/COMMIT barrier, zero lost updates, parity ≤ 1e-6"
    );
    Ok(())
}
