//! The paper's FULL three-tier topology, every role its own OS process on
//! loopback: 2 `persia serve-ps` embedding-PS shards × 1
//! `persia serve-embedding-worker` (the pipelined middle tier) × 2
//! `persia train-worker` NN ranks joined by the rank-0 TCP ring rendezvous
//! — cross-checked against the in-process threaded run (≤ 1e-6 parity).
//!
//! ```bash
//! cargo build --release            # builds the `persia` binary it spawns
//! cargo run --release --example three_tier_train
//! ```
//!
//! The by-hand equivalent:
//!
//! ```bash
//! persia serve-ps --preset taobao --dense tiny --shard-capacity 2048 \
//!     --seed 42 --addr 127.0.0.1:7700 --node-range 0..2 &
//! persia serve-ps --preset taobao --dense tiny --shard-capacity 2048 \
//!     --seed 42 --addr 127.0.0.1:7701 --node-range 2..4 &
//! persia serve-embedding-worker --addr 127.0.0.1:7900 \
//!     --remote-ps 127.0.0.1:7700,127.0.0.1:7701 <train flags> &
//! persia train-worker --rank 0 --world 2 --rendezvous 127.0.0.1:7800 \
//!     --embedding-workers 127.0.0.1:7900 <train flags> &
//! persia train-worker --rank 1 --world 2 --rendezvous 127.0.0.1:7800 \
//!     --embedding-workers 127.0.0.1:7900 <train flags>
//! ```

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::channel;

use anyhow::{Context, Result};

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: &str = "2048";
const SEED: &str = "42";
const STEPS: usize = 40;
const BATCH: usize = 32;

/// The `persia` binary next to this example's executable
/// (`target/<profile>/examples/three_tier_train` → `target/<profile>/persia`).
fn persia_bin() -> Result<PathBuf> {
    let exe = std::env::current_exe().context("current_exe")?;
    let dir = exe
        .parent()
        .and_then(|p| p.parent())
        .context("example executable has no target dir")?;
    let bin = dir.join(format!("persia{}", std::env::consts::EXE_SUFFIX));
    anyhow::ensure!(
        bin.exists(),
        "persia binary not found at {} — run `cargo build --release` first",
        bin.display()
    );
    Ok(bin)
}

/// A child whose stdout is streamed to our stdout (prefixed) while scanning
/// for marker lines; killed on drop so a failure never leaks processes.
struct Proc {
    child: Child,
    reader: Option<std::thread::JoinHandle<Vec<String>>>,
}

impl Proc {
    /// Spawn and return a channel yielding every stdout line as it arrives.
    fn spawn(
        tag: &'static str,
        args: &[String],
    ) -> Result<(Proc, std::sync::mpsc::Receiver<String>)> {
        let mut child = Command::new(persia_bin()?)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawning {tag}"))?;
        let stdout = child.stdout.take().context("stdout piped")?;
        let (tx, rx) = channel();
        let reader = std::thread::spawn(move || {
            let mut all = Vec::new();
            for line in std::io::BufReader::new(stdout).lines() {
                let Ok(line) = line else { break };
                println!("[{tag}] {line}");
                all.push(line.clone());
                let _ = tx.send(line);
            }
            all
        });
        Ok((Proc { child, reader: Some(reader) }, rx))
    }

    fn wait_success(&mut self, tag: &str) -> Result<Vec<String>> {
        let status = self.child.wait().with_context(|| format!("waiting for {tag}"))?;
        let lines = self
            .reader
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default();
        anyhow::ensure!(status.success(), "{tag} failed with {status}");
        Ok(lines)
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

/// Wait (bounded) for the first line containing `pat`; returns the suffix
/// after `pat`'s first whitespace-delimited token.
fn await_addr(rx: &std::sync::mpsc::Receiver<String>, pat: &str, what: &str) -> Result<String> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let remaining = deadline.saturating_duration_since(std::time::Instant::now());
        anyhow::ensure!(!remaining.is_zero(), "timed out waiting for {what}");
        match rx.recv_timeout(remaining) {
            Ok(line) if line.contains(pat) => {
                return line
                    .split(pat)
                    .nth(1)
                    .and_then(|r| r.split_whitespace().next())
                    .map(|s| s.to_string())
                    .with_context(|| format!("no address in {what} line"));
            }
            Ok(_) => continue,
            Err(_) => anyhow::bail!("stream ended before {what}"),
        }
    }
}

/// The train-loop flags every process of the deployment shares verbatim.
fn shared_flags() -> Vec<String> {
    [
        "--preset", PRESET, "--dense", DENSE, "--engine", "rust", "--mode", "sync",
        "--deterministic", "true", "--shard-capacity", CAPACITY, "--seed", SEED, "--lr",
        "0.05", "--tau", "4", "--emb-workers", "1", "--netsim", "false", "--compress",
        "false",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--batch".to_string(),
        BATCH.to_string(),
        "--steps".to_string(),
        STEPS.to_string(),
        "--eval-every".to_string(),
        STEPS.to_string(),
    ])
    .collect()
}

fn serve_ps_args(node_range: &str) -> Vec<String> {
    let mut args = vec!["serve-ps".to_string()];
    args.extend(shared_flags());
    args.extend([
        "--addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--node-range".to_string(),
        node_range.to_string(),
    ]);
    args
}

fn serve_ew_args(remote_ps: &str) -> Vec<String> {
    let mut args = vec!["serve-embedding-worker".to_string()];
    args.extend(shared_flags());
    args.extend([
        "--addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--world".to_string(),
        "2".to_string(),
        "--remote-ps".to_string(),
        remote_ps.to_string(),
    ]);
    args
}

fn worker_args(rank: usize, rendezvous: &str, embedding_workers: &str) -> Vec<String> {
    let mut args = vec![
        "train-worker".to_string(),
        "--rank".to_string(),
        rank.to_string(),
        "--world".to_string(),
        "2".to_string(),
        "--rendezvous".to_string(),
        rendezvous.to_string(),
    ];
    args.extend(shared_flags());
    args.extend(["--embedding-workers".to_string(), embedding_workers.to_string()]);
    args
}

/// The threaded single-process reference with the exact same preset knobs.
fn threaded_reference() -> Result<(f32, f64)> {
    let preset = BenchPreset::by_name(PRESET).context("preset")?;
    let model = preset.model(DENSE);
    let emb_cfg = preset.embedding(&model, CAPACITY.parse()?);
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster =
        ClusterConfig { n_nn_workers: 2, n_emb_workers: 1, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps: STEPS,
        eval_every: STEPS,
        seed: SEED.parse()?,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED.parse()?);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    let out = t.run_rust()?;
    Ok((out.report.final_loss, out.report.final_auc.context("reference AUC")?))
}

fn main() -> Result<()> {
    // 1. Two PS shard processes, each owning half the PS nodes.
    let (ps0, ps0_rx) = Proc::spawn("ps0", &serve_ps_args("0..2"))?;
    let (ps1, ps1_rx) = Proc::spawn("ps1", &serve_ps_args("2..4"))?;
    let addr0 = await_addr(&ps0_rx, "listening on ", "ps0 address")?;
    let addr1 = await_addr(&ps1_rx, "listening on ", "ps1 address")?;
    let remote_ps = format!("{addr0},{addr1}");
    println!("== tier 1 up: 2 PS shard processes at {remote_ps}");

    // 2. The embedding-worker tier: one pipelined prefetcher process
    //    between the PS shards and the NN ring.
    let (ew, ew_rx) = Proc::spawn("ew0", &serve_ew_args(&remote_ps))?;
    let ew_addr = await_addr(&ew_rx, "embedding worker listening on ", "embedding worker")?;
    println!("== tier 2 up: embedding worker at {ew_addr}");

    // 3. Two NN-worker rank processes; rank 0 hosts the ring rendezvous.
    let (mut w0, w0_rx) = Proc::spawn("rank0", &worker_args(0, "127.0.0.1:0", &ew_addr))?;
    let rendezvous = await_addr(&w0_rx, "rendezvous listening on ", "rendezvous address")?;
    let (mut w1, _w1_rx) = Proc::spawn("rank1", &worker_args(1, &rendezvous, &ew_addr))?;
    println!("== tier 3 up: 2 train-worker ranks (rendezvous {rendezvous})");

    // 4. Both ranks finish; rank 0 prints the machine-readable parity line.
    let w0_lines = w0.wait_success("rank 0")?;
    w1.wait_success("rank 1")?;
    let parity = w0_lines
        .iter()
        .find(|l| l.starts_with("PARITY "))
        .context("rank 0 printed no PARITY line")?;
    let mut final_loss = f32::NAN;
    let mut final_auc = f64::NAN;
    for field in parity["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            final_loss = v.parse()?;
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            final_auc = v.parse()?;
        }
    }

    // 5. Cross-check against the single-process threaded run.
    let (ref_loss, ref_auc) = threaded_reference()?;
    let loss_gap = (ref_loss - final_loss).abs();
    let auc_gap = (ref_auc - final_auc).abs();
    println!(
        "== parity: loss {final_loss:.6} vs threaded {ref_loss:.6} (gap {loss_gap:.2e}), \
         AUC {final_auc:.6} vs {ref_auc:.6} (gap {auc_gap:.2e})"
    );
    anyhow::ensure!(loss_gap <= 1e-6, "loss diverged across the three-tier deployment");
    anyhow::ensure!(auc_gap <= 1e-6, "AUC diverged across the three-tier deployment");

    // 6. Teardown: all three tiers are killed by Drop (state is ephemeral).
    drop(ps0_rx);
    drop(ps1_rx);
    drop(ew_rx);
    drop(ew);
    drop(ps0);
    drop(ps1);
    println!(
        "== three-tier deployment OK: 2 serve-ps × 1 serve-embedding-worker × \
         2 train-worker, parity ≤ 1e-6"
    );
    Ok(())
}
