//! Fault-tolerance walkthrough (paper §4.2.4): kill each component class
//! mid-training and show its recovery policy in action.
//!
//! ```bash
//! cargo run --release --example fault_tolerance
//! ```

use std::sync::Arc;

use persia::comm::NetSim;
use persia::config::{
    BenchPreset, NetModelConfig,
};
use persia::data::SyntheticDataset;
use persia::dense::{DenseModel, DenseOptimizer, DenseOptimizerKind};
use persia::embedding::checkpoint::CheckpointManager;
use persia::embedding::EmbeddingPs;
use persia::fault::{DenseBackup, PsBackup};
use persia::metrics::auc;
use persia::runtime::DenseEngine;
use persia::util::Rng;
use persia::worker::{elastic_assign, EmbeddingWorker};

fn main() -> anyhow::Result<()> {
    let preset = BenchPreset::by_name("taobao").unwrap();
    let model = preset.model("tiny");
    let emb_cfg = preset.embedding(&model, 65536);
    let ps = Arc::new(EmbeddingPs::new(&emb_cfg, model.emb_dim_per_group, 9));
    let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
    let ew = Arc::new(EmbeddingWorker::new(0, ps.clone(), &model, net.clone(), true));
    let ds = SyntheticDataset::new(&model, emb_cfg.rows_per_group, preset.zipf_exponent, 9);

    let mut rng_model = Rng::new(1);
    let dm = DenseModel::new(&model.dims(), model.emb_dim(), model.nid_dim, &mut rng_model);
    let mut params = dm.params_flat();
    let engine = DenseEngine::rust(dm);
    let mut opt = DenseOptimizer::new(DenseOptimizerKind::Sgd, 0.1, params.len());
    let mut rng = ds.train_rng(0);

    let ps_backup = PsBackup::new(emb_cfg.n_nodes);
    let dense_backup = DenseBackup::new();
    let ckpt_dir = std::env::temp_dir().join("persia_fault_example");
    let ckpt = CheckpointManager::new(&ckpt_dir)?;

    let eval = |params: &[f32], engine: &DenseEngine, ew: &EmbeddingWorker| -> f64 {
        let tb = ds.test_batch(2048);
        let (emb, _) = ew.lookup_direct(&tb).unwrap();
        let probs = engine.forward(params, &emb, &tb.nid, tb.len()).unwrap();
        auc(&probs, &tb.labels)
    };

    let mut step = |params: &mut Vec<f32>, opt: &mut DenseOptimizer, rng: &mut Rng| {
        let b = ds.batch(rng, 64);
        let sids = ew.register(b.ids.clone());
        let (emb, _) = ew.pull(&sids).unwrap();
        let out = engine.train_step(params, &emb, &b.nid, &b.labels).unwrap();
        opt.step(params, &out.grad_flat);
        ew.push_grads(&sids, &out.grad_emb).unwrap();
        out.loss
    };

    println!("== phase 1: healthy training (200 steps) ==");
    for s in 0..200 {
        let loss = step(&mut params, &mut opt, &mut rng);
        if s % 50 == 0 {
            println!("  step {s:>3} loss {loss:.4}");
        }
        if s % 50 == 49 {
            ckpt.save(&ps)?;
            dense_backup.save(s as u64, &params);
        }
    }
    let auc0 = eval(&params, &engine, &ew);
    println!("  AUC after phase 1: {auc0:.4}");

    println!("\n== fault A: embedding PS node 0 process crash (shared memory survives) ==");
    ps_backup.mirror_shared(&ps, 0)?;
    ps.wipe_node(0)?;
    let path = ps_backup.recover(&ps, 0, true)?;
    println!("  recovered via {path}; AUC now {:.4} (lossless)", eval(&params, &engine, &ew));

    println!("\n== fault B: embedding PS node 1 crash WITH memory loss (disk checkpoint) ==");
    ps.wipe_node(1)?;
    ckpt.restore_node(&ps, 1)?;
    println!(
        "  recovered from periodic checkpoint; AUC {:.4} (post-checkpoint puts lost)",
        eval(&params, &engine, &ew)
    );

    println!("\n== fault C: embedding worker crash (ranks reassigned to a survivor) ==");
    // A second worker over the SAME PS: embedding workers are
    // parameter-stateless, so a survivor can adopt a dead worker's ranks
    // and lose nothing — it re-registers the in-flight batch (the loader
    // streams are deterministic, so the re-draw is identical) and the
    // gradients land as if the crash never happened. This is the in-process
    // shape of `train --ew-failover` (see examples/ew_failover.rs for the
    // real three-tier drill).
    let survivor = Arc::new(EmbeddingWorker::new(1, ps.clone(), &model, net.clone(), true));
    let b = ds.batch(&mut rng, 64);
    let sids = ew.register(b.ids.clone());
    let (emb, _) = ew.pull(&sids).unwrap();
    let out = engine.train_step(&mut params, &emb, &b.nid, &b.labels).unwrap();
    opt.step(&mut params, &out.grad_flat);
    println!("  {} samples in flight on the dying worker", ew.buffered());
    ew.abandon_buffer();
    println!("  buffer abandoned; pulling those samples there fails: {}", ew.pull(&sids).is_err());
    let adopter = elastic_assign(0, 2, &[true, false]).expect("a survivor exists");
    println!("  elastic_assign moves rank 0 to surviving worker {adopter}");
    let sids2 = survivor.register(b.ids);
    survivor.push_grads(&sids2, &out.grad_emb).unwrap();
    println!("  batch re-registered on the adopter; gradient update NOT lost");

    println!("\n== fault D: NN worker crash (all replicas reload dense checkpoint) ==");
    let (ckpt_step, ckpt_params) = dense_backup.load().unwrap();
    params = ckpt_params;
    println!("  dense params reloaded from step {ckpt_step}");

    println!("\n== phase 2: training continues (100 steps) ==");
    for _ in 0..100 {
        step(&mut params, &mut opt, &mut rng);
    }
    let auc1 = eval(&params, &engine, &ew);
    println!("  final AUC {auc1:.4} (vs {auc0:.4} pre-fault)");
    anyhow::ensure!(auc1 > auc0 - 0.03, "convergence lost after faults");
    println!("\nfault tolerance OK: all four §4.2.4 policies exercised");
    std::fs::remove_dir_all(&ckpt_dir).ok();
    Ok(())
}
