//! Multi-shard PS demo: the embedding PS split across THREE server
//! instances (each owning a node range, exactly what three `persia serve-ps
//! --node-range` processes would host), trained against through one
//! [`ShardedRemotePs`], cross-checked against the in-process PS, and taken
//! through the §4.2.4 recovery drill — kill a shard, restart it empty,
//! restore it from its wire snapshot, keep training.
//!
//! ```bash
//! cargo run --release --example sharded_ps
//! ```
//!
//! The true multi-process version is:
//!
//! ```bash
//! persia serve-ps --addr 127.0.0.1:7700 --node-range 0..2 &
//! persia serve-ps --addr 127.0.0.1:7701 --node-range 2..3 &
//! persia serve-ps --addr 127.0.0.1:7702 --node-range 3..4 &
//! persia train --remote-ps 127.0.0.1:7700,127.0.0.1:7701,127.0.0.1:7702
//! ```

use std::sync::Arc;

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, RecoveryConfig, ServiceConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::hybrid::Trainer;
use persia::service::{PsBackend, PsServer, PsServerHandle, ShardedRemotePs};

const RANGES: [std::ops::Range<usize>; 3] = [0..2, 2..3, 3..4];

fn trainer(steps: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 2,
        emb_dim_per_group: 8,
        nid_dim: 4,
        hidden: vec![16, 8],
        ids_per_group: 2,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 1000,
        shard_capacity: 4096,
        n_nodes: 4,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster =
        ClusterConfig { n_nn_workers: 1, n_emb_workers: 2, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::Hybrid,
        batch_size: 64,
        lr: 0.1,
        staleness_bound: 4,
        steps,
        eval_every: steps,
        seed: 17,
        use_pjrt: false,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, 1000, 1.05, 17);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    // Inline gradient application: bit-reproducible, so sharded == local.
    t.deterministic = true;
    t
}

fn spawn_shard(base: &Trainer, range: std::ops::Range<usize>, addr: &str) -> PsServerHandle {
    // Retried: rebinding a just-released port (the restart leg of the
    // drill) can race the previous socket's teardown.
    let mut last_err = None;
    for _ in 0..40 {
        let ps = Arc::new(EmbeddingPs::new_range(
            &base.emb_cfg,
            base.model.emb_dim_per_group,
            base.train.seed,
            range.clone(),
        ));
        match PsServer::bind(ps, addr, &base.emb_cfg, base.train.seed) {
            Ok(server) => return server.spawn().expect("spawn shard"),
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
    panic!("could not bind shard on {addr}: {:#}", last_err.unwrap());
}

fn main() -> anyhow::Result<()> {
    let steps = 100;
    let base = trainer(steps);

    // 1. Three shard servers, each hosting its slice of the 4 PS nodes.
    let mut handles: Vec<PsServerHandle> = RANGES
        .iter()
        .map(|r| spawn_shard(&base, r.clone(), "127.0.0.1:0"))
        .collect();
    let addrs: Vec<String> = handles.iter().map(|h| h.addr().to_string()).collect();
    println!("3 PS shard processes: {}", addrs.join(", "));

    // 2. One sharded backend over all of them; train phase 1.
    let svc = ServiceConfig {
        addr: addrs.join(","),
        recovery: RecoveryConfig { attempts: 30, backoff_ms: 50, ..RecoveryConfig::default() },
        ..ServiceConfig::default()
    };
    let backend = Arc::new(ShardedRemotePs::connect(&svc)?);
    let mut t1 = trainer(steps);
    t1.ps_backend = Some(backend.clone());
    let out1 = t1.run_rust()?;
    print!("sharded   phase-1 ");
    out1.report.print_row();

    // In-process reference for the same two phases.
    let local_ps =
        Arc::new(EmbeddingPs::new(&base.emb_cfg, base.model.emb_dim_per_group, base.train.seed));
    let run_local = || -> anyhow::Result<_> {
        let mut t = trainer(steps);
        t.ps_backend = Some(local_ps.clone());
        t.run_rust()
    };
    let _local1 = run_local()?;
    let stats = PsBackend::stats(backend.as_ref())?;
    anyhow::ensure!(
        stats.total_rows == local_ps.total_rows(),
        "sharded rows {} != in-process rows {}",
        stats.total_rows,
        local_ps.total_rows()
    );
    println!(
        "merged shard stats: rows={} evictions={} imbalance={:.2} (in-process: {:.2})",
        stats.total_rows,
        stats.total_evictions,
        stats.imbalance,
        local_ps.imbalance()
    );

    // 3. Recovery drill: snapshot node 2 over the wire, kill its shard,
    //    restart it empty on the same port, restore, and train phase 2.
    let victim = 2;
    let snap = backend.snapshot_node(victim)?;
    let victim_addr = addrs[1].clone();
    handles.remove(1).shutdown()?;
    println!("killed shard {victim_addr} (node {victim}); restarting from snapshot...");
    handles.insert(1, spawn_shard(&base, RANGES[1].clone(), &victim_addr));
    backend.restore_node(victim, &snap)?;
    anyhow::ensure!(
        PsBackend::stats(backend.as_ref())?.total_rows == local_ps.total_rows(),
        "rows lost across the kill/restore drill"
    );

    let mut t2 = trainer(steps);
    t2.ps_backend = Some(backend.clone());
    let out2 = t2.run_rust()?;
    print!("sharded   phase-2 ");
    out2.report.print_row();
    let local2 = run_local()?;
    print!("in-process phase-2 ");
    local2.report.print_row();

    let auc_gap = (out2.report.final_auc.unwrap() - local2.report.final_auc.unwrap()).abs();
    println!("AUC gap sharded vs in-process after recovery: {auc_gap:.2e}");
    anyhow::ensure!(auc_gap < 1e-6, "sharded PS diverged from in-process PS");

    // 4. Graceful teardown.
    drop(t1);
    drop(t2);
    backend.shutdown_all()?;
    drop(backend);
    for h in handles {
        h.shutdown()?;
    }
    println!("all shards drained and stopped; sharded service mode OK");
    Ok(())
}
