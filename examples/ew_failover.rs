//! Elastic embedding-worker failover (ISSUE 8), every role its own OS
//! process on loopback: 2 `persia serve-ps` shards × 2
//! `persia serve-embedding-worker` processes × 2 `persia train-worker` NN
//! ranks with `--ew-failover true`. Mid-run, the worker serving rank 1 is
//! SIGKILLed: the survivor adopts rank 1 (ADOPT_RANK fast-forwards its
//! deterministic loader stream, the in-flight gradient push is re-drawn
//! and re-pushed), both ranks complete, and the final loss/AUC match the
//! unkilled in-process threaded run within 1e-6.
//!
//! ```bash
//! cargo build --release            # builds the `persia` binary it spawns
//! cargo run --release --example ew_failover
//! ```
//!
//! Both ranks are SIGSTOPped around the SIGKILL so the kill provably lands
//! mid-run — a loopback run this small could otherwise finish first.

use std::io::BufRead;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;

const PRESET: &str = "taobao";
const DENSE: &str = "tiny";
const CAPACITY: &str = "2048";
const SEED: &str = "42";
const STEPS: usize = 40;
const BATCH: usize = 32;

/// The `persia` binary next to this example's executable
/// (`target/<profile>/examples/ew_failover` → `target/<profile>/persia`).
fn persia_bin() -> Result<PathBuf> {
    let exe = std::env::current_exe().context("current_exe")?;
    let dir = exe
        .parent()
        .and_then(|p| p.parent())
        .context("example executable has no target dir")?;
    let bin = dir.join(format!("persia{}", std::env::consts::EXE_SUFFIX));
    anyhow::ensure!(
        bin.exists(),
        "persia binary not found at {} — run `cargo build --release` first",
        bin.display()
    );
    Ok(bin)
}

/// A child with stdout AND stderr streamed to our stdout (prefixed) while
/// scanning for marker lines — stderr matters here because the failover
/// notices (`ew-failover: ...`) are printed there. Killed on drop.
struct Proc {
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    readers: Vec<std::thread::JoinHandle<()>>,
}

impl Proc {
    /// Spawn and return a channel yielding every output line as it arrives.
    fn spawn(
        tag: &'static str,
        args: &[String],
    ) -> Result<(Proc, std::sync::mpsc::Receiver<String>)> {
        let mut child = Command::new(persia_bin()?)
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawning {tag}"))?;
        let stdout = child.stdout.take().context("stdout piped")?;
        let stderr = child.stderr.take().context("stderr piped")?;
        let lines = Arc::new(Mutex::new(Vec::new()));
        let (tx, rx) = channel();
        let mut readers = Vec::new();
        for reader in [Box::new(stdout) as Box<dyn std::io::Read + Send>, Box::new(stderr)] {
            let lines = lines.clone();
            let tx = tx.clone();
            readers.push(std::thread::spawn(move || {
                for line in std::io::BufReader::new(reader).lines() {
                    let Ok(line) = line else { break };
                    println!("[{tag}] {line}");
                    lines.lock().unwrap().push(line.clone());
                    let _ = tx.send(line);
                }
            }));
        }
        Ok((Proc { child, lines, readers }, rx))
    }

    fn wait_success(&mut self, tag: &str) -> Result<Vec<String>> {
        let status = self.child.wait().with_context(|| format!("waiting for {tag}"))?;
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
        let lines = self.lines.lock().unwrap().clone();
        anyhow::ensure!(status.success(), "{tag} failed with {status}");
        Ok(lines)
    }

    /// Send a signal name (`-STOP` / `-CONT`) to the child.
    fn signal(&self, sig: &str) -> Result<()> {
        let ok = Command::new("kill")
            .args([sig, &self.child.id().to_string()])
            .status()
            .map(|s| s.success())
            .unwrap_or(false);
        anyhow::ensure!(ok, "kill {sig} {} failed", self.child.id());
        Ok(())
    }
}

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        for r in self.readers.drain(..) {
            let _ = r.join();
        }
    }
}

/// Wait (bounded) for the first line containing `pat`; returns the suffix
/// after `pat`'s first whitespace-delimited token.
fn await_addr(rx: &std::sync::mpsc::Receiver<String>, pat: &str, what: &str) -> Result<String> {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(60);
    loop {
        let remaining = deadline.saturating_duration_since(std::time::Instant::now());
        anyhow::ensure!(!remaining.is_zero(), "timed out waiting for {what}");
        match rx.recv_timeout(remaining) {
            Ok(line) if line.contains(pat) => {
                return line
                    .split(pat)
                    .nth(1)
                    .and_then(|r| r.split_whitespace().next())
                    .map(|s| s.to_string())
                    .with_context(|| format!("no address in {what} line"));
            }
            Ok(_) => continue,
            Err(_) => anyhow::bail!("stream ended before {what}"),
        }
    }
}

/// The train-loop flags every process of the deployment shares verbatim.
fn shared_flags() -> Vec<String> {
    [
        "--preset", PRESET, "--dense", DENSE, "--engine", "rust", "--mode", "sync",
        "--deterministic", "true", "--shard-capacity", CAPACITY, "--seed", SEED, "--lr",
        "0.05", "--tau", "4", "--emb-workers", "2", "--netsim", "false", "--compress",
        "false",
    ]
    .iter()
    .map(|s| s.to_string())
    .chain([
        "--batch".to_string(),
        BATCH.to_string(),
        "--steps".to_string(),
        STEPS.to_string(),
        "--eval-every".to_string(),
        STEPS.to_string(),
    ])
    .collect()
}

fn serve_ps_args(node_range: &str) -> Vec<String> {
    let mut args = vec!["serve-ps".to_string()];
    args.extend(shared_flags());
    args.extend([
        "--addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--node-range".to_string(),
        node_range.to_string(),
    ]);
    args
}

fn serve_ew_args(ew_rank: usize, remote_ps: &str) -> Vec<String> {
    let mut args = vec!["serve-embedding-worker".to_string()];
    args.extend(shared_flags());
    args.extend([
        "--addr".to_string(),
        "127.0.0.1:0".to_string(),
        "--ew-rank".to_string(),
        ew_rank.to_string(),
        "--world".to_string(),
        "2".to_string(),
        "--remote-ps".to_string(),
        remote_ps.to_string(),
    ]);
    args
}

fn worker_args(rank: usize, rendezvous: &str, embedding_workers: &str) -> Vec<String> {
    let mut args = vec![
        "train-worker".to_string(),
        "--rank".to_string(),
        rank.to_string(),
        "--world".to_string(),
        "2".to_string(),
        "--rendezvous".to_string(),
        rendezvous.to_string(),
        // Headroom above the failover stall (--ew-retries × --ew-retry-ms
        // of redials + the adoption fast-forward) rank 1 rides out while
        // rank 0 waits at the AllReduce barrier.
        "--ring-timeout-ms".to_string(),
        "15000".to_string(),
    ];
    args.extend(shared_flags());
    args.extend([
        "--embedding-workers".to_string(),
        embedding_workers.to_string(),
        "--ew-failover".to_string(),
        "true".to_string(),
    ]);
    args
}

/// The threaded single-process reference with the exact same preset knobs.
fn threaded_reference() -> Result<(f32, f64)> {
    let preset = BenchPreset::by_name(PRESET).context("preset")?;
    let model = preset.model(DENSE);
    let emb_cfg = preset.embedding(&model, CAPACITY.parse()?);
    let rows = preset.embedding(&model, 1).rows_per_group;
    let cluster =
        ClusterConfig { n_nn_workers: 2, n_emb_workers: 2, net: NetModelConfig::disabled() };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: BATCH,
        lr: 0.05,
        staleness_bound: 4,
        steps: STEPS,
        eval_every: STEPS,
        seed: SEED.parse()?,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, rows, preset.zipf_exponent, SEED.parse()?);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    let out = t.run_rust()?;
    Ok((out.report.final_loss, out.report.final_auc.context("reference AUC")?))
}

fn main() -> Result<()> {
    // 1. Two PS shard processes, each owning half the PS nodes.
    let (ps0, ps0_rx) = Proc::spawn("ps0", &serve_ps_args("0..2"))?;
    let (ps1, ps1_rx) = Proc::spawn("ps1", &serve_ps_args("2..4"))?;
    let addr0 = await_addr(&ps0_rx, "listening on ", "ps0 address")?;
    let addr1 = await_addr(&ps1_rx, "listening on ", "ps1 address")?;
    let remote_ps = format!("{addr0},{addr1}");
    println!("== tier 1 up: 2 PS shard processes at {remote_ps}");

    // 2. TWO embedding workers: rank r is served by worker r % 2 until the
    //    tier reassigns.
    let (ew0, ew0_rx) = Proc::spawn("ew0", &serve_ew_args(0, &remote_ps))?;
    let (mut ew1, ew1_rx) = Proc::spawn("ew1", &serve_ew_args(1, &remote_ps))?;
    let ew0_addr = await_addr(&ew0_rx, "embedding worker listening on ", "ew0")?;
    let ew1_addr = await_addr(&ew1_rx, "embedding worker listening on ", "ew1")?;
    let ew_list = format!("{ew0_addr},{ew1_addr}");
    println!("== tier 2 up: embedding workers at {ew_list}");

    // 3. Two NN-worker ranks with --ew-failover true; rank 0 hosts the
    //    ring rendezvous.
    let (mut w0, w0_rx) = Proc::spawn("rank0", &worker_args(0, "127.0.0.1:0", &ew_list))?;
    let rendezvous = await_addr(&w0_rx, "rendezvous listening on ", "rendezvous address")?;
    let (mut w1, _w1_rx) = Proc::spawn("rank1", &worker_args(1, &rendezvous, &ew_list))?;
    await_addr(&w0_rx, "ring connected: rank ", "ring formation")?;
    println!("== tier 3 up: 2 train-worker ranks, elastic failover on");

    // 4. Freeze both ranks so the SIGKILL provably lands mid-run, kill the
    //    worker serving rank 1, resume.
    w0.signal("-STOP")?;
    w1.signal("-STOP")?;
    std::thread::sleep(std::time::Duration::from_millis(300));
    let _ = ew1.child.kill();
    let _ = ew1.child.wait();
    println!("== SIGKILLed ew1 ({ew1_addr}) — rank 1's batches must fail over to ew0");
    w0.signal("-CONT")?;
    w1.signal("-CONT")?;

    // 5. Both ranks still finish; rank 1 reports the reassignment.
    let w0_lines = w0.wait_success("rank 0")?;
    let w1_lines = w1.wait_success("rank 1")?;
    anyhow::ensure!(
        w1_lines.iter().any(|l| l.contains("ew-failover")),
        "rank 1 never reported a failover"
    );
    let parity = w0_lines
        .iter()
        .find(|l| l.starts_with("PARITY "))
        .context("rank 0 printed no PARITY line")?;
    let mut final_loss = f32::NAN;
    let mut final_auc = f64::NAN;
    for field in parity["PARITY ".len()..].split_whitespace() {
        if let Some(v) = field.strip_prefix("final_loss=") {
            final_loss = v.parse()?;
        }
        if let Some(v) = field.strip_prefix("final_auc=") {
            final_auc = v.parse()?;
        }
    }

    // 6. Cross-check against the UNKILLED single-process threaded run: the
    //    adopter re-drew the dead worker's streams, so nothing was lost.
    let (ref_loss, ref_auc) = threaded_reference()?;
    let loss_gap = (ref_loss - final_loss).abs();
    let auc_gap = (ref_auc - final_auc).abs();
    println!(
        "== parity: loss {final_loss:.6} vs unkilled {ref_loss:.6} (gap {loss_gap:.2e}), \
         AUC {final_auc:.6} vs {ref_auc:.6} (gap {auc_gap:.2e})"
    );
    anyhow::ensure!(loss_gap <= 1e-6, "loss diverged across the failover");
    anyhow::ensure!(auc_gap <= 1e-6, "AUC diverged across the failover");

    // 7. Teardown: the remaining tiers are killed by Drop.
    drop(ps0_rx);
    drop(ps1_rx);
    drop(ew0_rx);
    drop(ew1_rx);
    drop(ew0);
    drop(ew1);
    drop(ps0);
    drop(ps1);
    println!(
        "== elastic failover OK: one of two embedding workers SIGKILLed mid-run, \
         survivor adopted its rank, parity ≤ 1e-6"
    );
    Ok(())
}
