//! Quickstart: train a small recommender with the hybrid algorithm in ~30 s.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Uses the AOT-compiled PJRT artifacts when `artifacts/` exists, else the
//! pure-Rust dense tower.

use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::{PjrtEngineFactory, Trainer};
use persia::runtime::ArtifactManifest;

fn main() -> anyhow::Result<()> {
    // 1. Pick a Table-1 benchmark preset and a dense-tower size.
    let preset = BenchPreset::by_name("taobao").unwrap();
    let model = preset.model("tiny");
    let emb_cfg = preset.embedding(&model, 65536);

    // 2. Cluster geometry: 2 NN workers, 2 embedding workers, paper-like
    //    network cost model.
    let cluster =
        ClusterConfig { n_nn_workers: 2, n_emb_workers: 2, net: NetModelConfig::paper_like() };

    // 3. Training config: the hybrid algorithm with bounded staleness τ=4.
    let artifacts = ArtifactManifest::default_dir();
    let use_pjrt = artifacts.join("manifest.txt").exists();
    let batch =
        if use_pjrt { ArtifactManifest::load(&artifacts)?.preset("tiny")?.batch } else { 64 };
    let train = TrainConfig {
        mode: TrainMode::Hybrid,
        batch_size: batch,
        lr: 0.1,
        staleness_bound: 4,
        steps: 300,
        eval_every: 100,
        seed: 42,
        use_pjrt,
        compress: true,
    };

    // 4. Synthetic CTR stream with the preset's scale + skew.
    let dataset =
        SyntheticDataset::new(&model, emb_cfg.rows_per_group, preset.zipf_exponent, train.seed);

    println!(
        "quickstart: {} sparse rows/group x {} groups (virtual {} params), dense {} params, engine={}",
        emb_cfg.rows_per_group,
        model.n_groups,
        preset.sparse_params,
        model.dense_param_count(),
        if use_pjrt { "pjrt" } else { "rust" },
    );

    // 5. Run.
    let trainer = Trainer::new(model, emb_cfg, cluster, train, dataset);
    let out = if use_pjrt {
        trainer.run(&PjrtEngineFactory { artifacts_dir: artifacts, preset: "tiny".into() })?
    } else {
        trainer.run_rust()?
    };

    println!("\nloss curve (every 30 steps):");
    for (step, loss) in out.tracker.losses.iter().step_by(30) {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    println!("\nAUC evals: {:?}", out.tracker.aucs);
    out.report.print_row();
    Ok(())
}
