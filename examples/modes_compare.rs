//! Convergence comparison across synchronization modes (paper Fig. 7 /
//! Table 2 shape): hybrid ≈ sync on AUC, async measurably worse, and
//! sim-throughput ordering async ≥ hybrid > raw-hybrid > sync.
//!
//! ```bash
//! cargo run --release --example modes_compare
//! ```

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;

fn main() -> anyhow::Result<()> {
    let preset = BenchPreset::by_name("taobao").unwrap();
    println!("modes_compare on {} (3 seeds each, rust engine for speed)\n", preset.name);
    println!(
        "{:<12} {:>10} {:>12} {:>12} {:>8}",
        "mode", "final AUC", "thpt (sim)", "wall (s)", "max tau"
    );

    let mut baseline_auc = None;
    for mode in TrainMode::ALL {
        let mut auc_sum = 0.0;
        let mut thpt_sum = 0.0;
        let mut wall_sum = 0.0;
        let mut tau_max = 0u64;
        let seeds = [3u64, 17, 29];
        for &seed in &seeds {
            let model = preset.model("tiny");
            let emb_cfg = preset.embedding(&model, 65536);
            let cluster = ClusterConfig {
                n_nn_workers: 4,
                n_emb_workers: 2,
                net: NetModelConfig::paper_like(),
            };
            let train = TrainConfig {
                mode,
                batch_size: 64,
                lr: 0.1,
                staleness_bound: if mode == TrainMode::FullAsync { 16 } else { 4 },
                steps: 400,
                eval_every: 400,
                seed,
                use_pjrt: false,
                compress: true,
            };
            let dataset =
                SyntheticDataset::new(&model, emb_cfg.rows_per_group, preset.zipf_exponent, seed);
            let mut trainer = Trainer::new(model, emb_cfg, cluster, train, dataset);
            trainer.eval_rows = 2048;
            let out = trainer.run_rust()?;
            auc_sum += out.report.final_auc.unwrap();
            thpt_sum += out.report.samples_per_sec;
            wall_sum += out.report.wall_secs;
            tau_max = tau_max.max(out.report.max_staleness);
        }
        let n = 3.0;
        let auc = auc_sum / n;
        println!(
            "{:<12} {:>10.4} {:>12.0} {:>12.2} {:>8}",
            mode.name(),
            auc,
            thpt_sum / n,
            wall_sum / n,
            tau_max
        );
        if mode == TrainMode::FullSync {
            baseline_auc = Some(auc);
        }
    }
    if let Some(sync_auc) = baseline_auc {
        println!(
            "\npaper's claim: hybrid AUC within 0.1% of sync; async loses 0.5-1.0% — \
             compare the rows above against sync = {sync_auc:.4}"
        );
    }
    Ok(())
}
