//! End-to-end validation driver (see DESIGN.md): train a ~100M-parameter
//! recommender (98M embedding + 1.2M dense) for a few hundred hybrid steps
//! on the synthetic CTR stream, through the FULL stack:
//!
//!   data loader -> embedding workers -> embedding PS (array-LRU shards)
//!     -> PJRT train-step artifact (L2 JAX tower on L1 Pallas kernels)
//!     -> ring AllReduce across NN workers -> dense optimizer
//!     -> embedding gradients back through the async appliers to the PS.
//!
//! Logs the loss curve + test AUC; the run is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_train
//! ```

use persia::config::{
    BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::{PjrtEngineFactory, Trainer};
use persia::runtime::ArtifactManifest;

fn main() -> anyhow::Result<()> {
    let artifacts = ArtifactManifest::default_dir();
    anyhow::ensure!(
        artifacts.join("manifest.txt").exists(),
        "run `make artifacts` first — this driver exercises the PJRT path"
    );
    let manifest = ArtifactManifest::load(&artifacts)?;
    let info = manifest.preset("small")?.clone();

    // ~100M total parameters: 8 groups x 765,625 rows x dim 16 = 98M sparse
    // + ~1.2M dense ("small" tower)  — the sparse:dense ratio that defines
    // the problem (paper §2.1).
    let preset = BenchPreset::by_name("taobao").unwrap();
    let model = preset.model("small");
    let mut emb_cfg = preset.embedding(&model, 262_144);
    emb_cfg.rows_per_group = 765_625;
    let sparse_params =
        emb_cfg.rows_per_group as u128 * (model.n_groups * model.emb_dim_per_group) as u128;
    let dense_params = model.dense_param_count();

    let cluster =
        ClusterConfig { n_nn_workers: 2, n_emb_workers: 2, net: NetModelConfig::paper_like() };
    let train = TrainConfig {
        mode: TrainMode::Hybrid,
        batch_size: info.batch,
        lr: 0.05,
        staleness_bound: 4,
        steps: 300,
        eval_every: 50,
        seed: 1234,
        use_pjrt: true,
        compress: true,
    };
    let dataset = SyntheticDataset::new(&model, emb_cfg.rows_per_group, 1.05, train.seed);

    println!("=== e2e_train: full three-layer stack ===");
    println!(
        "model: {} sparse params (virtual, LRU-materialized) + {} dense params = {} total",
        sparse_params,
        dense_params,
        sparse_params + dense_params as u128
    );
    println!(
        "cluster: {} NN workers (ring AllReduce) | {} embedding workers | {}x{} PS shards",
        cluster.n_nn_workers, cluster.n_emb_workers, emb_cfg.n_nodes, emb_cfg.shards_per_node
    );
    println!(
        "dense engine: PJRT artifact train_small.hlo.txt (JAX tower on Pallas fused-MLP kernels)\n"
    );

    let mut trainer = Trainer::new(model, emb_cfg, cluster, train, dataset);
    trainer.eval_rows = 4096;
    let t0 = std::time::Instant::now();
    let out = trainer
        .run(&PjrtEngineFactory { artifacts_dir: artifacts, preset: "small".into() })?;
    let wall = t0.elapsed().as_secs_f64();

    println!("loss curve:");
    for (step, loss) in out.tracker.losses.iter().step_by(25) {
        println!("  step {step:>4}  loss {loss:.4}");
    }
    println!("\ntest AUC curve:");
    for (step, a) in &out.tracker.aucs {
        println!("  step {step:>4}  auc {a:.4}");
    }
    println!("\nphase timings (worker 0):");
    for (name, hist) in out.tracker.phases() {
        println!("  {name:<12} {}", hist.summary());
    }
    println!();
    out.report.print_row();
    println!("total wall: {wall:.1}s; ps imbalance {:.2}", out.ps_imbalance);

    let final_auc = out.report.final_auc.unwrap_or(0.5);
    anyhow::ensure!(final_auc > 0.55, "e2e run failed to learn (AUC {final_auc})");
    println!("\nE2E OK: all three layers composed; AUC {final_auc:.4} > 0.55");
    Ok(())
}
