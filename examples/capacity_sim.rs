//! 100-trillion-parameter capacity demonstration (paper Fig. 9 / §6.3).
//!
//! The virtual table is 781 billion rows per group (100T parameters at
//! dim 16 x 8 groups); rows materialize on first touch into the bounded
//! array-list LRU — physical memory stays flat while the id space spans the
//! full 100T-parameter range. Throughput is measured at each Criteo-Syn
//! scale and projected onto the paper's cloud cluster.
//!
//! ```bash
//! cargo run --release --example capacity_sim
//! ```

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;
use persia::sim::{project_throughput, Calibration, ClusterSpec};

fn main() -> anyhow::Result<()> {
    println!("capacity sweep: virtual Criteo-Syn tables, LRU-bounded physical memory\n");
    println!(
        "{:<14} {:>20} {:>14} {:>14} {:>12}",
        "preset", "sparse params", "measured/s", "max ids seen", "wall (s)"
    );
    let mut measured = Vec::new();
    for p in BenchPreset::capacity_sweep() {
        let model = p.model("tiny");
        let emb_cfg = p.embedding(&model, 65536);
        let cluster = ClusterConfig {
            n_nn_workers: 2,
            n_emb_workers: 2,
            net: NetModelConfig::paper_like(),
        };
        let train = TrainConfig {
            mode: TrainMode::Hybrid,
            batch_size: 64,
            lr: 0.1,
            staleness_bound: 4,
            steps: 80,
            eval_every: 0,
            seed: 7,
            use_pjrt: false,
            compress: true,
        };
        let dataset = SyntheticDataset::new(&model, emb_cfg.rows_per_group, p.zipf_exponent, 7);
        let trainer = Trainer::new(model, emb_cfg.clone(), cluster, train, dataset);
        let out = trainer.run_rust()?;
        println!(
            "{:<14} {:>20} {:>14.0} {:>14} {:>12.2}",
            p.name,
            p.sparse_params,
            out.report.samples_per_sec,
            emb_cfg.rows_per_group,
            out.report.wall_secs
        );
        measured.push((p.name, out.report.samples_per_sec));
    }

    // Flatness check (paper: "stable training throughput when increasing the
    // model size even up to 100 trillion parameters").
    let max = measured.iter().map(|(_, t)| *t).fold(f64::MIN, f64::max);
    let min = measured.iter().map(|(_, t)| *t).fold(f64::MAX, f64::min);
    println!("\nthroughput flatness across 6.25T -> 100T: max/min = {:.2}", max / min);

    // Projection onto the paper's Google-cloud cluster geometry.
    println!("\nprojected throughput on the paper's cloud cluster (samples/s):");
    let model = BenchPreset::by_name("criteo-syn5").unwrap().model("paper");
    let spec = ClusterSpec::paper_cloud();
    let cal = Calibration::default();
    let sync = project_throughput(&model, &spec, &cal, TrainMode::FullSync, 256);
    let hybrid = project_throughput(&model, &spec, &cal, TrainMode::Hybrid, 256);
    let asynch = project_throughput(&model, &spec, &cal, TrainMode::FullAsync, 256);
    println!("  sync   {sync:>12.0}");
    println!("  hybrid {hybrid:>12.0}   ({:.1}x over sync; paper reports 2.6x)", hybrid / sync);
    println!("  async  {asynch:>12.0}   ({:.2}x over hybrid; paper reports 1.2x)", asynch / hybrid);
    Ok(())
}
