//! Checkpoint-epoch overhead: training throughput with coordinated epochs
//! off vs. at several cadences (the §4.2.4 "check-pointing is very
//! efficient" claim, now measurable end to end).
//!
//! Each case trains the same deterministic FullSync run; the checkpointed
//! cases additionally drive the two-phase PREPARE/COMMIT + global-manifest
//! write every N steps. The delta is the full epoch cost: LRU flat-copy
//! snapshots, atomic (fsync) file writes, and the manifest. Emits
//! `BENCH_ckpt_overhead.json` when `BENCH_JSON_DIR` is set — CI uploads it
//! to seed the perf trajectory.

use persia::config::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, TrainConfig, TrainMode,
};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;
use persia::recovery::EpochConfig;
use persia::util::Bench;

mod common;

fn trainer(steps: usize) -> Trainer {
    let model = ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 4,
        emb_dim_per_group: 16,
        nid_dim: 8,
        hidden: vec![64, 32],
        ids_per_group: 4,
        pooling: Pooling::Sum,
    };
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 100_000,
        shard_capacity: 1 << 16,
        n_nodes: 4,
        shards_per_node: 2,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    };
    let cluster = ClusterConfig {
        n_nn_workers: 1,
        n_emb_workers: 2,
        net: NetModelConfig::disabled(),
    };
    let train = TrainConfig {
        mode: TrainMode::FullSync,
        batch_size: 64,
        lr: 0.05,
        staleness_bound: 4,
        steps,
        eval_every: 0,
        seed: 9,
        use_pjrt: false,
        compress: false,
    };
    let dataset = SyntheticDataset::new(&model, 100_000, 1.05, 9);
    let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
    t.deterministic = true;
    t
}

fn main() {
    common::banner(
        "checkpoint-epoch overhead: throughput with epochs off vs every N steps",
        "Persia (KDD'22) §4.2.4 (fault tolerance / efficient checkpointing)",
    );
    let steps = 60usize;
    let samples = (steps * 64) as f64;
    let bench = Bench::new(1, 5);
    let mut rows = Vec::new();

    rows.push(bench.run("train_no_checkpoints", Some(samples), || {
        trainer(steps).run_rust().unwrap();
    }));
    let baseline_mean = rows[0].mean_ns;

    for every in [20usize, 5, 1] {
        let dir = std::env::temp_dir().join(format!(
            "persia_ckpt_bench_{}_{every}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        rows.push(bench.run(&format!("train_checkpoint_every_{every}"), Some(samples), || {
            let mut t = trainer(steps);
            t.checkpoint = Some(EpochConfig { dir: dir.clone(), every });
            t.run_rust().unwrap();
        }));
        std::fs::remove_dir_all(&dir).ok();
    }

    persia::util::bench::print_and_emit("ckpt_overhead", "ckpt_overhead", &rows);
    println!("\nepoch overhead vs no-checkpoint baseline:");
    for r in &rows[1..] {
        let overhead = (r.mean_ns / baseline_mean - 1.0) * 100.0;
        println!("  {:<32} {overhead:>+7.1}%", r.name);
    }
}
