//! Figure 7 — test AUC vs training iteration for each mode.
//!
//! Reproduced shape: hybrid's curve tracks fully-sync almost exactly, while
//! fully-async converges to a visibly lower plateau.

mod common;

use persia::config::{BenchPreset, TrainMode};
use persia::util::csv::CsvWriter;

fn main() {
    common::banner("Fig. 7: AUC vs iteration per mode", "Persia (KDD'22) Figure 7");
    let preset = BenchPreset::by_name("taobao").unwrap();
    let steps = 500;
    let mut curves: Vec<(TrainMode, Vec<(u64, f64)>)> = Vec::new();
    for mode in [TrainMode::FullSync, TrainMode::Hybrid, TrainMode::FullAsync] {
        let mut auc_acc: Vec<(u64, f64)> = Vec::new();
        for seed in [3u64, 17, 29] {
            let mut trainer = common::trainer_for(&preset, mode, 4, steps, seed);
            trainer.train.eval_every = 50;
            trainer.eval_rows = 2048;
            let out = trainer.run_rust().expect("run");
            for (i, (s, a)) in out.tracker.aucs.iter().enumerate() {
                if auc_acc.len() <= i {
                    auc_acc.push((*s, 0.0));
                }
                auc_acc[i].1 += a / 3.0;
            }
        }
        curves.push((mode, auc_acc));
    }

    let mut csv =
        CsvWriter::create("results/fig7_taobao.csv", &["step", "sync", "hybrid", "async"]).unwrap();
    println!("\n{:<8} {:>10} {:>10} {:>10}", "step", "sync", "hybrid", "async");
    let n = curves[0].1.len();
    for i in 0..n {
        let step = curves[0].1[i].0;
        let vals: Vec<f64> = curves.iter().map(|(_, c)| c[i].1).collect();
        println!("{:<8} {:>10.4} {:>10.4} {:>10.4}", step, vals[0], vals[1], vals[2]);
        csv.row(&[
            step.to_string(),
            format!("{:.4}", vals[0]),
            format!("{:.4}", vals[1]),
            format!("{:.4}", vals[2]),
        ])
        .unwrap();
    }
    csv.flush().unwrap();

    let last: Vec<f64> = curves.iter().map(|(_, c)| c.last().unwrap().1).collect();
    let (sync, hybrid, asynch) = (last[0], last[1], last[2]);
    println!(
        "\nfinal: sync={sync:.4} hybrid={hybrid:.4} async={asynch:.4}  \
         (hybrid-sync gap {:.4}, async-sync gap {:.4})",
        hybrid - sync,
        asynch - sync
    );
    assert!((hybrid - sync).abs() < 0.02, "hybrid must track sync");
    assert!(asynch <= hybrid + 0.01, "async must not beat hybrid");
    println!("wrote results/fig7_taobao.csv");
    println!("fig7_convergence OK");
}
