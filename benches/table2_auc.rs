//! Table 2 — final test AUC per benchmark per mode.
//!
//! Reproduced shape: hybrid within ~0.1% (absolute) of sync on every
//! benchmark; async measurably below both.

mod common;

use persia::config::{BenchPreset, TrainMode};

fn main() {
    common::banner("Table 2: final test AUC per mode", "Persia (KDD'22) Table 2");
    println!(
        "{:<12} {:>14} {:>12} {:>12} {:>16}",
        "benchmark", "persia-hybrid", "sync", "async", "hybrid-sync gap"
    );
    for preset in BenchPreset::convergence_set() {
        let steps = if preset.name == "kwai" { 300 } else { 400 };
        let mut res = std::collections::HashMap::new();
        for mode in [TrainMode::Hybrid, TrainMode::FullSync, TrainMode::FullAsync] {
            let mut total = 0.0;
            for seed in [3u64, 17, 29] {
                let mut trainer = common::trainer_for(&preset, mode, 4, steps, seed);
                trainer.train.eval_every = steps;
                trainer.eval_rows = 2048;
                let out = trainer.run_rust().expect("run");
                total += out.report.final_auc.unwrap();
            }
            res.insert(mode.name(), total / 3.0);
        }
        let hybrid = res["hybrid"];
        let sync = res["sync"];
        let asynch = res["async"];
        println!(
            "{:<12} {:>14.4} {:>12.4} {:>12.4} {:>16.4}",
            preset.name,
            hybrid,
            sync,
            asynch,
            hybrid - sync
        );
        assert!((hybrid - sync).abs() < 0.02, "{}: hybrid deviates from sync", preset.name);
        assert!(
            asynch <= hybrid + 0.01,
            "{}: async should not beat hybrid ({asynch} vs {hybrid})",
            preset.name
        );
    }
    println!("table2_auc OK");
}
