//! Embedding-worker prefetch pipeline: inline vs. pipelined lookup
//! throughput (the paper's hybrid-pipeline claim, §4.1/§4.2.1).
//!
//! A `SlowPs` wrapper injects a real per-RPC latency in front of the
//! embedding PS — the cost a `serve-embedding-worker` process pays per
//! scatter-gather against remote `serve-ps` shards. The consumer loop plays
//! the NN rank: pull a batch, then "compute" on it for a fixed dense-step
//! time. With pipeline depth 1 every PS round-trip sits on the critical
//! path; with depth ≥ 2 the worker's draw/assemble stages overlap the next
//! batches' PS fetches with the current dense step, so throughput
//! approaches `1 / max(ps_latency, dense_step)` instead of
//! `1 / (ps_latency + dense_step)`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use persia::comm::NetSim;
use persia::config::{
    EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
};
use persia::data::SyntheticDataset;
use persia::embedding::EmbeddingPs;
use persia::service::{PsBackend, PsStats};
use persia::worker::{AssignMode, BatchPrep, EmbeddingWorker, PrefetchPipeline};

mod common;

/// A PS whose every batched call costs a fixed wire latency — a remote
/// shard fleet in miniature, with real (sleeping) rather than simulated
/// delay, so overlap actually saves wall time.
struct SlowPs {
    inner: EmbeddingPs,
    latency: Duration,
}

impl PsBackend for SlowPs {
    fn dim(&self) -> usize {
        PsBackend::dim(&self.inner)
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> anyhow::Result<()> {
        std::thread::sleep(self.latency);
        self.inner.get_many(keys, out);
        Ok(())
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> anyhow::Result<()> {
        std::thread::sleep(self.latency);
        self.inner.put_grads(keys, grads);
        Ok(())
    }

    fn stats(&self) -> anyhow::Result<PsStats> {
        PsBackend::stats(&self.inner)
    }
}

fn model() -> ModelConfig {
    ModelConfig {
        artifact_preset: "tiny".into(),
        n_groups: 4,
        emb_dim_per_group: 16,
        nid_dim: 8,
        hidden: vec![32, 16],
        ids_per_group: 8,
        pooling: Pooling::Sum,
    }
}

/// Drain `n_batches` through a fresh depth-`depth` pipeline with a
/// `compute`-long dense step per batch; returns batches/sec.
fn run_depth(depth: usize, n_batches: usize, ps_latency: Duration, compute: Duration) -> f64 {
    let model = model();
    let emb_cfg = EmbeddingConfig {
        rows_per_group: 1_000_000,
        shard_capacity: 1 << 16,
        n_nodes: 4,
        shards_per_node: 4,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.05,
    };
    let ps = Arc::new(SlowPs {
        inner: EmbeddingPs::new(&emb_cfg, model.emb_dim_per_group, 7),
        latency: ps_latency,
    });
    let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
    let worker = Arc::new(EmbeddingWorker::new(0, ps, &model, net, false));
    let dataset = SyntheticDataset::new(&model, 1_000_000, 1.05, 7);
    let prep = Arc::new(BatchPrep::new(
        dataset,
        vec![worker],
        256,
        model.nid_dim,
        1,
        AssignMode::Fixed(0),
        true,
    ));
    let pipeline = PrefetchPipeline::new(prep, depth);
    let t0 = Instant::now();
    for step in 0..n_batches {
        let pb = pipeline.next(0, step).expect("pipeline serves every step");
        assert_eq!(pb.step, step);
        // The dense fwd+bwd the GPU would run on this batch.
        std::thread::sleep(compute);
    }
    n_batches as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    common::banner(
        "embedding-worker prefetch pipeline: inline vs pipelined lookups",
        "Persia (KDD'22) §4.1 hybrid pipeline (embedding tier overlap)",
    );
    let ps_latency = Duration::from_millis(2);
    let compute = Duration::from_millis(2);
    let n_batches = 60;
    println!(
        "per-batch costs: PS scatter-gather {:?} (real sleep), dense step {:?}; {} batches",
        ps_latency, compute, n_batches
    );
    println!(
        "{:<28} {:>14} {:>12}",
        "pipeline depth", "batches/sec", "vs inline"
    );
    let mut json_rows = Vec::new();
    let record = |tput: f64, name: &str| persia::util::bench::BenchResult {
        name: name.to_string(),
        iters: n_batches as u64,
        mean_ns: 1e9 / tput.max(1e-9),
        p50_ns: (1e9 / tput.max(1e-9)) as u64,
        p95_ns: (1e9 / tput.max(1e-9)) as u64,
        throughput: Some(tput),
    };
    let inline = run_depth(1, n_batches, ps_latency, compute);
    println!("{:<28} {:>14.1} {:>11.2}x", "1 (inline, on-demand)", inline, 1.0);
    json_rows.push(record(inline, "depth_1_inline"));
    let mut best = inline;
    for depth in [2usize, 4, 8] {
        let tput = run_depth(depth, n_batches, ps_latency, compute);
        best = best.max(tput);
        println!("{:<28} {:>14.1} {:>11.2}x", format!("{depth}"), tput, tput / inline);
        json_rows.push(record(tput, &format!("depth_{depth}")));
    }
    persia::util::bench::emit_json("ew_pipeline", &json_rows);
    let ceiling = 1.0 / compute.as_secs_f64();
    let serial = 1.0 / (compute + ps_latency).as_secs_f64();
    println!(
        "\nmodel: serial bound {serial:.1}/s, overlap ceiling {ceiling:.1}/s; \
         pipelining {} PS latency behind dense compute",
        if best > inline * 1.2 { "HIDES" } else { "did NOT hide (check machine load)" }
    );
}
