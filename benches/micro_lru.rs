//! §4.2.2 micro-benchmarks — the array-list LRU:
//! get/put throughput under Zipf traffic, comparison against a plain
//! HashMap-of-Vec baseline (the allocation-heavy design the paper rejects),
//! and flat-memcpy snapshot bandwidth (the paper's checkpointing argument).

mod common;

use persia::embedding::LruStore;
use persia::util::{Bench, Rng, Zipf};

fn main() {
    common::banner(
        "micro: array-list LRU (get/put, eviction, snapshot bandwidth)",
        "Persia (KDD'22) §4.2.2 (Fig. 5 design)",
    );
    let bench = Bench::new(2, 8);
    let dim = 16usize; // embedding + adagrad state
    let capacity = 200_000;
    let ops = 500_000u64;
    let zipf = Zipf::new(2_000_000, 1.05);
    let mut rows = Vec::new();

    // Array-list LRU under Zipf access.
    {
        let mut lru = LruStore::new(capacity, dim);
        let mut rng = Rng::new(1);
        rows.push(bench.run("array_lru_get_or_insert (zipf)", Some(ops as f64), || {
            for _ in 0..ops {
                let k = zipf.sample(&mut rng);
                let (row, _) = lru.get_or_insert_with(k, |r| r.fill(0.5));
                row[0] += 1.0;
            }
        }));
        println!(
            "  occupancy {}/{capacity}, evictions {}",
            lru.len(),
            lru.evictions()
        );
    }

    // Baseline: HashMap<u64, Vec<f32>> with manual recency vector (what a
    // pointer-based design costs, approximated).
    {
        use std::collections::HashMap;
        let mut map: HashMap<u64, Vec<f32>> = HashMap::new();
        let mut order: Vec<u64> = Vec::new();
        let mut rng = Rng::new(1);
        rows.push(bench.run("hashmap_vec baseline (zipf)", Some(ops as f64), || {
            for _ in 0..ops {
                let k = zipf.sample(&mut rng);
                let row = map.entry(k).or_insert_with(|| {
                    order.push(k);
                    vec![0.5; dim]
                });
                row[0] += 1.0;
                if map.len() > capacity {
                    // Evict oldest-inserted (no true recency — cheaper than
                    // a linked list, still slower end-to-end).
                    if let Some(old) = order.first().copied() {
                        order.remove(0);
                        map.remove(&old);
                    }
                }
            }
        }));
    }

    // Snapshot bandwidth (flat memcpy serialization).
    {
        let mut lru = LruStore::new(capacity, dim);
        let mut rng = Rng::new(2);
        for _ in 0..capacity {
            lru.get_or_insert_with(rng.next_u64(), |r| r.fill(1.0));
        }
        let bytes = lru.to_bytes().len() as f64;
        let r = bench.run("snapshot to_bytes", Some(bytes), || {
            let b = lru.to_bytes();
            std::hint::black_box(&b);
        });
        println!(
            "  snapshot {} MB at {:.1} GB/s",
            (bytes / 1e6) as u64,
            r.throughput.unwrap() / 1e9
        );
        rows.push(r);
        let snap = lru.to_bytes();
        rows.push(bench.run("snapshot from_bytes (restore)", Some(bytes), || {
            let s = LruStore::from_bytes(&snap).unwrap();
            std::hint::black_box(s.len());
        }));
    }

    persia::util::bench::print_and_emit("micro_lru", "micro_lru", &rows);
    println!("micro_lru OK");
}
