//! Table 1 — model scales for every benchmark, plus verification that the
//! virtualized embedding geometry reconstructs the paper's parameter counts.

mod common;

use persia::config::BenchPreset;

fn main() {
    common::banner("Table 1: benchmark model scales", "Persia (KDD'22) Table 1");
    println!(
        "{:<14} {:>20} {:>14} {:>18} {:>12}",
        "benchmark", "sparse params", "dense params", "virtual rows/grp", "zipf"
    );
    for p in BenchPreset::all() {
        let model = p.model("paper");
        let emb = p.embedding(&model, 1);
        println!(
            "{:<14} {:>20} {:>14} {:>18} {:>12.2}",
            p.name, p.sparse_params, p.dense_params_paper, emb.rows_per_group, p.zipf_exponent
        );
        // The virtual geometry must reconstruct the advertised sparse scale.
        let virt = emb.virtual_params(&model);
        let denom = (model.n_groups * model.emb_dim_per_group) as u128;
        assert!(p.sparse_params.abs_diff(virt) < denom * 2, "{}: {virt}", p.name);
    }
    let paper_dense = BenchPreset::by_name("criteo").unwrap().model("paper").dense_param_count();
    println!("\n'paper' dense tower: {paper_dense} params (paper: ~12M, hidden 4096/2048/1024/512/256)");
    assert!((11_000_000..13_000_000).contains(&paper_dense));
    println!("table1_scales OK");
}
