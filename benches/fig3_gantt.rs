//! Figure 3 (right) — Gantt charts of the four training modes: which of the
//! five per-step stages serialize vs overlap, and the resulting step time.

mod common;

use persia::config::TrainMode;

fn main() {
    common::banner(
        "Fig. 3: per-step phase timelines (sync / async / raw hybrid / hybrid)",
        "Persia (KDD'22) Figure 3 right",
    );
    let preset = persia::config::BenchPreset::by_name("taobao").unwrap();
    let mut step_times = Vec::new();
    for mode in [TrainMode::FullSync, TrainMode::FullAsync, TrainMode::HybridRaw, TrainMode::Hybrid]
    {
        let mut trainer = common::trainer_for(&preset, mode, 1, 8, 7);
        trainer.record_gantt = true;
        let out = trainer.run_rust().expect("run");
        let span = out.gantt.total_span();
        let per_step = span / 8.0;
        step_times.push((mode, per_step));
        println!(
            "\n--- mode = {:<10} | step time {:.4}s (sim) | overlap fraction {:.2} ---",
            mode.name(),
            per_step,
            out.gantt.overlap_fraction()
        );
        print!("{}", out.gantt.render_ascii(96));
    }
    // Shape assertions: hybrid steps are shorter than sync; async shortest.
    let t = |m: TrainMode| step_times.iter().find(|(mm, _)| *mm == m).unwrap().1;
    let sync = t(TrainMode::FullSync);
    let hybrid = t(TrainMode::Hybrid);
    let raw = t(TrainMode::HybridRaw);
    let asynch = t(TrainMode::FullAsync);
    println!(
        "\nstep-time summary: sync={sync:.4} raw-hybrid={raw:.4} hybrid={hybrid:.4} async={asynch:.4}"
    );
    assert!(hybrid < sync, "hybrid must beat sync");
    assert!(asynch <= hybrid * 1.05, "async must be fastest");
    println!("fig3_gantt OK");
}
