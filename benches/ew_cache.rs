//! ISSUE-10 bench — the embedding-worker bounded-staleness cache under
//! Zipf traffic against a latency-injected PS.
//!
//! Stage-2 of the prefetch pipeline is modeled directly: each step draws a
//! Zipf(α=1.05) batch, dedups it, fetches the unique rows (through the
//! cache or straight from the PS) and pushes SGD gradients back. The fake
//! PS charges a per-call round-trip plus a per-row wire cost — the shape of
//! a real GET — and counts the rows it actually served, so the bench can
//! report both lookup throughput and PS GET bytes saved.
//!
//! Self-baselined like micro_comm: the cache-off row comes from the same
//! run on the same machine, and the acceptance gates (≥1.5× stage-2 lookup
//! throughput, ≥50% PS GET-byte reduction at the default capacity/staleness
//! point) are asserted on in-run ratios, never on absolute numbers.

mod common;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use persia::service::{PsBackend, PsStats};
use persia::util::{Bench, Rng, Zipf};
use persia::worker::{EmbCache, EwCacheParams, PushPolicy};

const DIM: usize = 32;
const UNIVERSE: u64 = 20_000;
const BATCH_DRAWS: usize = 512;
const ZIPF_ALPHA: f64 = 1.05;
/// Modeled PS round-trip: a fixed per-call latency plus a per-row wire
/// cost (batched GETs amortize the former; the cache attacks the latter).
const CALL_NS: u64 = 20_000;
const ROW_NS: u64 = 400;

/// In-process stand-in for a remote PS: deterministic rows, injected
/// latency, and GET counters for the bytes-saved report.
struct SlowPs {
    gets: AtomicU64,
    rows_served: AtomicU64,
}

impl SlowPs {
    fn new() -> SlowPs {
        SlowPs { gets: AtomicU64::new(0), rows_served: AtomicU64::new(0) }
    }
}

impl PsBackend for SlowPs {
    fn dim(&self) -> usize {
        DIM
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> anyhow::Result<()> {
        std::thread::sleep(Duration::from_nanos(CALL_NS + ROW_NS * keys.len() as u64));
        for (i, &(g, id)) in keys.iter().enumerate() {
            let base = (g as u64 * 31 + id) as f32 * 1e-6;
            for (j, w) in out[i * DIM..(i + 1) * DIM].iter_mut().enumerate() {
                *w = base + j as f32 * 1e-8;
            }
        }
        self.gets.fetch_add(1, Ordering::Relaxed);
        self.rows_served.fetch_add(keys.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn put_grads(&self, _keys: &[(u32, u64)], _grads: &[f32]) -> anyhow::Result<()> {
        Ok(())
    }

    fn stats(&self) -> anyhow::Result<PsStats> {
        Ok(PsStats::default())
    }
}

/// One deduped stage-2 batch: `BATCH_DRAWS` Zipf draws, unique keys out.
fn batch(zipf: &Zipf, rng: &mut Rng) -> Vec<(u32, u64)> {
    let mut keys: Vec<(u32, u64)> = (0..BATCH_DRAWS).map(|_| (0u32, zipf.sample(rng))).collect();
    keys.sort_unstable();
    keys.dedup();
    keys
}

/// One pull/push step: fetch the unique rows (through the cache when one is
/// given), then write-through an SGD gradient for every key.
fn step(
    ps: &SlowPs,
    cache: Option<&EmbCache>,
    zipf: &Zipf,
    rng: &mut Rng,
    rows: &mut Vec<f32>,
) -> usize {
    let keys = batch(zipf, rng);
    rows.clear();
    rows.resize(keys.len() * DIM, 0.0);
    match cache {
        Some(c) => {
            c.fetch_through(ps, &keys, rows).unwrap();
        }
        None => ps.get_many(&keys, rows).unwrap(),
    }
    let grads = vec![0.01f32; keys.len() * DIM];
    ps.put_grads(&keys, &grads).unwrap();
    if let Some(c) = cache {
        c.push_applied(&keys, &grads);
    }
    keys.len()
}

fn params(capacity: usize, staleness_ticks: u64) -> EwCacheParams {
    EwCacheParams {
        capacity,
        staleness_ticks,
        admit_threshold: 2, // the TieredStore default — same sketch, same gate
        push: PushPolicy::MirrorSgd { lr: 0.05 },
    }
}

/// (lookups asked of stage-2, rows the PS actually served) over `n` steps.
fn account(
    ps: &SlowPs,
    cache: Option<&EmbCache>,
    zipf: &Zipf,
    rng: &mut Rng,
    n: usize,
) -> (u64, u64) {
    let before = ps.rows_served.load(Ordering::Relaxed);
    let mut rows = Vec::new();
    let mut lookups = 0u64;
    for _ in 0..n {
        lookups += step(ps, cache, zipf, rng, &mut rows) as u64;
    }
    (lookups, ps.rows_served.load(Ordering::Relaxed) - before)
}

fn main() {
    common::banner(
        "ew_cache: bounded-staleness worker cache vs latency-injected PS",
        "Persia (KDD'22) §4.2 (bounded staleness legitimizes worker-side reuse)",
    );
    let bench = Bench::new(3, 10);
    let zipf = Zipf::new(UNIVERSE, ZIPF_ALPHA);
    let mut rows_out = Vec::new();
    const STEPS_PER_ITER: usize = 50;
    const ACCOUNT_STEPS: usize = 100;

    // --- baseline: cache off ---
    let ps = SlowPs::new();
    let mut rng = Rng::new(7);
    let (base_lookups, base_rows) = account(&ps, None, &zipf, &mut rng, ACCOUNT_STEPS);
    // Rows the PS serves per deduped lookup; 1.0 by construction when every
    // lookup is a GET, the denominator of the bytes-saved ratio.
    let base_rate = base_rows as f64 / base_lookups.max(1) as f64;
    let mut buf = Vec::new();
    let uncached = bench.run(
        "stage-2 lookup, cache off",
        Some((STEPS_PER_ITER * BATCH_DRAWS) as f64),
        || {
            for _ in 0..STEPS_PER_ITER {
                step(&ps, None, &zipf, &mut rng, &mut buf);
            }
        },
    );

    // --- sweep: capacity × staleness, default point gated ---
    let sweep: &[(usize, u64, bool)] = &[
        (65_536, 4, true), // the defaults: --ew-cache-capacity 65536, staleness τ=4
        (65_536, 1, false),
        (65_536, 16, false),
        (4_096, 4, false),
        (64, 4, false), // degenerate small cache: the floor of the sweep
    ];
    let mut gated: Option<(f64, f64)> = None;
    for &(capacity, staleness, gate) in sweep {
        let ps = SlowPs::new();
        let mut rng = Rng::new(7);
        let cache = EmbCache::new(params(capacity, staleness), DIM);
        // Warm the admission sketch and the resident set before measuring.
        let mut buf = Vec::new();
        for _ in 0..16 {
            step(&ps, Some(&cache), &zipf, &mut rng, &mut buf);
        }
        let (lookups, ps_rows) = account(&ps, Some(&cache), &zipf, &mut rng, ACCOUNT_STEPS);
        let cached = bench.run(
            &format!("stage-2 lookup, cap={capacity} s={staleness}"),
            Some((STEPS_PER_ITER * BATCH_DRAWS) as f64),
            || {
                for _ in 0..STEPS_PER_ITER {
                    step(&ps, Some(&cache), &zipf, &mut rng, &mut buf);
                }
            },
        );
        let s = cache.stats();
        let rate = ps_rows as f64 / lookups.max(1) as f64;
        let saved = 1.0 - rate / base_rate;
        let speedup = uncached.p50_ns as f64 / cached.p50_ns.max(1) as f64;
        println!(
            "  cap={capacity} s={staleness}: {speedup:.2}x lookup speedup, \
             {:.1}% PS GET bytes saved ({ps_rows} of {lookups} rows fetched, \
             {} GET calls), hit mix: hits={} coalesced={} misses={} \
             stale_refreshes={} evictions={}",
            saved * 100.0,
            ps.gets.load(Ordering::Relaxed),
            s.hits,
            s.coalesced,
            s.misses,
            s.stale_refreshes,
            s.evictions,
        );
        if gate {
            gated = Some((speedup, saved));
        }
        rows_out.push(cached);
    }
    rows_out.insert(0, uncached);

    let (speedup, saved) = gated.expect("sweep includes the default point");
    assert!(
        speedup >= 1.5,
        "worker cache must speed stage-2 lookups >= 1.5x at the default point \
         (got {speedup:.2}x)"
    );
    assert!(
        saved >= 0.5,
        "worker cache must save >= 50% of PS GET bytes at Zipf alpha=1.05 \
         (got {:.1}%)",
        saved * 100.0
    );

    persia::util::bench::print_and_emit("ew_cache", "ew_cache", &rows_out);
    println!("ew_cache OK");
}
