//! Figure 9 — capacity: (left) throughput vs virtual model scale
//! 6.25T → 100T parameters; (right) mode comparison at 100T.
//!
//! Reproduced shape: the throughput curve is FLAT across virtual scales
//! (hash + LRU materialization cost is scale-independent), and at 100T the
//! hybrid mode beats full sync by a multiple (paper: 2.6x) while async adds
//! a further ~1.2x.

mod common;

use persia::config::{BenchPreset, TrainMode};
use persia::embedding::store::EmbeddingStore;
use persia::embedding::{ColdStore, TieredStore};
use persia::sim::{project_throughput, Calibration, ClusterSpec};
use persia::util::csv::CsvWriter;
use persia::util::{Bench, Rng, Zipf};

fn main() {
    common::banner("Fig. 9: capacity up to 100T params", "Persia (KDD'22) Figure 9");

    // Left: measured throughput vs virtual scale (hybrid mode).
    let mut csv = CsvWriter::create(
        "results/fig9_capacity.csv",
        &["preset", "sparse_params", "samples_per_sec"],
    )
    .unwrap();
    println!("\n(left) throughput vs model scale, hybrid mode:");
    println!("{:<14} {:>20} {:>14}", "preset", "sparse params", "samples/s");
    let mut thpts = Vec::new();
    for preset in BenchPreset::capacity_sweep() {
        // Median of 3 runs — host scheduling noise otherwise dominates the
        // (structurally flat) curve.
        let mut runs: Vec<f64> = (0..3)
            .map(|i| {
                let trainer = common::trainer_for(&preset, TrainMode::Hybrid, 2, 100, 7 + i);
                trainer.run_rust().expect("run").report.samples_per_sec
            })
            .collect();
        runs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let thpt = runs[1];
        println!("{:<14} {:>20} {:>14.0}", preset.name, preset.sparse_params, thpt);
        csv.row(&[
            preset.name.to_string(),
            preset.sparse_params.to_string(),
            format!("{thpt:.0}"),
        ])
        .unwrap();
        thpts.push(thpt);
    }
    csv.flush().unwrap();
    let flatness = thpts.iter().fold(f64::MIN, |a, &b| a.max(b))
        / thpts.iter().fold(f64::MAX, |a, &b| a.min(b));
    println!("flatness (max/min) across 16x scale growth: {flatness:.2} (paper: ~flat)");
    assert!(flatness < 2.0, "capacity curve should be flat, got {flatness:.2}");

    // Right: mode comparison at the 100T point. The dedicated-device number
    // (real k=1 per-step compute calibration + the k-dependent network
    // model, as in fig8) carries the paper-comparable ordering; raw wall
    // numbers on this shared-core host are printed for transparency only.
    println!("\n(right) mode comparison at 100T:");
    let preset = BenchPreset::by_name("criteo-syn5").unwrap();
    let calib = common::trainer_for(&preset, TrainMode::Hybrid, 1, 60, 7)
        .run_rust()
        .expect("calibration");
    let t_train = calib.tracker.phase("train").map(|h| h.mean() / 1e9).unwrap_or(2e-3);
    let cal = Calibration { t_train, ..Calibration::default() };
    let model_tiny = preset.model("tiny");
    let spec = ClusterSpec {
        n_nn_workers: 4,
        n_emb_workers: 8,
        n_ps_nodes: 16,
        net: persia::config::NetModelConfig::paper_like(),
    };
    let mut rates = std::collections::HashMap::new();
    println!("  {:<12} {:>14} {:>22}", "mode", "dedicated/s", "measured (contended)");
    for mode in [TrainMode::FullSync, TrainMode::HybridRaw, TrainMode::Hybrid, TrainMode::FullAsync]
    {
        let dedicated = project_throughput(&model_tiny, &spec, &cal, mode, 64);
        let trainer = common::trainer_for(&preset, mode, 4, 80, 7);
        let measured = trainer.run_rust().expect("run").report.samples_per_sec;
        println!("  {:<12} {:>14.0} {:>22.0}", mode.name(), dedicated, measured);
        rates.insert(mode.name(), dedicated);
    }
    let hybrid_x = rates["hybrid"] / rates["sync"];
    let async_x = rates["async"] / rates["hybrid"];
    println!("  hybrid/sync = {hybrid_x:.2}x (paper: 2.6x); async/hybrid = {async_x:.2}x (paper: 1.2x)");
    assert!(hybrid_x > 1.5, "hybrid must beat sync at 100T, got {hybrid_x:.2}");
    assert!((1.0..2.5).contains(&async_x), "async/hybrid out of shape: {async_x:.2}");

    // Projection onto the paper's cloud geometry (30 PS x 12TB, 64 A100).
    println!("\nprojection onto the paper's Google-cloud cluster:");
    let model = preset.model("paper");
    let spec = ClusterSpec::paper_cloud();
    for mode in [TrainMode::FullSync, TrainMode::Hybrid, TrainMode::FullAsync] {
        let t = project_throughput(&model, &spec, &cal, mode, 256);
        println!("  {:<12} {:>12.0} samples/s (projected)", mode.name(), t);
    }

    tier_boundary_sweep();
    println!("fig9_capacity OK");
}

/// Tier boundary: the pluggable storage engine at the point where the table
/// stops fitting in RAM. The hot budget sweeps across the working set W;
/// throughput and the hot/cold hit mix are measured at each point, and the
/// shape is asserted structurally (the traffic is seeded and the LRU obeys
/// the stack-inclusion property, so these are theorems, not timing):
/// hot-hit share only grows with the hot budget, a hot tier at least the
/// working set never demotes, and no point ever loses a row — capacity past
/// RAM costs cold I/O, never rows. Rows land in `BENCH_fig9_capacity.json`
/// for the perf trajectory.
fn tier_boundary_sweep() {
    println!("\n(tier boundary) hot budget vs working set, tiered engine:");
    let bench = Bench::new(1, 3);
    let dim = 16usize; // embedding + adagrad state
    let ops = 80_000u64;
    let zipf = Zipf::new(40_000, 1.05);
    // One dry pass measures the working set the replayed traffic touches.
    let w = {
        let mut rng = Rng::new(11);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..ops {
            seen.insert(zipf.sample(&mut rng));
        }
        seen.len()
    };
    println!("  working set W = {w} distinct rows over {ops} Zipf(1.05) ops/iter");
    let cold_root = std::env::temp_dir().join(format!("persia_fig9_cold_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cold_root);
    std::fs::create_dir_all(&cold_root).unwrap();

    let mut rows = Vec::new();
    let mut stats = Vec::new();
    println!(
        "  {:<6} {:>12} {:>10} {:>10} {:>11} {:>11}",
        "hot", "ops/s", "hot-hit%", "cold-hit%", "demotions", "promotions"
    );
    for (tag, cap) in [("W/8", w / 8), ("W/4", w / 4), ("W/2", w / 2), ("W", w), ("2W", 2 * w)] {
        let file = cold_root.join(format!("{}.bin", tag.replace('/', "_")));
        let cold = ColdStore::open(&file, dim).unwrap();
        // Threshold 1 = admit everything: the pure capacity story (the
        // admission gate is pinned separately by the tiered-store tests).
        let mut ts = TieredStore::new(cap.max(1), cold, 1).unwrap();
        let r = bench.run(&format!("tiered_ops hot={tag}"), Some(ops as f64), || {
            // Replay the same key sequence every iteration so the working
            // set — and with it the tier pressure — is identical per iter.
            let mut rng = Rng::new(11);
            for _ in 0..ops {
                let k = zipf.sample(&mut rng);
                let row = ts.get_or_insert_with(k, &mut |r| r.fill(0.5)).unwrap();
                row[0] += 1.0;
            }
        });
        assert_eq!(ts.len(), w, "rows were lost at hot={tag}");
        let c = ts.counters();
        let served = (c.hot_hits + c.cold_hits) as f64;
        let hot_pct = 100.0 * c.hot_hits as f64 / served;
        println!(
            "  {:<6} {:>12.0} {:>9.1}% {:>9.1}% {:>11} {:>11}",
            tag,
            r.throughput.unwrap_or(0.0),
            hot_pct,
            100.0 * c.cold_hits as f64 / served,
            c.demotions,
            c.promotions
        );
        rows.push(r);
        stats.push((tag, hot_pct, c.demotions));
    }
    for pair in stats.windows(2) {
        assert!(
            pair[1].1 >= pair[0].1,
            "hot-hit share fell while the hot tier grew: {stats:?}"
        );
    }
    assert!(stats[0].2 > 0, "hot=W/8 never spilled — the sweep is not crossing the boundary");
    assert_eq!(stats[4].2, 0, "a hot tier >= the working set demoted rows: {stats:?}");
    persia::util::bench::print_and_emit("fig9_capacity tier boundary", "fig9_capacity", &rows);
    std::fs::remove_dir_all(&cold_root).ok();
}
