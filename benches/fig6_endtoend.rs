//! Figure 6 — end-to-end training time to a target test AUC per benchmark,
//! across modes (Persia-hybrid vs the XDL-sync/XDL-async-shaped baselines).
//!
//! Time is the *simulated* clock (compute wall time + injected network
//! model); absolute values are laptop-scale, the reproduced quantity is the
//! shape: hybrid reaches the target several times faster than full sync, and
//! async — although fast — reaches a LOWER final AUC (see table2_auc).

mod common;

use persia::config::{BenchPreset, NetModelConfig, TrainMode};
use persia::sim::{project_throughput, Calibration, ClusterSpec};
use persia::util::csv::CsvWriter;

fn main() {
    common::banner(
        "Fig. 6: end-to-end time-to-AUC per benchmark x mode",
        "Persia (KDD'22) Figure 6",
    );
    let mut csv = CsvWriter::create(
        "results/fig6_endtoend.csv",
        &["benchmark", "mode", "target_auc", "steps_to_target", "sim_secs_to_target", "final_auc"],
    )
    .unwrap();

    for preset in BenchPreset::convergence_set() {
        // kwai's virtual table is huge; same machinery, fewer steps.
        let steps = if preset.name == "kwai" { 300 } else { 400 };
        // Hardware-efficiency term: dedicated-device per-step time (real
        // k=1 compute calibration + k-dependent network model; same method
        // as fig8/fig9 — this host is 1 core, so raw wall conflates modes).
        let calib = common::trainer_for(&preset, TrainMode::Hybrid, 1, 40, 21)
            .run_rust()
            .expect("calibration");
        let t_train = calib.tracker.phase("train").map(|h| h.mean() / 1e9).unwrap_or(2e-3);
        let cal = Calibration { t_train, ..Calibration::default() };
        let model = preset.model("tiny");
        let spec = ClusterSpec {
            n_nn_workers: 4,
            n_emb_workers: 8,
            n_ps_nodes: 16,
            net: NetModelConfig::paper_like(),
        };
        let step_secs = |mode: TrainMode| -> f64 {
            4.0 * 64.0 / project_throughput(&model, &spec, &cal, mode, 64)
        };
        let mut sync_time = None;
        println!(
            "\n--- {} (target AUC {:.2}) ---",
            preset.name, preset.target_auc
        );
        println!(
            "{:<12} {:>16} {:>18} {:>10} {:>18}",
            "mode", "steps-to-AUC", "sim-secs-to-AUC", "final AUC", "speedup vs sync"
        );
        for mode in [TrainMode::FullSync, TrainMode::FullAsync, TrainMode::HybridRaw, TrainMode::Hybrid] {
            let mut trainer = common::trainer_for(&preset, mode, 4, steps, 21);
            trainer.train.eval_every = 25;
            trainer.eval_rows = 2048;
            let out = trainer.run_rust().expect("run");
            let sim_per_step = step_secs(mode);
            let hit = out.tracker.steps_to_auc(preset.target_auc);
            let sim_to_target = hit.map(|s| s as f64 * sim_per_step);
            let final_auc = out.report.final_auc.unwrap();
            if mode == TrainMode::FullSync {
                sync_time = sim_to_target;
            }
            let speedup = match (sync_time, sim_to_target) {
                (Some(s), Some(t)) => format!("{:.2}x", s / t),
                _ => "-".into(),
            };
            println!(
                "{:<12} {:>16} {:>18} {:>10.4} {:>18}",
                mode.name(),
                hit.map(|h| h.to_string()).unwrap_or_else(|| ">budget".into()),
                sim_to_target.map(|t| format!("{t:.2}")).unwrap_or_else(|| "-".into()),
                final_auc,
                speedup,
            );
            csv.row(&[
                preset.name.to_string(),
                mode.name().to_string(),
                format!("{}", preset.target_auc),
                hit.map(|h| h.to_string()).unwrap_or_default(),
                sim_to_target.map(|t| format!("{t:.4}")).unwrap_or_default(),
                format!("{final_auc:.4}"),
            ])
            .unwrap();
        }
    }
    csv.flush().unwrap();
    println!("\nwrote results/fig6_endtoend.csv");
    println!("fig6_endtoend OK");
}
