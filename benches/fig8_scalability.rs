//! Figure 8 — training-sample throughput vs number of NN workers per mode.
//!
//! Two columns per mode:
//! * **measured** — real wallclock of the in-process run. On this host all
//!   "GPU workers" share the same CPU cores, so contention flattens the
//!   curve beyond the core count (documented limitation).
//! * **dedicated** — the paper-comparable number: per-step compute time
//!   calibrated from a real k=1 run (real measurement), composed with the
//!   k-dependent AllReduce/transfer costs of the network model — i.e. each
//!   logical worker owns its device, as in the paper's cluster.
//!
//! Reproduced shape (dedicated columns): near-linear scaling for hybrid,
//! sync lagging, async on top.

mod common;

use persia::config::{BenchPreset, NetModelConfig, TrainMode};
use persia::sim::{project_throughput, Calibration, ClusterSpec};
use persia::util::csv::CsvWriter;

fn main() {
    common::banner("Fig. 8: throughput vs #NN workers per mode", "Persia (KDD'22) Figure 8");
    let preset = BenchPreset::by_name("taobao").unwrap();

    // Calibrate per-step compute from a real single-worker run.
    let trainer = common::trainer_for(&preset, TrainMode::Hybrid, 1, 60, 11);
    let out = trainer.run_rust().expect("calibration run");
    let t_train = out.tracker.phase("train").map(|h| h.mean() / 1e9).unwrap_or(2e-3);
    println!("\ncalibrated t_train (k=1, real measurement): {:.3} ms/step", t_train * 1e3);
    let cal = Calibration { t_train, ..Calibration::default() };
    let model = preset.model("tiny");

    let workers = [1usize, 2, 4, 8];
    let mut csv = CsvWriter::create(
        "results/fig8_scalability.csv",
        &["workers", "sync", "hybrid_raw", "hybrid", "async", "measured_hybrid"],
    )
    .unwrap();

    println!(
        "\n{:<8} {:>12} {:>12} {:>12} {:>12} {:>18}",
        "workers", "sync", "hybrid-raw", "hybrid", "async", "measured(hybrid)"
    );
    let mut hybrid_thpt = Vec::new();
    for &k in &workers {
        // The paper scales CPU-side resources with the GPU fleet (its cloud
        // run: 64 GPUs, 100 emb workers, 30 PS nodes) — keep the ratio fixed.
        // Intra-node NVLink/GPUDirect latency (the paper's NN workers are
        // 8-GPU machines; Bagua's hierarchical + fused buckets keep the
        // per-step latency in the microsecond range, not the Ethernet 50us).
        let net = NetModelConfig { latency_s: 5e-6, ..NetModelConfig::paper_like() };
        let spec = ClusterSpec {
            n_nn_workers: k,
            n_emb_workers: 2 * k,
            n_ps_nodes: 4 * k,
            net,
        };
        let proj: Vec<f64> = [
            TrainMode::FullSync,
            TrainMode::HybridRaw,
            TrainMode::Hybrid,
            TrainMode::FullAsync,
        ]
        .iter()
        .map(|&m| project_throughput(&model, &spec, &cal, m, 64))
        .collect();
        // Real contended measurement for the hybrid column.
        let trainer = common::trainer_for(&preset, TrainMode::Hybrid, k, 80, 11);
        let measured = trainer.run_rust().expect("run").report.samples_per_sec;
        println!(
            "{:<8} {:>12.0} {:>12.0} {:>12.0} {:>12.0} {:>18.0}",
            k, proj[0], proj[1], proj[2], proj[3], measured
        );
        csv.row(&[
            k.to_string(),
            format!("{:.0}", proj[0]),
            format!("{:.0}", proj[1]),
            format!("{:.0}", proj[2]),
            format!("{:.0}", proj[3]),
            format!("{measured:.0}"),
        ])
        .unwrap();
        hybrid_thpt.push(proj[2]);
    }
    csv.flush().unwrap();

    let scaling = hybrid_thpt.last().unwrap() / hybrid_thpt[0];
    println!("\nhybrid dedicated-device scaling 1 -> 8 workers: {scaling:.2}x (paper: near-linear)");
    assert!(scaling > 4.0, "hybrid should scale near-linearly, got {scaling:.2}x");
    // Sync must scale worse than hybrid at k=8.
    let spec8 = ClusterSpec {
        n_nn_workers: 8,
        n_emb_workers: 16,
        n_ps_nodes: 32,
        net: NetModelConfig { latency_s: 5e-6, ..NetModelConfig::paper_like() },
    };
    let sync8 = project_throughput(&model, &spec8, &cal, TrainMode::FullSync, 64);
    assert!(
        hybrid_thpt.last().unwrap() > &sync8,
        "hybrid must beat sync at scale"
    );
    println!("wrote results/fig8_scalability.csv");
    println!("fig8_scalability OK");
}
