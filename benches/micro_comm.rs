//! §4.2.3 micro-benchmarks — communication substrate:
//! zero-copy wire encode/decode bandwidth, lossless uint16 index compression
//! ratio under skew, lossy fp16 value compression ratio + error, and RPC
//! round-trip latency over the in-proc and TCP transports.

mod common;

use persia::comm::compress::{CompressedValues, IndexMap};
use persia::comm::rpc::{PipelinedClient, RpcClient, RpcServer};
use persia::comm::transport::{ChannelTransport, TcpTransport};
use persia::comm::wire::{WireReader, WireWriter};
use persia::config::{ModelConfig, Pooling};
use persia::data::SyntheticDataset;
use persia::util::{Bench, Rng};

fn main() {
    common::banner(
        "micro: zero-copy wire + compression + RPC",
        "Persia (KDD'22) §4.2.3 (RPC, lossless + lossy compression)",
    );
    let bench = Bench::new(3, 10);
    let mut rows = Vec::new();

    // Wire format bandwidth on a 4096x128 f32 tensor (one activation batch).
    {
        let mut rng = Rng::new(1);
        let data = rng.normal_vec(4096 * 128);
        let bytes = (data.len() * 4) as f64;
        rows.push(bench.run("wire encode 2MB f32", Some(bytes), || {
            let mut w = WireWriter::new(1);
            w.put_f32(&data);
            std::hint::black_box(w.finish());
        }));
        let mut w = WireWriter::new(1);
        w.put_f32(&data);
        let msg = w.finish();
        rows.push(bench.run("wire decode 2MB f32 (zero-copy)", Some(bytes), || {
            let r = WireReader::parse(&msg).unwrap();
            std::hint::black_box(r.f32_borrowed(0).unwrap().len());
        }));
    }

    // Lossless index compression on a skewed batch.
    {
        let model = ModelConfig {
            artifact_preset: "small".into(),
            n_groups: 8,
            emb_dim_per_group: 16,
            nid_dim: 16,
            hidden: vec![64],
            ids_per_group: 8,
            pooling: Pooling::Sum,
        };
        let ds = SyntheticDataset::new(&model, 100_000, 1.2, 3);
        let batch = ds.batch(&mut ds.train_rng(0), 4096);
        let m = IndexMap::from_batch(&batch);
        println!(
            "  index compression: naive {} B -> {} B (ratio {:.2}x), {} unique of {} ids",
            m.naive_bytes(),
            m.wire_bytes(),
            m.ratio(),
            m.keys.len(),
            m.rows.len()
        );
        rows.push(bench.run("index compress 4096-batch", Some(4096.0), || {
            std::hint::black_box(IndexMap::from_batch(&batch).wire_bytes());
        }));
        assert!(m.ratio() > 1.5, "skewed traffic must compress");
    }

    // Lossy value compression.
    {
        let mut rng = Rng::new(2);
        let vals = rng.normal_vec(4096 * 128);
        let bytes = (vals.len() * 4) as f64;
        rows.push(bench.run("fp16 value compress 2MB", Some(bytes), || {
            std::hint::black_box(CompressedValues::compress(&vals, 128).wire_bytes());
        }));
        let c = CompressedValues::compress(&vals, 128);
        let mut out = vec![0.0f32; vals.len()];
        rows.push(bench.run("fp16 value decompress 2MB", Some(bytes), || {
            c.decompress_into(&mut out);
            std::hint::black_box(out[0]);
        }));
        let max_err = vals
            .iter()
            .zip(&out)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!(
            "  value compression: {} B -> {} B ({:.2}x), max abs err {:.2e}",
            c.uncompressed_bytes(),
            c.wire_bytes(),
            c.uncompressed_bytes() as f64 / c.wire_bytes() as f64,
            max_err
        );
    }

    // RPC round-trip latency: in-proc channel vs TCP loopback.
    {
        let (server_t, client_t) = ChannelTransport::pair();
        let mut server = RpcServer::new();
        server.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let h = std::thread::spawn(move || server.serve(&server_t));
        let client = RpcClient::new(client_t);
        let mut w = WireWriter::new(1);
        w.put_f32(&vec![0.0; 256]);
        let msg = w.finish();
        rows.push(bench.run("rpc roundtrip in-proc 1KB x100", Some(100.0), || {
            for _ in 0..100 {
                std::hint::black_box(client.call(&msg).unwrap().len());
            }
        }));
        drop(client);
        h.join().unwrap().ok();

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (s, _) = listener.accept().unwrap();
            let t = TcpTransport::new(s);
            let mut server = RpcServer::new();
            server.register(1, Box::new(|msg| Ok(msg.to_vec())));
            server.serve(&t).ok();
        });
        let client = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
        rows.push(bench.run("rpc roundtrip tcp 1KB x100", Some(100.0), || {
            for _ in 0..100 {
                std::hint::black_box(client.call(&msg).unwrap().len());
            }
        }));
        drop(client);
        h.join().unwrap();
    }

    // Pipelined vs lock-step RPC against the production readiness-loop
    // server (`serve_rpc` — the exact stack `serve-ps` runs). Self-baselined:
    // both rows come from this same run on this same machine, and the
    // speedup gate is asserted on their ratio, not on absolute numbers.
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut server = RpcServer::new();
        server.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let rpc = Arc::new(server);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let h = std::thread::spawn(move || {
            persia::service::serve_rpc(listener, rpc, stop2, "micro-comm-bench")
        });

        let client = PipelinedClient::connect(
            &addr,
            32,
            Some(std::time::Duration::from_secs(30)),
        )
        .unwrap();
        let mut w = WireWriter::new(1);
        w.put_f32(&vec![0.0; 256]);
        let msg = w.finish();
        let lockstep =
            bench.run("rpc lock-step event-loop 1KB x200", Some(200.0), || {
                for _ in 0..200 {
                    std::hint::black_box(client.call(&msg).unwrap().len());
                }
            });
        let pipelined = bench.run("rpc pipelined w=32 1KB x200", Some(200.0), || {
            let mut pending = Vec::with_capacity(200);
            for _ in 0..200 {
                pending.push(client.call_async(&msg).unwrap());
            }
            for p in pending {
                std::hint::black_box(p.wait().unwrap().len());
            }
        });
        let speedup = lockstep.p50_ns as f64 / pipelined.p50_ns.max(1) as f64;
        println!("  pipelining speedup (p50, same run): {speedup:.2}x");
        assert!(
            speedup >= 2.0,
            "pipelined RPC must be >= 2x lock-step on loopback (got {speedup:.2}x)"
        );
        rows.push(lockstep);
        rows.push(pipelined);
        drop(client);
        stop.store(true, Ordering::SeqCst);
        let _ = std::net::TcpStream::connect(&addr);
        h.join().unwrap();
    }

    persia::util::bench::print_and_emit("micro_comm", "micro_comm", &rows);
    println!("micro_comm OK");
}
