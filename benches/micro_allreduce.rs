//! Dense-sync micro-benchmarks: ring AllReduce vs the central-PS baseline
//! (why Persia uses the AllReduce paradigm for w_nn, §4.1/§4.2.3) and the
//! bucket-size ablation of the Bagua-style flattening.

mod common;

use std::sync::Arc;

use persia::allreduce::{central_reduce, FlatBuckets, RingGroup};
use persia::comm::NetSim;
use persia::config::NetModelConfig;
use persia::tensor::Tensor;
use persia::util::{Bench, Rng};

fn ring_once(k: usize, n: usize) -> f64 {
    let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
    let members = RingGroup::new(k, net);
    let handles: Vec<_> = members
        .into_iter()
        .map(|m| {
            std::thread::spawn(move || {
                let mut buf = vec![1.0f32; n];
                m.all_reduce_mean(&mut buf)
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).fold(0.0, f64::max)
}

fn main() {
    common::banner(
        "micro: ring AllReduce vs central PS reduce; bucketing ablation",
        "Persia (KDD'22) §4.1 AllReduce paradigm + Bagua bucketing",
    );
    let bench = Bench::new(2, 8);
    let mut rows = Vec::new();
    let n = 1_200_000; // ~1.2M dense params ("small" tower scale)

    for k in [2usize, 4, 8] {
        let r = bench.run(&format!("ring_allreduce k={k} n={n}"), Some(n as f64), || {
            std::hint::black_box(ring_once(k, n));
        });
        rows.push(r);
        // Simulated wire time comparison.
        let ring_sim = ring_once(k, n);
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let grads: Vec<Vec<f32>> = (0..k).map(|_| vec![1.0f32; n]).collect();
        let (_, central_sim) = central_reduce(&grads, &net);
        println!(
            "  k={k}: simulated wire time ring {ring_sim:.5}s vs central {central_sim:.5}s ({:.1}x)",
            central_sim / ring_sim.max(1e-12)
        );
    }

    // Bucketing/flattening ablation: reduce cost of many small tensors vs
    // one flat buffer.
    {
        let mut rng = Rng::new(3);
        let shapes: Vec<Vec<usize>> = (0..64).map(|_| vec![1024, 16]).collect();
        let tensors: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor::from_vec(s, rng.normal_vec(s.iter().product())))
            .collect();
        let total: usize = tensors.iter().map(|t| t.len()).sum();
        rows.push(bench.run("flatten 64 tensors (1MB)", Some(total as f64), || {
            std::hint::black_box(FlatBuckets::flatten(&tensors, 1 << 16).total_elems());
        }));
        for bucket in [1 << 10, 1 << 14, 1 << 18] {
            let fb = FlatBuckets::flatten(&tensors, bucket);
            rows.push(bench.run(
                &format!("reduce via buckets of {bucket}"),
                Some(total as f64),
                || {
                    let mut fb2 = FlatBuckets::flatten(&tensors, bucket);
                    for i in 0..fb2.n_buckets() {
                        for x in fb2.bucket_mut(i) {
                            *x *= 0.5;
                        }
                    }
                    std::hint::black_box(fb2.total_elems());
                },
            ));
            std::hint::black_box(fb.n_buckets());
        }
    }

    persia::util::bench::print_and_emit("micro_allreduce", "micro_allreduce", &rows);
    println!("micro_allreduce OK");
}
