//! Theorem 1 empirical validation: convergence vs staleness bound τ.
//!
//! The rate is `σ/√T + 1/T + τ·α/T` — for τ within the paper's operating
//! range (τ < 5) the τα/T term is dominated and AUC is flat; pushing τ far
//! beyond it degrades convergence toward the async regime. We sweep τ and
//! the Zipf exponent (which controls α, the max ID frequency).

mod common;

use persia::config::{BenchPreset, TrainMode};

fn auc_at(tau: usize, zipf: f64, seeds: &[u64]) -> f64 {
    let preset = BenchPreset::by_name("taobao").unwrap();
    let mut total = 0.0;
    for &seed in seeds {
        let mut trainer = common::trainer_for(&preset, TrainMode::Hybrid, 4, 350, seed);
        trainer.train.staleness_bound = tau;
        trainer.train.eval_every = 350;
        trainer.eval_rows = 2048;
        // Override the dataset skew (α knob).
        trainer.dataset = persia::data::SyntheticDataset::new(
            &trainer.model,
            trainer.emb_cfg.rows_per_group,
            zipf,
            seed,
        );
        let out = trainer.run_rust().expect("run");
        total += out.report.final_auc.unwrap();
    }
    total / seeds.len() as f64
}

fn main() {
    common::banner(
        "ablation: AUC vs staleness bound τ and ID skew α",
        "Persia (KDD'22) Theorem 1 (rate σ/√T + 1/T + τα/T)",
    );
    let seeds = [3u64, 17];

    println!("\nAUC vs τ (zipf 1.05):");
    let mut by_tau = Vec::new();
    for tau in [0usize, 1, 4, 16, 64] {
        let a = auc_at(tau, 1.05, &seeds);
        println!("  tau={tau:<4} auc={a:.4}");
        by_tau.push((tau, a));
    }
    let small_tau = by_tau[1].1; // tau=1
    let paper_tau = by_tau[2].1; // tau=4 (paper: τ < 5 typical)
    let huge_tau = by_tau[4].1; // tau=64
    assert!(
        (small_tau - paper_tau).abs() < 0.015,
        "τ within the paper's range must not hurt: {small_tau} vs {paper_tau}"
    );
    assert!(
        huge_tau <= paper_tau + 0.01,
        "extreme staleness should not improve AUC: {huge_tau} vs {paper_tau}"
    );

    println!("\nAUC vs skew (τ=16): higher α (more skew) => staleness term bites harder");
    for zipf in [0.0f64, 1.05, 1.4] {
        let a = auc_at(16, zipf, &seeds);
        println!("  zipf={zipf:<5} auc={a:.4}");
    }
    println!("\n(The α sweep is directional: α multiplies the staleness term, but the");
    println!(" oracle AUC also shifts with skew, so only the τ sweep is asserted.)");
    println!("ablation_staleness OK");
}
