//! Live-resharding cost: what does a mid-run 2→3 shard split do to the
//! serving path, and how much imbalance does it buy back?
//!
//! Stands up the real service stack in-process — two owner `PsServer`s
//! (nodes 0..4 and 4..6) plus a `--join`-style spare — and drives batched
//! GET/PUT traffic through one `ShardedRemotePs`. A prober thread keeps
//! issuing GET batches while the coordinator runs
//! [`PsBackend::maybe_reshard`], so the emitted rows capture:
//!
//! * steady-state batch latency before and after the split,
//! * the latency of probes that overlap the migration window (dip depth),
//! * the coordinator's wall-clock stall (dip duration), and
//! * the process imbalance before/after, computed from the issued key
//!   stream with the same `route()` the fleet uses (carried in the
//!   `throughput` column — it is a ratio, not items/s).
//!
//! Emits `BENCH_reshard.json` when `BENCH_JSON_DIR` is set.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use persia::config::{
    EmbeddingConfig, OptimizerKind, PartitionPolicy, RecoveryConfig, ServiceConfig,
};
use persia::embedding::ps::{pack_key, route};
use persia::embedding::EmbeddingPs;
use persia::service::reshard::{apply, plan_rebalance, process_imbalance, RoutingTable};
use persia::service::{PsBackend, PsBindOpts, PsServer, PsServerHandle, ShardedRemotePs};
use persia::util::bench::BenchResult;
use persia::util::{Bench, Histogram, Rng};

mod common;

const N_NODES: usize = 6;
const SHARDS_PER_NODE: usize = 2;
const DIM: usize = 16;
const N_GROUPS: u64 = 4;
const ROWS_PER_GROUP: u64 = 50_000;
const BATCH: usize = 2048;
const SEED: u64 = 42;
/// Deployment: two owners at 4-vs-2 nodes (process imbalance 4/3 ≈ 1.333
/// under shuffled-uniform traffic) plus one idle spare for the split.
const OWNER_RANGES: [std::ops::Range<usize>; 2] = [0..4, 4..6];

fn emb_cfg() -> EmbeddingConfig {
    EmbeddingConfig {
        rows_per_group: ROWS_PER_GROUP as usize,
        shard_capacity: 1 << 16,
        n_nodes: N_NODES,
        shards_per_node: SHARDS_PER_NODE,
        optimizer: OptimizerKind::Adagrad,
        partition: PartitionPolicy::ShuffledUniform,
        lr: 0.1,
    }
}

/// Bind one server on an ephemeral port, retried like the integration
/// suites (rebinding can race a just-released socket's teardown).
fn spawn_server(
    cfg: &EmbeddingConfig,
    opts_for: impl Fn() -> (Arc<EmbeddingPs>, PsBindOpts),
) -> (PsServerHandle, String) {
    let mut last_err = None;
    for _ in 0..40 {
        let (ps, opts) = opts_for();
        match PsServer::bind_with_opts(ps, "127.0.0.1:0", cfg, SEED, opts) {
            Ok(server) => {
                let addr = server.local_addr().unwrap().to_string();
                return (server.spawn().unwrap(), addr);
            }
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    panic!("could not bind shard server: {:#}", last_err.unwrap());
}

/// A fixed pool of key batches, cycled by both the bench loops and the
/// prober so every phase sees the same traffic distribution.
fn key_pool(n_batches: usize) -> Vec<Vec<(u32, u64)>> {
    let mut rng = Rng::new(SEED ^ 0xBE9C);
    (0..n_batches)
        .map(|_| {
            (0..BATCH)
                .map(|_| (rng.below(N_GROUPS) as u32, rng.below(ROWS_PER_GROUP)))
                .collect()
        })
        .collect()
}

/// One serving round-trip: fetch a batch, push a constant gradient back.
fn get_put(backend: &ShardedRemotePs, keys: &[(u32, u64)], out: &mut [f32], grads: &[f32]) {
    backend.get_many(keys, out).expect("get_many");
    backend.put_grads(keys, grads).expect("put_grads");
}

fn main() {
    common::banner(
        "live reshard cost: serving dip + stall of a mid-run 2->3 shard split",
        "Persia (KDD'22) §4.2.2 (load balancing), made live over the epoch barrier",
    );
    // Stretch the per-node copy so the prober reliably lands samples inside
    // the migration window (the same hook the chaos drills use).
    std::env::set_var("PERSIA_MIGRATE_DELAY_MS", "150");

    let cfg = emb_cfg();
    let mut handles = Vec::new();
    let mut addrs = Vec::new();
    for range in OWNER_RANGES {
        let (h, a) = spawn_server(&cfg, || {
            let ps = Arc::new(EmbeddingPs::new_range(&cfg, DIM, SEED, range.clone()));
            (ps, PsBindOpts::default())
        });
        handles.push(h);
        addrs.push(a);
    }
    let (spare_handle, spare_addr) = spawn_server(&cfg, || {
        let ps = Arc::new(EmbeddingPs::new(&cfg, DIM, SEED));
        (ps, PsBindOpts { join: true, ..Default::default() })
    });
    handles.push(spare_handle);
    addrs.push(spare_addr);

    let backend = Arc::new(
        ShardedRemotePs::connect(&ServiceConfig {
            addr: addrs.join(","),
            client_conns: 2,
            wire_compress: false,
            recovery: RecoveryConfig { attempts: 4, backoff_ms: 50, ..RecoveryConfig::default() },
        })
        .expect("connect sharded backend"),
    );
    assert_eq!(backend.dim(), DIM);

    let pool = key_pool(64);
    let grads = vec![0.01f32; BATCH * DIM];
    let mut out = vec![0f32; BATCH * DIM];

    // The same imbalance arithmetic the coordinator runs, from the issued
    // key stream: tally per-node traffic with the fleet's own route().
    let mut traffic = vec![0u64; N_NODES];
    for batch in &pool {
        for &(g, id) in batch {
            let (node, _) = route(cfg.partition, N_NODES, SHARDS_PER_NODE, pack_key(g, id));
            traffic[node] += 1;
        }
    }
    let before_table =
        RoutingTable::initial(N_NODES, &[0..4, 4..6, 0..0], &addrs).expect("initial table");
    let imbalance_before = process_imbalance(&before_table, &traffic);
    let after_table = plan_rebalance(&before_table, &traffic, 1.25)
        .and_then(|plan| apply(&before_table, &plan).ok());
    let imbalance_after = after_table
        .as_ref()
        .map(|t| process_imbalance(t, &traffic))
        .unwrap_or(imbalance_before);

    let bench = Bench::new(3, 20);
    let mut rows = Vec::new();
    let keys_per_iter = BATCH as f64;

    let mut cursor = 0usize;
    rows.push(bench.run("get_put_steady_before_split", Some(keys_per_iter), || {
        get_put(&backend, &pool[cursor % pool.len()], &mut out, &grads);
        cursor += 1;
    }));

    // Prober: keeps timing GET batches on its own connection slots while
    // the main thread plays coordinator. Samples are classified against the
    // migration window afterwards.
    let stop = Arc::new(AtomicBool::new(false));
    let prober = {
        let backend = Arc::clone(&backend);
        let pool = pool.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut out = vec![0f32; BATCH * DIM];
            let mut samples: Vec<(Instant, u64)> = Vec::new();
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                backend.get_many(&pool[i % pool.len()], &mut out).expect("probe get_many");
                samples.push((t0, t0.elapsed().as_nanos() as u64));
                i += 1;
            }
            samples
        })
    };

    std::thread::sleep(Duration::from_millis(300));
    let window_start = Instant::now();
    let committed = backend.maybe_reshard(1.25).expect("maybe_reshard");
    let window_end = Instant::now();
    assert_eq!(committed, Some(1), "the 4-vs-2 deployment must trigger a split at 1.25");
    assert_eq!(backend.routing_epoch(), 1);
    std::thread::sleep(Duration::from_millis(300));
    stop.store(true, Ordering::Relaxed);
    let samples = prober.join().expect("prober thread");

    let stall_ns = (window_end - window_start).as_nanos() as u64;
    let mut in_window = Histogram::new();
    let mut in_count = 0u64;
    let mut in_total = 0u64;
    let mut in_max = 0u64;
    for &(t0, dur) in &samples {
        // A probe overlaps the window if it started before COMMIT returned
        // and ended after PREPARE began.
        if t0 < window_end && t0 + Duration::from_nanos(dur) > window_start {
            in_window.record(dur);
            in_count += 1;
            in_total += dur;
            in_max = in_max.max(dur);
        }
    }
    assert!(in_count > 0, "no probe overlapped the migration window — raise the delay hook");
    rows.push(BenchResult {
        name: "get_probe_during_migration".into(),
        iters: in_count,
        mean_ns: in_total as f64 / in_count as f64,
        p50_ns: in_window.percentile(50.0),
        p95_ns: in_window.percentile(95.0),
        throughput: Some(keys_per_iter / (in_total as f64 / in_count as f64 / 1e9)),
    });
    rows.push(BenchResult {
        name: "migration_stall_wallclock".into(),
        iters: 1,
        mean_ns: stall_ns as f64,
        p50_ns: stall_ns,
        p95_ns: stall_ns,
        throughput: None,
    });

    let mut cursor = 0usize;
    rows.push(bench.run("get_put_steady_after_split", Some(keys_per_iter), || {
        get_put(&backend, &pool[cursor % pool.len()], &mut out, &grads);
        cursor += 1;
    }));

    for (name, imb) in [
        ("process_imbalance_before", imbalance_before),
        ("process_imbalance_after", imbalance_after),
    ] {
        rows.push(BenchResult {
            name: name.into(),
            iters: 1,
            mean_ns: 0.0,
            p50_ns: 0,
            p95_ns: 0,
            throughput: Some(imb),
        });
    }

    persia::util::bench::print_and_emit("reshard", "reshard", &rows);

    let before_mean = rows[0].mean_ns;
    let during_mean = rows[1].mean_ns;
    let after_mean = rows[3].mean_ns;
    println!("\nreshard cost summary:");
    println!(
        "  dip depth   : probes during migration ran {:.2}x the pre-split mean \
         ({:.3} ms vs {:.3} ms, worst {:.3} ms)",
        during_mean / before_mean,
        during_mean / 1e6,
        before_mean / 1e6,
        in_max as f64 / 1e6,
    );
    println!(
        "  dip duration: coordinator stall {:.1} ms (PREPARE -> COMMIT)",
        stall_ns as f64 / 1e6
    );
    println!(
        "  steady state: {:.3} ms before vs {:.3} ms after ({:+.1}%)",
        before_mean / 1e6,
        after_mean / 1e6,
        (after_mean / before_mean - 1.0) * 100.0,
    );
    println!(
        "  imbalance   : {imbalance_before:.3} -> {imbalance_after:.3} \
         (max/mean over serving shards)"
    );
    drop(handles);
}
