//! Shared helpers for the paper-reproduction bench targets.

use persia::config::{BenchPreset, ClusterConfig, NetModelConfig, TrainConfig, TrainMode};
use persia::data::SyntheticDataset;
use persia::hybrid::Trainer;

/// Build a trainer for a benchmark preset with a scaled-down dense tower
/// (rust engine geometry; steps/batch set by the caller).
pub fn trainer_for(
    preset: &BenchPreset,
    mode: TrainMode,
    nn_workers: usize,
    steps: usize,
    seed: u64,
) -> Trainer {
    let model = preset.model("tiny");
    let emb_cfg = preset.embedding(&model, 65536);
    let cluster = ClusterConfig {
        n_nn_workers: nn_workers,
        n_emb_workers: 2,
        net: NetModelConfig::paper_like(),
    };
    let train = TrainConfig {
        mode,
        batch_size: 64,
        lr: 0.1,
        staleness_bound: if mode == TrainMode::FullAsync { 16 } else { 4 },
        steps,
        eval_every: 0,
        seed,
        use_pjrt: false,
        compress: true,
    };
    let dataset =
        SyntheticDataset::new(&model, emb_cfg.rows_per_group, preset.zipf_exponent, seed);
    Trainer::new(model, emb_cfg, cluster, train, dataset)
}

/// Standard bench banner.
pub fn banner(what: &str, paper_ref: &str) {
    println!("\n================================================================");
    println!("  {what}");
    println!("  reproduces: {paper_ref}");
    println!("================================================================");
}
