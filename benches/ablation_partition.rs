//! §4.2.3 "Workload balance of embedding PS" ablation: feature-group
//! partitioning vs Persia's shuffled-uniform placement under skewed traffic.
//!
//! Reproduced claim: with traffic leaning toward one feature group, the
//! naive placement congests a subset of PS nodes; shuffling ids uniformly
//! "effectively diminishes the congestion ... and keeps a balanced workload".

mod common;

use persia::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};
use persia::embedding::EmbeddingPs;
use persia::util::{Rng, Zipf};

fn run(policy: PartitionPolicy, skew_group: bool) -> (f64, Vec<u64>) {
    let cfg = EmbeddingConfig {
        rows_per_group: 10_000_000,
        shard_capacity: 4096,
        n_nodes: 8,
        shards_per_node: 4,
        optimizer: OptimizerKind::Sgd,
        partition: policy,
        lr: 0.1,
    };
    let ps = EmbeddingPs::new(&cfg, 8, 1);
    let zipf = Zipf::new(10_000_000, 1.05);
    let mut rng = Rng::new(2);
    let mut buf = vec![0.0f32; 8];
    for i in 0..60_000u64 {
        // Skewed regime: 80% of traffic leans toward feature group 0
        // ("the access of training data can irregularly lean towards a
        // particular embedding group", §4.2.3).
        let group = if skew_group && rng.bernoulli(0.8) { 0 } else { (i % 8) as u32 };
        ps.get(group, zipf.sample(&mut rng), &mut buf);
    }
    (ps.imbalance(), ps.node_traffic())
}

fn main() {
    common::banner(
        "ablation: PS partitioning under group-skewed traffic",
        "Persia (KDD'22) §4.2.3 workload balance",
    );
    println!("{:<20} {:>12} {:>14}  per-node traffic", "policy", "skewed", "imbalance");
    for (policy, name) in [
        (PartitionPolicy::FeatureGroup, "feature-group"),
        (PartitionPolicy::ShuffledUniform, "shuffled-uniform"),
    ] {
        for skew in [false, true] {
            let (imb, traffic) = run(policy, skew);
            println!("{:<20} {:>12} {:>14.2}  {:?}", name, skew, imb, traffic);
        }
    }
    let (naive_imb, _) = run(PartitionPolicy::FeatureGroup, true);
    let (shuffled_imb, _) = run(PartitionPolicy::ShuffledUniform, true);
    println!(
        "\nunder skew: feature-group imbalance {naive_imb:.2} vs shuffled {shuffled_imb:.2} \
         ({:.1}x better balanced)",
        naive_imb / shuffled_imb
    );
    assert!(naive_imb > 2.0, "naive placement should congest");
    assert!(shuffled_imb < 1.7, "shuffled placement should balance");
    assert!(naive_imb / shuffled_imb > 2.0, "shuffling should clearly win under skew");
    println!("ablation_partition OK");
}
