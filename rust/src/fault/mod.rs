//! Fault tolerance (paper §4.2.4): failure injection + per-component
//! recovery policies for the *simulated* cluster.
//!
//! This module models §4.2.4 inside one process (in-RAM "shared memory" and
//! checkpoint stand-ins, exercised by `examples/fault_tolerance.rs`). The
//! production-shaped machinery — the reconnect pool, gradient-put replay,
//! coordinated checkpoint epochs, and `--resume-from` — lives in
//! [`crate::recovery`] and is drilled cross-process by
//! `rust/tests/integration_recovery.rs`.
//!
//! Paper policies implemented here and exercised by the integration tests
//! and `examples/fault_tolerance.rs`:
//! * **data loader** — stateless here (synthetic stream): restart resumes.
//! * **embedding PS** — process-level failure re-attaches the shared-memory
//!   LRU (modeled as an in-RAM snapshot) or reloads the periodic checkpoint;
//!   a few lost `put`s are tolerated.
//! * **embedding worker** — *reassignment*: workers are parameter-stateless
//!   (the parameters live in the PS; the loader streams are deterministic),
//!   so a dead worker's NN ranks move to a survivor chosen by
//!   [`crate::worker::elastic_assign`], which re-registers the in-flight
//!   samples by re-drawing the identical batches — no update is lost. The
//!   cross-process version is the trainer's `--ew-failover` elastic tier
//!   ([`crate::service::RemoteEmbTier`]); a worker that abandons its buffer
//!   without an adopter only loses the in-flight updates, which Theorem 1's
//!   bounded-staleness analysis tolerates.
//! * **NN worker** — any drop of dense synchronization is fatal for
//!   convergence, so all replicas reload the latest dense checkpoint.

use std::sync::{Arc, Mutex};

use crate::embedding::EmbeddingPs;

/// What to break, when.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// (step, ps node) — process-level PS failure at that step.
    pub kill_ps_node: Option<(usize, usize)>,
    /// If true the PS failure also loses shared memory (forces checkpoint
    /// restore instead of shared-memory re-attach).
    pub lose_shared_memory: bool,
    /// (step, worker idx) — embedding worker failure. The dead worker's
    /// ranks are reassigned to a survivor, which re-draws the in-flight
    /// batches from its deterministic streams (elastic membership); with no
    /// survivor the buffer is abandoned and those updates are lost.
    pub kill_emb_worker: Option<(usize, usize)>,
    /// step — NN worker failure (dense params reload from checkpoint).
    pub kill_nn_worker: Option<usize>,
    /// Checkpoint cadence in steps (0 = never).
    pub checkpoint_every: usize,
}

/// In-RAM stand-in for the PS's shared-memory segment + periodic checkpoint.
pub struct PsBackup {
    /// Last periodic checkpoint (per node, per shard).
    checkpoints: Mutex<Vec<Option<Vec<Vec<u8>>>>>,
    /// "Shared memory": survives process-level failures unless
    /// `lose_shared_memory` is injected.
    shared: Mutex<Vec<Option<Vec<Vec<u8>>>>>,
}

impl PsBackup {
    pub fn new(n_nodes: usize) -> Self {
        Self {
            checkpoints: Mutex::new(vec![None; n_nodes]),
            shared: Mutex::new(vec![None; n_nodes]),
        }
    }

    /// Periodic checkpoint of every node (paper: "periodically save the
    /// in-memory copy of the embedding parameter shard").
    pub fn checkpoint(&self, ps: &EmbeddingPs) -> anyhow::Result<()> {
        let mut cks = self.checkpoints.lock().unwrap();
        for node in 0..ps.n_nodes() {
            cks[node] = Some(ps.snapshot_node(node)?);
        }
        Ok(())
    }

    /// Continuously mirror a node into "shared memory" (called right before
    /// a failure is injected — in a real deployment the LRU lives in shm at
    /// all times, so the mirror is implicit).
    pub fn mirror_shared(&self, ps: &EmbeddingPs, node: usize) -> anyhow::Result<()> {
        self.shared.lock().unwrap()[node] = Some(ps.snapshot_node(node)?);
        Ok(())
    }

    /// Recover a failed node: re-attach shared memory if available, else
    /// fall back to the checkpoint. Returns which path was used.
    pub fn recover(&self, ps: &EmbeddingPs, node: usize, shared_ok: bool) -> anyhow::Result<&'static str> {
        if shared_ok {
            if let Some(snap) = self.shared.lock().unwrap()[node].as_ref() {
                ps.restore_node(node, snap)?;
                return Ok("shared-memory");
            }
        }
        if let Some(snap) = self.checkpoints.lock().unwrap()[node].as_ref() {
            ps.restore_node(node, snap)?;
            return Ok("checkpoint");
        }
        anyhow::bail!("no recovery source for PS node {node}")
    }
}

/// Dense-parameter checkpoint slot shared by the NN workers.
#[derive(Default)]
pub struct DenseBackup {
    params: Mutex<Option<(u64, Vec<f32>)>>,
}

impl DenseBackup {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    pub fn save(&self, step: u64, params: &[f32]) {
        *self.params.lock().unwrap() = Some((step, params.to_vec()));
    }

    /// Latest (step, params) checkpoint.
    pub fn load(&self) -> Option<(u64, Vec<f32>)> {
        self.params.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};

    fn ps() -> EmbeddingPs {
        let cfg = EmbeddingConfig {
            rows_per_group: 1 << 20,
            shard_capacity: 128,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        EmbeddingPs::new(&cfg, 4, 3)
    }

    fn touch(ps: &EmbeddingPs, n: u64) -> Vec<f32> {
        let keys: Vec<(u32, u64)> = (0..n).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![1.0; keys.len() * 4]);
        let mut out = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut out);
        out
    }

    #[test]
    fn shared_memory_recovery_is_lossless() {
        let ps = ps();
        let backup = PsBackup::new(2);
        let want = touch(&ps, 40);
        backup.mirror_shared(&ps, 0).unwrap();
        backup.mirror_shared(&ps, 1).unwrap();
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        assert_eq!(backup.recover(&ps, 0, true).unwrap(), "shared-memory");
        assert_eq!(backup.recover(&ps, 1, true).unwrap(), "shared-memory");
        let keys: Vec<(u32, u64)> = (0..40).map(|i| (0, i)).collect();
        let mut got = vec![0.0; 160];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn checkpoint_recovery_loses_post_checkpoint_updates_only() {
        let ps = ps();
        let backup = PsBackup::new(2);
        let at_ckpt = touch(&ps, 20);
        backup.checkpoint(&ps).unwrap();
        let _later = touch(&ps, 20); // extra updates after the checkpoint
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        assert_eq!(backup.recover(&ps, 0, false).unwrap(), "checkpoint");
        assert_eq!(backup.recover(&ps, 1, false).unwrap(), "checkpoint");
        let keys: Vec<(u32, u64)> = (0..20).map(|i| (0, i)).collect();
        let mut got = vec![0.0; 80];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, at_ckpt, "state rolls back to the checkpoint");
    }

    #[test]
    fn recovery_without_sources_errors() {
        let ps = ps();
        let backup = PsBackup::new(2);
        assert!(backup.recover(&ps, 0, true).is_err());
    }

    #[test]
    fn dead_workers_ranks_are_adopted_without_losing_updates() {
        use crate::comm::NetSim;
        use crate::config::{ModelConfig, NetModelConfig, Pooling};
        use crate::data::SyntheticDataset;
        use crate::worker::{elastic_assign, EmbeddingWorker};

        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let dataset = SyntheticDataset::new(&model, 200, 1.05, 7);
        let mut rng = dataset.train_rng(0);
        let batch = dataset.batch(&mut rng, 8);
        let grads = vec![0.5f32; 8 * model.emb_dim()];

        // Reference: one healthy worker registers the batch and applies its
        // gradients.
        let ps_ref = Arc::new(ps());
        let healthy =
            EmbeddingWorker::new(0, ps_ref.clone(), &model, net.clone(), false);
        let sids = healthy.register(batch.ids.clone());
        healthy.pull(&sids).unwrap();
        healthy.push_grads(&sids, &grads).unwrap();
        let (want, _) = healthy.lookup_direct(&batch).unwrap();

        // Elastic run: two workers share one PS (same cfg + seed as the
        // reference, so initialization matches). Worker 0 registers the
        // batch and dies before its gradients land.
        let ps_shared = Arc::new(ps());
        let w0 = EmbeddingWorker::new(0, ps_shared.clone(), &model, net.clone(), false);
        let w1 = EmbeddingWorker::new(1, ps_shared.clone(), &model, net, false);
        let sids0 = w0.register(batch.ids.clone());
        w0.pull(&sids0).unwrap();
        w0.abandon_buffer();
        assert_eq!(w0.buffered(), 0, "the dead worker's buffer is gone");

        // Reassignment: the survivor adopts rank 0's stream. Workers are
        // parameter-stateless, so re-registering the same (deterministic)
        // batch and re-pushing the held gradients loses nothing.
        let adopter = elastic_assign(0, 2, &[true, false]).unwrap();
        assert_eq!(adopter, 1, "linear probing past dead worker 0 lands on 1");
        let sids1 = w1.register(batch.ids.clone());
        w1.push_grads(&sids1, &grads).unwrap();

        let (got, _) = w1.lookup_direct(&batch).unwrap();
        assert_eq!(got, want, "adoption must reproduce the unkilled run exactly");
    }

    #[test]
    fn dense_backup_roundtrip() {
        let b = DenseBackup::new();
        assert!(b.load().is_none());
        b.save(10, &[1.0, 2.0]);
        assert_eq!(b.load().unwrap(), (10, vec![1.0, 2.0]));
        b.save(20, &[3.0]);
        assert_eq!(b.load().unwrap().0, 20);
    }
}
