//! Minimal property-based testing (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs from
//! `gen`; on failure it reports the failing case index and re-derivable seed,
//! and attempts simple size-based shrinking when the generator supports it
//! via [`Shrink`].

use super::rng::Rng;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            Vec::new()
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        // Shrink one element.
        for (i, alt) in self[0].shrink().into_iter().enumerate().take(2) {
            let mut v = self.clone();
            let idx = i.min(v.len() - 1);
            v[idx] = alt;
            out.push(v);
        }
        out
    }
}

/// Run a property over `cases` random inputs. Panics with diagnostics on the
/// first failing input (after shrinking).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Clone + std::fmt::Debug, P: Fn(&T) -> bool>(start: T, prop: &P) -> T {
    let mut current = start;
    'outer: for _ in 0..64 {
        for cand in current.shrink() {
            if !prop(&cand) {
                current = cand;
                continue 'outer;
            }
        }
        break;
    }
    current
}

/// Convenience generator: vector of uniform f32 in [-scale, scale].
pub fn gen_f32_vec(len_max: usize, scale: f32) -> impl FnMut(&mut Rng) -> Vec<f32> {
    move |rng| {
        let len = rng.below(len_max as u64 + 1) as usize;
        (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, |rng| rng.below(1000), |x| *x < 1000);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 2000, |rng| rng.below(1000), |x| *x < 500);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: x < 500. Failing inputs are >= 500; shrinking halves
        // toward the boundary — the minimal example must still fail.
        let minimal = shrink_loop(997u64, &|x: &u64| *x < 500);
        assert!(minimal >= 500 && minimal <= 997);
        assert!(minimal < 997);
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let mut gen = gen_f32_vec(16, 2.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = gen(&mut rng);
            assert!(v.len() <= 16);
            assert!(v.iter().all(|x| x.abs() <= 2.0));
        }
    }
}
