//! Minimal property-based testing (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs from
//! `gen`; on failure it reports the failing case index and re-derivable seed,
//! and attempts simple size-based shrinking when the generator supports it
//! via [`Shrink`].

use super::rng::Rng;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as u32).collect()
    }
}

impl Shrink for u16 {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as u16).collect()
    }
}

impl Shrink for u8 {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as u8).collect()
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone, C: Shrink + Clone> Shrink for (A, B, C) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone(), self.2.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b, self.2.clone()));
        }
        for c in self.2.shrink() {
            out.push((self.0.clone(), self.1.clone(), c));
        }
        out
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            Vec::new()
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        // Shrink one element.
        for (i, alt) in self[0].shrink().into_iter().enumerate().take(2) {
            let mut v = self.clone();
            let idx = i.min(v.len() - 1);
            v[idx] = alt;
            out.push(v);
        }
        out
    }
}

/// Run a property over `cases` random inputs. Panics with diagnostics on the
/// first failing input (after shrinking).
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property failed (seed={seed}, case={case}); minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Clone + std::fmt::Debug, P: Fn(&T) -> bool>(start: T, prop: &P) -> T {
    let mut current = start;
    'outer: for _ in 0..64 {
        for cand in current.shrink() {
            if !prop(&cand) {
                current = cand;
                continue 'outer;
            }
        }
        break;
    }
    current
}

/// Convenience generator: vector of uniform f32 in [-scale, scale].
pub fn gen_f32_vec(len_max: usize, scale: f32) -> impl FnMut(&mut Rng) -> Vec<f32> {
    move |rng| {
        let len = rng.below(len_max as u64 + 1) as usize;
        (0..len).map(|_| (rng.f32() * 2.0 - 1.0) * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 200, |rng| rng.below(1000), |x| *x < 1000);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 2000, |rng| rng.below(1000), |x| *x < 500);
    }

    #[test]
    fn shrinking_finds_small_counterexample() {
        // Property: x < 500. Failing inputs are >= 500; shrinking halves
        // toward the boundary — the minimal example must still fail.
        let minimal = shrink_loop(997u64, &|x: &u64| *x < 500);
        assert!(minimal >= 500 && minimal <= 997);
        assert!(minimal < 997);
    }

    #[test]
    fn tuple_shrinking_varies_one_component_at_a_time() {
        let cands = (4u64, 6u64).shrink();
        assert!(!cands.is_empty());
        for (a, b) in &cands {
            // Exactly one component shrank; the other is untouched.
            assert!((*a == 4) != (*b == 6), "candidate ({a}, {b})");
        }
        let minimal = shrink_loop((997u64, 3u64), &|t: &(u64, u64)| t.0 < 500);
        assert!(minimal.0 >= 500 && minimal.1 <= 3);
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let mut gen = gen_f32_vec(16, 2.0);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = gen(&mut rng);
            assert!(v.len() <= 16);
            assert!(v.iter().all(|x| x.abs() <= 2.0));
        }
    }
}
