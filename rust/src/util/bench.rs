//! Mini bench harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` target uses `harness = false` and drives this:
//! warmup, timed iterations, mean/p50/p95 reporting, and aligned table
//! printing so every paper table/figure bench emits the same row format.

use std::time::Instant;

use super::hist::Histogram;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    /// Optional user-defined throughput metric (items/sec).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Simple timed-loop bench runner.
pub struct Bench {
    warmup_iters: u64,
    measure_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, measure_iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup_iters: u64, measure_iters: u64) -> Self {
        Self { warmup_iters, measure_iters }
    }

    /// Time `f` (one call = one iteration). `items_per_iter` computes
    /// a throughput column when `Some`.
    pub fn run<F: FnMut()>(&self, name: &str, items_per_iter: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut hist = Histogram::new();
        let mut total_ns = 0u64;
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            let ns = t0.elapsed().as_nanos() as u64;
            hist.record(ns);
            total_ns += ns;
        }
        let mean_ns = total_ns as f64 / self.measure_iters as f64;
        BenchResult {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns,
            p50_ns: hist.percentile(50.0),
            p95_ns: hist.percentile(95.0),
            throughput: items_per_iter.map(|items| items / (mean_ns / 1e9)),
        }
    }
}

/// Print an aligned table of results (used by every bench target).
pub fn print_table(title: &str, rows: &[BenchResult]) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>14}",
        "case", "iters", "mean(ms)", "p95(ms)", "items/s"
    );
    for r in rows {
        println!(
            "{:<44} {:>10} {:>12.3} {:>12.3} {:>14}",
            r.name,
            r.iters,
            r.mean_ns / 1e6,
            r.p95_ns as f64 / 1e6,
            r.throughput.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Measure wall time of a single closure call in seconds.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_and_reports() {
        let b = Bench::new(1, 5);
        let mut counter = 0u64;
        let r = b.run("spin", Some(100.0), || {
            for _ in 0..10_000 {
                counter = counter.wrapping_add(1);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput.unwrap() > 0.0);
        assert!(counter > 0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
