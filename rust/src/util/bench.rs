//! Mini bench harness (criterion is unavailable offline).
//!
//! Each `benches/*.rs` target uses `harness = false` and drives this:
//! warmup, timed iterations, mean/p50/p95 reporting, and aligned table
//! printing so every paper table/figure bench emits the same row format.

use std::time::Instant;

use super::hist::Histogram;

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    /// Optional user-defined throughput metric (items/sec).
    pub throughput: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Simple timed-loop bench runner.
pub struct Bench {
    warmup_iters: u64,
    measure_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup_iters: 3, measure_iters: 10 }
    }
}

impl Bench {
    pub fn new(warmup_iters: u64, measure_iters: u64) -> Self {
        Self { warmup_iters, measure_iters }
    }

    /// Time `f` (one call = one iteration). `items_per_iter` computes
    /// a throughput column when `Some`.
    pub fn run<F: FnMut()>(&self, name: &str, items_per_iter: Option<f64>, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut hist = Histogram::new();
        let mut total_ns = 0u64;
        for _ in 0..self.measure_iters {
            let t0 = Instant::now();
            f();
            let ns = t0.elapsed().as_nanos() as u64;
            hist.record(ns);
            total_ns += ns;
        }
        let mean_ns = total_ns as f64 / self.measure_iters as f64;
        BenchResult {
            name: name.to_string(),
            iters: self.measure_iters,
            mean_ns,
            p50_ns: hist.percentile(50.0),
            p95_ns: hist.percentile(95.0),
            throughput: items_per_iter.map(|items| items / (mean_ns / 1e9)),
        }
    }
}

/// Escape a string for inclusion in a JSON string literal.
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Serialize bench results as machine-readable JSON (hand-rolled — serde is
/// unavailable offline). Schema:
/// `{"bench": NAME, "results": [{"name", "iters", "mean_ns", "p50_ns",
/// "p95_ns", "throughput"}...]}` — the shape CI uploads to seed the perf
/// trajectory.
pub fn write_json(
    path: &std::path::Path,
    bench: &str,
    rows: &[BenchResult],
) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(bench)));
    out.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let throughput = match r.throughput {
            Some(t) if t.is_finite() => format!("{t:.3}"),
            _ => "null".to_string(),
        };
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {:.1}, \
             \"p50_ns\": {}, \"p95_ns\": {}, \"throughput\": {}}}{}\n",
            json_escape(&r.name),
            r.iters,
            r.mean_ns,
            r.p50_ns,
            r.p95_ns,
            throughput,
            if i + 1 == rows.len() { "" } else { "," },
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// Emit `BENCH_<name>.json` into the directory named by the
/// `BENCH_JSON_DIR` env var (no-op when unset) — how CI collects
/// machine-readable bench output without changing local runs.
pub fn emit_json(name: &str, rows: &[BenchResult]) {
    let Ok(dir) = std::env::var("BENCH_JSON_DIR") else { return };
    if dir.is_empty() {
        return;
    }
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    match write_json(&path, name, rows) {
        Ok(()) => println!("bench json: wrote {}", path.display()),
        Err(e) => eprintln!("bench json: could not write {}: {e}", path.display()),
    }
}

/// [`print_table`] + [`emit_json`] in one call — the standard tail of a
/// bench target (`file_stem` names the JSON artifact).
pub fn print_and_emit(title: &str, file_stem: &str, rows: &[BenchResult]) {
    print_table(title, rows);
    emit_json(file_stem, rows);
}

/// Print an aligned table of results (used by every bench target).
pub fn print_table(title: &str, rows: &[BenchResult]) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>14}",
        "case", "iters", "mean(ms)", "p95(ms)", "items/s"
    );
    for r in rows {
        println!(
            "{:<44} {:>10} {:>12.3} {:>12.3} {:>14}",
            r.name,
            r.iters,
            r.mean_ns / 1e6,
            r.p95_ns as f64 / 1e6,
            r.throughput.map(|t| format!("{t:.0}")).unwrap_or_else(|| "-".into()),
        );
    }
}

/// Measure wall time of a single closure call in seconds.
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_and_reports() {
        let b = Bench::new(1, 5);
        let mut counter = 0u64;
        let r = b.run("spin", Some(100.0), || {
            for _ in 0..10_000 {
                counter = counter.wrapping_add(1);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput.unwrap() > 0.0);
        assert!(counter > 0);
    }

    #[test]
    fn write_json_emits_parseable_shape() {
        let rows = vec![
            BenchResult {
                name: "case \"a\"".into(),
                iters: 3,
                mean_ns: 1234.5,
                p50_ns: 1200,
                p95_ns: 1300,
                throughput: Some(1e6),
            },
            BenchResult {
                name: "case_b".into(),
                iters: 3,
                mean_ns: 10.0,
                p50_ns: 10,
                p95_ns: 10,
                throughput: None,
            },
        ];
        let path = std::env::temp_dir()
            .join(format!("persia_bench_json_{}", std::process::id()))
            .join("BENCH_test.json");
        write_json(&path, "test", &rows).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"bench\": \"test\""), "{body}");
        assert!(body.contains("case \\\"a\\\""), "escaping broken: {body}");
        assert!(body.contains("\"throughput\": null"), "{body}");
        // Balanced braces/brackets — the cheap structural sanity check.
        assert_eq!(body.matches('{').count(), body.matches('}').count());
        assert_eq!(body.matches('[').count(), body.matches(']').count());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
