//! Zipf-distributed sampling over huge id spaces.
//!
//! Production recommender traffic is heavily skewed ("the access of training
//! data can irregularly lean towards a particular embedding group", paper
//! §4.2.3) — a Zipf law over item/user ids is the standard model. The skew
//! exponent also controls the paper's α (max per-sample ID frequency) in the
//! Theorem-1 staleness ablation.
//!
//! For the virtualized 100-trillion-parameter tables the id space is far too
//! large to precompute a CDF, so we use the classic two-region rejection
//! sampler (Devroye) that needs O(1) memory for any `n`.

use super::rng::Rng;

/// Zipf(α) sampler over `{0, 1, .., n-1}` (rank 1 = id 0 is the hottest).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: u64,
    exponent: f64,
    // Precomputed constants for the rejection sampler.
    h_x1: f64,
    h_n: f64,
}

impl Zipf {
    /// `n`: id-space size; `exponent`: skew (0 = uniform, ~1.05 typical CTR).
    pub fn new(n: u64, exponent: f64) -> Self {
        assert!(n >= 1);
        assert!(exponent >= 0.0);
        let h = |x: f64| -> f64 {
            if (exponent - 1.0).abs() < 1e-12 {
                (x).ln()
            } else {
                (x.powf(1.0 - exponent) - 1.0) / (1.0 - exponent)
            }
        };
        let h_x1 = h(1.5) - 1.0f64;
        let h_n = h(n as f64 + 0.5);
        Zipf { n, exponent, h_x1, h_n }
    }

    /// The skew exponent this distribution was built with.
    pub fn exponent(&self) -> f64 {
        self.exponent
    }

    fn h_inv_static(exponent: f64, x: f64) -> f64 {
        if (exponent - 1.0).abs() < 1e-12 {
            x.exp()
        } else {
            (1.0 + x * (1.0 - exponent)).powf(1.0 / (1.0 - exponent))
        }
    }

    fn h_inv(&self, x: f64) -> f64 {
        Self::h_inv_static(self.exponent, x)
    }

    /// Draw one id in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        if self.exponent < 1e-9 {
            return rng.below(self.n);
        }
        loop {
            let u = self.h_x1 + rng.f64() * (self.h_n - self.h_x1);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(self.n as f64);
            let h_k = if (self.exponent - 1.0).abs() < 1e-12 {
                (k + 0.5).ln() - (k - 0.5).ln()
            } else {
                ((k + 0.5).powf(1.0 - self.exponent) - (k - 0.5).powf(1.0 - self.exponent))
                    / (1.0 - self.exponent)
            };
            let ratio = h_k / k.powf(-self.exponent);
            if rng.f64() * ratio <= 1.0 {
                return k as u64 - 1;
            }
        }
    }

    /// Analytic probability that a sample hits rank-1 (the hottest id); an
    /// estimate of the paper's α when each sample carries one id per group.
    pub fn top_probability(&self) -> f64 {
        // p(k) ∝ k^-e; approximate the normalizer with the integral.
        let e = self.exponent;
        if e < 1e-9 {
            return 1.0 / self.n as f64;
        }
        let norm: f64 = (1..=self.n.min(10_000))
            .map(|k| (k as f64).powf(-e))
            .sum::<f64>()
            + if self.n > 10_000 {
                let a = 10_000f64;
                let b = self.n as f64;
                if (e - 1.0).abs() < 1e-12 {
                    (b / a).ln()
                } else {
                    (b.powf(1.0 - e) - a.powf(1.0 - e)) / (1.0 - e)
                }
            } else {
                0.0
            };
        1.0 / norm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_when_exponent_zero() {
        let z = Zipf::new(100, 0.0);
        let mut rng = Rng::new(1);
        let mut counts = [0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap() as f64;
        assert!(max / min < 1.6, "max={max} min={min}");
    }

    #[test]
    fn skewed_prefers_low_ranks() {
        let z = Zipf::new(1_000_000, 1.05);
        let mut rng = Rng::new(2);
        let hits_top100 = (0..20_000)
            .filter(|_| z.sample(&mut rng) < 100)
            .count();
        // Under uniform this would be ~2; under Zipf(1.05) a large fraction.
        assert!(hits_top100 > 2_000, "hits={hits_top100}");
    }

    #[test]
    fn samples_within_range_even_for_huge_n() {
        let n = 781_000_000_000u64; // 100T params / dim 128
        let z = Zipf::new(n, 1.05);
        let mut rng = Rng::new(3);
        for _ in 0..2_000 {
            assert!(z.sample(&mut rng) < n);
        }
    }

    #[test]
    fn empirical_frequency_matches_power_law() {
        let z = Zipf::new(10_000, 1.2);
        let mut rng = Rng::new(4);
        let n = 200_000;
        let mut c1 = 0usize;
        let mut c2 = 0usize;
        for _ in 0..n {
            match z.sample(&mut rng) {
                0 => c1 += 1,
                1 => c2 += 1,
                _ => {}
            }
        }
        let ratio = c1 as f64 / c2 as f64;
        // p(1)/p(2) = 2^1.2 ≈ 2.3
        assert!((ratio - 2.3).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn top_probability_decreases_with_n() {
        let a = Zipf::new(1_000, 1.05).top_probability();
        let b = Zipf::new(1_000_000, 1.05).top_probability();
        assert!(a > b && b > 0.0);
    }
}
