//! Offline-friendly utilities: seeded RNG, Zipf sampling, property testing,
//! histograms, CSV emission, and a tiny bench harness.
//!
//! The build image vendors only the `xla` crate's dependency closure, so the
//! usual ecosystem crates (rand/proptest/criterion/serde) are unavailable;
//! these modules provide the minimal equivalents the rest of the system needs.

pub mod bench;
pub mod csv;
pub mod hist;
pub mod quickcheck;
pub mod rng;
pub mod sync;
pub mod zipf;

pub use bench::Bench;
pub use hist::Histogram;
pub use rng::Rng;
pub use sync::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};
pub use zipf::Zipf;
