//! Poison-tolerant locking.
//!
//! The service/recovery layer shares mutexes (connection-pool slots, gossip
//! replica slots, replay rings) across many worker threads. A panic in one
//! connection worker used to poison those mutexes, turning every later
//! `lock().unwrap()` into a panic cascade — the exact opposite of the
//! recovery layer's job. The shared state behind these locks is always left
//! consistent at panic sites (plain `Vec`/`HashMap` writes with no
//! multi-step invariants), so taking the inner guard is sound.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Use this instead of `lock().unwrap()` wherever one panicking thread must
/// not take down every other user of the shared state (pool slots, gossip
/// slots, replay caches).
pub fn lock_unpoisoned<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`lock_unpoisoned`] for the read side of an [`RwLock`] (the resharding
/// ownership/gate state shared by every PS connection worker).
pub fn read_unpoisoned<T: ?Sized>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`lock_unpoisoned`] for the write side of an [`RwLock`].
pub fn write_unpoisoned<T: ?Sized>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned(), "the panic must have poisoned the mutex");
        // A plain lock().unwrap() would panic here; the recovering lock
        // hands back the guard and the state is still usable.
        let mut g = lock_unpoisoned(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*lock_unpoisoned(&m), 8);
    }
}
