//! Latency/size histogram with percentile queries.
//!
//! Log-bucketed (HdrHistogram-style, base-2 with 16 sub-buckets) so recording
//! is allocation-free and O(1) — safe to call on the training hot path.

/// Log-bucketed histogram of non-negative u64 values (e.g. nanoseconds).
#[derive(Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: u64,
    max: u64,
    min: u64,
}

const SUB: usize = 16;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64 * SUB], count: 0, sum: 0, max: 0, min: u64::MAX }
    }

    #[inline]
    fn index(v: u64) -> usize {
        if v < SUB as u64 {
            return v as usize;
        }
        let exp = 63 - v.leading_zeros() as usize;
        let mantissa = (v >> (exp.saturating_sub(4))) as usize & (SUB - 1);
        ((exp - 3) * SUB + mantissa).min(64 * SUB - 1)
    }

    #[inline]
    fn bucket_value(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let exp = idx / SUB + 3;
        let mantissa = idx % SUB;
        (1u64 << exp) | ((mantissa as u64) << (exp - 4))
    }

    /// Record one observation.
    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
        self.min = self.min.min(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn max(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.max
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Approximate percentile (q in [0, 100]).
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
        self.min = self.min.min(other.min);
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1} p50={} p95={} p99={} max={}",
            self.count,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(99.0), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn small_values_exact() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 3, 3, 10] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 10);
        assert_eq!(h.percentile(50.0), 3);
    }

    #[test]
    fn percentiles_monotone_and_bounded() {
        let mut h = Histogram::new();
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..10_000 {
            h.record(rng.below(1_000_000));
        }
        let p50 = h.percentile(50.0);
        let p95 = h.percentile(95.0);
        let p99 = h.percentile(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 <= h.max());
        // Uniform: p50 about 500k within log-bucket error.
        assert!((p50 as f64 - 500_000.0).abs() / 500_000.0 < 0.15, "p50={p50}");
    }

    #[test]
    fn merge_equals_union() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut u = Histogram::new();
        let mut rng = crate::util::Rng::new(8);
        for i in 0..1000 {
            let v = rng.below(10_000);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
            u.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), u.count());
        assert_eq!(a.percentile(90.0), u.percentile(90.0));
        assert_eq!(a.max(), u.max());
    }

    #[test]
    fn large_values_within_bucket_error() {
        let mut h = Histogram::new();
        h.record(1_000_000_000);
        let p = h.percentile(50.0);
        let err = (p as f64 - 1e9).abs() / 1e9;
        assert!(err < 0.07, "p={p}");
    }
}
