//! Seeded PCG32 pseudo-random number generator.
//!
//! Deterministic, fast, and dependency-free. Every stochastic component in
//! the system (data synthesis, shard shuffling, failure injection, property
//! tests) takes an explicit seed so experiments are exactly reproducible.

/// PCG-XSH-RR 64/32 (Melissa O'Neill's PCG family, minimal variant).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Rng {
    /// Create a generator from a seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create a generator with an explicit stream selector; distinct streams
    /// with the same seed are independent (used to decorrelate workers).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Next uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Simple modulo with 64-bit draw; bias < 2^-32 for bounds < 2^32 and
        // acceptable for simulation workloads (ids up to 781G << 2^64).
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Rng::with_stream(7, 1);
        let mut b = Rng::with_stream(7, 2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(9);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64 + 5] {
            for _ in 0..200 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
