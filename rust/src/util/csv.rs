//! Tiny CSV writer for experiment outputs (Fig-6/7/8/9 series).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    columns: usize,
}

impl CsvWriter {
    /// Create/truncate `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", header.join(","))?;
        Ok(Self { out, columns: header.len() })
    }

    /// Write one data row; panics if the arity differs from the header.
    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.columns, "csv row arity mismatch");
        writeln!(self.out, "{}", values.join(","))
    }

    /// Convenience: format any Display values.
    pub fn rowf(&mut self, values: &[&dyn std::fmt::Display]) -> std::io::Result<()> {
        let vs: Vec<String> = values.iter().map(|v| v.to_string()).collect();
        self.row(&vs)
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let path = std::env::temp_dir().join("persia_csv_test.csv");
        {
            let mut w = CsvWriter::create(&path, &["step", "loss"]).unwrap();
            w.rowf(&[&1, &0.5]).unwrap();
            w.rowf(&[&2, &0.25]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "step,loss\n1,0.5\n2,0.25\n");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let path = std::env::temp_dir().join("persia_csv_test2.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}
