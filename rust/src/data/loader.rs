//! Streaming data loader (paper Fig. 4's "data loader" component).
//!
//! A background thread generates the online sample stream and feeds a bounded
//! channel — the backpressure boundary between ingestion and the workers. In
//! the paper the loader fronts Hadoop/Kafka; here it fronts the synthetic
//! generator (same interface, DESIGN.md substitutions). Fault tolerance per
//! §4.2.4: the loader has no recovery state of its own — restarting it simply
//! resumes the stream.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::thread::JoinHandle;

use super::sample::Batch;
use super::synthetic::SyntheticDataset;

/// Handle to a running loader thread delivering batches.
pub struct StreamLoader {
    rx: Receiver<Batch>,
    handle: Option<JoinHandle<()>>,
    stop: SyncSender<()>,
}

impl StreamLoader {
    /// Spawn a loader producing `batch_size`-sized batches; `depth` bounds
    /// the in-flight queue (backpressure). `worker_stream` decorrelates
    /// multiple loaders.
    pub fn spawn(
        dataset: SyntheticDataset,
        batch_size: usize,
        depth: usize,
        worker_stream: u64,
    ) -> Self {
        let (tx, rx) = sync_channel::<Batch>(depth);
        let (stop_tx, stop_rx) = sync_channel::<()>(1);
        let handle = std::thread::Builder::new()
            .name(format!("data-loader-{worker_stream}"))
            .spawn(move || {
                let mut rng = dataset.train_rng(worker_stream);
                loop {
                    if stop_rx.try_recv().is_ok() {
                        return;
                    }
                    let batch = dataset.batch(&mut rng, batch_size);
                    // Block while downstream is full (backpressure), but keep
                    // polling the stop signal so shutdown is prompt.
                    let mut pending = batch;
                    loop {
                        match tx.try_send(pending) {
                            Ok(()) => break,
                            Err(TrySendError::Full(b)) => {
                                pending = b;
                                if stop_rx.try_recv().is_ok() {
                                    return;
                                }
                                std::thread::sleep(std::time::Duration::from_micros(50));
                            }
                            Err(TrySendError::Disconnected(_)) => return,
                        }
                    }
                }
            })
            .expect("spawn data loader");
        Self { rx, handle: Some(handle), stop: stop_tx }
    }

    /// Blocking fetch of the next batch.
    pub fn next_batch(&self) -> Batch {
        self.rx.recv().expect("loader thread alive")
    }

    /// Non-blocking fetch.
    pub fn try_next(&self) -> Option<Batch> {
        self.rx.try_recv().ok()
    }
}

impl Drop for StreamLoader {
    fn drop(&mut self) {
        let _ = self.stop.try_send(());
        // Drain so a blocked sender can observe the stop signal.
        while self.rx.try_recv().is_ok() {}
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Pooling};

    fn dataset() -> SyntheticDataset {
        let m = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        SyntheticDataset::new(&m, 1000, 1.05, 11)
    }

    #[test]
    fn delivers_batches_of_requested_size() {
        let loader = StreamLoader::spawn(dataset(), 16, 4, 0);
        for _ in 0..5 {
            let b = loader.next_batch();
            assert_eq!(b.len(), 16);
            assert_eq!(b.nid_dim, 4);
        }
    }

    #[test]
    fn stream_matches_direct_generation() {
        let ds = dataset();
        let loader = StreamLoader::spawn(ds.clone(), 8, 2, 3);
        let got = loader.next_batch();
        let want = ds.batch(&mut ds.train_rng(3), 8);
        assert_eq!(got.labels, want.labels);
        assert_eq!(got.ids, want.ids);
    }

    #[test]
    fn shutdown_is_prompt() {
        let loader = StreamLoader::spawn(dataset(), 1024, 1, 0);
        let _ = loader.next_batch();
        let t0 = std::time::Instant::now();
        drop(loader);
        assert!(t0.elapsed().as_secs_f64() < 2.0);
    }
}
