//! Sample and batch types (paper §2.1: [x_ID, x_NID, y]).

/// Unique sample id minted by an embedding worker. Per the paper's footnote 3
/// the top byte encodes the rank of the embedding worker that generated it,
/// so any component can route a gradient back to the right buffer.
pub type SampleId = u64;

/// Pack a worker rank + a locally unique counter into a [`SampleId`].
#[inline]
pub fn make_sample_id(worker_rank: u8, counter: u64) -> SampleId {
    ((worker_rank as u64) << 56) | (counter & 0x00ff_ffff_ffff_ffff)
}

/// Extract the embedding-worker rank from a [`SampleId`].
#[inline]
pub fn sample_id_rank(id: SampleId) -> u8 {
    (id >> 56) as u8
}

/// ID-type features: one id list per feature group
/// (`x_ID = [<VideoIDs>, <LocIDs>, ...]` in §2.1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IdFeatures {
    /// `groups[g]` = the ids of feature group `g` present in this sample.
    pub groups: Vec<Vec<u64>>,
}

impl IdFeatures {
    pub fn n_ids(&self) -> usize {
        self.groups.iter().map(|g| g.len()).sum()
    }
}

/// One complete training sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub ids: IdFeatures,
    /// Non-ID dense features.
    pub nid: Vec<f32>,
    /// Binary label (CTR click).
    pub label: f32,
}

/// A mini-batch in struct-of-arrays layout (what the NN worker assembles).
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub ids: Vec<IdFeatures>,
    /// Flattened `[B, nid_dim]` row-major.
    pub nid: Vec<f32>,
    pub labels: Vec<f32>,
    pub nid_dim: usize,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn push(&mut self, s: Sample) {
        debug_assert!(self.nid_dim == 0 || s.nid.len() == self.nid_dim);
        self.nid_dim = s.nid.len();
        self.ids.push(s.ids);
        self.nid.extend_from_slice(&s.nid);
        self.labels.push(s.label);
    }

    /// Every distinct (group, id) pair in the batch, with the sample indices
    /// that reference it — the paper's lossless index compression layout
    /// (§4.2.3): key = unique id, value = uint16 sample indices.
    pub fn unique_ids(&self) -> Vec<((usize, u64), Vec<u16>)> {
        let mut map: std::collections::HashMap<(usize, u64), Vec<u16>> =
            std::collections::HashMap::new();
        for (row, ids) in self.ids.iter().enumerate() {
            for (g, group) in ids.groups.iter().enumerate() {
                for &id in group {
                    map.entry((g, id)).or_default().push(row as u16);
                }
            }
        }
        let mut out: Vec<_> = map.into_iter().collect();
        out.sort_unstable_by_key(|(k, _)| *k);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_id_packs_rank() {
        for rank in [0u8, 1, 17, 255] {
            for counter in [0u64, 1, 123_456_789, 0x00ff_ffff_ffff_ffff] {
                let id = make_sample_id(rank, counter);
                assert_eq!(sample_id_rank(id), rank);
                assert_eq!(id & 0x00ff_ffff_ffff_ffff, counter);
            }
        }
    }

    #[test]
    fn batch_accumulates_rows() {
        let mut b = Batch::default();
        for i in 0..3 {
            b.push(Sample {
                ids: IdFeatures { groups: vec![vec![i], vec![10 + i]] },
                nid: vec![i as f32, 0.0],
                label: (i % 2) as f32,
            });
        }
        assert_eq!(b.len(), 3);
        assert_eq!(b.nid_dim, 2);
        assert_eq!(b.nid.len(), 6);
    }

    #[test]
    fn unique_ids_dedup_and_index() {
        let mut b = Batch::default();
        b.push(Sample { ids: IdFeatures { groups: vec![vec![5, 7]] }, nid: vec![], label: 0.0 });
        b.push(Sample { ids: IdFeatures { groups: vec![vec![5]] }, nid: vec![], label: 1.0 });
        let uniq = b.unique_ids();
        assert_eq!(uniq.len(), 2);
        assert_eq!(uniq[0], ((0, 5), vec![0u16, 1u16]));
        assert_eq!(uniq[1], ((0, 7), vec![0u16]));
    }

    #[test]
    fn n_ids_counts_all_groups() {
        let f = IdFeatures { groups: vec![vec![1, 2], vec![], vec![3]] };
        assert_eq!(f.n_ids(), 3);
    }
}
