//! Training data: sample types, synthetic CTR generation, streaming loader.

pub mod loader;
pub mod sample;
pub mod synthetic;

pub use loader::StreamLoader;
pub use sample::{Batch, IdFeatures, Sample, SampleId};
pub use synthetic::SyntheticDataset;
