//! Synthetic CTR workload with a planted ground-truth model.
//!
//! We cannot ship Taobao/Avazu/Criteo/Kwai data (DESIGN.md substitutions), so
//! each benchmark preset is emulated by a generator that preserves what the
//! experiments actually measure:
//!
//! * **Learnable signal** — every id carries a deterministic latent weight
//!   (hash-derived, so the 781-billion-row virtual tables need no storage);
//!   the label is Bernoulli(sigmoid(sum of latents + beta.nid)). A model that
//!   learns per-id embeddings recovers the latents, so test AUC climbs well
//!   above 0.5 and *degrades under gradient staleness* — the mechanism behind
//!   the paper's sync/async/hybrid AUC gaps (Fig. 7, Table 2).
//! * **Skewed access** — ids are Zipf-distributed, exercising the LRU cache,
//!   the shuffled-uniform partitioning, and setting Theorem 1's α.
//! * **Online stream** — samples are generated on the fly in arrival order
//!   (the paper's data loader does no shuffling, §4.2.4).

use crate::config::ModelConfig;
use crate::util::{Rng, Zipf};

use super::sample::{Batch, IdFeatures, Sample};

/// Deterministic splitmix64 hash (id -> latent weight derivation).
#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Latent ground-truth weight of an id, in [-1, 1].
#[inline]
pub fn id_latent(group: usize, id: u64) -> f32 {
    let h = splitmix64(id ^ ((group as u64) << 48) ^ 0xabcd_ef01);
    ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
}

/// Synthetic dataset bound to a model geometry.
#[derive(Clone)]
pub struct SyntheticDataset {
    pub n_groups: usize,
    pub ids_per_group: usize,
    pub nid_dim: usize,
    pub rows_per_group: u64,
    zipf: Zipf,
    /// Planted dense weights for the Non-ID features.
    beta: Vec<f32>,
    /// Logit sharpness: larger = cleaner labels = higher reachable AUC.
    pub signal_scale: f32,
    seed: u64,
}

impl SyntheticDataset {
    pub fn new(model: &ModelConfig, rows_per_group: u64, zipf_exponent: f64, seed: u64) -> Self {
        let mut rng = Rng::with_stream(seed, 0xbeef);
        let beta = (0..model.nid_dim).map(|_| rng.normal() * 0.5).collect();
        Self {
            n_groups: model.n_groups,
            ids_per_group: model.ids_per_group,
            nid_dim: model.nid_dim,
            rows_per_group,
            zipf: Zipf::new(rows_per_group, zipf_exponent),
            beta,
            signal_scale: 2.0,
            seed,
        }
    }

    /// Digest of every knob that shapes the sampled distribution (feature
    /// geometry, id-space size, Zipf skew, label sharpness, stream seed).
    /// Folded into `Trainer::config_fingerprint` so two `train-worker`
    /// processes sampling different data are rejected at the rendezvous
    /// instead of silently diverging mid-run.
    pub fn numeric_fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for x in [
            self.n_groups as u64,
            self.ids_per_group as u64,
            self.nid_dim as u64,
            self.rows_per_group,
            self.zipf.exponent().to_bits(),
            u64::from(self.signal_scale.to_bits()),
            self.seed,
        ] {
            h ^= x;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Ground-truth logit of a sample (used by tests + the oracle AUC bound).
    pub fn true_logit(&self, ids: &IdFeatures, nid: &[f32]) -> f32 {
        let mut logit = 0.0f32;
        for (g, group) in ids.groups.iter().enumerate() {
            for &id in group {
                logit += id_latent(g, id);
            }
        }
        for (b, x) in self.beta.iter().zip(nid) {
            logit += b * x;
        }
        // Normalize by sqrt(#ids) (random-walk scaling) so the logit variance
        // is O(signal_scale^2) regardless of geometry — keeps the oracle AUC
        // comfortably above chance for every preset.
        logit * self.signal_scale / ((self.n_groups * self.ids_per_group) as f32).sqrt()
    }

    /// Draw one sample using the caller's RNG (stream position = arrival order).
    pub fn sample(&self, rng: &mut Rng) -> Sample {
        let groups: Vec<Vec<u64>> = (0..self.n_groups)
            .map(|_| (0..self.ids_per_group).map(|_| self.zipf.sample(rng)).collect())
            .collect();
        let ids = IdFeatures { groups };
        let nid: Vec<f32> = (0..self.nid_dim).map(|_| rng.normal()).collect();
        let logit = self.true_logit(&ids, &nid);
        let p = 1.0 / (1.0 + (-logit).exp());
        let label = if rng.bernoulli(p as f64) { 1.0 } else { 0.0 };
        Sample { ids, nid, label }
    }

    /// Batch of consecutive stream samples.
    pub fn batch(&self, rng: &mut Rng, b: usize) -> Batch {
        let mut batch = Batch::default();
        for _ in 0..b {
            batch.push(self.sample(rng));
        }
        batch
    }

    /// Deterministic held-out test batch (separate stream from training).
    pub fn test_batch(&self, b: usize) -> Batch {
        let mut rng = Rng::with_stream(self.seed, 0x7e57);
        self.batch(&mut rng, b)
    }

    /// RNG for the training stream of a given worker.
    pub fn train_rng(&self, worker: u64) -> Rng {
        Rng::with_stream(self.seed, 0x1000 + worker)
    }

    /// AUC of the ground-truth model itself on a test batch — the ceiling any
    /// learner can reach (label noise bounds it below 1.0).
    pub fn oracle_auc(&self, b: usize) -> f64 {
        let batch = self.test_batch(b);
        let mut scores = Vec::with_capacity(b);
        for (i, ids) in batch.ids.iter().enumerate() {
            let nid = &batch.nid[i * self.nid_dim..(i + 1) * self.nid_dim];
            scores.push(self.true_logit(ids, nid));
        }
        crate::metrics::auc(&scores, &batch.labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelConfig, Pooling};

    fn model() -> ModelConfig {
        ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 4,
            emb_dim_per_group: 8,
            nid_dim: 8,
            hidden: vec![32, 16],
            ids_per_group: 4,
            pooling: Pooling::Sum,
        }
    }

    #[test]
    fn id_latent_deterministic_and_bounded() {
        for g in 0..4 {
            for id in [0u64, 1, 999_999_999_999] {
                let a = id_latent(g, id);
                assert_eq!(a, id_latent(g, id));
                assert!((-1.0..=1.0).contains(&a));
            }
        }
        assert_ne!(id_latent(0, 5), id_latent(1, 5));
    }

    #[test]
    fn samples_have_model_geometry() {
        let m = model();
        let ds = SyntheticDataset::new(&m, 10_000, 1.05, 7);
        let mut rng = ds.train_rng(0);
        let s = ds.sample(&mut rng);
        assert_eq!(s.ids.groups.len(), 4);
        assert!(s.ids.groups.iter().all(|g| g.len() == 4));
        assert_eq!(s.nid.len(), 8);
        assert!(s.label == 0.0 || s.label == 1.0);
        assert!(s.ids.groups.iter().flatten().all(|&id| id < 10_000));
    }

    #[test]
    fn test_batch_is_deterministic() {
        let m = model();
        let ds = SyntheticDataset::new(&m, 10_000, 1.05, 7);
        let a = ds.test_batch(64);
        let b = ds.test_batch(64);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.ids, b.ids);
    }

    #[test]
    fn labels_correlate_with_true_logit() {
        let m = model();
        let ds = SyntheticDataset::new(&m, 1_000, 1.05, 3);
        let oracle = ds.oracle_auc(4_000);
        // The planted model must be meaningfully learnable.
        assert!(oracle > 0.62, "oracle auc={oracle}");
    }

    #[test]
    fn train_streams_differ_by_worker() {
        let m = model();
        let ds = SyntheticDataset::new(&m, 10_000, 1.05, 7);
        let s0 = ds.batch(&mut ds.train_rng(0), 8);
        let s1 = ds.batch(&mut ds.train_rng(1), 8);
        assert_ne!(s0.ids, s1.ids);
    }
}
