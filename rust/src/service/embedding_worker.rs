//! The embedding-worker tier as a standalone TCP service (paper §4.1's
//! middle tier, deployed as its own OS process).
//!
//! `persia serve-embedding-worker` runs ONE embedding worker per process:
//! it owns the data-loader streams of the NN ranks assigned to it, runs the
//! [`PrefetchPipeline`](crate::worker::PrefetchPipeline) (stage 1 draws
//! samples, stage 2 scatter-gathers deduplicated lookups against the —
//! possibly sharded — embedding PS and assembles activation tensors), and
//! serves assembled batches to NN ranks over TCP, so PS latency hides
//! behind the ranks' dense compute. Gradients flow back asynchronously with
//! the same re-buffer-on-failure semantics
//! [`EmbeddingWorker::push_grads`](crate::worker::EmbeddingWorker::push_grads)
//! has in-process.
//!
//! # Wire protocol
//!
//! Requests/responses are zero-copy wire messages over the shared
//! [`crate::comm::wire`] frames (kinds `0x70xx`, disjoint from the PS's
//! `0x50xx` and the ring's `0x60xx`):
//!
//! | kind         | request sections                  | response sections                        |
//! |--------------|-----------------------------------|------------------------------------------|
//! | `INFO`       | –                                 | u64 fingerprint/geometry/PS deployment/boot nonce |
//! | `NEXT_BATCH` | u64 `[rank, step]`                | u64 `[step, sim]`, u64 sids, f32 nid, f32 labels, u8 flags, activations |
//! | `PUSH_GRADS` | u64 sids, u8 flags, gradients     | u64 `[sim]`                              |
//! | `EVAL`       | u64 `[rows]`                      | u64 `[sim]`, f32 activations             |
//! | `STATS`      | –                                 | u64 worker counters, u64 PS stats        |
//! | `SHUTDOWN`   | –                                 | – (ack)                                  |
//! | `ADOPT_RANK` | u64 `[rank, next_step]`           | u64 `[1]` (ack)                          |
//!
//! `activations`/`gradients` are one raw f32 section, or — when the flags
//! byte carries the compress bit — an fp16 section plus per-sample scales
//! (§4.2.3 lossy value compression with `dim = emb_dim`, numerically
//! identical to the in-process simulated round-trip, now saving real wire
//! bytes). The `PUSH_GRADS` flags byte also carries a *discard* bit: same
//! sids, no gradient payload — the applier's give-up path
//! ([`EmbComm::discard`]).
//!
//! The INFO handshake carries the server's full
//! [`Trainer::config_fingerprint`](crate::hybrid::Trainer::config_fingerprint)
//! plus a digest of its PS deployment, and trainers whose config differs are
//! rejected at connect time — exactly the PS INFO / ring-rendezvous policy.
//! `NEXT_BATCH` must be called strictly in step order per rank; the server
//! keeps a per-rank [`crate::recovery::ReplayRing`] (`--replay-depth` deep,
//! default 4) so a retried request for any of the last served steps (a
//! reconnect that lost responses) is answered from cache, while a step
//! outside the ring is a loud desync error. Successful `PUSH_GRADS` acks
//! ride a `4 × replay-depth` ring (keyed by the batch's never-reused sample
//! ids), so a push retried after a lost ack is answered idempotently
//! instead of failing on its already-released buffer entries. `CKPT` relays
//! the trainer-coordinated checkpoint epoch to the PS deployment this
//! worker fronts (kinds table: `CKPT` = `0x7007`, u64 `[step, mode]`).

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::netsim::Link;
use crate::comm::rpc::{PipelinedClient, RpcClient, RpcServer};
use crate::comm::transport::TcpTransport;
use crate::comm::wire::{WireReader, WireWriter};
use crate::comm::NetSim;
use crate::config::{EmbWorkerConfig, EwFailoverConfig, ServiceConfig};
use crate::data::sample::SampleId;
use crate::embedding::EmbeddingPs;
use crate::hybrid::Trainer;
use crate::recovery::{PooledConn, ReconnectPool, Redial, ReplayRing, RetryPolicy, Unreachable};
use crate::util::lock_unpoisoned;
use crate::worker::{
    elastic_assign, AssignMode, BatchPrep, CacheStats, EmbCache, EmbComm, EmbeddingWorker,
    EwCacheConfig, EwCacheParams, PrefetchPipeline, PreparedBatch, WorkerStats,
};

use super::backend::{PsBackend, PsStats};
use super::server::{accept_loop, boot_nonce, wake_addr};

/// INFO handshake of the embedding-worker service.
pub const KIND_EW_INFO: u32 = 0x7001;
/// Pull the next prepared batch for `(rank, step)`.
pub const KIND_EW_NEXT: u32 = 0x7002;
/// Push (or discard) a served batch's activation gradients.
pub const KIND_EW_PUSH: u32 = 0x7003;
/// Eval-path pooled lookup of the shared held-out test batch.
pub const KIND_EW_EVAL: u32 = 0x7004;
/// Worker + PS statistics.
pub const KIND_EW_STATS: u32 = 0x7005;
/// Graceful shutdown (acked before the server stops accepting).
pub const KIND_EW_SHUTDOWN: u32 = 0x7006;
/// Checkpoint-epoch relay: the trainer (coordinator) asks this worker to
/// drive the two-phase epoch on its PS deployment (`mode` = full) or to
/// just truncate its put replay log at a committed epoch (`mode` = mark).
pub const KIND_EW_CKPT: u32 = 0x7007;
/// Elastic-membership adoption: a trainer whose previous worker died (or
/// whose restarted home worker is taking its ranks back) asks this process
/// to own an NN rank's stream from `next_step` on — the server fast-forwards
/// the rank's loader stream via `BatchPrep::skip_to` and quiesces any stale
/// prefetch pipe (`--ew-failover`).
pub const KIND_EW_ADOPT: u32 = 0x7008;

/// CKPT mode: drive PREPARE/COMMIT across the PS shards, then mark.
pub const EW_CKPT_FULL: u64 = 0;
/// CKPT mode: only truncate this worker's put replay logs at the epoch.
pub const EW_CKPT_MARK: u64 = 1;

/// Flag bit: value payload is fp16 + per-sample scales.
const FLAG_COMPRESS: u8 = 1;
/// Flag bit (PUSH only): discard the sids' buffer entries, no gradients.
const FLAG_DISCARD: u8 = 2;

// ---------------------------------------------------------------------------
// INFO
// ---------------------------------------------------------------------------

/// Everything a trainer needs to verify an embedding-worker process serves
/// *its* run: the server's trainer-config fingerprint (every numeric knob),
/// the batch geometry it will ship, and which PS deployment it talks to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EwInfo {
    /// [`Trainer::config_fingerprint`] of the flags the server was started
    /// with — rank-independent, so it must equal the trainer's own.
    pub fingerprint: u64,
    /// This worker's rank (top byte of the sample ids it mints).
    pub ew_rank: u8,
    /// Full activation width (`n_groups * emb_dim_per_group`).
    pub emb_dim: usize,
    /// Non-ID feature width of served batches.
    pub nid_dim: usize,
    /// Samples per served batch.
    pub batch_size: usize,
    /// In-flight batches per rank (1 = on-demand; forced in deterministic
    /// mode).
    pub pipeline_depth: usize,
    /// PS shard processes behind this worker (0 = worker-private in-process
    /// PS, only sound for single-worker deployments).
    pub ps_processes: usize,
    /// Order-independent digest of the PS shard address list; every worker
    /// of one tier must report the same value or they are not looking up
    /// the same parameters.
    pub ps_sig: u64,
    /// Whether the worker applies lossy fp16 compression on its own PS wire
    /// (changes numerics; parity runs keep it off).
    pub ps_wire_compress: bool,
    /// Per-process random nonce (same policy as the PS INFO handshake): lets
    /// a reconnecting trainer distinguish "same process, transient wire
    /// failure" from "restarted process" — the membership signal elastic
    /// failover and rejoin are built on.
    pub boot_nonce: u64,
    /// Whether this worker keeps a `--ps-replay` gradient-put log. A trainer
    /// must refuse to fail over away from such a worker: the log died with
    /// the process and cannot be handed to the adopter, so a later PS-shard
    /// replay would silently drop the dead worker's puts.
    pub ps_replay: bool,
}

impl EwInfo {
    /// Whether `other` advertises the same logical deployment: every field
    /// except the per-process `boot_nonce` matches. This is the rejoin bar —
    /// a restarted process is the same *member* if its config, geometry, and
    /// PS deployment are unchanged, even though its boot nonce is new.
    pub fn same_deployment(&self, other: &EwInfo) -> bool {
        EwInfo { boot_nonce: 0, ..*self } == EwInfo { boot_nonce: 0, ..*other }
    }
}

/// Digest of a PS deployment: `(shard process count, order-independent
/// address hash)`. `None`/empty means a worker-private in-process PS.
pub fn ps_deployment_sig(remote_ps: Option<&str>) -> (usize, u64) {
    let Some(list) = remote_ps else { return (0, 0) };
    let mut addrs: Vec<&str> =
        list.split(',').map(|s| s.trim()).filter(|s| !s.is_empty()).collect();
    addrs.sort_unstable();
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for a in &addrs {
        for &b in a.as_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (addrs.len(), h)
}

/// Encode an INFO request (empty body).
pub fn encode_ew_info_request() -> Vec<u8> {
    WireWriter::new(KIND_EW_INFO).finish()
}

/// Encode an INFO response.
pub fn encode_ew_info_response(info: &EwInfo) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_INFO);
    w.put_u64(&[
        info.fingerprint,
        u64::from(info.ew_rank),
        info.emb_dim as u64,
        info.nid_dim as u64,
        info.batch_size as u64,
        info.pipeline_depth as u64,
        info.ps_processes as u64,
        info.ps_sig,
        u64::from(info.ps_wire_compress),
        info.boot_nonce,
        u64::from(info.ps_replay),
    ]);
    w.finish()
}

/// Decode an INFO response.
pub fn decode_ew_info_response(msg: &[u8]) -> Result<EwInfo> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_INFO, "expected EW INFO response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 11, "malformed EW INFO response ({} fields)", xs.len());
    let info = EwInfo {
        fingerprint: xs[0],
        ew_rank: xs[1] as u8,
        emb_dim: xs[2] as usize,
        nid_dim: xs[3] as usize,
        batch_size: xs[4] as usize,
        pipeline_depth: xs[5] as usize,
        ps_processes: xs[6] as usize,
        ps_sig: xs[7],
        ps_wire_compress: xs[8] != 0,
        boot_nonce: xs[9],
        ps_replay: xs[10] != 0,
    };
    ensure!(
        info.emb_dim > 0 && info.batch_size > 0 && info.pipeline_depth > 0,
        "EW INFO reports degenerate geometry: {info:?}"
    );
    Ok(info)
}

// ---------------------------------------------------------------------------
// NEXT_BATCH
// ---------------------------------------------------------------------------

/// Encode a NEXT_BATCH request for `(rank, step)`.
pub fn encode_next_request(rank: usize, step: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_NEXT);
    w.put_u64(&[rank as u64, step as u64]);
    w.finish()
}

/// Decode a NEXT_BATCH request into `(rank, step)`.
pub fn decode_next_request(msg: &[u8]) -> Result<(usize, usize)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_NEXT, "expected NEXT_BATCH, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 2, "malformed NEXT_BATCH request");
    Ok((xs[0] as usize, xs[1] as usize))
}

/// Encode a prepared batch. `emb_dim` is the per-sample activation width
/// (the lossy compression's block size); `compress` selects fp16+scales.
pub fn encode_next_response(pb: &PreparedBatch, emb_dim: usize, compress: bool) -> Vec<u8> {
    debug_assert_eq!(pb.emb.len(), pb.sids.len() * emb_dim);
    let mut w = WireWriter::new(KIND_EW_NEXT);
    w.put_u64(&[pb.step as u64, pb.sim_prep.to_bits()]);
    w.put_u64(&pb.sids);
    w.put_f32(&pb.nid);
    w.put_f32(&pb.labels);
    w.put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    if compress {
        let c = CompressedValues::compress(&pb.emb, emb_dim);
        w.put_f16(&c.vals);
        w.put_f32(&c.scales);
    } else {
        w.put_f32(&pb.emb);
    }
    w.finish()
}

/// Decode a served batch (the `ew` field is filled by the caller, which
/// knows which worker process it asked).
pub fn decode_next_response(msg: &[u8], emb_dim: usize, nid_dim: usize) -> Result<PreparedBatch> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_NEXT, "expected NEXT_BATCH response, got kind {}", r.kind());
    let head = r.u64(0)?;
    ensure!(head.len() == 2, "malformed NEXT_BATCH response header");
    let sids = r.u64(1)?;
    let nid = r.f32(2)?;
    let labels = r.f32(3)?;
    let flags = r.u8(4)?;
    ensure!(flags.len() == 1, "malformed NEXT_BATCH flags");
    let emb = if flags[0] & FLAG_COMPRESS != 0 {
        let vals = r.f16(5)?;
        let scales = r.f32(6)?;
        ensure!(
            vals.len() == scales.len() * emb_dim,
            "compressed activation shape mismatch"
        );
        CompressedValues { vals, scales, dim: emb_dim }.decompress()
    } else {
        r.f32(5)?
    };
    ensure!(
        emb.len() == sids.len() * emb_dim
            && nid.len() == sids.len() * nid_dim
            && labels.len() == sids.len(),
        "NEXT_BATCH shape mismatch: {} sids, {} emb, {} nid, {} labels",
        sids.len(),
        emb.len(),
        nid.len(),
        labels.len()
    );
    Ok(PreparedBatch {
        step: head[0] as usize,
        ew: 0,
        sids,
        emb,
        nid,
        labels,
        sim_prep: f64::from_bits(head[1]),
    })
}

// ---------------------------------------------------------------------------
// PUSH_GRADS
// ---------------------------------------------------------------------------

/// Encode a gradient push. `grads` must be `sids.len() * emb_dim` floats.
pub fn encode_push_request(
    sids: &[SampleId],
    grads: &[f32],
    emb_dim: usize,
    compress: bool,
) -> Vec<u8> {
    debug_assert_eq!(grads.len(), sids.len() * emb_dim);
    let mut w = WireWriter::new(KIND_EW_PUSH);
    w.put_u64(sids);
    w.put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    if compress {
        let c = CompressedValues::compress(grads, emb_dim);
        w.put_f16(&c.vals);
        w.put_f32(&c.scales);
    } else {
        w.put_f32(grads);
    }
    w.finish()
}

/// Encode a discard: the applier gave up on these sids (no gradients).
pub fn encode_discard_request(sids: &[SampleId]) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_PUSH);
    w.put_u64(sids);
    w.put_u8(&[FLAG_DISCARD]);
    w.put_f32(&[]);
    w.finish()
}

/// Decode a push request: `(sids, Some(gradients))`, or `(sids, None)` for
/// a discard.
pub fn decode_push_request(
    msg: &[u8],
    emb_dim: usize,
) -> Result<(Vec<SampleId>, Option<Vec<f32>>)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_PUSH, "expected PUSH_GRADS, got kind {}", r.kind());
    let sids = r.u64(0)?;
    let flags = r.u8(1)?;
    ensure!(flags.len() == 1, "malformed PUSH_GRADS flags");
    if flags[0] & FLAG_DISCARD != 0 {
        return Ok((sids, None));
    }
    let grads = if flags[0] & FLAG_COMPRESS != 0 {
        let vals = r.f16(2)?;
        let scales = r.f32(3)?;
        ensure!(vals.len() == scales.len() * emb_dim, "compressed gradient shape mismatch");
        CompressedValues { vals, scales, dim: emb_dim }.decompress()
    } else {
        r.f32(2)?
    };
    ensure!(grads.len() == sids.len() * emb_dim, "PUSH_GRADS shape mismatch");
    Ok((sids, Some(grads)))
}

/// Encode the push ack (simulated seconds of the worker→PS leg).
pub fn encode_push_response(sim: f64) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_PUSH);
    w.put_u64(&[sim.to_bits()]);
    w.finish()
}

/// Decode the push ack.
pub fn decode_push_response(msg: &[u8]) -> Result<f64> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_PUSH, "expected PUSH_GRADS response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed PUSH_GRADS response");
    Ok(f64::from_bits(xs[0]))
}

// ---------------------------------------------------------------------------
// EVAL
// ---------------------------------------------------------------------------

/// Encode an eval-lookup request for the first `rows` test samples.
pub fn encode_eval_request(rows: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_EVAL);
    w.put_u64(&[rows as u64]);
    w.finish()
}

/// Decode an eval-lookup request.
pub fn decode_eval_request(msg: &[u8]) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_EVAL, "expected EVAL, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed EVAL request");
    Ok(xs[0] as usize)
}

/// Encode the eval activations (always raw f32 — the in-process eval path
/// never applies the lossy leg either).
pub fn encode_eval_response(emb: &[f32], sim: f64) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_EVAL);
    w.put_u64(&[sim.to_bits()]);
    w.put_f32(emb);
    w.finish()
}

/// Decode the eval activations.
pub fn decode_eval_response(msg: &[u8]) -> Result<(Vec<f32>, f64)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_EVAL, "expected EVAL response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed EVAL response");
    Ok((r.f32(1)?, f64::from_bits(xs[0])))
}

// ---------------------------------------------------------------------------
// STATS / SHUTDOWN
// ---------------------------------------------------------------------------

/// Encode a STATS request (empty body).
pub fn encode_ew_stats_request() -> Vec<u8> {
    WireWriter::new(KIND_EW_STATS).finish()
}

/// Encode the worker's counters + its PS backend's statistics + the
/// worker-side hot-embedding cache counters (all zeros when the cache is
/// off — the section is always present so the frame stays fixed-shape).
pub fn encode_ew_stats_response(
    buffered: usize,
    w: &WorkerStats,
    ps: &PsStats,
    cache: &CacheStats,
) -> Vec<u8> {
    let mut msg = WireWriter::new(KIND_EW_STATS);
    msg.put_u64(&[
        buffered as u64,
        w.samples_registered,
        w.batches_fetched,
        w.ids_looked_up,
        w.rows_fetched,
        w.batches_flushed,
        w.samples_flushed,
        w.grad_ids,
        w.rows_put,
        w.put_failures,
        w.rebuffered_samples,
    ]);
    msg.put_u64(&[
        ps.total_rows as u64,
        ps.total_evictions,
        ps.imbalance.to_bits(),
        ps.hot_hits,
        ps.cold_hits,
        ps.demotions,
        ps.promotions,
        ps.cold_rows as u64,
    ]);
    msg.put_u64(&[
        cache.hits,
        cache.misses,
        cache.stale_refreshes,
        cache.invalidations,
        cache.updates,
        cache.flushes,
        cache.coalesced,
        cache.evictions,
    ]);
    msg.finish()
}

/// Decode a STATS response into `(buffered, worker stats, PS stats, cache
/// stats)`.
pub fn decode_ew_stats_response(msg: &[u8]) -> Result<(usize, WorkerStats, PsStats, CacheStats)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_STATS, "expected EW STATS response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 11, "malformed EW STATS response");
    let ps = r.u64(1)?;
    ensure!(ps.len() == 8, "malformed EW STATS PS section");
    let cs = r.u64(2)?;
    ensure!(cs.len() == 8, "malformed EW STATS cache section");
    Ok((
        xs[0] as usize,
        WorkerStats {
            samples_registered: xs[1],
            batches_fetched: xs[2],
            ids_looked_up: xs[3],
            rows_fetched: xs[4],
            batches_flushed: xs[5],
            samples_flushed: xs[6],
            grad_ids: xs[7],
            rows_put: xs[8],
            put_failures: xs[9],
            rebuffered_samples: xs[10],
        },
        PsStats {
            total_rows: ps[0] as usize,
            total_evictions: ps[1],
            imbalance: f64::from_bits(ps[2]),
            hot_hits: ps[3],
            cold_hits: ps[4],
            demotions: ps[5],
            promotions: ps[6],
            cold_rows: ps[7] as usize,
        },
        CacheStats {
            hits: cs[0],
            misses: cs[1],
            stale_refreshes: cs[2],
            invalidations: cs[3],
            updates: cs[4],
            flushes: cs[5],
            coalesced: cs[6],
            evictions: cs[7],
        },
    ))
}

/// Encode a SHUTDOWN request (empty body).
pub fn encode_ew_shutdown_request() -> Vec<u8> {
    WireWriter::new(KIND_EW_SHUTDOWN).finish()
}

// ---------------------------------------------------------------------------
// CKPT
// ---------------------------------------------------------------------------

/// Encode a checkpoint-epoch relay request (`mode` is [`EW_CKPT_FULL`] or
/// [`EW_CKPT_MARK`]).
pub fn encode_ew_ckpt_request(step: u64, mode: u64) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_CKPT);
    w.put_u64(&[step, mode]);
    w.finish()
}

/// Decode a checkpoint-epoch relay request into `(step, mode)`.
pub fn decode_ew_ckpt_request(msg: &[u8]) -> Result<(u64, u64)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_CKPT, "expected EW CKPT, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 2, "malformed EW CKPT request");
    Ok((xs[0], xs[1]))
}

/// Encode the checkpoint-epoch relay ack.
pub fn encode_ew_ckpt_response() -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_CKPT);
    w.put_u64(&[1]);
    w.finish()
}

/// Decode the checkpoint-epoch relay ack.
pub fn decode_ew_ckpt_response(msg: &[u8]) -> Result<()> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_CKPT, "expected EW CKPT ack, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1 && xs[0] == 1, "malformed EW CKPT ack");
    Ok(())
}

// ---------------------------------------------------------------------------
// ADOPT_RANK
// ---------------------------------------------------------------------------

/// Encode an ADOPT_RANK request: this server should own `rank`'s stream and
/// serve its next `NEXT_BATCH` at exactly `next_step`.
pub fn encode_ew_adopt_request(rank: usize, next_step: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_ADOPT);
    w.put_u64(&[rank as u64, next_step as u64]);
    w.finish()
}

/// Decode an ADOPT_RANK request into `(rank, next_step)`.
pub fn decode_ew_adopt_request(msg: &[u8]) -> Result<(usize, usize)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_ADOPT, "expected EW ADOPT, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 2, "malformed EW ADOPT request");
    Ok((xs[0] as usize, xs[1] as usize))
}

/// Encode the ADOPT_RANK ack.
pub fn encode_ew_adopt_response() -> Vec<u8> {
    let mut w = WireWriter::new(KIND_EW_ADOPT);
    w.put_u64(&[1]);
    w.finish()
}

/// Decode the ADOPT_RANK ack.
pub fn decode_ew_adopt_response(msg: &[u8]) -> Result<()> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_EW_ADOPT, "expected EW ADOPT ack, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1 && xs[0] == 1, "malformed EW ADOPT ack");
    Ok(())
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Deployment identity of one `serve-embedding-worker` process (everything
/// the INFO handshake advertises beyond the worker's own geometry, plus its
/// local recovery knobs).
#[derive(Clone, Debug)]
pub struct EwServerConfig {
    /// The server's trainer-config fingerprint.
    pub fingerprint: u64,
    /// This process's embedding-worker rank.
    pub ew_rank: u8,
    /// PS shard processes behind this worker (0 = in-process PS).
    pub ps_processes: usize,
    /// Digest of the PS shard address list (see [`ps_deployment_sig`]).
    pub ps_sig: u64,
    /// Lossy compression on the worker's own PS wire.
    pub ps_wire_compress: bool,
    /// Lossy compression on served activations / received gradients
    /// (`train --compress`; part of the fingerprint, so both sides agree).
    pub compress: bool,
    /// Per-rank NEXT_BATCH replay-ring depth (`--replay-depth`; the
    /// PUSH_GRADS ack cache is sized `4 ×` this).
    pub replay_depth: usize,
    /// Checkpoint root for CKPT relays when the worker fronts an in-process
    /// PS (remote shards use their own `--checkpoint-dir` and ignore it).
    pub ckpt_dir: Option<PathBuf>,
    /// Whether the worker's PS backend keeps a `--ps-replay` put log
    /// (advertised in INFO; see [`EwInfo::ps_replay`]).
    pub ps_replay: bool,
}

/// A bound-but-not-yet-serving embedding-worker service.
pub struct EmbeddingWorkerServer {
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
}

impl EmbeddingWorkerServer {
    /// Bind `addr` and register the protocol handlers over `pipeline` (whose
    /// [`BatchPrep`] holds the resident worker and data source) and
    /// `backend` (the worker's PS, for STATS relay).
    pub fn bind(
        pipeline: Arc<PrefetchPipeline>,
        backend: Arc<dyn PsBackend>,
        cfg: EwServerConfig,
        addr: &str,
    ) -> Result<EmbeddingWorkerServer> {
        ensure!(
            pipeline.prep().n_workers() == 1,
            "serve-embedding-worker hosts exactly one resident worker"
        );
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding embedding-worker service on {addr}"))?;
        let local = listener.local_addr()?;
        let mut rpc = RpcServer::new();
        let stop = rpc.stop_flag();

        let prep = pipeline.prep().clone();
        let emb_dim = prep.worker(0).emb_dim();
        let info = EwInfo {
            fingerprint: cfg.fingerprint,
            ew_rank: cfg.ew_rank,
            emb_dim,
            nid_dim: prep.nid_dim(),
            batch_size: prep.batch_size(),
            pipeline_depth: pipeline.depth(),
            ps_processes: cfg.ps_processes,
            ps_sig: cfg.ps_sig,
            ps_wire_compress: cfg.ps_wire_compress,
            boot_nonce: boot_nonce(&listener),
            ps_replay: cfg.ps_replay,
        };
        rpc.register(
            KIND_EW_INFO,
            Box::new(move |_msg| Ok(encode_ew_info_response(&info))),
        );
        // Per-rank NEXT_BATCH replay rings, shared between the NEXT handler
        // (which fills them) and the ADOPT handler (which drops a rank's ring
        // when its stream is fast-forwarded — cached responses for old steps
        // belong to the stream position the adoption just abandoned).
        type RankRing = Arc<Mutex<ReplayRing<usize, Vec<u8>>>>;
        let next_replay: Arc<Mutex<HashMap<usize, RankRing>>> =
            Arc::new(Mutex::new(HashMap::new()));
        {
            // NEXT_BATCH: serve from the pipeline, with a per-rank replay
            // ring (`--replay-depth` deep, shared `recovery::ReplayRing`)
            // so a reconnect that lost up to `replay_depth` responses can
            // re-ask for any of the last served steps (any step outside the
            // ring is a desync and fails loudly inside the pipeline — the
            // PR-4 one-deep cache desynced after two lost responses in a
            // row).
            let replay = next_replay.clone();
            let pipeline = pipeline.clone();
            let compress = cfg.compress;
            let depth = cfg.replay_depth.max(1);
            rpc.register(
                KIND_EW_NEXT,
                Box::new(move |msg| {
                    let (rank, step) = decode_next_request(msg)?;
                    let ring: RankRing = lock_unpoisoned(&replay)
                        .entry(rank)
                        .or_insert_with(|| Arc::new(Mutex::new(ReplayRing::new(depth))))
                        .clone();
                    // Per-rank lock: concurrent ranks proceed in parallel,
                    // retries of one rank serialize.
                    let mut ring = lock_unpoisoned(&ring);
                    if let Some(bytes) = ring.get(&step) {
                        return Ok(bytes.clone());
                    }
                    let pb = pipeline.next(rank, step)?;
                    let resp = encode_next_response(&pb, emb_dim, compress);
                    ring.insert(step, resp.clone());
                    Ok(resp)
                }),
            );
        }
        {
            // ADOPT_RANK: elastic membership. A trainer routes a rank here
            // after its previous worker died (or when this restarted process
            // takes its home ranks back): quiesce any stale prefetch pipe,
            // discard its buffered samples, fast-forward the rank's loader
            // stream to `next_step`, and forget cached NEXT responses drawn
            // at the abandoned stream position.
            let pipeline = pipeline.clone();
            let replay = next_replay.clone();
            rpc.register(
                KIND_EW_ADOPT,
                Box::new(move |msg| {
                    let (rank, step) = decode_ew_adopt_request(msg)?;
                    pipeline.adopt(rank, step)?;
                    lock_unpoisoned(&replay).remove(&rank);
                    Ok(encode_ew_adopt_response())
                }),
            );
        }
        {
            // PUSH replay cache: a retried push whose first attempt APPLIED
            // but whose ack was lost on the wire must be answered
            // idempotently — the samples are no longer buffered, so
            // replaying it through push_grads_raw would abort the run on a
            // transient blip whose update actually landed. Acks of the last
            // few successful pushes ride a `recovery::ReplayRing` keyed by
            // the batch's first sample id (sids are minted monotonically by
            // this worker and never reused, so an exact sids match IS the
            // same batch). Failed pushes cache nothing: their samples
            // re-buffered, and the retry must really re-apply.
            type PushRing = Arc<Mutex<ReplayRing<SampleId, (Vec<SampleId>, Vec<u8>)>>>;
            let push_depth = cfg.replay_depth.max(1) * 4;
            let replay: PushRing = Arc::new(Mutex::new(ReplayRing::new(push_depth)));
            let prep = prep.clone();
            rpc.register(
                KIND_EW_PUSH,
                Box::new(move |msg| {
                    let (sids, grads) = decode_push_request(msg, emb_dim)?;
                    // The NN→worker leg already happened on the real wire;
                    // apply the raw (buffer take + dedup + PS put) half. A
                    // failed put re-buffers server-side and the error tears
                    // down this connection — the client's retried RPC
                    // replays the identical batch.
                    let Some(grads) = grads else {
                        prep.worker(0).discard(&sids);
                        return Ok(encode_push_response(0.0));
                    };
                    let key = sids.first().copied().unwrap_or(0);
                    {
                        let cache = lock_unpoisoned(&replay);
                        if let Some((cached_sids, ack)) = cache.get(&key) {
                            if *cached_sids == sids {
                                return Ok(ack.clone());
                            }
                        }
                    }
                    let sim = prep.worker(0).push_grads_raw(&sids, &grads)?;
                    let ack = encode_push_response(sim);
                    lock_unpoisoned(&replay).insert(key, (sids, ack.clone()));
                    Ok(ack)
                }),
            );
        }
        {
            let prep = prep.clone();
            rpc.register(
                KIND_EW_EVAL,
                Box::new(move |msg| {
                    let rows = decode_eval_request(msg)?;
                    let batch = prep.dataset().test_batch(rows);
                    let (emb, sim) = prep.worker(0).lookup_direct(&batch)?;
                    Ok(encode_eval_response(&emb, sim))
                }),
            );
        }
        {
            let prep = prep.clone();
            let backend = backend.clone();
            rpc.register(
                KIND_EW_STATS,
                Box::new(move |_msg| {
                    Ok(encode_ew_stats_response(
                        prep.worker(0).buffered(),
                        &prep.worker(0).stats(),
                        &backend.stats()?,
                        &prep.worker(0).cache_stats(),
                    ))
                }),
            );
        }
        {
            // CKPT relay: the trainer coordinates checkpoint epochs, but in
            // the three-tier topology only this worker holds the PS
            // connections (and the put replay logs that must truncate at a
            // commit) — so the coordinator's PREPARE/COMMIT arrives here
            // and is driven against the backend on the trainer's behalf.
            let backend = backend.clone();
            let ckpt_dir = cfg.ckpt_dir.clone();
            rpc.register(
                KIND_EW_CKPT,
                Box::new(move |msg| {
                    let (step, mode) = decode_ew_ckpt_request(msg)?;
                    match mode {
                        EW_CKPT_FULL => {
                            let dir = ckpt_dir.clone().unwrap_or_default();
                            backend.checkpoint_epoch(&dir, step)?;
                        }
                        EW_CKPT_MARK => backend.mark_epoch_committed(step),
                        m => anyhow::bail!("unknown EW CKPT mode {m}"),
                    }
                    Ok(encode_ew_ckpt_response())
                }),
            );
        }
        {
            let stop = stop.clone();
            rpc.register(
                KIND_EW_SHUTDOWN,
                Box::new(move |_msg| {
                    stop.store(true, Ordering::SeqCst);
                    let _ = TcpStream::connect(wake_addr(local));
                    Ok(WireWriter::new(KIND_EW_SHUTDOWN).finish())
                }),
            );
        }

        Ok(EmbeddingWorkerServer { listener, rpc: Arc::new(rpc), stop })
    }

    /// Build the full server for one trainer config: the PS backend (the
    /// trainer's override, e.g. a [`super::ShardedRemotePs`], or a private
    /// in-process [`EmbeddingPs`]), the resident worker, the per-rank batch
    /// streams, and the prefetch pipeline. `ew.pipeline_depth` of `None`
    /// picks the mode's own pipeline depth
    /// ([`Trainer::pipeline_depth`](crate::hybrid::Trainer::pipeline_depth),
    /// floored at 1): FullSync serves on demand — zero staleness is that
    /// mode's contract — while the async modes prefetch up to τ (2τ for
    /// FullAsync) batches ahead. Deterministic mode always forces 1
    /// (bitwise parity needs on-demand lookups with ordered puts).
    ///
    /// `ew.start_step > 0` fast-forwards every rank's loader stream to that
    /// step — the resumed-run deployment, where NN ranks start asking at
    /// the checkpoint epoch's boundary. `ckpt_dir` is only consulted when
    /// the worker fronts an in-process PS (remote shards own their dirs).
    pub fn for_trainer(
        trainer: &Trainer,
        ew: &EmbWorkerConfig,
        ps_deployment: Option<&str>,
        ps_wire_compress: bool,
        ckpt_dir: Option<&str>,
    ) -> Result<EmbeddingWorkerServer> {
        ew.validate()?;
        let backend: Arc<dyn PsBackend> = match &trainer.ps_backend {
            Some(b) => b.clone(),
            None => Arc::new(EmbeddingPs::new(
                &trainer.emb_cfg,
                trainer.model.emb_dim_per_group,
                trainer.train.seed,
            )),
        };
        ensure!(
            backend.dim() == trainer.model.emb_dim_per_group,
            "PS backend dim {} != model group dim {}",
            backend.dim(),
            trainer.model.emb_dim_per_group
        );
        backend.check_compat(&trainer.emb_cfg, trainer.train.seed)?;
        let net = Arc::new(NetSim::new(trainer.cluster.net));
        // Worker-side hot-embedding cache: governed by the EW deployment
        // flags (`--ew-cache*`), but unconditionally off in deterministic
        // mode — bitwise parity requires every lookup to read the PS.
        let cache = if ew.ew_cache && !trainer.deterministic {
            let cfg = EwCacheConfig {
                capacity: ew.ew_cache_capacity,
                staleness: ew.ew_cache_staleness,
                ..EwCacheConfig::default()
            };
            let tau = trainer.train.staleness_bound.max(1) as u64;
            let n_ew = trainer.cluster.n_emb_workers.max(1);
            let ranks_per_worker = (trainer.cluster.n_nn_workers + n_ew - 1) / n_ew;
            Some(Arc::new(EmbCache::new(
                EwCacheParams::resolve(
                    &cfg,
                    tau,
                    ranks_per_worker.max(1),
                    trainer.emb_cfg.optimizer,
                    trainer.emb_cfg.lr,
                ),
                trainer.model.emb_dim_per_group,
            )))
        } else {
            None
        };
        let worker = Arc::new(
            EmbeddingWorker::new(
                ew.ew_rank,
                backend.clone(),
                &trainer.model,
                net,
                trainer.train.compress,
            )
            .with_cache(cache),
        );
        let prep = Arc::new(BatchPrep::new(
            trainer.dataset.clone(),
            vec![worker],
            trainer.train.batch_size,
            trainer.model.nid_dim,
            trainer.cluster.n_nn_workers,
            AssignMode::Fixed(0),
            true,
        ));
        if ew.start_step > 0 {
            // A resumed run: every rank's first NEXT_BATCH will ask for
            // `start_step`, so the strictly-sequential streams must already
            // stand there (the draws are loader-RNG only — no PS traffic).
            for rank in 0..trainer.cluster.n_nn_workers {
                prep.skip_to(rank, ew.start_step)?;
            }
        }
        let depth = if trainer.deterministic {
            1
        } else {
            ew.pipeline_depth.unwrap_or_else(|| trainer.pipeline_depth().max(1))
        };
        let pipeline = Arc::new(PrefetchPipeline::new(prep, depth));
        let (ps_processes, ps_sig) = ps_deployment_sig(ps_deployment);
        let cfg = EwServerConfig {
            fingerprint: trainer.config_fingerprint(),
            ew_rank: ew.ew_rank,
            ps_processes,
            ps_sig,
            ps_wire_compress,
            compress: trainer.train.compress,
            replay_depth: ew.replay_depth,
            ckpt_dir: ckpt_dir.map(PathBuf::from),
            ps_replay: backend.replay_puts(),
        };
        Self::bind(pipeline, backend, cfg, &ew.addr)
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve on the calling thread until a SHUTDOWN RPC arrives.
    pub fn serve_forever(self) -> Result<()> {
        accept_loop(self.listener, self.rpc, self.stop, "serve-embedding-worker");
        Ok(())
    }

    /// Serve on a background thread; returns a shutdown handle.
    pub fn spawn(self) -> Result<EwServerHandle> {
        let addr = self.local_addr()?;
        let EmbeddingWorkerServer { listener, rpc, stop } = self;
        let stop_for_loop = stop.clone();
        let accept = std::thread::Builder::new()
            .name("ew-accept".to_string())
            .spawn(move || accept_loop(listener, rpc, stop_for_loop, "serve-embedding-worker"))
            .context("spawning embedding-worker accept thread")?;
        Ok(EwServerHandle { addr, stop, accept })
    }
}

/// Handle to a background embedding-worker service.
pub struct EwServerHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept: std::thread::JoinHandle<()>,
}

impl EwServerHandle {
    /// The service's bound address.
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, deliver in-flight responses, and join every server
    /// thread (same protocol as [`super::PsServerHandle::shutdown`]).
    pub fn shutdown(self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(wake_addr(self.addr));
        self.accept
            .join()
            .map_err(|_| anyhow::anyhow!("embedding-worker accept thread panicked"))
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Dial/handshake policy for one embedding-worker endpoint: re-run the INFO
/// handshake and insist the server is the same logical deployment.
///
/// Without `allow_rejoin` (the pre-elastic behavior, still the default), a
/// changed boot nonce is fatal: a *restarted* embedding worker cannot
/// transparently resume — its stream positions and sample buffers died with
/// it. With `allow_rejoin` (`--ew-failover`), a restart with an unchanged
/// deployment is accepted and the stored expectation tracks the new boot:
/// the trainer's elastic tier re-establishes every affected rank's stream
/// position with an explicit `ADOPT_RANK` before trusting it again.
struct EwRedial {
    addr: String,
    expect: Mutex<EwInfo>,
    allow_rejoin: bool,
    window: usize,
    io_timeout: Option<std::time::Duration>,
}

impl Redial for EwRedial {
    fn redial(&self) -> Result<PooledConn> {
        let client = PipelinedClient::connect(&self.addr, self.window, self.io_timeout)
            .with_context(|| format!("reconnecting to embedding worker at {}", self.addr))?;
        let resp = client
            .call(&encode_ew_info_request())
            .context("embedding-worker INFO re-handshake")?;
        let info = decode_ew_info_response(&resp)?;
        let mut expect = lock_unpoisoned(&self.expect);
        ensure!(
            info.same_deployment(&expect),
            "embedding worker at {} came back with a different config: {info:?} != {:?}",
            self.addr,
            *expect
        );
        if info.boot_nonce != expect.boot_nonce {
            ensure!(
                self.allow_rejoin,
                "embedding worker at {} was restarted (boot nonce changed): its stream \
                 positions and sample buffers died with the old process — restart the run \
                 from a checkpoint, or run the trainer with --ew-failover so its ranks are \
                 adopted elsewhere and the restarted worker can rejoin",
                self.addr
            );
            *expect = info;
        }
        Ok(client)
    }

    fn describe(&self) -> String {
        format!("embedding worker at {}", self.addr)
    }
}

/// TCP client for one `serve-embedding-worker` process: a
/// [`ReconnectPool`](crate::recovery::ReconnectPool) shared by the NN-rank
/// thread and the gradient appliers, healing itself exactly like
/// [`super::RemotePs`] — a failed call drops its pooled connection and
/// re-dials with backoff through the shared recovery layer.
///
/// Retry semantics: `PUSH_GRADS` is replay-safe both ways — a failed put
/// re-buffers server-side so the retry re-applies, and a put whose ack was
/// lost after applying is answered idempotently from the server's push
/// replay ring (same sids ⇒ same cached ack, no double apply). A retried
/// `NEXT_BATCH` for any of the last `--replay-depth` served steps is
/// answered from the per-rank replay ring; any other desync fails loudly.
pub struct RemoteEmbeddingWorker {
    pool: ReconnectPool<EwRedial>,
    info: EwInfo,
}

impl RemoteEmbeddingWorker {
    /// Connect a pool to one worker address, taking pool size and recovery
    /// policy from `cfg`. A restarted server process is rejected at redial
    /// time; use [`Self::connect_addr_elastic`] to accept rejoins.
    pub fn connect_addr(cfg: &ServiceConfig, addr: &str) -> Result<RemoteEmbeddingWorker> {
        Self::connect_addr_elastic(cfg, addr, false)
    }

    /// Like [`Self::connect_addr`], but `allow_rejoin` selects whether a
    /// redial may accept a *restarted* server process (same deployment, new
    /// boot nonce). Only sound under `--ew-failover`, where the elastic tier
    /// re-establishes stream positions with `ADOPT_RANK` after a restart.
    pub fn connect_addr_elastic(
        cfg: &ServiceConfig,
        addr: &str,
        allow_rejoin: bool,
    ) -> Result<RemoteEmbeddingWorker> {
        let probe = TcpTransport::connect(addr)
            .with_context(|| format!("connecting to embedding worker at {addr}"))?;
        probe.set_timeouts(cfg.recovery.io_timeout())?;
        let probe = RpcClient::new(probe);
        let resp = probe
            .call(&encode_ew_info_request())
            .context("embedding-worker INFO handshake")?;
        let info = decode_ew_info_response(&resp)?;
        drop(probe);
        let pool = ReconnectPool::connect(
            EwRedial {
                addr: addr.to_string(),
                expect: Mutex::new(info),
                allow_rejoin,
                window: cfg.inflight_window,
                io_timeout: cfg.recovery.io_timeout(),
            },
            cfg.client_conns,
            RetryPolicy::from(&cfg.recovery),
        )?;
        Ok(RemoteEmbeddingWorker { pool, info })
    }

    /// The server's INFO handshake.
    pub fn info(&self) -> &EwInfo {
        &self.info
    }

    /// The address this client dials (and re-dials).
    pub fn addr(&self) -> &str {
        &self.pool.redialer().addr
    }

    /// One RPC over the recovery pool.
    fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        self.pool.call(msg)
    }

    /// Pull the prepared batch for `(rank, step)`. Returns the batch (with
    /// `ew` left 0 for the caller to fill) and the response wire bytes (the
    /// worker→NN transfer, for [`Link::EW_NN`] accounting).
    pub fn next_batch(&self, rank: usize, step: usize) -> Result<(PreparedBatch, usize)> {
        let resp = self
            .call(&encode_next_request(rank, step))
            .with_context(|| format!("NEXT_BATCH rank {rank} step {step}"))?;
        let pb = decode_next_response(&resp, self.info.emb_dim, self.info.nid_dim)?;
        Ok((pb, resp.len()))
    }

    /// Push a served batch's gradients back. Returns the server-side
    /// simulated seconds and the request wire bytes (the NN→worker
    /// transfer).
    pub fn push_grads(
        &self,
        sids: &[SampleId],
        grads: &[f32],
        compress: bool,
    ) -> Result<(f64, usize)> {
        ensure!(
            grads.len() == sids.len() * self.info.emb_dim,
            "PUSH_GRADS gradient shape mismatch"
        );
        let msg = encode_push_request(sids, grads, self.info.emb_dim, compress);
        let bytes = msg.len();
        let resp = self.call(&msg).context("PUSH_GRADS")?;
        Ok((decode_push_response(&resp)?, bytes))
    }

    /// Drop the sids' buffered features (applier give-up path).
    pub fn discard(&self, sids: &[SampleId]) -> Result<()> {
        let resp = self.call(&encode_discard_request(sids)).context("PUSH_GRADS discard")?;
        decode_push_response(&resp)?;
        Ok(())
    }

    /// Eval-path pooled lookup of the first `rows` test samples.
    pub fn eval(&self, rows: usize) -> Result<(Vec<f32>, f64)> {
        let resp = self.call(&encode_eval_request(rows)).context("EVAL lookup")?;
        let (emb, sim) = decode_eval_response(&resp)?;
        ensure!(emb.len() == rows * self.info.emb_dim, "EVAL shape mismatch");
        Ok((emb, sim))
    }

    /// Worker counters + relayed PS statistics + worker-cache counters.
    pub fn stats(&self) -> Result<(usize, WorkerStats, PsStats, CacheStats)> {
        let resp = self.call(&encode_ew_stats_request()).context("EW STATS")?;
        decode_ew_stats_response(&resp)
    }

    /// Relay one checkpoint-epoch operation (`mode` = [`EW_CKPT_FULL`] or
    /// [`EW_CKPT_MARK`]) to this worker.
    pub fn ckpt(&self, step: u64, mode: u64) -> Result<()> {
        let resp = self
            .call(&encode_ew_ckpt_request(step, mode))
            .with_context(|| format!("EW CKPT epoch {step} (mode {mode})"))?;
        decode_ew_ckpt_response(&resp)
    }

    /// Ask the server to shut down gracefully.
    pub fn shutdown_server(&self) -> Result<()> {
        self.call(&encode_ew_shutdown_request()).context("EW shutdown request")?;
        Ok(())
    }

    /// Ask this worker to own `rank`'s stream and serve its next batch at
    /// exactly `next_step` (elastic failover / rejoin take-back).
    pub fn adopt_rank(&self, rank: usize, next_step: usize) -> Result<()> {
        let resp = self
            .call(&encode_ew_adopt_request(rank, next_step))
            .with_context(|| format!("ADOPT_RANK rank {rank} at step {next_step}"))?;
        decode_ew_adopt_response(&resp)
    }

    /// One-shot INFO probe over a *fresh* connection, bypassing the pool and
    /// its retry budget: the rejoin poll wants "is a compatible process
    /// listening right now?", answered in one dial, without the pool's
    /// backoff schedule or its connection slots.
    pub fn probe_info(&self) -> Result<EwInfo> {
        let redialer = self.pool.redialer();
        let probe = TcpTransport::connect(&redialer.addr)
            .with_context(|| format!("probing embedding worker at {}", redialer.addr))?;
        probe.set_timeouts(redialer.io_timeout)?;
        let probe = RpcClient::new(probe);
        let resp = probe
            .call(&encode_ew_info_request())
            .context("embedding-worker INFO probe")?;
        decode_ew_info_response(&resp)
    }
}

// ---------------------------------------------------------------------------
// The remote tier
// ---------------------------------------------------------------------------

/// What a trainer expects every embedding-worker process to advertise.
#[derive(Clone, Copy, Debug)]
pub struct EwExpect {
    /// The trainer's own [`Trainer::config_fingerprint`].
    pub fingerprint: u64,
    /// Full activation width the dense tower consumes.
    pub emb_dim: usize,
    /// Non-ID feature width.
    pub nid_dim: usize,
    /// Samples per batch.
    pub batch_size: usize,
}

/// [`EmbComm`] over M `serve-embedding-worker` processes: NN ranks are
/// assigned to their *home* worker round-robin (`rank % M`), so each rank's
/// whole sample stream lives in one worker process; the worker→NN
/// activation/gradient transfers are charged on [`Link::EW_NN`] with the
/// frame bytes actually sent.
///
/// With `--ew-failover` ([`EwFailoverConfig::enabled`]) membership is
/// *elastic*: a worker whose retry budget is exhausted is marked dead, and
/// [`elastic_assign`] linearly probes each of its ranks to the next live
/// worker, which adopts the rank's stream via `ADOPT_RANK` and re-draws its
/// in-flight batches (workers are parameter-stateless — the shared remote PS
/// plus deterministic per-rank loader streams make the adopted batches
/// *identical*, so sync-mode numerics survive the move). A restarted worker
/// rejoins at the next step boundary and takes its home ranks back.
pub struct RemoteEmbTier {
    workers: Vec<RemoteEmbeddingWorker>,
    net: Arc<NetSim>,
    /// Lossy fp16 on the activation/gradient wire (`train --compress`).
    compress: bool,
    expect: EwExpect,
    failover: EwFailoverConfig,
    state: Mutex<TierState>,
}

/// Mutable elastic-membership state of a [`RemoteEmbTier`].
struct TierState {
    /// Liveness per worker index (all live at connect).
    dead: Vec<bool>,
    /// Bumped on every membership change; stale epochs invalidate `route`.
    epoch: u64,
    /// Per-rank route cache: `rank → (epoch, worker)`. An entry whose epoch
    /// is current means `worker` has already ADOPTed the rank's stream.
    route: HashMap<usize, (u64, usize)>,
    /// In-flight batches awaiting their gradient push: first sample id →
    /// `(rank, step)`, enough to re-draw the identical batch on an adopter
    /// when the serving worker dies between NEXT and PUSH.
    inflight: HashMap<SampleId, (usize, usize)>,
    /// Last rejoin probe, throttling dead-address polls to `rejoin_ms`.
    last_probe: Option<std::time::Instant>,
}

impl RemoteEmbTier {
    /// Connect to every address in `cfg.addr` (comma-separated) and verify
    /// the processes jointly form one coherent embedding-worker tier for
    /// exactly this trainer config. Failover stays off (the pre-elastic
    /// fatal behavior); use [`Self::connect_elastic`] to enable it.
    pub fn connect(
        cfg: &ServiceConfig,
        expect: EwExpect,
        compress: bool,
        net: Arc<NetSim>,
    ) -> Result<RemoteEmbTier> {
        Self::connect_elastic(cfg, expect, compress, net, EwFailoverConfig::default())
    }

    /// [`Self::connect`] with an explicit elastic-membership policy
    /// (`--ew-failover`, `--ew-rejoin`, `--ew-rejoin-ms`).
    pub fn connect_elastic(
        cfg: &ServiceConfig,
        expect: EwExpect,
        compress: bool,
        net: Arc<NetSim>,
        failover: EwFailoverConfig,
    ) -> Result<RemoteEmbTier> {
        cfg.validate()?;
        failover.validate()?;
        let addrs = cfg.shard_addrs();
        let workers: Vec<RemoteEmbeddingWorker> = addrs
            .iter()
            .map(|addr| RemoteEmbeddingWorker::connect_addr_elastic(cfg, addr, failover.enabled))
            .collect::<Result<_>>()?;
        for w in &workers {
            let info = w.info();
            ensure!(
                info.fingerprint == expect.fingerprint,
                "embedding worker at {} was started with a different config \
                 (fingerprint {:#x} != trainer's {:#x}) — start serve-embedding-worker \
                 and the trainer with identical preset/train flags",
                w.addr(),
                info.fingerprint,
                expect.fingerprint
            );
            ensure!(
                info.emb_dim == expect.emb_dim
                    && info.nid_dim == expect.nid_dim
                    && info.batch_size == expect.batch_size,
                "embedding worker at {} serves geometry (emb {}, nid {}, batch {}), \
                 trainer expects (emb {}, nid {}, batch {})",
                w.addr(),
                info.emb_dim,
                info.nid_dim,
                info.batch_size,
                expect.emb_dim,
                expect.nid_dim,
                expect.batch_size
            );
        }
        // All workers must front the SAME PS deployment, or the tier is
        // several disjoint models wearing one name.
        let first = workers[0].info();
        for w in &workers[1..] {
            let info = w.info();
            ensure!(
                (info.ps_processes, info.ps_sig, info.ps_wire_compress)
                    == (first.ps_processes, first.ps_sig, first.ps_wire_compress),
                "embedding workers at {} and {} front different PS deployments",
                workers[0].addr(),
                w.addr()
            );
        }
        ensure!(
            workers.len() == 1 || first.ps_processes >= 1,
            "multiple embedding workers need a shared --remote-ps PS deployment \
             (each process currently owns a private in-process PS)"
        );
        let n = workers.len();
        Ok(RemoteEmbTier {
            workers,
            net,
            compress,
            expect,
            failover,
            state: Mutex::new(TierState {
                dead: vec![false; n],
                epoch: 0,
                route: HashMap::new(),
                inflight: HashMap::new(),
                last_probe: None,
            }),
        })
    }

    /// Number of worker processes behind this tier.
    pub fn n_processes(&self) -> usize {
        self.workers.len()
    }

    /// The `i`-th worker-process client.
    pub fn worker(&self, i: usize) -> &RemoteEmbeddingWorker {
        &self.workers[i]
    }

    /// The tier's prefetch depth (uniform across workers by fingerprint).
    pub fn pipeline_depth(&self) -> usize {
        self.workers[0].info().pipeline_depth
    }

    /// Gracefully stop every worker process still reachable (dead members
    /// have nothing left to stop).
    pub fn shutdown_all(&self) -> Result<()> {
        for (i, w) in self.workers.iter().enumerate() {
            if self.is_dead(i) {
                continue;
            }
            w.shutdown_server()?;
        }
        Ok(())
    }

    /// Whether worker `idx` is currently marked dead (always false with
    /// failover off).
    fn is_dead(&self, idx: usize) -> bool {
        if !self.failover.enabled {
            return false;
        }
        lock_unpoisoned(&self.state).dead.get(idx).copied().unwrap_or(false)
    }

    /// First live worker index — the tier's stand-in for "worker 0" on
    /// rank-independent calls (eval, stats, checkpoint lead).
    fn first_live(&self) -> usize {
        if !self.failover.enabled {
            return 0;
        }
        lock_unpoisoned(&self.state).dead.iter().position(|d| !d).unwrap_or(0)
    }

    /// Record worker `idx` as dead and bump the membership epoch. Errors if
    /// losing this worker makes exact continuation impossible: every worker
    /// is gone, or the dead worker held a `--ps-replay` put log (the log died
    /// with the process, so a later PS-shard replay would silently drop its
    /// puts — aborting loudly beats diverging quietly).
    fn mark_dead(&self, idx: usize) -> Result<()> {
        ensure!(
            !self.workers[idx].info().ps_replay,
            "embedding worker at {} died holding a --ps-replay put log; its logged delta \
             cannot be handed to an adopting process, so exact shard replay is no longer \
             guaranteed — aborting instead of failing over (restart from the last \
             checkpoint epoch, or run the workers without --ps-replay to allow failover)",
            self.workers[idx].addr()
        );
        let mut st = lock_unpoisoned(&self.state);
        if !st.dead[idx] {
            st.dead[idx] = true;
            st.epoch += 1;
            eprintln!(
                "ew-failover: embedding worker at {} is unreachable; reassigning its \
                 ranks to survivors",
                self.workers[idx].addr()
            );
        }
        ensure!(
            st.dead.iter().any(|d| !d),
            "every embedding worker is unreachable — nothing left to adopt the ranks"
        );
        Ok(())
    }

    /// Record worker `idx` as live again (rejoin) and bump the epoch, so the
    /// next routed call moves its home ranks back via `ADOPT_RANK`.
    fn mark_alive(&self, idx: usize) {
        let mut st = lock_unpoisoned(&self.state);
        if st.dead[idx] {
            st.dead[idx] = false;
            st.epoch += 1;
            eprintln!(
                "ew-failover: embedding worker at {} rejoined; returning its home ranks",
                self.workers[idx].addr()
            );
        }
    }

    /// Resolve which worker serves `rank`, adopting the rank's stream at
    /// `step` on the target whenever the assignment changed since the last
    /// call (first use, a death, or a rejoin take-back). With failover off
    /// this is exactly the static `rank % M`.
    fn route(&self, rank: usize, step: usize) -> Result<usize> {
        if !self.failover.enabled {
            return Ok(rank % self.workers.len());
        }
        // Each pass either returns or marks one more worker dead, so M+1
        // passes bound the loop.
        for _ in 0..=self.workers.len() {
            let (cached, desired, epoch) = {
                let st = lock_unpoisoned(&self.state);
                let desired = elastic_assign(rank, self.workers.len(), &st.dead).context(
                    "every embedding worker is unreachable — nothing left to adopt the ranks",
                )?;
                (st.route.get(&rank).copied(), desired, st.epoch)
            };
            if let Some((e, w)) = cached {
                if e == epoch {
                    return Ok(w);
                }
            }
            // The assignment changed: the target must own the rank's stream
            // from `step` before we trust it with NEXT/PUSH traffic.
            match self.workers[desired].adopt_rank(rank, step) {
                Ok(()) => {
                    let mut st = lock_unpoisoned(&self.state);
                    if st.epoch == epoch {
                        st.route.insert(rank, (epoch, desired));
                        return Ok(desired);
                    }
                    // Membership moved underneath the adoption — re-resolve.
                }
                Err(e) if Unreachable::in_chain(&e) => self.mark_dead(desired)?,
                Err(e) => {
                    return Err(e.context(format!(
                        "adopting rank {rank} at step {step} on embedding worker {}",
                        self.workers[desired].addr()
                    )))
                }
            }
        }
        anyhow::bail!("embedding-tier routing for rank {rank} did not converge")
    }

    /// Throttled poll of dead worker addresses (`--ew-rejoin` every
    /// `--ew-rejoin-ms`): a fresh INFO probe that reports the same logical
    /// deployment marks the worker live again. Probe failures are expected
    /// (the process is usually still down) and stay silent.
    fn maybe_probe_rejoin(&self) {
        if !(self.failover.enabled && self.failover.rejoin) {
            return;
        }
        let dead_idxs: Vec<usize> = {
            let mut st = lock_unpoisoned(&self.state);
            if !st.dead.iter().any(|d| *d) {
                return;
            }
            let now = std::time::Instant::now();
            if let Some(t) = st.last_probe {
                if now.duration_since(t)
                    < std::time::Duration::from_millis(self.failover.rejoin_ms)
                {
                    return;
                }
            }
            st.last_probe = Some(now);
            st.dead
                .iter()
                .enumerate()
                .filter(|&(_, d)| *d)
                .map(|(i, _)| i)
                .collect()
        };
        for idx in dead_idxs {
            if let Ok(info) = self.workers[idx].probe_info() {
                if info.same_deployment(self.workers[idx].info()) {
                    self.mark_alive(idx);
                }
            }
        }
    }

    /// Recover a batch whose serving worker died between NEXT and PUSH: the
    /// adopter re-draws the *identical* batch (deterministic per-rank loader
    /// streams over the same shared PS) under fresh sample ids, and the
    /// held gradients are pushed against those. Returns the simulated
    /// seconds of the replacement push.
    fn rebuffer_push(&self, sids: &[SampleId], grads: &[f32]) -> Result<f64> {
        let sid0 = sids.first().copied().context("empty gradient push")?;
        let (rank, step) = lock_unpoisoned(&self.state)
            .inflight
            .get(&sid0)
            .copied()
            .context("no in-flight record for the failed batch — cannot re-draw it")?;
        // The death bumped the epoch, so route() re-adopts at exactly the
        // lost batch's step; the adopter's next serve IS that batch.
        let idx = self.route(rank, step)?;
        let t0 = std::time::Instant::now();
        let (pb, wire_in) = self.workers[idx].next_batch(rank, step)?;
        ensure!(
            pb.step == step && pb.sids.len() == sids.len(),
            "re-drawn batch for rank {rank} step {step} changed shape — loader streams \
             are not deterministic across workers"
        );
        let (sim, wire_out) = self.workers[idx].push_grads(&pb.sids, grads, self.compress)?;
        lock_unpoisoned(&self.state).inflight.remove(&sid0);
        eprintln!(
            "ew-failover: re-buffered rank {rank} step {step} on {} (batch re-drawn, \
             gradients re-pushed)",
            self.workers[idx].addr()
        );
        Ok(sim + self.net.record(Link::EW_NN, wire_in + wire_out) + t0.elapsed().as_secs_f64())
    }
}

impl EmbComm for RemoteEmbTier {
    fn n_workers(&self) -> usize {
        self.workers.len()
    }

    fn assign(&self, rank: usize, _step: usize) -> usize {
        if !self.failover.enabled {
            return rank % self.workers.len();
        }
        let st = lock_unpoisoned(&self.state);
        // Prefer the established route — that worker's buffer holds the
        // rank's in-flight samples even if membership just changed.
        if let Some(&(e, w)) = st.route.get(&rank) {
            if e == st.epoch {
                return w;
            }
        }
        elastic_assign(rank, self.workers.len(), &st.dead)
            .unwrap_or(rank % self.workers.len())
    }

    fn next_batch(&self, rank: usize, step: usize) -> Result<PreparedBatch> {
        self.maybe_probe_rejoin();
        let mut adopted_retry = false;
        loop {
            let idx = self.route(rank, step)?;
            let t0 = std::time::Instant::now();
            match self.workers[idx].next_batch(rank, step) {
                Ok((mut pb, wire_bytes)) => {
                    pb.ew = idx;
                    // The worker→NN leg, now real: charge the frame bytes
                    // actually sent and fold the transfer + RPC wall time
                    // into the prep cost.
                    pb.sim_prep += self.net.record(Link::EW_NN, wire_bytes);
                    pb.sim_prep += t0.elapsed().as_secs_f64();
                    if self.failover.enabled {
                        if let Some(&sid0) = pb.sids.first() {
                            lock_unpoisoned(&self.state).inflight.insert(sid0, (rank, step));
                        }
                    }
                    return Ok(pb);
                }
                Err(e) if self.failover.enabled && Unreachable::in_chain(&e) => {
                    // Retry budget exhausted against this worker: mark it
                    // dead; route() will adopt the rank on a survivor at
                    // exactly this step.
                    self.mark_dead(idx)?;
                }
                Err(e)
                    if self.failover.enabled
                        && !adopted_retry
                        && format!("{e:#}").contains("out of sync") =>
                {
                    // The worker restarted *within* the retry window, so the
                    // pool transparently redialed it — but its fresh streams
                    // do not stand at `step`. One explicit adoption
                    // re-establishes the position; a second desync is real.
                    adopted_retry = true;
                    self.workers[idx].adopt_rank(rank, step).with_context(|| {
                        format!(
                            "re-adopting rank {rank} on restarted embedding worker {}",
                            self.workers[idx].addr()
                        )
                    })?;
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn push_grads(&self, ew: usize, sids: &[SampleId], grads: &[f32]) -> Result<f64> {
        let t0 = std::time::Instant::now();
        match self.workers[ew].push_grads(sids, grads, self.compress) {
            Ok((sim, wire_bytes)) => {
                if self.failover.enabled {
                    if let Some(sid0) = sids.first() {
                        lock_unpoisoned(&self.state).inflight.remove(sid0);
                    }
                }
                Ok(sim + self.net.record(Link::EW_NN, wire_bytes) + t0.elapsed().as_secs_f64())
            }
            Err(e) if self.failover.enabled && Unreachable::in_chain(&e) => {
                // The serving worker died holding this batch's buffer. Mark
                // it dead and replay the batch on the adopter: re-draw the
                // identical samples, push the same gradients. No update is
                // lost, so sync-mode numerics are preserved.
                self.mark_dead(ew)?;
                self.rebuffer_push(sids, grads).with_context(|| {
                    format!(
                        "recovering a gradient push lost with embedding worker {}",
                        self.workers[ew].addr()
                    )
                })
            }
            Err(e) => Err(e),
        }
    }

    fn discard(&self, ew: usize, sids: &[SampleId]) {
        // Best-effort: the worker may already be gone, which also discards.
        if self.failover.enabled {
            if let Some(sid0) = sids.first() {
                lock_unpoisoned(&self.state).inflight.remove(sid0);
            }
        }
        let _ = self.workers[ew].discard(sids);
    }

    fn eval_lookup(&self, rows: usize) -> Result<(Vec<f32>, f64)> {
        self.workers[self.first_live()].eval(rows)
    }

    fn ps_stats(&self) -> Result<PsStats> {
        Ok(self.workers[self.first_live()].stats()?.2)
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        // Each EW process owns a private cache; the tier total is the sum
        // over live members. Dead workers are skipped (their counters died
        // with them), and a tier running with `--ew-cache false` everywhere
        // reports all-zero sections — surfaced as `None` so the trainer
        // prints nothing.
        let mut total = CacheStats::default();
        let mut any = false;
        for (i, w) in self.workers.iter().enumerate() {
            if self.is_dead(i) {
                continue;
            }
            if let Ok((_, _, _, cs)) = w.stats() {
                any = any || cs.any();
                total.merge(&cs);
            }
        }
        any.then_some(total)
    }

    fn check_compat(&self, fingerprint: u64) -> Result<()> {
        ensure!(
            fingerprint == self.expect.fingerprint,
            "embedding-worker tier was connected for fingerprint {:#x}, trainer now \
             reports {fingerprint:#x} — the trainer config changed after connect",
            self.expect.fingerprint
        );
        Ok(())
    }

    fn checkpoint_epoch(&self, _dir: &Path, step: u64) -> Result<()> {
        // The first live worker drives the full two-phase epoch on the
        // (shared) PS deployment; every other live worker only truncates its
        // own put replay logs at the now-committed epoch. All workers front
        // the same PS fleet (proved at connect time), so one PREPARE/COMMIT
        // pass is the whole tier's epoch; dead members are skipped — they
        // hold no replay logs worth truncating any more.
        let lead = self.first_live();
        self.workers[lead]
            .ckpt(step, EW_CKPT_FULL)
            .with_context(|| format!("checkpoint epoch via {}", self.workers[lead].addr()))?;
        for (i, w) in self.workers.iter().enumerate() {
            if i == lead || self.is_dead(i) {
                continue;
            }
            w.ckpt(step, EW_CKPT_MARK)
                .with_context(|| format!("epoch commit mark via {}", w.addr()))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind,
        PartitionPolicy, Pooling, TrainConfig, TrainMode,
    };
    use crate::data::SyntheticDataset;

    fn small_trainer(compress: bool, deterministic: bool) -> Trainer {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let emb_cfg = EmbeddingConfig {
            rows_per_group: 500,
            shard_capacity: 2048,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let cluster = ClusterConfig {
            n_nn_workers: 1,
            n_emb_workers: 1,
            net: NetModelConfig::disabled(),
        };
        let train = TrainConfig {
            mode: TrainMode::Hybrid,
            batch_size: 8,
            lr: 0.1,
            staleness_bound: 2,
            steps: 4,
            eval_every: 0,
            seed: 11,
            use_pjrt: false,
            compress,
        };
        let dataset = SyntheticDataset::new(&model, 500, 1.05, 11);
        let mut t = Trainer::new(model, emb_cfg, cluster, train, dataset);
        t.deterministic = deterministic;
        t
    }

    fn expect_of(t: &Trainer) -> EwExpect {
        EwExpect {
            fingerprint: t.config_fingerprint(),
            emb_dim: t.model.emb_dim(),
            nid_dim: t.model.nid_dim,
            batch_size: t.train.batch_size,
        }
    }

    #[test]
    fn info_codec_roundtrip() {
        let info = EwInfo {
            fingerprint: 0xdead_beef,
            ew_rank: 3,
            emb_dim: 8,
            nid_dim: 4,
            batch_size: 32,
            pipeline_depth: 4,
            ps_processes: 2,
            ps_sig: 42,
            ps_wire_compress: true,
            boot_nonce: 0x1234_5678_9abc_def0,
            ps_replay: true,
        };
        let back = decode_ew_info_response(&encode_ew_info_response(&info)).unwrap();
        assert_eq!(back, info);
        // A restart (new boot nonce) is the same deployment; any other
        // field difference is not.
        let restarted = EwInfo { boot_nonce: 7, ..info };
        assert!(info.same_deployment(&restarted));
        assert_ne!(info, restarted);
        let reconfigured = EwInfo { batch_size: 64, ..info };
        assert!(!info.same_deployment(&reconfigured));
    }

    #[test]
    fn adopt_codec_roundtrip() {
        let (rank, step) = decode_ew_adopt_request(&encode_ew_adopt_request(3, 77)).unwrap();
        assert_eq!((rank, step), (3, 77));
        decode_ew_adopt_response(&encode_ew_adopt_response()).unwrap();
        // Wrong kind is rejected.
        assert!(decode_ew_adopt_request(&encode_ew_info_request()).is_err());
    }

    #[test]
    fn next_codec_roundtrip_raw_and_compressed() {
        let pb = PreparedBatch {
            step: 7,
            ew: 0,
            sids: vec![1, 2, 3],
            emb: vec![0.5f32; 3 * 8],
            nid: vec![1.0f32; 3 * 4],
            labels: vec![1.0, 0.0, 1.0],
            sim_prep: 0.25,
        };
        let raw = decode_next_response(&encode_next_response(&pb, 8, false), 8, 4).unwrap();
        assert_eq!(raw.step, 7);
        assert_eq!(raw.sids, pb.sids);
        assert_eq!(raw.emb, pb.emb);
        assert_eq!(raw.nid, pb.nid);
        assert_eq!(raw.labels, pb.labels);
        assert!((raw.sim_prep - 0.25).abs() < 1e-12);
        let comp = decode_next_response(&encode_next_response(&pb, 8, true), 8, 4).unwrap();
        for (a, b) in pb.emb.iter().zip(&comp.emb) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
        // Wrong geometry fails the shape check.
        assert!(decode_next_response(&encode_next_response(&pb, 8, false), 4, 4).is_err());
    }

    #[test]
    fn push_and_discard_codec_roundtrip() {
        let sids = vec![9u64, 10];
        let grads = vec![0.25f32; 2 * 8];
        let (s2, g2) = decode_push_request(&encode_push_request(&sids, &grads, 8, false), 8)
            .unwrap();
        assert_eq!(s2, sids);
        assert_eq!(g2.unwrap(), grads);
        let (s3, g3) = decode_push_request(&encode_discard_request(&sids), 8).unwrap();
        assert_eq!(s3, sids);
        assert!(g3.is_none());
        let sim = decode_push_response(&encode_push_response(1.5)).unwrap();
        assert!((sim - 1.5).abs() < 1e-12);
    }

    #[test]
    fn eval_and_stats_codec_roundtrip() {
        let emb = vec![1.0f32, 2.0, 3.0, 4.0];
        let (back, sim) = decode_eval_response(&encode_eval_response(&emb, 0.5)).unwrap();
        assert_eq!(back, emb);
        assert!((sim - 0.5).abs() < 1e-12);

        let w = WorkerStats {
            samples_registered: 1,
            batches_fetched: 2,
            ids_looked_up: 3,
            rows_fetched: 4,
            batches_flushed: 5,
            samples_flushed: 6,
            grad_ids: 7,
            rows_put: 8,
            put_failures: 9,
            rebuffered_samples: 10,
        };
        let ps = PsStats {
            total_rows: 11,
            total_evictions: 12,
            imbalance: 1.5,
            cold_hits: 21,
            cold_rows: 6,
            ..Default::default()
        };
        let cs = CacheStats {
            hits: 31,
            misses: 32,
            stale_refreshes: 33,
            invalidations: 34,
            updates: 35,
            flushes: 36,
            coalesced: 37,
            evictions: 38,
        };
        let (buffered, w2, ps2, cs2) =
            decode_ew_stats_response(&encode_ew_stats_response(13, &w, &ps, &cs)).unwrap();
        assert_eq!(buffered, 13);
        assert_eq!(w2, w);
        assert_eq!(ps2.total_rows, 11);
        assert!((ps2.imbalance - 1.5).abs() < 1e-12);
        assert_eq!(ps2.cold_hits, 21);
        assert_eq!(ps2.cold_rows, 6);
        assert_eq!(cs2, cs);
    }

    #[test]
    fn ps_deployment_sig_is_order_independent() {
        let a = ps_deployment_sig(Some("h1:1,h2:2"));
        let b = ps_deployment_sig(Some("h2:2, h1:1"));
        assert_eq!(a, b);
        assert_eq!(a.0, 2);
        assert_ne!(a, ps_deployment_sig(Some("h1:1,h3:3")));
        assert_eq!(ps_deployment_sig(None), (0, 0));
    }

    #[test]
    fn loopback_serve_and_train_cycle() {
        let trainer = small_trainer(false, false);
        let ew = EmbWorkerConfig { addr: "127.0.0.1:0".into(), ..EmbWorkerConfig::default() };
        let server =
            EmbeddingWorkerServer::for_trainer(&trainer, &ew, None, false, None).unwrap();
        let handle = server.spawn().unwrap();
        let svc = ServiceConfig::at(handle.addr().to_string());
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let tier =
            RemoteEmbTier::connect(&svc, expect_of(&trainer), false, net.clone()).unwrap();
        assert_eq!(tier.n_workers(), 1);
        assert_eq!(tier.pipeline_depth(), 2);

        // Batch parity with the local stream draw.
        let mut rng = trainer.dataset.train_rng(0);
        let want = trainer.dataset.batch(&mut rng, 8);
        let pb = tier.next_batch(0, 0).unwrap();
        assert_eq!(pb.step, 0);
        assert_eq!(pb.labels, want.labels);
        assert_eq!(pb.nid, want.nid);
        assert_eq!(pb.emb.len(), 8 * trainer.model.emb_dim());
        assert!(net.link_bytes(Link::EW_NN) > 0, "NEXT must charge the EW↔NN link");

        // Gradient push-back clears the remote buffer.
        let grads = vec![0.1f32; pb.sids.len() * trainer.model.emb_dim()];
        tier.push_grads(pb.ew, &pb.sids, &grads).unwrap();
        let (buffered, wstats, pstats, _) = tier.worker(0).stats().unwrap();
        assert_eq!(buffered, 0);
        assert_eq!(wstats.samples_flushed, 8);
        assert!(pstats.total_rows > 0);

        // A push retried after a lost ack (same sids, buffer already
        // released) is answered idempotently from the replay cache: no
        // error, and the gradient is NOT applied a second time.
        tier.push_grads(pb.ew, &pb.sids, &grads)
            .expect("replayed push must be answered idempotently");
        let (_, wstats2, _, _) = tier.worker(0).stats().unwrap();
        assert_eq!(wstats2.batches_flushed, 1, "replay must not re-apply");
        assert_eq!(wstats2.samples_flushed, 8);

        // Eval matches an in-process worker over an equally-trained PS? At
        // minimum: correct shape and finite values against live state.
        let (emb, _) = tier.eval_lookup(16).unwrap();
        assert_eq!(emb.len(), 16 * trainer.model.emb_dim());
        assert!(emb.iter().all(|x| x.is_finite()));

        // Replay ring: retrying the last served step returns the identical
        // payload instead of desyncing — and with the default depth of 4, a
        // step TWO behind the head still replays (the PR-4 one-deep cache
        // desynced here).
        let pb1 = tier.next_batch(0, 1).unwrap();
        let pb1_again = tier.next_batch(0, 1).unwrap();
        assert_eq!(pb1.sids, pb1_again.sids);
        assert_eq!(pb1.emb, pb1_again.emb);
        let pb2 = tier.next_batch(0, 2).unwrap();
        let pb1_deep = tier.next_batch(0, 1).unwrap();
        assert_eq!(pb1.sids, pb1_deep.sids);
        assert_eq!(pb1.emb, pb1_deep.emb);
        let pb2_again = tier.next_batch(0, 2).unwrap();
        assert_eq!(pb2.sids, pb2_again.sids);

        tier.shutdown_all().unwrap();
        handle.shutdown().unwrap();
    }

    #[test]
    fn loopback_adopt_fast_forwards_the_stream() {
        let trainer = small_trainer(false, false);
        let ew = EmbWorkerConfig { addr: "127.0.0.1:0".into(), ..EmbWorkerConfig::default() };
        let server =
            EmbeddingWorkerServer::for_trainer(&trainer, &ew, None, false, None).unwrap();
        let handle = server.spawn().unwrap();
        let svc = ServiceConfig::at(handle.addr().to_string());
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let tier = RemoteEmbTier::connect(&svc, expect_of(&trainer), false, net).unwrap();

        // This worker never served the rank: ADOPT at step 2 fast-forwards
        // the loader stream there, and the served batch equals the local
        // reference draw — the determinism elastic failover's exactness
        // rests on.
        tier.worker(0).adopt_rank(0, 2).unwrap();
        let pb = tier.next_batch(0, 2).unwrap();
        let mut rng = trainer.dataset.train_rng(0);
        let _ = trainer.dataset.batch(&mut rng, 8);
        let _ = trainer.dataset.batch(&mut rng, 8);
        let want = trainer.dataset.batch(&mut rng, 8);
        assert_eq!(pb.step, 2);
        assert_eq!(pb.labels, want.labels);
        assert_eq!(pb.nid, want.nid);

        // Adopting *behind* the stream head is a loud error, not a rewind.
        let err = tier.worker(0).adopt_rank(0, 0).unwrap_err();
        assert!(format!("{err:#}").contains("cannot fast-forward"), "{err:#}");

        tier.shutdown_all().unwrap();
        handle.shutdown().unwrap();
    }

    #[test]
    fn ckpt_codec_roundtrip() {
        let (step, mode) = decode_ew_ckpt_request(&encode_ew_ckpt_request(24, EW_CKPT_MARK))
            .unwrap();
        assert_eq!((step, mode), (24, EW_CKPT_MARK));
        decode_ew_ckpt_response(&encode_ew_ckpt_response()).unwrap();
        // Wrong kind is rejected.
        assert!(decode_ew_ckpt_request(&encode_ew_info_request()).is_err());
    }

    #[test]
    fn fingerprint_mismatch_rejected_at_connect() {
        let trainer = small_trainer(false, true);
        let ew = EmbWorkerConfig { addr: "127.0.0.1:0".into(), ..EmbWorkerConfig::default() };
        let server =
            EmbeddingWorkerServer::for_trainer(&trainer, &ew, None, false, None).unwrap();
        let handle = server.spawn().unwrap();
        let svc = ServiceConfig::at(handle.addr().to_string());
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let mut expect = expect_of(&trainer);
        expect.fingerprint ^= 1;
        let err = RemoteEmbTier::connect(&svc, expect, false, net).unwrap_err();
        assert!(format!("{err:#}").contains("fingerprint"), "{err:#}");
        handle.shutdown().unwrap();
    }

    #[test]
    fn deterministic_mode_forces_depth_one() {
        let trainer = small_trainer(false, true);
        let ew = EmbWorkerConfig {
            addr: "127.0.0.1:0".into(),
            pipeline_depth: Some(8),
            ..EmbWorkerConfig::default()
        };
        let server =
            EmbeddingWorkerServer::for_trainer(&trainer, &ew, None, false, None).unwrap();
        let handle = server.spawn().unwrap();
        let svc = ServiceConfig::at(handle.addr().to_string());
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let tier = RemoteEmbTier::connect(&svc, expect_of(&trainer), false, net).unwrap();
        assert_eq!(tier.pipeline_depth(), 1, "deterministic mode must pin depth to 1");
        tier.shutdown_all().unwrap();
        handle.shutdown().unwrap();
    }
}
