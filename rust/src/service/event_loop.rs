//! The non-blocking readiness-loop core of every `persia` service.
//!
//! One poller thread multiplexes the listener and every live connection
//! through [`poll_fds`](crate::comm::poll::poll_fds); requests are
//! dispatched on a small bounded worker pool, and responses flow back
//! through per-connection outboxes that the poller flushes with
//! non-blocking writes. Replaces the PR-1 thread-per-connection model: a
//! PS serving hundreds of pipelined trainer connections now costs a fixed
//! number of threads, a slow client can no longer pin an OS thread, and
//! requests from *one* connection execute concurrently — which is what
//! makes client-side pipelining ([`crate::comm::PipelinedClient`]) pay off
//! server-side.
//!
//! ```text
//!              ┌─────────────── poller thread ───────────────┐
//!   accept ──▶ │ poll([listener, wake, conn…])               │
//!              │   readable conn → rbuf → peel frames ───────┼──▶ job queue
//!              │   writable conn ← wbuf ← outbox ◀───────────┼─── workers
//!              └───────────────▲─────────────────────────────┘    (dispatch)
//!                              └── UDP self-wake (response ready)
//! ```
//!
//! Per-connection state machine: `rbuf` accumulates partial reads until a
//! complete `[len][corr][msg]` frame peels off; each frame becomes a job
//! (`inflight` incremented) that dispatches through the shared
//! [`RpcServer`] and pushes its framed response into the connection's
//! `outbox`, then nudges the poller over a loopback UDP socket (push
//! *before* wake, drain wake *before* flush — no lost-wakeup window).
//! Responses may complete out of order; correlation ids route them
//! client-side. A handler error drops the connection after flushing
//! already-queued responses (same contract as the old per-connection
//! `serve` loop).
//!
//! Graceful shutdown keeps the documented protocol: once the stop flag is
//! observed the loop stops accepting and reading, flushes every outbox,
//! waits for in-flight jobs (the SHUTDOWN ack included) to drain — bounded
//! by a hard deadline so a peer that stops reading cannot wedge shutdown —
//! then joins the workers.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, UdpSocket};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::comm::poll::{poll_fds, PollFd, POLLIN, POLLOUT};
use crate::comm::rpc::RpcServer;
use crate::util::lock_unpoisoned;

/// Largest accepted request frame (matches the transport layer's bound).
const MAX_FRAME: usize = 1 << 30;

/// Poll timeout: a pure safety net (every state change also wakes the
/// poller), so it only bounds reaction time to external stop requests.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);

/// How long shutdown waits for peers to drain queued responses before
/// force-closing their connections.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Consecutive accept failures tolerated before the listener is declared
/// broken (transient ECONNABORTED/EMFILE bursts must not kill a PS).
const MAX_ACCEPT_ERRORS: u32 = 64;

type Job = Box<dyn FnOnce() + Send>;

/// State a connection shares with its in-flight dispatch jobs.
struct ConnShared {
    /// Completed responses (length-prefixed, ready for the wire), in
    /// completion order.
    outbox: Mutex<VecDeque<Vec<u8>>>,
    /// Requests handed to the worker pool and not yet answered.
    inflight: AtomicUsize,
    /// Set by a handler error: stop reading, flush, then close.
    dead: AtomicBool,
}

/// Poller-private per-connection state machine.
struct Conn {
    stream: TcpStream,
    shared: Arc<ConnShared>,
    /// Accumulates partial reads until complete frames peel off.
    rbuf: Vec<u8>,
    /// The response currently being written, and how much already went out.
    wbuf: Vec<u8>,
    woff: usize,
    /// Peer sent EOF (or the read half errored): no new requests.
    read_closed: bool,
    /// Unrecoverable socket error: close without waiting to drain.
    broken: bool,
}

impl Conn {
    fn write_idle(&self) -> bool {
        self.woff >= self.wbuf.len()
    }

    /// Everything accepted has been answered and flushed.
    fn drained(&self) -> bool {
        self.shared.inflight.load(Ordering::SeqCst) == 0
            && self.write_idle()
            && lock_unpoisoned(&self.shared.outbox).is_empty()
    }
}

/// Run the readiness loop until `stop` is set (and everything in flight
/// drains) or the listener breaks persistently. Blocks the calling thread;
/// `label` names the service in diagnostics.
pub fn run(listener: TcpListener, rpc: Arc<RpcServer>, stop: Arc<AtomicBool>, label: &'static str) {
    if let Err(e) = run_inner(&listener, &rpc, &stop, label) {
        eprintln!("persia {label}: event loop failed: {e:#}");
    }
}

fn run_inner(
    listener: &TcpListener,
    rpc: &Arc<RpcServer>,
    stop: &Arc<AtomicBool>,
    label: &'static str,
) -> anyhow::Result<()> {
    listener.set_nonblocking(true)?;
    // Loopback UDP self-wake: workers nudge the poller out of poll() when a
    // response is ready. Connected to itself so plain send() delivers.
    let wake = UdpSocket::bind("127.0.0.1:0")?;
    wake.connect(wake.local_addr()?)?;
    wake.set_nonblocking(true)?;
    let wake_tx = Arc::new(wake.try_clone()?);

    let n_workers =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
    let (job_tx, job_rx): (Sender<Job>, Receiver<Job>) = channel();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let workers: Vec<_> = (0..n_workers)
        .map(|i| {
            let job_rx = job_rx.clone();
            std::thread::Builder::new()
                .name(format!("{label}-worker-{i}"))
                .spawn(move || loop {
                    // Holding the lock across recv() is the classic shared-
                    // receiver pattern: idle workers queue on the mutex.
                    let job = lock_unpoisoned(&job_rx).recv();
                    match job {
                        Ok(job) => job(),
                        Err(_) => return,
                    }
                })
        })
        .collect::<std::io::Result<_>>()?;

    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_conn_id = 0u64;
    let mut consecutive_errors = 0u32;
    let mut listener_broken = false;
    let mut drain_deadline: Option<Instant> = None;
    let mut chunk = vec![0u8; 64 * 1024];

    loop {
        let stopping = stop.load(Ordering::SeqCst) || listener_broken;
        if stopping {
            let deadline = *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_DEADLINE);
            conns.retain(|_, c| !c.drained() && !c.broken);
            if conns.is_empty() || Instant::now() >= deadline {
                break;
            }
        }

        // Interest sets: wake and (unless stopping) the listener are always
        // read-watched; connections ask for POLLIN while accepting requests
        // and POLLOUT while output is queued.
        let mut fds = vec![PollFd::new(wake.as_raw_fd(), POLLIN)];
        let conn_base = if stopping {
            1
        } else {
            fds.push(PollFd::new(listener.as_raw_fd(), POLLIN));
            2
        };
        let mut conn_ids: Vec<u64> = Vec::with_capacity(conns.len());
        for (&id, c) in &conns {
            let mut events = 0i16;
            if !stopping && !c.read_closed && !c.shared.dead.load(Ordering::SeqCst) {
                events |= POLLIN;
            }
            if !c.write_idle() || !lock_unpoisoned(&c.shared.outbox).is_empty() {
                events |= POLLOUT;
            }
            if events != 0 {
                fds.push(PollFd::new(c.stream.as_raw_fd(), events));
                conn_ids.push(id);
            }
        }
        poll_fds(&mut fds, Some(POLL_TIMEOUT))?;

        // Drain the wake socket FIRST: any wake sent after this point
        // belongs to state this iteration might miss, and must survive to
        // re-trigger the next poll.
        let mut sink = [0u8; 64];
        while wake.recv(&mut sink).is_ok() {}

        // Accept every pending connection.
        if !stopping && fds[1].readable() {
            loop {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        consecutive_errors = 0;
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        stream.set_nodelay(true).ok();
                        conns.insert(
                            next_conn_id,
                            Conn {
                                stream,
                                shared: Arc::new(ConnShared {
                                    outbox: Mutex::new(VecDeque::new()),
                                    inflight: AtomicUsize::new(0),
                                    dead: AtomicBool::new(false),
                                }),
                                rbuf: Vec::new(),
                                wbuf: Vec::new(),
                                woff: 0,
                                read_closed: false,
                                broken: false,
                            },
                        );
                        next_conn_id += 1;
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) => {
                        consecutive_errors += 1;
                        if consecutive_errors >= MAX_ACCEPT_ERRORS {
                            eprintln!(
                                "persia {label}: accept failing persistently ({e}); stopping"
                            );
                            listener_broken = true;
                            break;
                        }
                    }
                }
            }
        }

        // Read phase: pull bytes off readable connections, peel complete
        // frames, dispatch each as a worker-pool job.
        for (i, &id) in conn_ids.iter().enumerate() {
            if !fds[conn_base + i].readable() {
                continue;
            }
            let Some(c) = conns.get_mut(&id) else { continue };
            if c.read_closed || c.shared.dead.load(Ordering::SeqCst) {
                continue;
            }
            loop {
                match c.stream.read(&mut chunk) {
                    Ok(0) => {
                        c.read_closed = true;
                        break;
                    }
                    Ok(n) => {
                        c.rbuf.extend_from_slice(&chunk[..n]);
                        if n < chunk.len() {
                            // Short read: the socket buffer is drained.
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => {
                        // Hard read error = disconnect (same as the old
                        // recv-error path); deliver what is already queued.
                        c.read_closed = true;
                        break;
                    }
                }
            }
            // Peel complete frames.
            loop {
                if c.rbuf.len() < 4 {
                    break;
                }
                let len = u32::from_le_bytes(c.rbuf[..4].try_into().unwrap()) as usize;
                if len > MAX_FRAME {
                    eprintln!("persia {label}: oversized frame ({len} bytes); dropping peer");
                    c.shared.dead.store(true, Ordering::SeqCst);
                    break;
                }
                if c.rbuf.len() < 4 + len {
                    break;
                }
                let req: Vec<u8> = c.rbuf[4..4 + len].to_vec();
                c.rbuf.drain(..4 + len);
                c.shared.inflight.fetch_add(1, Ordering::SeqCst);
                let rpc = rpc.clone();
                let shared = c.shared.clone();
                let wake_tx = wake_tx.clone();
                let job: Job = Box::new(move || {
                    match rpc.dispatch_frame(&req) {
                        Ok(resp) => {
                            let mut out = Vec::with_capacity(4 + resp.len());
                            out.extend_from_slice(&(resp.len() as u32).to_le_bytes());
                            out.extend_from_slice(&resp);
                            lock_unpoisoned(&shared.outbox).push_back(out);
                        }
                        Err(e) => {
                            eprintln!("persia {label}: connection dropped: {e:#}");
                            shared.dead.store(true, Ordering::SeqCst);
                        }
                    }
                    // Publish before waking; the poller drains the wake
                    // socket before it re-reads this state.
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    let _ = wake_tx.send(&[1]);
                });
                if job_tx.send(job).is_err() {
                    // Workers are gone (only during teardown).
                    c.shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    break;
                }
            }
        }

        // Write phase: flush outboxes with non-blocking writes. Attempted
        // for every connection with queued output (not just POLLOUT hits) —
        // a freshly completed response should not wait one extra poll round.
        for c in conns.values_mut() {
            loop {
                if c.write_idle() {
                    match lock_unpoisoned(&c.shared.outbox).pop_front() {
                        Some(next) => {
                            c.wbuf = next;
                            c.woff = 0;
                        }
                        None => break,
                    }
                }
                match c.stream.write(&c.wbuf[c.woff..]) {
                    Ok(0) => {
                        c.broken = true;
                        break;
                    }
                    Ok(n) => {
                        c.woff += n;
                        if c.write_idle() {
                            c.wbuf = Vec::new();
                            c.woff = 0;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(_) => {
                        c.broken = true;
                        break;
                    }
                }
            }
        }

        // Retire connections: broken sockets immediately; closed/errored
        // peers once everything they asked for has been flushed.
        conns.retain(|_, c| {
            if c.broken {
                return false;
            }
            let done = (c.read_closed || c.shared.dead.load(Ordering::SeqCst)) && c.drained();
            !done
        });
    }

    // Stop the workers: close the queue and join (pending jobs finish, but
    // their connections are gone — their outbox pushes are no-ops).
    drop(job_tx);
    for w in workers {
        let _ = w.join();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::rpc::{PendingReply, PipelinedClient, RpcClient};
    use crate::comm::transport::TcpTransport;
    use crate::comm::wire::{WireReader, WireWriter};
    use std::net::SocketAddr;
    use std::thread::JoinHandle;

    /// Spawn the readiness loop serving a kind-1 echo handler.
    fn spawn_echo() -> (SocketAddr, Arc<AtomicBool>, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut rpc = RpcServer::new();
        rpc.register(1, Box::new(|msg| Ok(msg.to_vec())));
        let rpc = Arc::new(rpc);
        let stop = rpc.stop_flag();
        let stop_for_loop = stop.clone();
        let handle =
            std::thread::spawn(move || run(listener, rpc, stop_for_loop, "event-loop-test"));
        (addr, stop, handle)
    }

    fn stop_loop(addr: SocketAddr, stop: &Arc<AtomicBool>, handle: JoinHandle<()>) {
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr); // wake the poller
        handle.join().unwrap();
    }

    fn echo_msg(x: u64) -> Vec<u8> {
        let mut w = WireWriter::new(1);
        w.put_u64(&[x]);
        w.finish()
    }

    #[test]
    fn serves_lockstep_and_pipelined_clients_concurrently() {
        let (addr, stop, handle) = spawn_echo();
        let lockstep = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
        let pipelined =
            PipelinedClient::connect(&addr.to_string(), 16, Some(Duration::from_secs(30)))
                .unwrap();
        // Fill the pipeline, then interleave a lock-step call on a second
        // connection while those responses are still outstanding.
        let pending: Vec<PendingReply> =
            (0..32u64).map(|i| pipelined.call_async(&echo_msg(i)).unwrap()).collect();
        let resp = lockstep.call(&echo_msg(999)).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![999]);
        for (i, p) in pending.into_iter().enumerate().rev() {
            let resp = p.wait().unwrap();
            assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![i as u64]);
        }
        drop(lockstep);
        drop(pipelined);
        stop_loop(addr, &stop, handle);
    }

    #[test]
    fn handler_error_drops_only_the_offending_connection() {
        let (addr, stop, handle) = spawn_echo();
        let bad = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
        // Kind 99 has no handler: the server drops this connection.
        assert!(bad.call(&WireWriter::new(99).finish()).is_err());
        // A fresh connection is unaffected.
        let good = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
        let resp = good.call(&echo_msg(7)).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![7]);
        drop(bad);
        drop(good);
        stop_loop(addr, &stop, handle);
    }

    #[test]
    fn survives_mid_stream_disconnects_and_garbage() {
        let (addr, stop, handle) = spawn_echo();
        // Peer 1: connects and vanishes without sending anything.
        drop(TcpStream::connect(addr).unwrap());
        // Peer 2: sends half a frame header, then disconnects.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&[3, 0]).unwrap();
        }
        // Peer 3: announces an absurd frame length.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        }
        // A well-behaved client still gets served.
        let client = RpcClient::new(TcpTransport::connect(&addr.to_string()).unwrap());
        let resp = client.call(&echo_msg(42)).unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![42]);
        drop(client);
        stop_loop(addr, &stop, handle);
    }

    #[test]
    fn shutdown_flushes_inflight_responses() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut rpc = RpcServer::new();
        // A deliberately slow handler: the stop flag flips while its
        // response is still being computed.
        rpc.register(
            1,
            Box::new(|msg| {
                std::thread::sleep(Duration::from_millis(100));
                Ok(msg.to_vec())
            }),
        );
        let rpc = Arc::new(rpc);
        let stop = rpc.stop_flag();
        let stop_for_loop = stop.clone();
        let handle =
            std::thread::spawn(move || run(listener, rpc, stop_for_loop, "event-loop-test"));
        let client =
            PipelinedClient::connect(&addr.to_string(), 4, Some(Duration::from_secs(30)))
                .unwrap();
        let pending = client.call_async(&echo_msg(5)).unwrap();
        std::thread::sleep(Duration::from_millis(20)); // request is in flight
        stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(addr);
        // Shutdown drains: the in-flight response still arrives.
        let resp = pending.wait().unwrap();
        assert_eq!(WireReader::parse(&resp).unwrap().u64(0).unwrap(), vec![5]);
        handle.join().unwrap();
    }
}
