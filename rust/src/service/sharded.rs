//! [`ShardedRemotePs`]: one [`PsBackend`] over N independent PS processes.
//!
//! The paper's capacity story (§4.2.2–§4.2.4) requires *many* embedding PS
//! processes, each owning a slice of the key space via the global hash. This
//! client takes the full list of shard addresses, routes every packed key
//! with the **same** [`route`](crate::embedding::ps::route) function the
//! servers use (factored out of `EmbeddingPs` precisely so both sides
//! provably agree), and scatter-gathers batched get/put traffic:
//!
//! * each shard process gets its own [`RemotePs`] pool of pipelined
//!   connections;
//! * hot-path GET/PUT sub-batches are issued as pipelined async requests —
//!   every shard's request is on the wire before any response is claimed —
//!   so a mini-batch costs one round-trip to the *slowest* shard, not the
//!   sum, without spawning a thread per shard per batch (control-plane
//!   calls — stats, checkpoint epochs — still use scoped-thread scatter);
//! * responses are reassembled into the caller's slot order, so workers are
//!   oblivious to the sharding;
//! * per-shard [`PsStats`] are merged from the raw per-node traffic vectors
//!   (summed element-wise), which yields the *correct* global max/mean
//!   imbalance — averaging per-process imbalance ratios would not.
//!
//! **Live resharding.** Ownership is no longer fixed at connect time: the
//! `node → shard` map lives behind an epoch-versioned routing view. A
//! shard that answers a routed GET/PUT with an in-band NOT_OWNER frame
//! (nothing served, nothing applied) makes the client refresh its view from
//! the fleet's committed [`RoutingTable`] and retry *only the refused
//! sub-batch* against the new owner — retrying the whole batch would
//! double-apply the sub-batches other shards already accepted. One client —
//! the trainer's rank 0, through [`PsBackend::maybe_reshard`] — acts as the
//! reshard *coordinator*: it merges fleet traffic stats, runs
//! [`plan_rebalance`](super::reshard::plan_rebalance), and drives the
//! PREPARE → MIGRATE_OUT → COMMIT barrier over one-shot control
//! connections, aborting everywhere if any step fails so the deployment
//! falls back to its current layout.
//!
//! Connect-time validation: every shard must report the same config
//! fingerprint, and the shards' node ranges must partition `0..n_nodes`
//! exactly (full coverage, no overlap; `--join` spares own nothing and are
//! valid). A killed-and-restarted shard rejoins transparently via
//! [`RemotePs`]'s reconnect-with-retry, and
//! [`ShardedRemotePs::snapshot_node`]/[`ShardedRemotePs::restore_node`]
//! drive the §4.2.4 recovery drill over the wire.

use std::path::Path;
use std::sync::RwLock;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::comm::rpc::RpcClient;
use crate::comm::transport::TcpTransport;
use crate::config::{EmbeddingConfig, PartitionPolicy, ServiceConfig};
use crate::embedding::ps::{imbalance_of, pack_key, route};
use crate::embedding::NodeSnapshot;
use crate::util::{read_unpoisoned, write_unpoisoned};

use super::backend::{PsBackend, PsStats};
use super::client::{RemotePs, ShardCall};
use super::protocol;
use super::reshard::{self, MigrationPlan, RoutingTable};

/// How many times a routed batch may chase a moving routing table before
/// giving up. Each retry re-partitions only the refused sub-batches after a
/// fleet-wide routing refresh; commits are serialized at the coordinator,
/// so more than a couple of refreshes means the fleet is inconsistent.
const MAX_ROUTE_REFRESHES: usize = 4;

/// Per-call deadline of one-shot reshard control RPCs (PREPARE / COMMIT /
/// ABORT): cheap state flips that either answer promptly or are down.
const CTL_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-call deadline of the MIGRATE_OUT control RPC, which streams every
/// migrating node's snapshot to the destination before acking.
const MIGRATE_TIMEOUT: Duration = Duration::from_secs(600);

/// The client's current belief about node ownership, versioned by routing
/// epoch. Epoch 0 is derived from the INFO handshake ranges; committed
/// reshards advance it (eagerly at the coordinator, lazily — via NOT_OWNER
/// — everywhere else).
struct RoutingView {
    epoch: u64,
    /// Global node index -> index into `shards`.
    node_owner: Vec<usize>,
}

/// A sharded remote embedding PS: the union of N `serve-ps` processes.
pub struct ShardedRemotePs {
    shards: Vec<RemotePs>,
    view: RwLock<RoutingView>,
    policy: PartitionPolicy,
    dim: usize,
    n_nodes: usize,
    shards_per_node: usize,
}

impl ShardedRemotePs {
    /// Connect to every address in `cfg.addr` (comma-separated) and verify
    /// the processes jointly form one coherent PS.
    pub fn connect(cfg: &ServiceConfig) -> Result<ShardedRemotePs> {
        cfg.validate()?;
        let addrs = cfg.shard_addrs();
        let shards: Vec<RemotePs> = addrs
            .iter()
            .map(|addr| RemotePs::connect_addr(cfg, addr))
            .collect::<Result<_>>()?;

        // Every shard must describe the same global PS (same numerics
        // fingerprint and geometry); only the owned node range — and the
        // per-process instance identity (boot nonce, restored epoch,
        // joinable role, committed routing epoch) — may differ.
        let first = *shards[0].info();
        for s in &shards[1..] {
            let info = s.info();
            let strip = |i: &protocol::PsInfo| {
                let mut i = *i;
                i.node_start = 0;
                i.node_end = i.n_nodes;
                i.boot_nonce = 0;
                i.restored_step = 0;
                i.joinable = false;
                i.routing_epoch = 0;
                i
            };
            ensure!(
                strip(info) == strip(&first),
                "shard {} disagrees with shard {} on the PS config: {info:?} vs {first:?}",
                s.addr(),
                shards[0].addr()
            );
        }
        let policy = protocol::partition_from_code(first.partition_code)
            .ok_or_else(|| anyhow::anyhow!("unknown partition code {}", first.partition_code))?;

        // The node ranges must partition 0..n_nodes exactly. A `--join`
        // spare advertises the empty range and contributes nothing here —
        // it becomes routable only through a committed reshard.
        let mut node_owner = vec![usize::MAX; first.n_nodes];
        for (si, s) in shards.iter().enumerate() {
            for node in s.node_range() {
                ensure!(
                    node_owner[node] == usize::MAX,
                    "node {node} owned by both {} and {}",
                    shards[node_owner[node]].addr(),
                    s.addr()
                );
                node_owner[node] = si;
            }
        }
        if let Some(orphan) = node_owner.iter().position(|&o| o == usize::MAX) {
            bail!(
                "node {orphan} of {} is not served by any of the {} shard(s); \
                 pass the complete --node-range partition",
                first.n_nodes,
                shards.len()
            );
        }
        // A restarted deployment that resharded before dying advertises the
        // post-migration ranges AND the epoch it committed; adopt the
        // highest so this client's NOT_OWNER handling starts from the
        // fleet's real epoch instead of re-deriving 0.
        let epoch = shards.iter().map(|s| s.info().routing_epoch).max().unwrap_or(0);

        Ok(ShardedRemotePs {
            shards,
            view: RwLock::new(RoutingView { epoch, node_owner }),
            policy,
            dim: first.dim,
            n_nodes: first.n_nodes,
            shards_per_node: first.shards_per_node,
        })
    }

    /// Number of shard processes behind this backend.
    pub fn n_shard_processes(&self) -> usize {
        self.shards.len()
    }

    /// The shard process client currently serving global `node`.
    pub fn shard_for_node(&self, node: usize) -> &RemotePs {
        let owner = read_unpoisoned(&self.view).node_owner[node];
        &self.shards[owner]
    }

    /// Global node count.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Lock-striped shards per node (uniform across the deployment).
    pub fn shards_per_node(&self) -> usize {
        self.shards_per_node
    }

    /// A point-in-time copy of the `node → shard` map. Each routing round
    /// partitions against one immutable snapshot, so a concurrent refresh
    /// can at worst make this round's requests stale (answered NOT_OWNER
    /// and retried) — never torn.
    fn owner_snapshot(&self) -> Vec<usize> {
        read_unpoisoned(&self.view).node_owner.clone()
    }

    /// The shard-process index a packed key routes to under `owner`.
    #[inline]
    fn owner_of(&self, owner: &[usize], packed: u64) -> usize {
        let (node, _) = route(self.policy, self.n_nodes, self.shards_per_node, packed);
        owner[node]
    }

    /// Split the given `slots` of `packed` per owning shard process under
    /// `owner`, remembering each key's slot in the caller's batch so
    /// responses reassemble in order.
    fn partition_slots(
        &self,
        owner: &[usize],
        packed: &[u64],
        slots: &[usize],
    ) -> Vec<(Vec<usize>, Vec<u64>)> {
        let mut per: Vec<(Vec<usize>, Vec<u64>)> =
            (0..self.shards.len()).map(|_| (Vec::new(), Vec::new())).collect();
        for &slot in slots {
            let s = self.owner_of(owner, packed[slot]);
            per[s].0.push(slot);
            per[s].1.push(packed[slot]);
        }
        per
    }

    /// Run `f(shard_index)` for every shard listed in `active`, concurrently
    /// when there is more than one. Returns results in `active` order.
    fn scatter<T: Send, F>(&self, active: &[usize], f: F) -> Vec<Result<T>>
    where
        F: Fn(usize) -> Result<T> + Sync,
    {
        if active.len() == 1 {
            // Common fast path (single shard deployment / skewed batch):
            // no thread spawn.
            return vec![f(active[0])];
        }
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = active.iter().map(|&si| scope.spawn(move || f(si))).collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow::anyhow!("shard request thread panicked")),
                })
                .collect()
        })
    }

    /// Pull the committed [`RoutingTable`] from the fleet and adopt the
    /// highest epoch found. Called when some shard answered NOT_OWNER: at
    /// least one server must hold a committed table whose epoch exceeds
    /// this client's view, or the refusal is unexplainable and surfaced as
    /// an error. Adopting a new epoch drops every shard's put-replay log —
    /// entries recorded against the old routing would replay migrated keys
    /// into a shard that no longer owns them.
    fn refresh_routing(&self) -> Result<()> {
        let mut best: Option<RoutingTable> = None;
        for s in &self.shards {
            match s.fetch_routing() {
                Ok(Some(t)) => {
                    let newer = match &best {
                        None => true,
                        Some(b) => t.epoch > b.epoch,
                    };
                    if newer {
                        best = Some(t);
                    }
                }
                Ok(None) => {}
                Err(e) => {
                    eprintln!("RESHARD: routing fetch from {} failed: {e:#}", s.addr());
                }
            }
        }
        let Some(table) = best else {
            bail!(
                "a shard refused a routed batch (NOT_OWNER) but no shard serves a \
                 committed routing table — fleet is inconsistent"
            );
        };
        table.validate()?;
        ensure!(
            table.n_nodes == self.n_nodes,
            "committed routing table spans {} nodes, deployment has {}",
            table.n_nodes,
            self.n_nodes
        );
        ensure!(
            table.addrs.len() == self.shards.len(),
            "committed routing table lists {} shard(s), this client dialed {}; every \
             process must pass the same --remote-ps list in the same order",
            table.addrs.len(),
            self.shards.len()
        );
        let adopted = {
            let mut v = write_unpoisoned(&self.view);
            if table.epoch > v.epoch {
                v.epoch = table.epoch;
                v.node_owner = table.owner.iter().map(|&o| o as usize).collect();
                true
            } else {
                false
            }
        };
        if adopted {
            let dropped: usize = self.shards.iter().map(|s| s.clear_replay()).sum();
            if dropped > 0 {
                eprintln!(
                    "RESHARD: dropped {dropped} recorded put batch(es) made stale by \
                     routing epoch {}; crash-replay coverage resumes at the next \
                     committed checkpoint",
                    table.epoch
                );
            }
            eprintln!("RESHARD: adopted routing epoch {} from the fleet", table.epoch);
        }
        Ok(())
    }

    /// One reshard control RPC on a fresh, short-lived connection.
    /// Deliberately NOT the recovery pool: a control step that cannot reach
    /// its shard must fail fast into the ABORT path, not silently redial
    /// and replay into a half-staged barrier.
    fn ctl_call(&self, shard: usize, msg: &[u8], timeout: Duration) -> Result<Vec<u8>> {
        let addr = self.shards[shard].addr();
        let t = TcpTransport::connect(addr)
            .with_context(|| format!("dialing shard {addr} for reshard control"))?;
        t.set_timeouts(Some(timeout))?;
        RpcClient::new(t).call(msg)
    }

    /// Best-effort ABORT_RESHARD on every shard (idempotent server-side:
    /// shards with nothing staged ack trivially). Failures are reported but
    /// not propagated — the caller is already on the failure path.
    fn abort_reshard(&self, from_epoch: u64) {
        let msg = protocol::encode_reshard_ctl(protocol::KIND_ABORT_RESHARD, from_epoch);
        for s in 0..self.shards.len() {
            if let Err(e) = self.ctl_call(s, &msg, CTL_TIMEOUT) {
                eprintln!(
                    "RESHARD: ABORT to shard {} failed: {e:#} (its stale stage clears \
                     at its next PREPARE or restart)",
                    self.shards[s].addr()
                );
            }
        }
    }

    /// Merged fleet statistics plus the element-wise sum of every shard's
    /// per-node traffic vector (the planner's input).
    fn fleet_stats(&self) -> Result<(PsStats, Vec<u64>)> {
        let all: Vec<usize> = (0..self.shards.len()).collect();
        let results = self.scatter(&all, |si| self.shards[si].stats_full());
        let mut merged = PsStats::default();
        let mut traffic = vec![0u64; self.n_nodes];
        for r in results {
            let (stats, node_traffic) = r?;
            merged.total_rows += stats.total_rows;
            merged.total_evictions += stats.total_evictions;
            merged.hot_hits += stats.hot_hits;
            merged.cold_hits += stats.cold_hits;
            merged.demotions += stats.demotions;
            merged.promotions += stats.promotions;
            merged.cold_rows += stats.cold_rows;
            ensure!(
                node_traffic.len() == self.n_nodes,
                "shard reported {} traffic entries, want {}",
                node_traffic.len(),
                self.n_nodes
            );
            for (acc, t) in traffic.iter_mut().zip(&node_traffic) {
                *acc += t;
            }
        }
        // Global imbalance from the summed per-node traffic — the same
        // shared formula the in-process EmbeddingPs uses.
        merged.imbalance = imbalance_of(&traffic);
        Ok((merged, traffic))
    }

    /// This client's view of the fleet as a [`RoutingTable`] (current
    /// epoch, current ownership, `--remote-ps` address order).
    fn current_table(&self) -> Result<RoutingTable> {
        let (epoch, owner) = {
            let v = read_unpoisoned(&self.view);
            (v.epoch, v.node_owner.clone())
        };
        let table = RoutingTable {
            epoch,
            n_nodes: self.n_nodes,
            owner: owner.iter().map(|&o| o as u32).collect(),
            addrs: self.shards.iter().map(|s| s.addr().to_string()).collect(),
        };
        table.validate()?;
        Ok(table)
    }

    /// Drive one planned migration through the fleet-wide barrier:
    /// PREPARE on every shard, MIGRATE_OUT on the source, COMMIT in
    /// dest → source → bystander order. Any failure before the first
    /// COMMIT aborts everywhere and leaves the deployment on its current
    /// layout; a failure *between* COMMITs is reported loudly (a partially
    /// committed epoch self-heals only through the lazy NOT_OWNER path).
    fn execute_plan(&self, plan: &MigrationPlan, next: &RoutingTable) -> Result<Option<u64>> {
        for s in 0..self.shards.len() {
            let msg = protocol::encode_prepare_reshard(plan, next, s);
            let staged = self
                .ctl_call(s, &msg, CTL_TIMEOUT)
                .and_then(|resp| {
                    protocol::decode_reshard_ack(&resp, protocol::KIND_PREPARE_RESHARD)
                })
                .with_context(|| format!("PREPARE_RESHARD on shard {}", self.shards[s].addr()));
            if let Err(e) = staged {
                eprintln!("RESHARD: {e:#}; aborting epoch {} everywhere", next.epoch);
                self.abort_reshard(plan.from_epoch);
                return Ok(None);
            }
        }

        let migrate = protocol::encode_reshard_ctl(protocol::KIND_MIGRATE_OUT, plan.from_epoch);
        let copied = self
            .ctl_call(plan.source, &migrate, MIGRATE_TIMEOUT)
            .and_then(|resp| protocol::decode_reshard_ack(&resp, protocol::KIND_MIGRATE_OUT))
            .with_context(|| {
                format!("MIGRATE_OUT on shard {}", self.shards[plan.source].addr())
            });
        match copied {
            Ok(n) if n == plan.nodes.len() => {}
            Ok(n) => {
                eprintln!(
                    "RESHARD: source copied {n} of {} node(s); aborting epoch {}",
                    plan.nodes.len(),
                    next.epoch
                );
                self.abort_reshard(plan.from_epoch);
                return Ok(None);
            }
            Err(e) => {
                eprintln!("RESHARD: {e:#}; aborting epoch {} everywhere", next.epoch);
                self.abort_reshard(plan.from_epoch);
                return Ok(None);
            }
        }

        // COMMIT order is load-bearing: the destination must own the moved
        // nodes before the source drains its queued copy-window puts into
        // it and gives them up, and bystanders flip last so no shard ever
        // answers for an epoch its neighbours have not reached.
        let mut order = vec![plan.dest, plan.source];
        order.extend((0..self.shards.len()).filter(|&s| s != plan.dest && s != plan.source));
        let commit = protocol::encode_reshard_ctl(protocol::KIND_COMMIT_RESHARD, plan.from_epoch);
        for (i, &s) in order.iter().enumerate() {
            let done = self
                .ctl_call(s, &commit, CTL_TIMEOUT)
                .and_then(|resp| {
                    protocol::decode_reshard_ack(&resp, protocol::KIND_COMMIT_RESHARD)
                })
                .with_context(|| format!("COMMIT_RESHARD on shard {}", self.shards[s].addr()));
            if let Err(e) = done {
                if i == 0 {
                    // Destination never committed: full abort is clean.
                    eprintln!("RESHARD: {e:#}; aborting epoch {} everywhere", next.epoch);
                } else {
                    // Some shards committed epoch N+1, some did not: the
                    // abort clears the stragglers' stage, and the committed
                    // shards' NOT_OWNER answers teach every client the new
                    // table. Loud, because convergence on the new epoch now
                    // depends on that lazy path.
                    eprintln!(
                        "RESHARD: {e:#} AFTER {i} of {} shard(s) committed epoch {}; \
                         aborting stragglers — clients converge via NOT_OWNER",
                        order.len(),
                        next.epoch
                    );
                }
                self.abort_reshard(plan.from_epoch);
                return Ok(None);
            }
        }

        // Fleet committed: flip this client eagerly (other clients learn
        // lazily through NOT_OWNER → refresh_routing).
        {
            let mut v = write_unpoisoned(&self.view);
            if next.epoch > v.epoch {
                v.epoch = next.epoch;
                v.node_owner = next.owner.iter().map(|&o| o as usize).collect();
            }
        }
        let dropped: usize = self.shards.iter().map(|s| s.clear_replay()).sum();
        if dropped > 0 {
            eprintln!(
                "RESHARD: dropped {dropped} recorded put batch(es) made stale by the \
                 migration; crash-replay coverage resumes at the next committed checkpoint"
            );
        }
        Ok(Some(next.epoch))
    }

    /// Snapshot one global node (both tiers, when the owning process runs a
    /// tiered store) via the shard process that owns it.
    pub fn snapshot_node(&self, node: usize) -> Result<NodeSnapshot> {
        ensure!(node < self.n_nodes, "node {node} out of range");
        self.shard_for_node(node).snapshot_node(node)
    }

    /// Restore one global node via the shard process that owns it.
    pub fn restore_node(&self, node: usize, snap: &NodeSnapshot) -> Result<()> {
        ensure!(node < self.n_nodes, "node {node} out of range");
        self.shard_for_node(node).restore_node(node, snap)
    }

    /// The checkpoint-epoch step each shard process restored at startup
    /// (`0` = fresh start), in shard order. A resuming trainer checks these
    /// against the resume epoch so a shard that restored the wrong epoch —
    /// mixed-epoch state — is rejected before any training step runs.
    pub fn restored_steps(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.info().restored_step).collect()
    }

    /// Gracefully shut down every shard process (best-effort: all are
    /// attempted, the first error is reported).
    pub fn shutdown_all(&self) -> Result<()> {
        let mut first_err = None;
        for s in &self.shards {
            if let Err(e) = s.shutdown_server() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

impl PsBackend for ShardedRemotePs {
    fn dim(&self) -> usize {
        self.dim
    }

    fn check_compat(&self, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
        // All shards already proved mutually identical at connect time, so
        // checking the first against the trainer covers the fleet. Coverage
        // of 0..n_nodes was also proved at connect time.
        protocol::check_fingerprint(self.shards[0].info(), cfg, seed)
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == keys.len() * self.dim, "GET output shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let dim = self.dim;
        let mut pending: Vec<usize> = (0..packed.len()).collect();
        for _round in 0..=MAX_ROUTE_REFRESHES {
            let owner = self.owner_snapshot();
            let per = self.partition_slots(&owner, &packed, &pending);
            let active: Vec<usize> = (0..per.len()).filter(|&si| !per[si].1.is_empty()).collect();
            // Every shard's GET departs before any response is claimed: the
            // N round-trips overlap on the pipelined connections.
            let calls: Vec<_> =
                active.iter().map(|&si| self.shards[si].start_get(&per[si].1)).collect();
            let mut refused: Vec<usize> = Vec::new();
            // Claim and reassemble into the caller's slot order; NOT_OWNER
            // sub-batches (untouched server-side) queue for the next round.
            for (&si, call) in active.iter().zip(calls) {
                let (slots, shard_keys) = &per[si];
                let mut rows = vec![0.0f32; shard_keys.len() * dim];
                let outcome = self.shards[si]
                    .finish_get(call, &mut rows)
                    .with_context(|| format!("GET from shard {}", self.shards[si].addr()))?;
                match outcome {
                    ShardCall::Applied => {
                        for (i, &slot) in slots.iter().enumerate() {
                            out[slot * dim..(slot + 1) * dim]
                                .copy_from_slice(&rows[i * dim..(i + 1) * dim]);
                        }
                    }
                    ShardCall::NotOwner(_) => refused.extend_from_slice(slots),
                }
            }
            if refused.is_empty() {
                return Ok(());
            }
            pending = refused;
            self.refresh_routing().context("refreshing routing after a NOT_OWNER GET")?;
        }
        bail!(
            "GET still refused for {} key(s) after {MAX_ROUTE_REFRESHES} routing refreshes",
            pending.len()
        )
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        ensure!(grads.len() == keys.len() * self.dim, "PUT gradient shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let dim = self.dim;
        let mut pending: Vec<usize> = (0..packed.len()).collect();
        for _round in 0..=MAX_ROUTE_REFRESHES {
            let owner = self.owner_snapshot();
            let per = self.partition_slots(&owner, &packed, &pending);
            let active: Vec<usize> = (0..per.len()).filter(|&si| !per[si].1.is_empty()).collect();
            // Gather each shard's gradient rows contiguously before sending
            // (indexed by shard process; inactive shards stay empty).
            let payloads: Vec<Vec<f32>> = per
                .iter()
                .map(|(slots, _)| {
                    let mut rows = Vec::with_capacity(slots.len() * dim);
                    for &slot in slots {
                        rows.extend_from_slice(&grads[slot * dim..(slot + 1) * dim]);
                    }
                    rows
                })
                .collect();
            // Same overlap as get_many: all PUTs depart, then all acks
            // claimed. A NOT_OWNER ack applied NOTHING server-side, so
            // retrying only that sub-batch elsewhere cannot double-apply.
            let calls: Vec<_> = active
                .iter()
                .map(|&si| self.shards[si].start_put(&per[si].1, &payloads[si]))
                .collect();
            let mut refused: Vec<usize> = Vec::new();
            for (&si, call) in active.iter().zip(calls) {
                let outcome = self.shards[si]
                    .finish_put(call, &per[si].1, &payloads[si])
                    .with_context(|| format!("PUT to shard {}", self.shards[si].addr()))?;
                match outcome {
                    ShardCall::Applied => {}
                    ShardCall::NotOwner(_) => refused.extend_from_slice(&per[si].0),
                }
            }
            if refused.is_empty() {
                return Ok(());
            }
            pending = refused;
            self.refresh_routing().context("refreshing routing after a NOT_OWNER PUT")?;
        }
        bail!(
            "PUT still refused for {} key(s) after {MAX_ROUTE_REFRESHES} routing refreshes",
            pending.len()
        )
    }

    fn stats(&self) -> Result<PsStats> {
        Ok(self.fleet_stats()?.0)
    }

    /// The coordinated two-phase epoch (recovery::coordinator): PREPARE on
    /// every shard concurrently, COMMIT only once *all* staged, then
    /// truncate every shard's put replay log. An epoch that fails PREPARE
    /// anywhere commits nowhere — a restore can never mix steps.
    fn checkpoint_epoch(&self, _dir: &Path, step: u64) -> Result<()> {
        let all: Vec<usize> = (0..self.shards.len()).collect();
        for r in self.scatter(&all, |si| {
            self.shards[si]
                .prepare_ckpt(step)
                .with_context(|| format!("PREPARE_CKPT on shard {}", self.shards[si].addr()))
        }) {
            r?;
        }
        for r in self.scatter(&all, |si| {
            self.shards[si]
                .commit_ckpt(step)
                .with_context(|| format!("COMMIT_CKPT on shard {}", self.shards[si].addr()))
        }) {
            r?;
        }
        self.mark_epoch_committed(step);
        Ok(())
    }

    fn mark_epoch_committed(&self, step: u64) {
        for s in &self.shards {
            s.mark_committed(step);
        }
    }

    fn replay_puts(&self) -> bool {
        self.shards.iter().any(|s| PsBackend::replay_puts(s))
    }

    /// The reshard coordinator (paper §4.2.2's load balancing made live):
    /// merge the fleet's per-node traffic, plan one hot-suffix migration if
    /// the per-process imbalance is at or above `threshold`, and drive it
    /// through the PREPARE → MIGRATE_OUT → COMMIT barrier. `Ok(None)`
    /// means "no migration committed" — below threshold, no spare to
    /// receive a split, or a failure that aborted cleanly; training always
    /// continues on the old table in that case.
    fn maybe_reshard(&self, threshold: f64) -> Result<Option<u64>> {
        let (_, traffic) = self.fleet_stats().context("merging fleet stats for reshard")?;
        let table = self.current_table()?;
        let Some(plan) = reshard::plan_rebalance(&table, &traffic, threshold) else {
            return Ok(None);
        };
        let next = reshard::apply(&table, &plan).context("applying migration plan")?;
        eprintln!(
            "RESHARD: imbalance {:.3} >= {threshold:.3}; moving nodes {:?} from shard {} to \
             shard {} (epoch {} -> {})",
            reshard::process_imbalance(&table, &traffic),
            plan.nodes,
            self.shards[plan.source].addr(),
            self.shards[plan.dest].addr(),
            table.epoch,
            next.epoch
        );
        self.execute_plan(&plan, &next)
    }

    /// The committed epoch of the routing view this client is serving GETs
    /// from. Doubles as the embedding-worker cache's flush signal: an
    /// [`EmbCache`](crate::worker::EmbCache) snapshots this value on every
    /// fetch and drops its whole contents when it moves — rows cached under
    /// the old layout may have been owned by a different shard, and the
    /// copy-window semantics only guarantee freshness for reads issued
    /// against the new table.
    fn routing_epoch(&self) -> u64 {
        read_unpoisoned(&self.view).epoch
    }
}
