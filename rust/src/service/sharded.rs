//! [`ShardedRemotePs`]: one [`PsBackend`] over N independent PS processes.
//!
//! The paper's capacity story (§4.2.2–§4.2.4) requires *many* embedding PS
//! processes, each owning a slice of the key space via the global hash. This
//! client takes the full list of shard addresses, routes every packed key
//! with the **same** [`route`](crate::embedding::ps::route) function the
//! servers use (factored out of `EmbeddingPs` precisely so both sides
//! provably agree), and scatter-gathers batched get/put traffic:
//!
//! * each shard process gets its own [`RemotePs`] pool of pipelined
//!   connections;
//! * hot-path GET/PUT sub-batches are issued as pipelined async requests —
//!   every shard's request is on the wire before any response is claimed —
//!   so a mini-batch costs one round-trip to the *slowest* shard, not the
//!   sum, without spawning a thread per shard per batch (control-plane
//!   calls — stats, checkpoint epochs — still use scoped-thread scatter);
//! * responses are reassembled into the caller's slot order, so workers are
//!   oblivious to the sharding;
//! * per-shard [`PsStats`] are merged from the raw per-node traffic vectors
//!   (summed element-wise), which yields the *correct* global max/mean
//!   imbalance — averaging per-process imbalance ratios would not.
//!
//! Connect-time validation: every shard must report the same config
//! fingerprint, and the shards' node ranges must partition `0..n_nodes`
//! exactly (full coverage, no overlap). A killed-and-restarted shard rejoins
//! transparently via [`RemotePs`]'s reconnect-with-retry, and
//! [`ShardedRemotePs::snapshot_node`]/[`ShardedRemotePs::restore_node`]
//! drive the §4.2.4 recovery drill over the wire.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{EmbeddingConfig, PartitionPolicy, ServiceConfig};
use crate::embedding::ps::{imbalance_of, pack_key, route};
use crate::embedding::NodeSnapshot;

use super::backend::{PsBackend, PsStats};
use super::client::RemotePs;
use super::protocol;

/// A sharded remote embedding PS: the union of N `serve-ps` processes.
pub struct ShardedRemotePs {
    shards: Vec<RemotePs>,
    /// Global node index -> index into `shards`.
    node_owner: Vec<usize>,
    policy: PartitionPolicy,
    dim: usize,
    n_nodes: usize,
    shards_per_node: usize,
}

impl ShardedRemotePs {
    /// Connect to every address in `cfg.addr` (comma-separated) and verify
    /// the processes jointly form one coherent PS.
    pub fn connect(cfg: &ServiceConfig) -> Result<ShardedRemotePs> {
        cfg.validate()?;
        let addrs = cfg.shard_addrs();
        let shards: Vec<RemotePs> = addrs
            .iter()
            .map(|addr| RemotePs::connect_addr(cfg, addr))
            .collect::<Result<_>>()?;

        // Every shard must describe the same global PS (same numerics
        // fingerprint and geometry); only the owned node range — and the
        // per-process instance identity (boot nonce, restored epoch) — may
        // differ.
        let first = *shards[0].info();
        for s in &shards[1..] {
            let info = s.info();
            let strip = |i: &protocol::PsInfo| {
                let mut i = *i;
                i.node_start = 0;
                i.node_end = i.n_nodes;
                i.boot_nonce = 0;
                i.restored_step = 0;
                i
            };
            ensure!(
                strip(info) == strip(&first),
                "shard {} disagrees with shard {} on the PS config: {info:?} vs {first:?}",
                s.addr(),
                shards[0].addr()
            );
        }
        let policy = protocol::partition_from_code(first.partition_code)
            .ok_or_else(|| anyhow::anyhow!("unknown partition code {}", first.partition_code))?;

        // The node ranges must partition 0..n_nodes exactly.
        let mut node_owner = vec![usize::MAX; first.n_nodes];
        for (si, s) in shards.iter().enumerate() {
            for node in s.node_range() {
                ensure!(
                    node_owner[node] == usize::MAX,
                    "node {node} owned by both {} and {}",
                    shards[node_owner[node]].addr(),
                    s.addr()
                );
                node_owner[node] = si;
            }
        }
        if let Some(orphan) = node_owner.iter().position(|&o| o == usize::MAX) {
            bail!(
                "node {orphan} of {} is not served by any of the {} shard(s); \
                 pass the complete --node-range partition",
                first.n_nodes,
                shards.len()
            );
        }

        Ok(ShardedRemotePs {
            shards,
            node_owner,
            policy,
            dim: first.dim,
            n_nodes: first.n_nodes,
            shards_per_node: first.shards_per_node,
        })
    }

    /// Number of shard processes behind this backend.
    pub fn n_shard_processes(&self) -> usize {
        self.shards.len()
    }

    /// The shard process client serving global `node`.
    pub fn shard_for_node(&self, node: usize) -> &RemotePs {
        &self.shards[self.node_owner[node]]
    }

    /// Global node count.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    /// Lock-striped shards per node (uniform across the deployment).
    pub fn shards_per_node(&self) -> usize {
        self.shards_per_node
    }

    /// The shard-process index a packed key routes to.
    #[inline]
    fn owner_of(&self, packed: u64) -> usize {
        let (node, _) = route(self.policy, self.n_nodes, self.shards_per_node, packed);
        self.node_owner[node]
    }

    /// Split `packed` keys per owning shard process, remembering each key's
    /// slot in the caller's batch so responses reassemble in order.
    fn partition_keys(&self, packed: &[u64]) -> Vec<(Vec<usize>, Vec<u64>)> {
        let mut per: Vec<(Vec<usize>, Vec<u64>)> =
            (0..self.shards.len()).map(|_| (Vec::new(), Vec::new())).collect();
        for (slot, &key) in packed.iter().enumerate() {
            let s = self.owner_of(key);
            per[s].0.push(slot);
            per[s].1.push(key);
        }
        per
    }

    /// Run `f(shard_index)` for every shard listed in `active`, concurrently
    /// when there is more than one. Returns results in `active` order.
    fn scatter<T: Send, F>(&self, active: &[usize], f: F) -> Vec<Result<T>>
    where
        F: Fn(usize) -> Result<T> + Sync,
    {
        if active.len() == 1 {
            // Common fast path (single shard deployment / skewed batch):
            // no thread spawn.
            return vec![f(active[0])];
        }
        let f = &f;
        std::thread::scope(|scope| {
            let handles: Vec<_> = active.iter().map(|&si| scope.spawn(move || f(si))).collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(_) => Err(anyhow::anyhow!("shard request thread panicked")),
                })
                .collect()
        })
    }

    /// Snapshot one global node (both tiers, when the owning process runs a
    /// tiered store) via the shard process that owns it.
    pub fn snapshot_node(&self, node: usize) -> Result<NodeSnapshot> {
        ensure!(node < self.n_nodes, "node {node} out of range");
        self.shard_for_node(node).snapshot_node(node)
    }

    /// Restore one global node via the shard process that owns it.
    pub fn restore_node(&self, node: usize, snap: &NodeSnapshot) -> Result<()> {
        ensure!(node < self.n_nodes, "node {node} out of range");
        self.shard_for_node(node).restore_node(node, snap)
    }

    /// The checkpoint-epoch step each shard process restored at startup
    /// (`0` = fresh start), in shard order. A resuming trainer checks these
    /// against the resume epoch so a shard that restored the wrong epoch —
    /// mixed-epoch state — is rejected before any training step runs.
    pub fn restored_steps(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.info().restored_step).collect()
    }

    /// Gracefully shut down every shard process (best-effort: all are
    /// attempted, the first error is reported).
    pub fn shutdown_all(&self) -> Result<()> {
        let mut first_err = None;
        for s in &self.shards {
            if let Err(e) = s.shutdown_server() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

impl PsBackend for ShardedRemotePs {
    fn dim(&self) -> usize {
        self.dim
    }

    fn check_compat(&self, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
        // All shards already proved mutually identical at connect time, so
        // checking the first against the trainer covers the fleet. Coverage
        // of 0..n_nodes was also proved at connect time.
        protocol::check_fingerprint(self.shards[0].info(), cfg, seed)
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == keys.len() * self.dim, "GET output shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let per = self.partition_keys(&packed);
        let active: Vec<usize> = (0..per.len()).filter(|&si| !per[si].1.is_empty()).collect();
        let dim = self.dim;
        // Every shard's GET departs before any response is claimed: the N
        // round-trips overlap on the pipelined connections.
        let calls: Vec<_> = active.iter().map(|&si| self.shards[si].start_get(&per[si].1)).collect();
        // Claim and reassemble into the caller's slot order.
        for (&si, call) in active.iter().zip(calls) {
            let (slots, shard_keys) = &per[si];
            let mut rows = vec![0.0f32; shard_keys.len() * dim];
            self.shards[si]
                .finish_get(call, &mut rows)
                .with_context(|| format!("GET from shard {}", self.shards[si].addr()))?;
            for (i, &slot) in slots.iter().enumerate() {
                out[slot * dim..(slot + 1) * dim].copy_from_slice(&rows[i * dim..(i + 1) * dim]);
            }
        }
        Ok(())
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        ensure!(grads.len() == keys.len() * self.dim, "PUT gradient shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let per = self.partition_keys(&packed);
        let active: Vec<usize> = (0..per.len()).filter(|&si| !per[si].1.is_empty()).collect();
        let dim = self.dim;
        // Gather each shard's gradient rows contiguously before sending
        // (indexed by shard process; inactive shards stay empty).
        let payloads: Vec<Vec<f32>> = per
            .iter()
            .map(|(slots, _)| {
                let mut rows = Vec::with_capacity(slots.len() * dim);
                for &slot in slots {
                    rows.extend_from_slice(&grads[slot * dim..(slot + 1) * dim]);
                }
                rows
            })
            .collect();
        // Same overlap as get_many: all PUTs depart, then all acks claimed.
        let calls: Vec<_> = active
            .iter()
            .map(|&si| self.shards[si].start_put(&per[si].1, &payloads[si]))
            .collect();
        for (&si, call) in active.iter().zip(calls) {
            self.shards[si]
                .finish_put(call, &per[si].1, &payloads[si])
                .with_context(|| format!("PUT to shard {}", self.shards[si].addr()))?;
        }
        Ok(())
    }

    fn stats(&self) -> Result<PsStats> {
        let all: Vec<usize> = (0..self.shards.len()).collect();
        let results = self.scatter(&all, |si| self.shards[si].stats_full());
        let mut merged = PsStats::default();
        let mut traffic = vec![0u64; self.n_nodes];
        for r in results {
            let (stats, node_traffic) = r?;
            merged.total_rows += stats.total_rows;
            merged.total_evictions += stats.total_evictions;
            merged.hot_hits += stats.hot_hits;
            merged.cold_hits += stats.cold_hits;
            merged.demotions += stats.demotions;
            merged.promotions += stats.promotions;
            merged.cold_rows += stats.cold_rows;
            ensure!(
                node_traffic.len() == self.n_nodes,
                "shard reported {} traffic entries, want {}",
                node_traffic.len(),
                self.n_nodes
            );
            for (acc, t) in traffic.iter_mut().zip(&node_traffic) {
                *acc += t;
            }
        }
        // Global imbalance from the summed per-node traffic — the same
        // shared formula the in-process EmbeddingPs uses.
        merged.imbalance = imbalance_of(&traffic);
        Ok(merged)
    }

    /// The coordinated two-phase epoch (recovery::coordinator): PREPARE on
    /// every shard concurrently, COMMIT only once *all* staged, then
    /// truncate every shard's put replay log. An epoch that fails PREPARE
    /// anywhere commits nowhere — a restore can never mix steps.
    fn checkpoint_epoch(&self, _dir: &Path, step: u64) -> Result<()> {
        let all: Vec<usize> = (0..self.shards.len()).collect();
        for r in self.scatter(&all, |si| {
            self.shards[si]
                .prepare_ckpt(step)
                .with_context(|| format!("PREPARE_CKPT on shard {}", self.shards[si].addr()))
        }) {
            r?;
        }
        for r in self.scatter(&all, |si| {
            self.shards[si]
                .commit_ckpt(step)
                .with_context(|| format!("COMMIT_CKPT on shard {}", self.shards[si].addr()))
        }) {
            r?;
        }
        self.mark_epoch_committed(step);
        Ok(())
    }

    fn mark_epoch_committed(&self, step: u64) {
        for s in &self.shards {
            s.mark_committed(step);
        }
    }

    fn replay_puts(&self) -> bool {
        self.shards.iter().any(|s| PsBackend::replay_puts(s))
    }
}
