//! The TCP service mode: run the embedding PS as a standalone server
//! (paper §4.2.2/§4.2.3 deployed across processes instead of simulated
//! in-process).
//!
//! * [`backend`] — the [`PsBackend`] trait embedding workers program
//!   against; implemented by the in-process [`crate::embedding::EmbeddingPs`]
//!   and by the TCP client stub.
//! * [`protocol`] — message kinds + codecs over the zero-copy wire format,
//!   with the paper's index compression (deduplicated packed keys) and
//!   optional lossy fp16 value compression.
//! * [`server`] — [`PsServer`]: accept loop, per-connection dispatch
//!   threads, graceful sleep-free shutdown; serves a full PS or one
//!   process's `--node-range` slice, including SNAPSHOT/RESTORE RPCs.
//! * [`client`] — [`RemotePs`]: a mutex-guarded connection pool shared by
//!   every trainer thread, with transparent reconnect-with-retry.
//! * [`sharded`] — [`ShardedRemotePs`]: one backend over N shard processes,
//!   routing with the servers' own global hash and scatter-gathering
//!   batches concurrently.
//!
//! Entry points: `persia serve-ps [--node-range a..b]` starts a (slice of
//! a) server; `persia train --remote-ps <addr>[,<addr>...]` (or setting
//! [`crate::hybrid::Trainer::ps_backend`]) trains against it. The loopback
//! integration tests (`rust/tests/integration_service.rs`,
//! `rust/tests/integration_sharded.rs`) prove the remote paths are
//! numerically identical to the in-process PS and survive the §4.2.4
//! kill/restore recovery drill.

pub mod backend;
pub mod client;
pub mod protocol;
pub mod server;
pub mod sharded;

pub use backend::{PsBackend, PsStats};
pub use client::RemotePs;
pub use server::{PsServer, PsServerHandle};
pub use sharded::ShardedRemotePs;
