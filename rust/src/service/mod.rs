//! The TCP service mode: run the embedding PS as a standalone server
//! (paper §4.2.2/§4.2.3 deployed across processes instead of simulated
//! in-process).
//!
//! * [`backend`] — the [`PsBackend`] trait embedding workers program
//!   against; implemented by the in-process [`crate::embedding::EmbeddingPs`]
//!   and by the TCP client stub.
//! * [`protocol`] — message kinds + codecs over the zero-copy wire format,
//!   with the paper's index compression (deduplicated packed keys) and
//!   optional lossy fp16 value compression.
//! * [`server`] — [`PsServer`]: accept loop, per-connection dispatch
//!   threads, graceful sleep-free shutdown.
//! * [`client`] — [`RemotePs`]: a mutex-guarded connection pool shared by
//!   every trainer thread.
//!
//! Entry points: `persia serve-ps` starts a server;
//! `persia train --remote-ps <addr>` (or setting
//! [`crate::hybrid::Trainer::ps_backend`]) trains against it. The loopback
//! integration test (`rust/tests/integration_service.rs`) proves the remote
//! path is numerically identical to the in-process one.

pub mod backend;
pub mod client;
pub mod protocol;
pub mod server;

pub use backend::{PsBackend, PsStats};
pub use client::RemotePs;
pub use server::{PsServer, PsServerHandle};
