//! The TCP service mode: Persia's stateful tiers as standalone server
//! processes (paper §4.1/§4.2 deployed across processes instead of
//! simulated in-process).
//!
//! Two services live here, sharing the zero-copy wire format, the
//! [`event_loop`] readiness-loop service core, and the
//! fingerprint-handshake policy:
//!
//! **The embedding PS** (`persia serve-ps`):
//! * [`backend`] — the [`PsBackend`] trait embedding workers program
//!   against; implemented by the in-process [`crate::embedding::EmbeddingPs`]
//!   and by the TCP client stub.
//! * [`protocol`] — message kinds + codecs over the zero-copy wire format,
//!   with the paper's index compression (deduplicated packed keys) and
//!   optional lossy fp16 value compression.
//! * [`server`] — [`PsServer`]: the non-blocking readiness loop (one
//!   poller + a bounded worker pool; see [`event_loop`]), graceful
//!   sleep-free shutdown; serves a full PS or one process's `--node-range`
//!   slice, including SNAPSHOT/RESTORE RPCs.
//! * [`client`] — [`RemotePs`]: a [`crate::recovery::ReconnectPool`] shared
//!   by every trainer thread — transparent reconnect-with-retry plus the
//!   put-replay that brings a restarted shard back to exact state. All
//!   retry/backoff/replay policy lives in `recovery/`, not here.
//! * [`sharded`] — [`ShardedRemotePs`]: one backend over N shard processes,
//!   routing with the servers' own global hash and scatter-gathering
//!   batches concurrently.
//!
//! **The embedding-worker tier** (`persia serve-embedding-worker`):
//! * [`embedding_worker`] — the paper's middle tier as its own process:
//!   [`EmbeddingWorkerServer`] runs the pipelined prefetcher
//!   ([`crate::worker::PrefetchPipeline`]) between the PS shards and the NN
//!   ring and serves NEXT_BATCH / PUSH_GRADS / EVAL / STATS / SHUTDOWN;
//!   [`RemoteEmbeddingWorker`] is the pooled client, and [`RemoteEmbTier`]
//!   implements the trainer's [`crate::worker::EmbComm`] seam over M worker
//!   processes with round-robin rank assignment.
//!
//! Entry points: `persia serve-ps [--node-range a..b]` starts a (slice of
//! a) PS; `persia serve-embedding-worker --remote-ps <addr,...>` starts an
//! embedding worker over the PS fleet; `persia train` reaches them with
//! `--remote-ps` (two-tier) or `--embedding-workers` (three-tier), or via
//! [`crate::hybrid::Trainer::ps_backend`] /
//! [`crate::hybrid::Trainer::emb_comm`]. The loopback integration tests
//! (`rust/tests/integration_service.rs`, `rust/tests/integration_sharded.rs`,
//! `rust/tests/integration_embedding_worker.rs`) prove the remote paths are
//! numerically identical to the in-process ones and survive the §4.2.4
//! kill/restore recovery drills.

pub mod backend;
pub mod client;
pub mod embedding_worker;
#[cfg(unix)]
pub mod event_loop;
pub mod protocol;
pub mod reshard;
pub mod server;
pub mod sharded;

pub use backend::{PsBackend, PsStats};
pub use client::RemotePs;
pub use embedding_worker::{
    EmbeddingWorkerServer, EwExpect, EwInfo, EwServerHandle, RemoteEmbTier,
    RemoteEmbeddingWorker,
};
pub use reshard::{plan_rebalance, MigrationPlan, RoutingTable};
pub use server::{serve_rpc, PsBindOpts, PsServer, PsServerHandle};
pub use sharded::ShardedRemotePs;
