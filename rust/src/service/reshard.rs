//! Live PS resharding: versioned routing tables + migration planning.
//!
//! The static deployment assumption so far was that the `--node-range`
//! slices given to each `serve-ps` at startup ARE the routing table, forever.
//! Zipf traffic breaks that: the per-node stats from PR 2 show a few nodes
//! absorbing most of the load, and Lui et al. (PAPERS.md) argue
//! placement/rebalancing is *the* operative problem at this scale. This
//! module supplies the data plane-independent half of the fix:
//!
//! * [`RoutingTable`] — an **epoch-versioned** map `node → shard process`,
//!   serialized with the same magic + CRC framing as every other durable
//!   artifact in the repo (corruption ⇒ `Err`, never a panic, never a
//!   structurally inconsistent table).
//! * [`MigrationPlan`] — one contiguous node range moving from a hot source
//!   shard to an empty (freshly `--join`ed) destination shard.
//! * [`plan_rebalance`] — the planner: merged per-node traffic in, a plan
//!   out iff the per-process imbalance exceeds the caller's threshold AND
//!   the move provably reduces it.
//! * [`apply`] — pure function from `(table at epoch N, plan)` to the table
//!   at epoch N+1; the property suite pins totality (every node owned by
//!   exactly one shard) and minimal movement (only `plan.nodes` changes
//!   owner).
//!
//! The wire/barrier machinery that *executes* a plan (PREPARE → MIGRATE →
//! COMMIT/ABORT) lives in [`super::server`] and [`super::sharded`]; this
//! module stays free of sockets so the planner is exhaustively testable.

use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::comm::wire::{WireReader, WireWriter};
use crate::embedding::checkpoint::crc32;

/// Leading magic of a serialized [`RoutingTable`].
const TABLE_MAGIC: &[u8; 8] = b"PRRT0001";
/// Wire-message kind of the table body (file-local, not a network kind).
const KIND_TABLE: u32 = 0x7F03;
/// Leading magic of a serialized [`MigrationPlan`].
const PLAN_MAGIC: &[u8; 8] = b"PRMP0001";
/// Wire-message kind of the plan body (file-local, not a network kind).
const KIND_PLAN: u32 = 0x7F04;

/// When a trainer probes for live resharding (`--reshard-every` +
/// `--reshard-threshold`): every `every` steps, rank 0 merges the fleet's
/// per-node traffic and runs [`plan_rebalance`] with `threshold`.
#[derive(Clone, Debug)]
pub struct ReshardConfig {
    /// Probe the fleet's imbalance every this many steps (at step
    /// boundaries, like checkpoint epochs).
    pub every: usize,
    /// Migrate when the per-process imbalance (max over mean of per-shard
    /// traffic) is at or above this. Must exceed 1.0 — the imbalance of a
    /// perfectly balanced fleet — or every probe would trigger a migration.
    pub threshold: f64,
}

impl ReshardConfig {
    /// Error on a configuration that can never behave sensibly.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.every >= 1, "reshard cadence must be >= 1 step");
        ensure!(
            self.threshold > 1.0 && self.threshold.is_finite(),
            "reshard threshold must be a finite value > 1.0 (got {})",
            self.threshold
        );
        Ok(())
    }
}

/// Epoch-versioned ownership map: which shard *process* serves each PS node.
///
/// Epoch 0 is the implicit table every deployment starts with — derived
/// from the `--node-range` slices advertised in the INFO handshake, in
/// `--remote-ps` list order. Every committed reshard bumps the epoch by
/// one; clients and servers compare epochs to decide who is stale.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoutingTable {
    /// Version counter; a higher epoch always supersedes a lower one.
    pub epoch: u64,
    /// Total PS nodes (the global `route()` space, unchanged by resharding).
    pub n_nodes: usize,
    /// `owner[node]` = index into `addrs` of the shard serving that node.
    pub owner: Vec<u32>,
    /// Shard process addresses, in the deployment's `--remote-ps` order.
    pub addrs: Vec<String>,
}

impl RoutingTable {
    /// The epoch-0 table of a fresh deployment: `ranges[s]` is shard `s`'s
    /// advertised node range (empty for a `--join` spare).
    pub fn initial(n_nodes: usize, ranges: &[Range<usize>], addrs: &[String]) -> Result<Self> {
        ensure!(ranges.len() == addrs.len(), "ranges/addrs length mismatch");
        let mut owner = vec![u32::MAX; n_nodes];
        for (s, range) in ranges.iter().enumerate() {
            for node in range.clone() {
                ensure!(node < n_nodes, "shard {s} advertises node {node} >= {n_nodes}");
                ensure!(
                    owner[node] == u32::MAX,
                    "node {node} advertised by two shards ({} and {s})",
                    owner[node]
                );
                owner[node] = s as u32;
            }
        }
        for (node, &o) in owner.iter().enumerate() {
            ensure!(o != u32::MAX, "node {node} owned by no shard");
        }
        let t = RoutingTable { epoch: 0, n_nodes, owner, addrs: addrs.to_vec() };
        t.validate()?;
        Ok(t)
    }

    /// Structural invariants every table must satisfy (shared by the codec
    /// and in-memory construction): totality, owner indices in range,
    /// well-formed addresses.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_nodes >= 1, "routing table over zero nodes");
        ensure!(self.owner.len() == self.n_nodes, "owner map length != n_nodes");
        ensure!(!self.addrs.is_empty(), "routing table has no shard addresses");
        for (node, &o) in self.owner.iter().enumerate() {
            ensure!(
                (o as usize) < self.addrs.len(),
                "node {node} owned by shard {o}, only {} shards",
                self.addrs.len()
            );
        }
        for (s, a) in self.addrs.iter().enumerate() {
            ensure!(!a.is_empty(), "shard {s} has an empty address");
            ensure!(!a.contains('\n'), "shard {s} address contains a newline");
        }
        Ok(())
    }

    /// The contiguous node range shard `s` owns (`start..end`), or an empty
    /// range if it owns nothing. Errors if its owned set is not contiguous —
    /// the planner only ever creates contiguous ownership, and the
    /// checkpoint file naming (`shard_A_B`) depends on it.
    pub fn owned_range(&self, s: usize) -> Result<Range<usize>> {
        let nodes: Vec<usize> = (0..self.n_nodes).filter(|&n| self.owner[n] == s as u32).collect();
        let Some(&start) = nodes.first() else {
            return Ok(0..0);
        };
        let end = start + nodes.len();
        ensure!(
            nodes.iter().enumerate().all(|(i, &n)| n == start + i),
            "shard {s} owns a non-contiguous node set {nodes:?}"
        );
        Ok(start..end)
    }

    /// Nodes owned by shard `s` (count only; never errors).
    pub fn owned_count(&self, s: usize) -> usize {
        self.owner.iter().filter(|&&o| o == s as u32).count()
    }

    /// Serialize: magic, CRC-32 of the body, then the wire-format body.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = WireWriter::new(KIND_TABLE);
        w.put_u64(&[self.epoch, self.n_nodes as u64]);
        let owner64: Vec<u64> = self.owner.iter().map(|&o| o as u64).collect();
        w.put_u64(&owner64);
        w.put_u8(self.addrs.join("\n").as_bytes());
        let body = w.finish();
        let mut out = Vec::with_capacity(12 + body.len());
        out.extend_from_slice(TABLE_MAGIC);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse + validate. Arbitrary, truncated, or bit-flipped bytes return
    /// `Err` — never a panic, never an inconsistent table (the reshard
    /// property suite pins this).
    pub fn from_bytes(bytes: &[u8]) -> Result<RoutingTable> {
        ensure!(bytes.len() >= 12, "routing table too short ({} bytes)", bytes.len());
        ensure!(&bytes[..8] == TABLE_MAGIC, "routing table magic mismatch");
        let want = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        let body = &bytes[12..];
        ensure!(crc32(body) == want, "routing table CRC mismatch (torn write?)");
        let r = WireReader::parse(body)?;
        ensure!(r.kind() == KIND_TABLE, "routing table body kind {:#x}", r.kind());
        let head = r.u64(0)?;
        ensure!(head.len() == 2, "routing table header has {} fields", head.len());
        let owner64 = r.u64(1)?;
        let mut owner = Vec::with_capacity(owner64.len());
        for o in owner64 {
            ensure!(o <= u32::MAX as u64, "owner index {o} overflows");
            owner.push(o as u32);
        }
        let addrs: Vec<String> = std::str::from_utf8(r.u8(2)?)
            .context("routing table addresses are not UTF-8")?
            .split('\n')
            .map(|s| s.to_string())
            .collect();
        let t = RoutingTable {
            epoch: head[0],
            n_nodes: usize::try_from(head[1]).context("n_nodes overflows")?,
            owner,
            addrs,
        };
        t.validate()?;
        Ok(t)
    }
}

/// One contiguous node range migrating from `source` to `dest` (both
/// indices into the table's `addrs`), planned against `from_epoch`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MigrationPlan {
    /// The routing epoch this plan was computed against; executing it
    /// produces epoch `from_epoch + 1`.
    pub from_epoch: u64,
    /// Shard index giving up `nodes`.
    pub source: usize,
    /// Shard index receiving `nodes` (must own nothing at `from_epoch`).
    pub dest: usize,
    /// The migrating node range (end-exclusive, non-empty).
    pub nodes: Range<usize>,
}

impl MigrationPlan {
    /// Structural invariants shared by the codec and the planner.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.nodes.start < self.nodes.end, "empty migration range");
        ensure!(self.source != self.dest, "source and destination are the same shard");
        Ok(())
    }

    /// Serialize: magic, CRC-32 of the body, then the wire-format body.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = WireWriter::new(KIND_PLAN);
        w.put_u64(&[
            self.from_epoch,
            self.source as u64,
            self.dest as u64,
            self.nodes.start as u64,
            self.nodes.end as u64,
        ]);
        let body = w.finish();
        let mut out = Vec::with_capacity(12 + body.len());
        out.extend_from_slice(PLAN_MAGIC);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Parse + validate (total: corruption ⇒ `Err`, never a panic).
    pub fn from_bytes(bytes: &[u8]) -> Result<MigrationPlan> {
        ensure!(bytes.len() >= 12, "migration plan too short ({} bytes)", bytes.len());
        ensure!(&bytes[..8] == PLAN_MAGIC, "migration plan magic mismatch");
        let want = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
        let body = &bytes[12..];
        ensure!(crc32(body) == want, "migration plan CRC mismatch");
        let r = WireReader::parse(body)?;
        ensure!(r.kind() == KIND_PLAN, "migration plan body kind {:#x}", r.kind());
        let head = r.u64(0)?;
        ensure!(head.len() == 5, "migration plan header has {} fields", head.len());
        let p = MigrationPlan {
            from_epoch: head[0],
            source: usize::try_from(head[1]).context("source overflows")?,
            dest: usize::try_from(head[2]).context("dest overflows")?,
            nodes: usize::try_from(head[3]).context("range start overflows")?
                ..usize::try_from(head[4]).context("range end overflows")?,
        };
        p.validate()?;
        Ok(p)
    }
}

/// Per-process traffic imbalance of `traffic` (one counter per node) under
/// `table`: max over mean of the per-shard sums, counting only shards that
/// own at least one node. `1.0` for an idle or perfectly balanced
/// deployment — the same convention as the per-node
/// [`imbalance_of`](crate::embedding::ps::imbalance_of).
pub fn process_imbalance(table: &RoutingTable, traffic: &[u64]) -> f64 {
    let sums = per_shard_traffic(table, traffic);
    let serving: Vec<u64> = (0..table.addrs.len())
        .filter(|&s| table.owned_count(s) > 0)
        .map(|s| sums[s])
        .collect();
    let total: u64 = serving.iter().sum();
    if serving.is_empty() || total == 0 {
        return 1.0;
    }
    let mean = total as f64 / serving.len() as f64;
    serving.iter().copied().max().unwrap_or(0) as f64 / mean
}

/// Sum `traffic` per owning shard.
fn per_shard_traffic(table: &RoutingTable, traffic: &[u64]) -> Vec<u64> {
    let mut sums = vec![0u64; table.addrs.len()];
    for (node, &count) in traffic.iter().enumerate().take(table.n_nodes) {
        sums[table.owner[node] as usize] += count;
    }
    sums
}

/// Plan one migration against `table` given merged per-node `traffic`.
///
/// Returns `Some(plan)` only when ALL of the following hold — otherwise
/// `None`, and the deployment keeps its current layout:
///
/// 1. the per-process imbalance is at or above `threshold`;
/// 2. the hottest shard owns ≥ 2 contiguous nodes (a single node cannot
///    split — key-granular splitting is out of scope);
/// 3. some shard owns 0 nodes (a `--join` spare): only an empty shard is a
///    valid destination, because a `--join` server materializes unseen keys
///    over the FULL node range and therefore agrees bitwise with every
///    possible migration — a partial-range server does not;
/// 4. moving the chosen suffix *strictly reduces* the predicted imbalance
///    (a move that merely reshuffles the hot spot is refused).
///
/// The migrated range is the contiguous **suffix** of the hot shard's range
/// split at the traffic midpoint (the split minimizing `|kept − moved|`),
/// which keeps every shard's ownership contiguous forever.
pub fn plan_rebalance(
    table: &RoutingTable,
    traffic: &[u64],
    threshold: f64,
) -> Option<MigrationPlan> {
    if traffic.len() < table.n_nodes || threshold <= 0.0 {
        return None;
    }
    let current = process_imbalance(table, traffic);
    if current < threshold {
        return None;
    }
    let sums = per_shard_traffic(table, traffic);
    // Hottest shard that can actually split (owns >= 2 nodes, contiguous).
    let source = (0..table.addrs.len())
        .filter(|&s| table.owned_count(s) >= 2 && table.owned_range(s).is_ok())
        .max_by_key(|&s| sums[s])?;
    // Destination: the first idle spare.
    let dest = (0..table.addrs.len()).find(|&s| table.owned_count(s) == 0)?;
    let range = table.owned_range(source).ok()?;
    // Split the source range at its traffic midpoint: choose the suffix
    // whose sum is closest to half, both halves non-empty.
    let node_traffic = &traffic[range.start..range.end];
    let total: u64 = node_traffic.iter().sum();
    let mut best_split = None;
    let mut moved_sum: u64 = 0;
    for k in (1..range.len()).rev() {
        // Suffix [k..): moving nodes range.start+k .. range.end.
        moved_sum += node_traffic[k];
        let kept = total - moved_sum;
        let gap = kept.abs_diff(moved_sum);
        match best_split {
            Some((_, g)) if g <= gap => {}
            _ => best_split = Some((k, gap)),
        }
    }
    let (k, _) = best_split?;
    let plan = MigrationPlan {
        from_epoch: table.epoch,
        source,
        dest,
        nodes: range.start + k..range.end,
    };
    // Refuse a move that does not strictly improve the imbalance.
    let predicted = process_imbalance(&apply(table, &plan).ok()?, traffic);
    if predicted >= current {
        return None;
    }
    Some(plan)
}

/// The table at epoch N+1: `plan` applied to `table` (epoch N). Errors if
/// the plan does not fit the table — stale epoch, out-of-range shards or
/// nodes, or a migrating node the source does not own.
pub fn apply(table: &RoutingTable, plan: &MigrationPlan) -> Result<RoutingTable> {
    plan.validate()?;
    ensure!(
        plan.from_epoch == table.epoch,
        "plan targets epoch {}, table is at {}",
        plan.from_epoch,
        table.epoch
    );
    ensure!(plan.source < table.addrs.len(), "plan source {} out of range", plan.source);
    ensure!(plan.dest < table.addrs.len(), "plan dest {} out of range", plan.dest);
    ensure!(
        plan.nodes.end <= table.n_nodes,
        "plan range {:?} exceeds {} nodes",
        plan.nodes,
        table.n_nodes
    );
    let mut next = table.clone();
    for node in plan.nodes.clone() {
        ensure!(
            table.owner[node] == plan.source as u32,
            "node {node} is owned by shard {}, not plan source {}",
            table.owner[node],
            plan.source
        );
        next.owner[node] = plan.dest as u32;
    }
    next.epoch += 1;
    next.validate()?;
    Ok(next)
}

/// Path of the persisted routing table under a checkpoint directory. A
/// shard with `--checkpoint-dir` writes the committed table here at every
/// reshard commit; a restarted `serve-ps` and a resuming trainer both read
/// it so the post-migration layout survives process death.
pub fn routing_path(ckpt_dir: &Path) -> PathBuf {
    ckpt_dir.join("ROUTING")
}

/// Load the persisted routing table under `ckpt_dir`, if present. A
/// missing file is `Ok(None)` (a never-resharded deployment); a corrupt
/// file is an `Err` — silently ignoring it could resurrect a pre-migration
/// layout and serve every migrated node from the wrong shard.
pub fn load_routing(ckpt_dir: &Path) -> Result<Option<RoutingTable>> {
    let path = routing_path(ckpt_dir);
    match std::fs::read(&path) {
        Ok(bytes) => {
            let t = RoutingTable::from_bytes(&bytes)
                .with_context(|| format!("parsing {}", path.display()))?;
            Ok(Some(t))
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(e).with_context(|| format!("reading {}", path.display())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:77{i:02}")).collect()
    }

    /// 2 owning shards + 1 spare over 6 nodes: ps0 = 0..4, ps1 = 4..6.
    fn sample_table() -> RoutingTable {
        RoutingTable::initial(6, &[0..4, 4..6, 0..0], &addrs(3)).unwrap()
    }

    #[test]
    fn initial_table_requires_exact_partition() {
        let t = sample_table();
        assert_eq!(t.epoch, 0);
        assert_eq!(t.owner, vec![0, 0, 0, 0, 1, 1]);
        assert_eq!(t.owned_range(0).unwrap(), 0..4);
        assert_eq!(t.owned_range(2).unwrap(), 0..0);
        // Overlap and orphan are both rejected.
        assert!(RoutingTable::initial(4, &[0..3, 2..4], &addrs(2)).is_err());
        assert!(RoutingTable::initial(4, &[0..1, 2..4], &addrs(2)).is_err());
        assert!(RoutingTable::initial(4, &[0..1, 1..5], &addrs(2)).is_err());
    }

    #[test]
    fn table_roundtrips_and_rejects_corruption() {
        let t = sample_table();
        let bytes = t.to_bytes();
        assert_eq!(RoutingTable::from_bytes(&bytes).unwrap(), t);
        assert!(RoutingTable::from_bytes(&[]).is_err());
        assert!(RoutingTable::from_bytes(&bytes[..bytes.len() - 3]).is_err());
        for i in [0usize, 9, 13, bytes.len() - 1] {
            let mut b = bytes.clone();
            b[i] ^= 0xff;
            assert!(RoutingTable::from_bytes(&b).is_err(), "flip at {i} accepted");
        }
    }

    #[test]
    fn plan_roundtrips_and_rejects_corruption() {
        let p = MigrationPlan { from_epoch: 3, source: 0, dest: 2, nodes: 2..4 };
        let bytes = p.to_bytes();
        assert_eq!(MigrationPlan::from_bytes(&bytes).unwrap(), p);
        assert!(MigrationPlan::from_bytes(&bytes[..7]).is_err());
        for i in [0usize, 9, 12, bytes.len() - 1] {
            let mut b = bytes.clone();
            b[i] ^= 0x10;
            assert!(MigrationPlan::from_bytes(&b).is_err(), "flip at {i} accepted");
        }
        // Structurally invalid plans are rejected even with a valid CRC.
        let empty = MigrationPlan { from_epoch: 0, source: 0, dest: 1, nodes: 2..2 };
        assert!(MigrationPlan::from_bytes(&empty.to_bytes()).is_err());
        let self_move = MigrationPlan { from_epoch: 0, source: 1, dest: 1, nodes: 0..1 };
        assert!(MigrationPlan::from_bytes(&self_move.to_bytes()).is_err());
    }

    #[test]
    fn planner_splits_the_hot_shard_at_the_traffic_midpoint() {
        let t = sample_table();
        // ps0's 4 nodes carry 4x the per-node load of ps1's 2: imbalance
        // (4/6)/(1/2) = 1.333...
        let traffic = vec![10, 10, 10, 10, 10, 10];
        let imb = process_imbalance(&t, &traffic);
        assert!((imb - 4.0 / 3.0).abs() < 1e-9, "imbalance {imb}");
        let plan = plan_rebalance(&t, &traffic, 1.25).expect("imbalance above threshold");
        assert_eq!(plan, MigrationPlan { from_epoch: 0, source: 0, dest: 2, nodes: 2..4 });
        let next = apply(&t, &plan).unwrap();
        assert_eq!(next.epoch, 1);
        assert_eq!(next.owner, vec![0, 0, 2, 2, 1, 1]);
        assert!((process_imbalance(&next, &traffic) - 1.0).abs() < 1e-9);
        // Below threshold: no plan.
        assert!(plan_rebalance(&t, &traffic, 1.5).is_none());
    }

    #[test]
    fn planner_refuses_without_a_spare_or_a_splittable_source() {
        // No empty shard to receive the split.
        let t = RoutingTable::initial(6, &[0..4, 4..6], &addrs(2)).unwrap();
        assert!(plan_rebalance(&t, &[10; 6], 1.1).is_none());
        // The hottest shard owns a single node: nothing to split.
        let t = RoutingTable::initial(3, &[0..1, 1..3, 0..0], &addrs(3)).unwrap();
        assert!(plan_rebalance(&t, &[100, 1, 1], 1.2).is_none());
        // Idle deployment: imbalance is 1.0, below any sane threshold.
        let t = sample_table();
        assert!(plan_rebalance(&t, &[0; 6], 1.01).is_none());
    }

    #[test]
    fn planner_requires_strict_improvement() {
        // All of ps0's traffic is on its FIRST node: every suffix move
        // leaves the hot node on ps0, so no split helps and the planner
        // must refuse rather than churn state.
        let t = sample_table();
        let traffic = vec![100, 0, 0, 0, 10, 10];
        assert!(plan_rebalance(&t, &traffic, 1.1).is_none());
        // Mirrored onto the LAST node, the suffix move does help.
        let traffic = vec![0, 0, 0, 100, 10, 10];
        let plan = plan_rebalance(&t, &traffic, 1.1).expect("suffix move helps");
        assert_eq!(plan.nodes, 3..4);
        assert_eq!(plan.dest, 2);
    }

    #[test]
    fn apply_rejects_plans_that_do_not_fit() {
        let t = sample_table();
        let ok = MigrationPlan { from_epoch: 0, source: 0, dest: 2, nodes: 2..4 };
        // Stale epoch.
        let mut stale = ok.clone();
        stale.from_epoch = 1;
        assert!(apply(&t, &stale).is_err());
        // Source does not own the range.
        let wrong = MigrationPlan { from_epoch: 0, source: 1, dest: 2, nodes: 2..4 };
        assert!(apply(&t, &wrong).is_err());
        // Range beyond the node space.
        let oob = MigrationPlan { from_epoch: 0, source: 1, dest: 2, nodes: 4..7 };
        assert!(apply(&t, &oob).is_err());
        // Shard index beyond the deployment.
        let bad_dest = MigrationPlan { from_epoch: 0, source: 0, dest: 9, nodes: 2..4 };
        assert!(apply(&t, &bad_dest).is_err());
    }

    #[test]
    fn routing_persistence_roundtrips_and_rejects_corruption() {
        let dir =
            std::env::temp_dir().join(format!("persia_reshard_rt_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(load_routing(&dir).unwrap().is_none(), "missing file is not an error");
        let t = sample_table();
        crate::recovery::atomic_write(&routing_path(&dir), &t.to_bytes()).unwrap();
        assert_eq!(load_routing(&dir).unwrap(), Some(t.clone()));
        let mut bytes = t.to_bytes();
        bytes[16] ^= 0x01;
        std::fs::write(routing_path(&dir), &bytes).unwrap();
        assert!(load_routing(&dir).is_err(), "corrupt ROUTING file must not be ignored");
        std::fs::remove_dir_all(&dir).ok();
    }
}
