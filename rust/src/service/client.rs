//! TCP client stub: [`RemotePs`] implements [`PsBackend`] against a
//! [`super::PsServer`].
//!
//! A small pool of TCP connections (see
//! [`ServiceConfig::client_conns`](crate::config::ServiceConfig)) is shared
//! round-robin by all threads of the trainer process (NN workers pulling,
//! gradient appliers putting); each connection carries one request at a
//! time, guarded by a mutex, so responses always match their requests
//! without relying on correlation-id reordering.
//!
//! Connections heal themselves: when a call fails, the pooled connection is
//! dropped and re-dialed up to
//! [`ServiceConfig::reconnect_attempts`](crate::config::ServiceConfig) times
//! (constant backoff), re-running the INFO handshake and insisting the
//! server's fingerprint is unchanged. That is what lets a PS shard process
//! that was killed and restarted from its snapshot rejoin a training run
//! mid-flight (§4.2.4, cross-process): the trainer's next get/put simply
//! reconnects and proceeds.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use crate::comm::rpc::RpcClient;
use crate::comm::transport::TcpTransport;
use crate::config::{EmbeddingConfig, ServiceConfig};
use crate::embedding::ps::pack_key;

use super::backend::{PsBackend, PsStats};
use super::protocol;
use super::protocol::PsInfo;

/// Remote embedding-PS backend over TCP (one server process).
pub struct RemotePs {
    addr: String,
    info: PsInfo,
    wire_compress: bool,
    reconnect_attempts: u32,
    reconnect_backoff: Duration,
    /// `None` marks a connection that died and awaits re-dialing.
    clients: Vec<Mutex<Option<RpcClient<TcpTransport>>>>,
    next: AtomicUsize,
}

impl RemotePs {
    /// Connect a pool to the single address in `cfg` and handshake the PS
    /// geometry + config. For a comma-separated shard list use
    /// [`super::ShardedRemotePs`].
    pub fn connect(cfg: &ServiceConfig) -> Result<RemotePs> {
        cfg.validate()?;
        let addrs = cfg.shard_addrs();
        ensure!(
            addrs.len() == 1,
            "RemotePs takes exactly one address (got {:?}); use ShardedRemotePs \
             for a shard list",
            cfg.addr
        );
        Self::connect_addr(cfg, &addrs[0])
    }

    /// Connect a pool to one specific `addr`, taking every other knob
    /// (pool size, compression, retry policy) from `cfg`.
    pub(super) fn connect_addr(cfg: &ServiceConfig, addr: &str) -> Result<RemotePs> {
        let mut clients = Vec::with_capacity(cfg.client_conns);
        for i in 0..cfg.client_conns {
            let transport = TcpTransport::connect(addr)
                .with_context(|| format!("connecting PS pool conn {i} to {addr}"))?;
            clients.push(Mutex::new(Some(RpcClient::new(transport))));
        }
        let resp = {
            let slot = clients[0].lock().unwrap();
            slot.as_ref()
                .expect("fresh pool connection")
                .call(&protocol::encode_info_request())
                .context("PS INFO handshake")?
        };
        let info = protocol::decode_info_response(&resp)?;
        ensure!(info.dim > 0, "remote PS reports dim 0");
        Ok(RemotePs {
            addr: addr.to_string(),
            info,
            wire_compress: cfg.wire_compress,
            reconnect_attempts: cfg.reconnect_attempts,
            reconnect_backoff: Duration::from_millis(cfg.reconnect_backoff_ms),
            clients,
            next: AtomicUsize::new(0),
        })
    }

    /// The server's INFO handshake (geometry + config fingerprint).
    pub fn info(&self) -> &PsInfo {
        &self.info
    }

    /// The address this client dials (and re-dials).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// PS node count reported by the server.
    pub fn n_nodes(&self) -> usize {
        self.info.n_nodes
    }

    /// Lock-striped shards per node reported by the server.
    pub fn shards_per_node(&self) -> usize {
        self.info.shards_per_node
    }

    /// Global node indices owned by this server.
    pub fn node_range(&self) -> std::ops::Range<usize> {
        self.info.node_start..self.info.node_end
    }

    /// Dial a fresh connection and verify the server is (still) the PS we
    /// originally handshook — a shard restarted with different flags must
    /// not be allowed to silently rejoin with different numerics.
    fn redial(&self) -> Result<RpcClient<TcpTransport>> {
        let transport = TcpTransport::connect(&self.addr)
            .with_context(|| format!("reconnecting to PS at {}", self.addr))?;
        let client = RpcClient::new(transport);
        let resp = client.call(&protocol::encode_info_request()).context("PS INFO re-handshake")?;
        let info = protocol::decode_info_response(&resp)?;
        ensure!(
            info == self.info,
            "PS at {} came back with a different config: {info:?} != {:?}",
            self.addr,
            self.info
        );
        Ok(client)
    }

    /// One RPC over the pool, transparently re-dialing a dead connection.
    ///
    /// Note on retries: GET/STATS/SNAPSHOT are idempotent. A retried PUT or
    /// RESTORE whose first attempt died *after* the server applied it is
    /// applied twice — the paper's §4.2.4 stance is that occasional gradient
    /// anomalies during recovery are tolerated, and RESTORE is idempotent in
    /// effect (same bytes, same state).
    fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.clients.len();
        let slot = &self.clients[i];
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.reconnect_attempts {
            if attempt > 0 {
                // Backoff with the slot lock RELEASED: during an outage every
                // thread waiting on this slot sleeps in parallel instead of
                // queueing behind one holder's full retry schedule. (Redial
                // itself stays under the lock — connecting to a live server
                // is fast, and a dead one refuses immediately on loopback.)
                std::thread::sleep(self.reconnect_backoff);
            }
            let mut guard = slot.lock().unwrap();
            if guard.is_none() {
                match self.redial() {
                    Ok(client) => *guard = Some(client),
                    Err(e) => {
                        last_err = Some(e);
                        continue;
                    }
                }
            }
            match guard.as_ref().expect("connection present").call(msg) {
                Ok(resp) => return Ok(resp),
                Err(e) => {
                    // Connection is toast (peer died, frame torn): drop it so
                    // the next attempt re-dials instead of reusing it.
                    *guard = None;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.expect("at least one attempt ran")).with_context(|| {
            format!(
                "PS at {} unreachable after {} reconnect attempt(s)",
                self.addr, self.reconnect_attempts
            )
        })
    }

    /// Ask the server to shut down gracefully (stop accepting, drain
    /// connections). The ack is received before the server exits its loop.
    pub fn shutdown_server(&self) -> Result<()> {
        self.call(&protocol::encode_shutdown_request()).context("PS shutdown request")?;
        Ok(())
    }

    /// Batched GET of already-packed keys (the sharded client routes packed
    /// keys, so this skips a pointless unpack/repack).
    pub(super) fn get_packed(&self, packed: &[u64], out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == packed.len() * self.info.dim, "GET output shape mismatch");
        if packed.is_empty() {
            return Ok(());
        }
        let resp = self.call(&protocol::encode_get_request(packed, self.wire_compress))?;
        protocol::decode_get_response_into(&resp, self.info.dim, out)?;
        Ok(())
    }

    /// Batched gradient PUT of already-packed keys.
    pub(super) fn put_packed(&self, packed: &[u64], grads: &[f32]) -> Result<()> {
        ensure!(grads.len() == packed.len() * self.info.dim, "PUT gradient shape mismatch");
        if packed.is_empty() {
            return Ok(());
        }
        let msg = protocol::encode_put_request(packed, grads, self.info.dim, self.wire_compress);
        let resp = self.call(&msg)?;
        let applied = protocol::decode_put_response(&resp)?;
        ensure!(applied == packed.len(), "PS applied {applied} of {} rows", packed.len());
        Ok(())
    }

    /// STATS including the server's global-length per-node traffic vector.
    pub(super) fn stats_full(&self) -> Result<(PsStats, Vec<u64>)> {
        let resp = self.call(&protocol::encode_stats_request())?;
        protocol::decode_stats_full(&resp)
    }

    /// Fetch the flat per-shard snapshots of one (server-owned, globally
    /// indexed) node over the wire — §4.2.4 checkpointing, cross-process.
    pub fn snapshot_node(&self, node: usize) -> Result<Vec<Vec<u8>>> {
        let resp = self
            .call(&protocol::encode_snapshot_request(node))
            .with_context(|| format!("SNAPSHOT of node {node}"))?;
        protocol::decode_snapshot_response(&resp)
    }

    /// Replace one node's shards from snapshots over the wire.
    pub fn restore_node(&self, node: usize, shards: &[Vec<u8>]) -> Result<()> {
        let resp = self
            .call(&protocol::encode_restore_request(node, shards))
            .with_context(|| format!("RESTORE of node {node}"))?;
        let restored = protocol::decode_restore_response(&resp)?;
        ensure!(restored == shards.len(), "PS restored {restored} of {} shards", shards.len());
        Ok(())
    }
}

impl PsBackend for RemotePs {
    fn dim(&self) -> usize {
        self.info.dim
    }

    fn check_compat(&self, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
        protocol::check_fingerprint(&self.info, cfg, seed)?;
        // A single-server backend must own every node, or keys would route
        // into ranges nobody serves.
        ensure!(
            self.info.node_start == 0 && self.info.node_end == self.info.n_nodes,
            "server at {} owns nodes {}..{} of {}; a partial shard needs \
             ShardedRemotePs with the full shard list",
            self.addr,
            self.info.node_start,
            self.info.node_end,
            self.info.n_nodes
        );
        Ok(())
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        self.get_packed(&packed, out)
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        self.put_packed(&packed, grads)
    }

    fn stats(&self) -> Result<PsStats> {
        Ok(self.stats_full()?.0)
    }
}
