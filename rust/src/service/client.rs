//! TCP client stub: [`RemotePs`] implements [`PsBackend`] against a
//! [`super::PsServer`].
//!
//! A small pool of TCP connections (see
//! [`ServiceConfig::client_conns`](crate::config::ServiceConfig)) is shared
//! round-robin by all threads of the trainer process (NN workers pulling,
//! gradient appliers putting); each connection carries one request at a
//! time, guarded by a mutex, so responses always match their requests
//! without relying on correlation-id reordering.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use crate::comm::rpc::RpcClient;
use crate::comm::transport::TcpTransport;
use crate::config::{EmbeddingConfig, ServiceConfig};
use crate::embedding::ps::pack_key;

use super::backend::{PsBackend, PsStats};
use super::protocol;
use super::protocol::PsInfo;

/// Remote embedding-PS backend over TCP.
pub struct RemotePs {
    info: PsInfo,
    wire_compress: bool,
    clients: Vec<Mutex<RpcClient<TcpTransport>>>,
    next: AtomicUsize,
}

impl RemotePs {
    /// Connect a pool to `cfg.addr` and handshake the PS geometry + config.
    pub fn connect(cfg: &ServiceConfig) -> Result<RemotePs> {
        cfg.validate()?;
        let mut clients = Vec::with_capacity(cfg.client_conns);
        for i in 0..cfg.client_conns {
            let transport = TcpTransport::connect(&cfg.addr)
                .with_context(|| format!("connecting PS pool conn {i} to {}", cfg.addr))?;
            clients.push(Mutex::new(RpcClient::new(transport)));
        }
        let resp = {
            let client = clients[0].lock().unwrap();
            client.call(&protocol::encode_info_request()).context("PS INFO handshake")?
        };
        let info = protocol::decode_info_response(&resp)?;
        ensure!(info.dim > 0, "remote PS reports dim 0");
        Ok(RemotePs { info, wire_compress: cfg.wire_compress, clients, next: AtomicUsize::new(0) })
    }

    /// The server's INFO handshake (geometry + config fingerprint).
    pub fn info(&self) -> &PsInfo {
        &self.info
    }

    /// PS node count reported by the server.
    pub fn n_nodes(&self) -> usize {
        self.info.n_nodes
    }

    /// Lock-striped shards per node reported by the server.
    pub fn shards_per_node(&self) -> usize {
        self.info.shards_per_node
    }

    fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.clients.len();
        let client = self.clients[i].lock().unwrap();
        client.call(msg)
    }

    /// Ask the server to shut down gracefully (stop accepting, drain
    /// connections). The ack is received before the server exits its loop.
    pub fn shutdown_server(&self) -> Result<()> {
        self.call(&protocol::encode_shutdown_request()).context("PS shutdown request")?;
        Ok(())
    }
}

impl PsBackend for RemotePs {
    fn dim(&self) -> usize {
        self.info.dim
    }

    fn check_compat(&self, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
        let want = (
            cfg.n_nodes,
            cfg.shards_per_node,
            seed,
            cfg.shard_capacity,
            protocol::optimizer_code(cfg.optimizer),
            protocol::partition_code(cfg.partition),
            cfg.lr.to_bits(),
        );
        let got = (
            self.info.n_nodes,
            self.info.shards_per_node,
            self.info.seed,
            self.info.shard_capacity,
            self.info.optimizer_code,
            self.info.partition_code,
            self.info.lr_bits,
        );
        ensure!(
            want == got,
            "remote PS config mismatch: trainer expects \
             (nodes, shards, seed, capacity, opt, partition, lr_bits) = {want:?}, \
             server reports {got:?} — start serve-ps and train with the same \
             --preset/--dense/--shard-capacity/--seed flags"
        );
        Ok(())
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == keys.len() * self.info.dim, "GET output shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let resp = self.call(&protocol::encode_get_request(&packed, self.wire_compress))?;
        protocol::decode_get_response_into(&resp, self.info.dim, out)?;
        Ok(())
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        ensure!(grads.len() == keys.len() * self.info.dim, "PUT gradient shape mismatch");
        if keys.is_empty() {
            return Ok(());
        }
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        let msg = protocol::encode_put_request(&packed, grads, self.info.dim, self.wire_compress);
        let resp = self.call(&msg)?;
        let applied = protocol::decode_put_response(&resp)?;
        ensure!(applied == keys.len(), "PS applied {applied} of {} rows", keys.len());
        Ok(())
    }

    fn stats(&self) -> Result<PsStats> {
        let resp = self.call(&protocol::encode_stats_request())?;
        protocol::decode_stats_response(&resp)
    }
}
