//! TCP client stub: [`RemotePs`] implements [`PsBackend`] against a
//! [`super::PsServer`].
//!
//! All transport-level resilience lives in the shared recovery layer: the
//! pool of pipelined connections is a
//! [`ReconnectPool`](crate::recovery::ReconnectPool) whose `PsRedial`
//! policy re-dials a dead connection, re-runs the INFO handshake, and
//! insists the server is still the deployment originally connected
//! ([`PsInfo::same_deployment`]). That is what lets a PS shard process that
//! was killed and restarted rejoin a training run mid-flight (§4.2.4): the
//! trainer's next get/put simply reconnects and proceeds. Every dialed
//! connection carries the configured `--inflight-window` of overlapping
//! requests and the `--io-timeout-ms` per-call deadline, so a wedged (not
//! just dead) server also trips the retry path instead of hanging.
//!
//! On top of reconnection, exact state recovery: when
//! [`RecoveryConfig::replay_puts`](crate::config::RecoveryConfig) is on,
//! every applied gradient put is recorded in a
//! [`PutReplayLog`](crate::recovery::PutReplayLog). A redial that finds a
//! *new* boot nonce (the shard was killed and restarted, restored from its
//! newest committed checkpoint epoch) replays the recorded puts after that
//! epoch over the fresh connection — in deterministic mode the shard is
//! bitwise back to its pre-crash state before any other traffic reaches it.

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::comm::rpc::{PipelinedClient, RpcClient};
use crate::comm::transport::TcpTransport;
use crate::config::{EmbeddingConfig, ServiceConfig};
use crate::embedding::ps::pack_key;
use crate::embedding::NodeSnapshot;
use crate::recovery::{
    PoolAsyncCall, PooledConn, PutReplayLog, ReconnectPool, Redial, RetryPolicy,
};

use super::backend::{PsBackend, PsStats};
use super::protocol;
use super::protocol::PsInfo;
use super::reshard::RoutingTable;

/// Outcome of a routed GET/PUT against one shard. A server that no longer
/// (or does not yet) own some key's node answers the WHOLE batch with an
/// in-band NOT_OWNER frame — nothing applied, nothing served — carrying its
/// committed routing epoch so the caller can refresh and re-route.
pub(super) enum ShardCall {
    /// The batch was served/applied in full.
    Applied,
    /// The batch was refused; the shard's committed routing epoch rides
    /// along (always a re-route signal, never a partial application).
    NotOwner(u64),
}

/// Dial/handshake/replay policy for one PS shard endpoint.
pub(super) struct PsRedial {
    addr: String,
    expect: PsInfo,
    wire_compress: bool,
    /// Pipelining window of each dialed connection (`--inflight-window`).
    window: usize,
    /// Per-call I/O deadline (`--io-timeout-ms`; `None` = wait forever).
    io_timeout: Option<std::time::Duration>,
    replay: Arc<PutReplayLog>,
}

impl Redial for PsRedial {
    fn redial(&self) -> Result<PooledConn> {
        let client = PipelinedClient::connect(&self.addr, self.window, self.io_timeout)
            .with_context(|| format!("reconnecting to PS at {}", self.addr))?;
        let resp = client.call(&protocol::encode_info_request()).context("PS INFO re-handshake")?;
        let info = protocol::decode_info_response(&resp)?;
        // A shard restarted with different flags must not be allowed to
        // silently rejoin with different numerics; a restarted instance of
        // the SAME deployment (new boot nonce) is §4.2.4's recovery case.
        ensure!(
            info.same_deployment(&self.expect),
            "PS at {} came back with a different config: {info:?} != {:?}",
            self.addr,
            self.expect
        );
        // New process: bring it back to this client's state by replaying
        // the put log since its restored epoch, over this very connection,
        // before the pool serves anything else on it. Idempotent per boot —
        // concurrent pool slots replay once.
        let dim = self.expect.dim;
        let compress = self.wire_compress;
        let replayed = self.replay.replay_after_reconnect(
            info.boot_nonce,
            info.restored_step,
            &format!("PS at {}", self.addr),
            &mut |keys, grads| {
                let msg = protocol::encode_put_request(keys, grads, dim, compress);
                let resp = client.call(&msg).context("replaying logged put")?;
                let applied = protocol::decode_put_response(&resp)?;
                ensure!(applied == keys.len(), "replay applied {applied} of {} rows", keys.len());
                Ok(())
            },
        )?;
        if replayed > 0 {
            eprintln!(
                "recovery: replayed {replayed} gradient put batch(es) into restarted PS at {} \
                 (restored from epoch {})",
                self.addr, info.restored_step
            );
        }
        Ok(client)
    }

    fn describe(&self) -> String {
        format!("PS at {}", self.addr)
    }
}

/// Remote embedding-PS backend over TCP (one server process).
pub struct RemotePs {
    pool: ReconnectPool<PsRedial>,
    info: PsInfo,
    wire_compress: bool,
}

impl RemotePs {
    /// Connect a pool to the single address in `cfg` and handshake the PS
    /// geometry + config. For a comma-separated shard list use
    /// [`super::ShardedRemotePs`].
    pub fn connect(cfg: &ServiceConfig) -> Result<RemotePs> {
        cfg.validate()?;
        let addrs = cfg.shard_addrs();
        ensure!(
            addrs.len() == 1,
            "RemotePs takes exactly one address (got {:?}); use ShardedRemotePs \
             for a shard list",
            cfg.addr
        );
        Self::connect_addr(cfg, &addrs[0])
    }

    /// Connect a pool to one specific `addr`, taking every other knob
    /// (pool size, compression, recovery policy) from `cfg`.
    pub(super) fn connect_addr(cfg: &ServiceConfig, addr: &str) -> Result<RemotePs> {
        // Probe handshake first: the pool's redial policy needs to know the
        // server's identity before it can verify anything. The probe gets
        // the same I/O deadline as the pool, so a wedged server fails the
        // connect instead of hanging it.
        let probe = TcpTransport::connect(addr)
            .with_context(|| format!("connecting to PS at {addr}"))?;
        probe.set_timeouts(cfg.recovery.io_timeout())?;
        let probe = RpcClient::new(probe);
        let resp = probe.call(&protocol::encode_info_request()).context("PS INFO handshake")?;
        let info = protocol::decode_info_response(&resp)?;
        ensure!(info.dim > 0, "remote PS reports dim 0");
        drop(probe);

        let replay = Arc::new(if cfg.recovery.replay_puts {
            PutReplayLog::with_owner(cfg.recovery.replay_cap, cfg.recovery.replay_owner)
        } else {
            PutReplayLog::disabled()
        });
        // The current boot's state trivially includes everything recorded
        // so far (nothing): replay must only trigger on a *new* boot.
        replay.sync_boot(info.boot_nonce);
        let redial = PsRedial {
            addr: addr.to_string(),
            expect: info,
            wire_compress: cfg.wire_compress,
            window: cfg.inflight_window,
            io_timeout: cfg.recovery.io_timeout(),
            replay,
        };
        let pool =
            ReconnectPool::connect(redial, cfg.client_conns, RetryPolicy::from(&cfg.recovery))?;
        Ok(RemotePs { pool, info, wire_compress: cfg.wire_compress })
    }

    /// The server's INFO handshake (geometry + config fingerprint).
    pub fn info(&self) -> &PsInfo {
        &self.info
    }

    /// The address this client dials (and re-dials).
    pub fn addr(&self) -> &str {
        &self.pool.redialer().addr
    }

    /// PS node count reported by the server.
    pub fn n_nodes(&self) -> usize {
        self.info.n_nodes
    }

    /// Lock-striped shards per node reported by the server.
    pub fn shards_per_node(&self) -> usize {
        self.info.shards_per_node
    }

    /// Global node indices owned by this server.
    pub fn node_range(&self) -> std::ops::Range<usize> {
        self.info.node_start..self.info.node_end
    }

    /// One RPC over the recovery pool (see
    /// [`ReconnectPool::call`](crate::recovery::ReconnectPool::call) for
    /// the retry/idempotence contract).
    fn call(&self, msg: &[u8]) -> Result<Vec<u8>> {
        self.pool.call(msg)
    }

    /// Ask the server to shut down gracefully (stop accepting, drain
    /// connections). The ack is received before the server exits its loop.
    pub fn shutdown_server(&self) -> Result<()> {
        self.call(&protocol::encode_shutdown_request()).context("PS shutdown request")?;
        Ok(())
    }

    /// Batched GET of already-packed keys (the sharded client routes packed
    /// keys, so this skips a pointless unpack/repack).
    pub(super) fn get_packed(&self, packed: &[u64], out: &mut [f32]) -> Result<()> {
        ensure!(out.len() == packed.len() * self.info.dim, "GET output shape mismatch");
        if packed.is_empty() {
            return Ok(());
        }
        let resp = self.call(&protocol::encode_get_request(packed, self.wire_compress))?;
        if let Some(epoch) = protocol::decode_not_owner(&resp) {
            anyhow::bail!(
                "PS at {} does not own every requested key (its routing epoch is {epoch}); \
                 single-server clients cannot re-route — use ShardedRemotePs",
                self.addr()
            );
        }
        protocol::decode_get_response_into(&resp, self.info.dim, out)?;
        Ok(())
    }

    /// Batched gradient PUT of already-packed keys. Applied puts are
    /// recorded in the replay log (when enabled), so a later shard restart
    /// can be replayed back to this exact state.
    pub(super) fn put_packed(&self, packed: &[u64], grads: &[f32]) -> Result<()> {
        ensure!(grads.len() == packed.len() * self.info.dim, "PUT gradient shape mismatch");
        if packed.is_empty() {
            return Ok(());
        }
        let msg = protocol::encode_put_request(packed, grads, self.info.dim, self.wire_compress);
        let resp = self.call(&msg)?;
        if let Some(epoch) = protocol::decode_not_owner(&resp) {
            anyhow::bail!(
                "PS at {} refused a put: it does not own every key (routing epoch {epoch}); \
                 single-server clients cannot re-route — use ShardedRemotePs",
                self.addr()
            );
        }
        let applied = protocol::decode_put_response(&resp)?;
        ensure!(applied == packed.len(), "PS applied {applied} of {} rows", packed.len());
        self.pool.redialer().replay.record(packed, grads);
        Ok(())
    }

    /// Start a pipelined GET without blocking for the response: the request
    /// departs on a pooled connection and the handle claims it later, so a
    /// scatter over N shards overlaps all N round-trips
    /// ([`super::ShardedRemotePs`]'s hot path). `packed` must be non-empty.
    pub(super) fn start_get(&self, packed: &[u64]) -> PoolAsyncCall<'_, PsRedial> {
        self.pool.call_async(&protocol::encode_get_request(packed, self.wire_compress))
    }

    /// Claim a [`Self::start_get`] response into `out` (shaped
    /// `packed.len() * dim`). [`ShardCall::NotOwner`] means nothing was
    /// served and `out` is untouched — the sharded client refreshes its
    /// routing table and retries the sub-batch elsewhere.
    pub(super) fn finish_get(
        &self,
        call: PoolAsyncCall<'_, PsRedial>,
        out: &mut [f32],
    ) -> Result<ShardCall> {
        let resp = call.wait()?;
        if let Some(epoch) = protocol::decode_not_owner(&resp) {
            return Ok(ShardCall::NotOwner(epoch));
        }
        protocol::decode_get_response_into(&resp, self.info.dim, out)?;
        Ok(ShardCall::Applied)
    }

    /// Start a pipelined gradient PUT (non-empty `packed`; `grads` shaped
    /// `packed.len() * dim`).
    pub(super) fn start_put(&self, packed: &[u64], grads: &[f32]) -> PoolAsyncCall<'_, PsRedial> {
        let msg = protocol::encode_put_request(packed, grads, self.info.dim, self.wire_compress);
        self.pool.call_async(&msg)
    }

    /// Claim a [`Self::start_put`] ack; on [`ShardCall::Applied`] the put
    /// is recorded in the replay log exactly as the synchronous path
    /// records it. [`ShardCall::NotOwner`] means NO row was applied (the
    /// server's put is all-or-nothing per batch), so the whole sub-batch is
    /// safe to retry against the current owner.
    pub(super) fn finish_put(
        &self,
        call: PoolAsyncCall<'_, PsRedial>,
        packed: &[u64],
        grads: &[f32],
    ) -> Result<ShardCall> {
        let resp = call.wait()?;
        if let Some(epoch) = protocol::decode_not_owner(&resp) {
            return Ok(ShardCall::NotOwner(epoch));
        }
        let applied = protocol::decode_put_response(&resp)?;
        ensure!(applied == packed.len(), "PS applied {applied} of {} rows", packed.len());
        self.pool.redialer().replay.record(packed, grads);
        Ok(ShardCall::Applied)
    }

    /// Fetch the server's committed routing table over the pool (`None`
    /// before the deployment's first reshard).
    pub(super) fn fetch_routing(&self) -> Result<Option<RoutingTable>> {
        let resp = self.call(&protocol::encode_routing_request())?;
        protocol::decode_routing_response(&resp)
    }

    /// Drop every recorded put batch (returns how many were discarded).
    /// Required at a reshard flip: entries recorded against the pre-flip
    /// routing would replay keys into a shard that no longer owns them.
    pub(super) fn clear_replay(&self) -> usize {
        self.pool.redialer().replay.clear()
    }

    /// STATS including the server's global-length per-node traffic vector.
    pub(super) fn stats_full(&self) -> Result<(PsStats, Vec<u64>)> {
        let resp = self.call(&protocol::encode_stats_request())?;
        protocol::decode_stats_full(&resp)
    }

    /// Fetch the full snapshot (per-shard hot blobs, plus cold blobs when
    /// the server runs a tiered store) of one (server-owned, globally
    /// indexed) node over the wire — §4.2.4 checkpointing, cross-process.
    pub fn snapshot_node(&self, node: usize) -> Result<NodeSnapshot> {
        let resp = self
            .call(&protocol::encode_snapshot_request(node))
            .with_context(|| format!("SNAPSHOT of node {node}"))?;
        protocol::decode_snapshot_response(&resp)
    }

    /// Replace one node's tiers from a snapshot over the wire. The server
    /// rejects tier-shape mismatches (cold snapshot → all-hot PS or vice
    /// versa) loudly.
    pub fn restore_node(&self, node: usize, snap: &NodeSnapshot) -> Result<()> {
        let resp = self
            .call(&protocol::encode_restore_request(node, snap))
            .with_context(|| format!("RESTORE of node {node}"))?;
        let restored = protocol::decode_restore_response(&resp)?;
        ensure!(restored == snap.hot.len(), "PS restored {restored} of {} shards", snap.hot.len());
        Ok(())
    }

    /// Checkpoint-epoch phase 1: ask the server to stage its owned nodes.
    pub fn prepare_ckpt(&self, step: u64) -> Result<usize> {
        let resp = self
            .call(&protocol::encode_ckpt_request(protocol::KIND_PREPARE_CKPT, step))
            .with_context(|| format!("PREPARE_CKPT epoch {step}"))?;
        protocol::decode_ckpt_response(&resp, protocol::KIND_PREPARE_CKPT)
    }

    /// Checkpoint-epoch phase 2: ask the server to commit the staged epoch.
    pub fn commit_ckpt(&self, step: u64) -> Result<usize> {
        let resp = self
            .call(&protocol::encode_ckpt_request(protocol::KIND_COMMIT_CKPT, step))
            .with_context(|| format!("COMMIT_CKPT epoch {step}"))?;
        protocol::decode_ckpt_response(&resp, protocol::KIND_COMMIT_CKPT)
    }

    /// Truncate this client's put replay log at globally committed epoch
    /// `step` (no-op when replay is disabled).
    pub fn mark_committed(&self, step: u64) {
        self.pool.redialer().replay.mark_committed(step);
    }
}

impl PsBackend for RemotePs {
    fn dim(&self) -> usize {
        self.info.dim
    }

    fn check_compat(&self, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
        protocol::check_fingerprint(&self.info, cfg, seed)?;
        // A single-server backend must own every node, or keys would route
        // into ranges nobody serves.
        ensure!(
            self.info.node_start == 0 && self.info.node_end == self.info.n_nodes,
            "server at {} owns nodes {}..{} of {}; a partial shard needs \
             ShardedRemotePs with the full shard list",
            self.addr(),
            self.info.node_start,
            self.info.node_end,
            self.info.n_nodes
        );
        Ok(())
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        self.get_packed(&packed, out)
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();
        self.put_packed(&packed, grads)
    }

    fn stats(&self) -> Result<PsStats> {
        Ok(self.stats_full()?.0)
    }

    fn checkpoint_epoch(&self, _dir: &Path, step: u64) -> Result<()> {
        self.prepare_ckpt(step)?;
        self.commit_ckpt(step)?;
        self.mark_committed(step);
        Ok(())
    }

    fn mark_epoch_committed(&self, step: u64) {
        self.mark_committed(step);
    }

    fn replay_puts(&self) -> bool {
        self.pool.redialer().replay.is_enabled()
    }
}
