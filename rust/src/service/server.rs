//! The embedding PS as a standalone TCP service.
//!
//! One [`PsServer`] wraps an [`EmbeddingPs`] — the full key space, or just
//! the node range a multi-process deployment assigned to this process
//! (`EmbeddingPs::new_range`, `persia serve-ps --node-range`) — and serves
//! the [`super::protocol`] RPCs over length-prefixed TCP frames, including
//! whole-node SNAPSHOT/RESTORE for the cross-process §4.2.4 recovery drill.
//! Keys that route outside the owned range are rejected loudly.
//!
//! Connections are served by the non-blocking readiness-loop core in
//! [`super::event_loop`]: one poller thread multiplexes the listener and
//! every live connection, and a small bounded worker pool runs the shared
//! [`RpcServer`] dispatch — so a PS serving hundreds of pipelined trainer
//! connections costs a fixed number of threads, and requests from one
//! connection execute concurrently (shard-level lock striping, not
//! connection-level serialization, provides the parallelism — as in the
//! paper's PS nodes). On non-unix hosts a thread-per-connection fallback
//! preserves the exact same RPC semantics.
//!
//! Shutdown is graceful and sleep-free: the SHUTDOWN handler sets the stop
//! flag and self-connects to wake the poller; the loop then stops
//! accepting and reading, flushes every queued response (the SHUTDOWN ack
//! included), and joins its workers.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::comm::rpc::RpcServer;
#[cfg(not(unix))]
use crate::comm::transport::TcpTransport;
use crate::config::EmbeddingConfig;
use crate::embedding::{CheckpointManager, EmbeddingPs};

use super::backend::PsBackend;
use super::protocol;
use super::protocol::PsInfo;

/// A per-process random nonce: lets reconnecting clients distinguish "same
/// server, transient wire failure" from "new process after a kill" — the
/// trigger for the recovery layer's put-log replay. Mixes the clock, the
/// pid, and an address so even rapid restart loops get distinct nonces.
pub(super) fn boot_nonce(salt: &TcpListener) -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let addr_entropy = salt as *const TcpListener as usize as u64;
    (nanos ^ (u64::from(std::process::id()) << 32) ^ addr_entropy.rotate_left(17)) | 1
}

/// A bound-but-not-yet-serving PS service.
pub struct PsServer {
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
}

impl PsServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and register the
    /// protocol handlers over `ps`. `cfg`/`seed` must be the config the PS
    /// was built from — they are served in the INFO handshake so clients
    /// can hard-fail on a trainer/server config mismatch instead of
    /// silently diverging. No checkpoint-epoch support; see
    /// [`PsServer::bind_with_epochs`].
    pub fn bind(
        ps: Arc<EmbeddingPs>,
        addr: &str,
        cfg: &EmbeddingConfig,
        seed: u64,
    ) -> Result<PsServer> {
        Self::bind_with_epochs(ps, addr, cfg, seed, None, 0)
    }

    /// [`PsServer::bind`] plus coordinated-checkpoint support: with a
    /// `ckpt` manager the PREPARE_CKPT/COMMIT_CKPT RPCs stage and commit
    /// epoch snapshots of this shard's owned nodes; `restored_step` is the
    /// epoch this process restored at startup (0 = fresh) and is advertised
    /// in INFO so reconnecting clients replay exactly the delta.
    pub fn bind_with_epochs(
        ps: Arc<EmbeddingPs>,
        addr: &str,
        cfg: &EmbeddingConfig,
        seed: u64,
        ckpt: Option<Arc<CheckpointManager>>,
        restored_step: u64,
    ) -> Result<PsServer> {
        anyhow::ensure!(
            cfg.n_nodes == ps.n_nodes() && cfg.shards_per_node == ps.shards_per_node(),
            "EmbeddingConfig does not describe this EmbeddingPs"
        );
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding PS service on {addr}"))?;
        let local = listener.local_addr()?;
        let mut rpc = RpcServer::new();
        let stop = rpc.stop_flag();

        let dim = ps.dim();
        let range = ps.node_range();
        let info = PsInfo {
            dim,
            n_nodes: ps.n_nodes(),
            shards_per_node: ps.shards_per_node(),
            seed,
            shard_capacity: cfg.shard_capacity,
            optimizer_code: protocol::optimizer_code(cfg.optimizer),
            partition_code: protocol::partition_code(cfg.partition),
            lr_bits: cfg.lr.to_bits(),
            node_start: range.start,
            node_end: range.end,
            boot_nonce: boot_nonce(&listener),
            restored_step,
        };
        rpc.register(
            protocol::KIND_INFO,
            Box::new(move |_msg| Ok(protocol::encode_info_response(&info))),
        );
        // GET/PUT go through the packed-key entry points: each key is routed
        // exactly once, and a key outside this server's node range fails the
        // whole request loudly (all-or-nothing, before any row materializes)
        // — a misrouted key means client and server disagree on the global
        // hash, and silently serving it would create a row the rest of the
        // deployment never sees.
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_GET,
                Box::new(move |msg| {
                    let (packed, compress) = protocol::decode_get_request(msg)?;
                    let mut rows = vec![0.0f32; packed.len() * dim];
                    ps.get_packed_into(&packed, &mut rows)?;
                    Ok(protocol::encode_get_response(&rows, dim, compress))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_PUT,
                Box::new(move |msg| {
                    let (packed, grads) = protocol::decode_put_request(msg, dim)?;
                    ps.put_grads_packed(&packed, &grads)?;
                    Ok(protocol::encode_put_response(packed.len()))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_STATS,
                Box::new(move |_msg| {
                    Ok(protocol::encode_stats_response(
                        &PsBackend::stats(ps.as_ref())?,
                        &ps.node_traffic(),
                    ))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_SNAPSHOT,
                Box::new(move |msg| {
                    let node = protocol::decode_snapshot_request(msg)?;
                    anyhow::ensure!(
                        ps.node_range().contains(&node),
                        "SNAPSHOT of node {node} outside this server's range {:?}",
                        ps.node_range()
                    );
                    // snapshot_node_full is fallible (cold-tier I/O, node
                    // ownership): failures become wire errors to the client,
                    // never a server panic.
                    Ok(protocol::encode_snapshot_response(&ps.snapshot_node_full(node)?))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_RESTORE,
                Box::new(move |msg| {
                    let (node, snap) = protocol::decode_restore_request(msg)?;
                    // restore_node_full re-checks ownership, shard count,
                    // and tier shape (a cold snapshot against an all-hot PS
                    // is a loud error), and the hardened snapshot decoders
                    // reject corrupt blobs without panicking — a bad RESTORE
                    // leaves state intact up to the first failing shard.
                    ps.restore_node_full(node, &snap)?;
                    Ok(protocol::encode_restore_response(snap.hot.len()))
                }),
            );
        }
        {
            // PREPARE_CKPT: stage this shard's owned nodes for the epoch.
            let ps = ps.clone();
            let ckpt_prep = ckpt.clone();
            rpc.register(
                protocol::KIND_PREPARE_CKPT,
                Box::new(move |msg| {
                    let step = protocol::decode_ckpt_request(msg, protocol::KIND_PREPARE_CKPT)?;
                    let mgr = ckpt_prep.as_ref().with_context(|| {
                        "PREPARE_CKPT on a PS started without --checkpoint-dir".to_string()
                    })?;
                    mgr.prepare_epoch(&ps, step)?;
                    Ok(protocol::encode_ckpt_response(
                        protocol::KIND_PREPARE_CKPT,
                        ps.node_range().len(),
                    ))
                }),
            );
        }
        {
            // COMMIT_CKPT: rename the staged epoch into place + write the
            // shard's commit manifest.
            let ps = ps.clone();
            let ckpt_commit = ckpt.clone();
            rpc.register(
                protocol::KIND_COMMIT_CKPT,
                Box::new(move |msg| {
                    let step = protocol::decode_ckpt_request(msg, protocol::KIND_COMMIT_CKPT)?;
                    let mgr = ckpt_commit.as_ref().with_context(|| {
                        "COMMIT_CKPT on a PS started without --checkpoint-dir".to_string()
                    })?;
                    let nodes = mgr.commit_epoch(&ps, step)?;
                    Ok(protocol::encode_ckpt_response(protocol::KIND_COMMIT_CKPT, nodes))
                }),
            );
        }
        {
            let stop = stop.clone();
            rpc.register(
                protocol::KIND_SHUTDOWN,
                Box::new(move |_msg| {
                    stop.store(true, Ordering::SeqCst);
                    // Wake the blocking accept so serve_forever/spawned
                    // accept loops observe the flag without polling.
                    let _ = TcpStream::connect(wake_addr(local));
                    Ok(protocol::encode_shutdown_response())
                }),
            );
        }

        Ok(PsServer { listener, rpc: Arc::new(rpc), stop })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve on the calling thread until a SHUTDOWN RPC arrives.
    pub fn serve_forever(self) -> Result<()> {
        accept_loop(self.listener, self.rpc, self.stop, "serve-ps");
        Ok(())
    }

    /// Serve on a background thread; returns a shutdown handle.
    pub fn spawn(self) -> Result<PsServerHandle> {
        let addr = self.local_addr()?;
        let PsServer { listener, rpc, stop } = self;
        let stop_for_loop = stop.clone();
        let accept = std::thread::Builder::new()
            .name("ps-accept".to_string())
            .spawn(move || accept_loop(listener, rpc, stop_for_loop, "serve-ps"))
            .context("spawning PS accept thread")?;
        Ok(PsServerHandle { addr, stop, accept })
    }
}

/// An address that provably reaches the listener from this host: wildcard
/// binds (0.0.0.0 / ::) are not connectable targets everywhere, so rewrite
/// them to the matching loopback.
pub(super) fn wake_addr(bound: SocketAddr) -> SocketAddr {
    let mut addr = bound;
    if addr.ip().is_unspecified() {
        let loopback: std::net::IpAddr = if addr.is_ipv4() {
            std::net::Ipv4Addr::LOCALHOST.into()
        } else {
            std::net::Ipv6Addr::LOCALHOST.into()
        };
        addr.set_ip(loopback);
    }
    addr
}

/// Serve an arbitrary [`RpcServer`] on the shared service core (the
/// readiness loop on unix, thread-per-connection elsewhere). Blocks the
/// calling thread until `stop` is set (wake it with a no-op connect to the
/// listener) or the listener breaks persistently; `label` names the
/// service in diagnostics. This is the entry point benches and soak tests
/// use to drive the exact server stack `serve-ps` runs in production.
pub fn serve_rpc(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    accept_loop(listener, rpc, stop, label)
}

/// The shared connection core of every `persia` service ([`PsServer`], the
/// embedding-worker tier's
/// [`EmbeddingWorkerServer`](super::embedding_worker::EmbeddingWorkerServer),
/// and [`serve_rpc`]): transient-accept-error tolerance and the sleep-free
/// graceful-shutdown protocol described in the module docs. On unix this
/// delegates to the [`super::event_loop`] readiness loop; elsewhere it
/// falls back to one thread per connection with identical RPC semantics.
pub(super) fn accept_loop(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    #[cfg(unix)]
    super::event_loop::run(listener, rpc, stop, label);
    #[cfg(not(unix))]
    accept_loop_threaded(listener, rpc, stop, label);
}

/// The PR-1 thread-per-connection loop, kept as the portable fallback for
/// hosts without `poll(2)`.
#[cfg(not(unix))]
fn accept_loop_threaded(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    // (thread, read-half handle for shutdown wakeup) per live connection.
    let mut conns: Vec<(JoinHandle<()>, Option<TcpStream>)> = Vec::new();
    let mut consecutive_errors = 0u32;
    for (conn_id, stream) in listener.incoming().enumerate() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => {
                consecutive_errors = 0;
                stream
            }
            Err(e) => {
                // Transient accept failures (ECONNABORTED, EMFILE bursts)
                // must not kill a long-running PS; only a persistently
                // broken listener ends the loop.
                consecutive_errors += 1;
                if consecutive_errors >= 64 {
                    eprintln!("persia {label}: accept failing persistently ({e}); stopping");
                    break;
                }
                continue;
            }
        };
        // Reap finished connections so a long-running PS stays flat on
        // memory (dropping a finished JoinHandle just detaches it).
        conns.retain(|(h, _)| !h.is_finished());
        let peer = stream.peer_addr().ok();
        let wake_handle = stream.try_clone().ok();
        let rpc = rpc.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ps-conn-{conn_id}"))
            .spawn(move || {
                let transport = TcpTransport::new(stream);
                // Serve until the peer disconnects, stop is set, or the
                // peer sends garbage (malformed frames drop the connection).
                if let Err(e) = rpc.serve(&transport) {
                    eprintln!("persia {label}: connection {peer:?} dropped: {e:#}");
                }
            })
            .expect("spawn PS connection thread");
        conns.push((handle, wake_handle));
    }
    // Unblock readers parked in recv() on idle connections so the joins
    // below cannot hang on clients that never disconnect. Only the read
    // half closes: in-flight responses (including the SHUTDOWN ack) still
    // reach their peers.
    for (_, wake_handle) in &conns {
        if let Some(s) = wake_handle {
            let _ = s.shutdown(std::net::Shutdown::Read);
        }
    }
    for (handle, _) in conns {
        let _ = handle.join();
    }
}

/// Handle to a background PS service.
pub struct PsServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: JoinHandle<()>,
}

impl PsServerHandle {
    /// The service's bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, deliver in-flight responses, unblock idle
    /// connections, and join every server thread. Clients still holding
    /// [`super::RemotePs`] handles see their next call fail.
    pub fn shutdown(self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept (the no-op connection is discarded by the
        // stop check before it is served).
        let _ = TcpStream::connect(wake_addr(self.addr));
        self.accept.join().map_err(|_| anyhow::anyhow!("PS accept thread panicked"))
    }
}
