//! The embedding PS as a standalone TCP service.
//!
//! One [`PsServer`] wraps an [`EmbeddingPs`] — the full key space, or just
//! the node range a multi-process deployment assigned to this process
//! (`EmbeddingPs::new_range`, `persia serve-ps --node-range`) — and serves
//! the [`super::protocol`] RPCs over length-prefixed TCP frames, including
//! whole-node SNAPSHOT/RESTORE for the cross-process §4.2.4 recovery drill.
//! Keys that route outside the owned range are rejected loudly.
//!
//! Connections are served by the non-blocking readiness-loop core in
//! [`super::event_loop`]: one poller thread multiplexes the listener and
//! every live connection, and a small bounded worker pool runs the shared
//! [`RpcServer`] dispatch — so a PS serving hundreds of pipelined trainer
//! connections costs a fixed number of threads, and requests from one
//! connection execute concurrently (shard-level lock striping, not
//! connection-level serialization, provides the parallelism — as in the
//! paper's PS nodes). On non-unix hosts a thread-per-connection fallback
//! preserves the exact same RPC semantics.
//!
//! Shutdown is graceful and sleep-free: the SHUTDOWN handler sets the stop
//! flag and self-connects to wake the poller; the loop then stops
//! accepting and reading, flushes every queued response (the SHUTDOWN ack
//! included), and joins its workers.

use std::collections::HashSet;
use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::comm::rpc::{RpcClient, RpcServer};
use crate::comm::transport::TcpTransport;
use crate::config::EmbeddingConfig;
use crate::embedding::{CheckpointManager, EmbeddingPs};
use crate::util::{lock_unpoisoned, read_unpoisoned, write_unpoisoned};

use super::backend::PsBackend;
use super::protocol;
use super::protocol::PsInfo;
use super::reshard::{self, MigrationPlan, RoutingTable};

/// A per-process random nonce: lets reconnecting clients distinguish "same
/// server, transient wire failure" from "new process after a kill" — the
/// trigger for the recovery layer's put-log replay. Mixes the clock, the
/// pid, and an address so even rapid restart loops get distinct nonces.
pub(super) fn boot_nonce(salt: &TcpListener) -> u64 {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let addr_entropy = salt as *const TcpListener as usize as u64;
    (nanos ^ (u64::from(std::process::id()) << 32) ^ addr_entropy.rotate_left(17)) | 1
}

/// Optional capabilities for [`PsServer::bind_with_opts`]; `Default` is the
/// plain static server [`PsServer::bind`] creates.
#[derive(Default)]
pub struct PsBindOpts {
    /// Checkpoint-epoch support: with a manager the PREPARE_CKPT /
    /// COMMIT_CKPT RPCs stage and commit epoch snapshots of the owned nodes.
    pub ckpt: Option<Arc<CheckpointManager>>,
    /// The epoch this process restored at startup (0 = fresh), advertised
    /// in INFO so reconnecting clients replay exactly the delta.
    pub restored_step: u64,
    /// Serve as a `--join` spare: physically materialize the FULL node
    /// range (a spare's deterministic row materialization then agrees
    /// bitwise with any donor for any migrated range) but own nothing until
    /// a reshard commits nodes over.
    pub join: bool,
    /// Committed routing table recovered from a persisted `ROUTING` file
    /// plus this shard's index in it — a restarted shard re-enters the
    /// deployment at that epoch owning whatever the table assigns it.
    pub routing: Option<(RoutingTable, usize)>,
    /// Where to persist the committed table at every reshard commit
    /// (normally the checkpoint dir). `None` = routing state is RAM-only.
    pub routing_dir: Option<PathBuf>,
}

/// Server-side live-resharding state, shared by every connection worker.
///
/// `owned` is the SERVER-level ownership, distinct from the physical
/// [`EmbeddingPs::node_range`]: a `--join` spare materializes the full
/// range but owns nothing; a donor keeps migrated nodes physically
/// allocated (wiped empty) after narrowing. GET/PUT consult `owned`;
/// SNAPSHOT/RESTORE stay physical, which is what lets a migration push
/// rows into a destination before it owns them. Lock order everywhere:
/// `owned` → `forward` → `queue`; `staged`/`committed` are leaf mutexes
/// held only inside control handlers.
struct ReshardState {
    /// Node range this server answers GET/PUT for.
    owned: RwLock<Range<usize>>,
    /// Committed routing epoch (0 = the initial static layout).
    epoch: AtomicU64,
    /// The committed routing table, once one exists.
    committed: Mutex<Option<RoutingTable>>,
    /// `(plan, staged table, this shard's index)` between PREPARE and
    /// COMMIT/ABORT.
    staged: Mutex<Option<(MigrationPlan, RoutingTable, usize)>>,
    /// Nodes currently mid-copy: puts routed to them are queued as well as
    /// applied (read source, write both).
    forward: RwLock<HashSet<usize>>,
    /// Copy-window put sub-batches, drained into the destination at commit.
    queue: Mutex<Vec<(Vec<u64>, Vec<f32>)>>,
    /// Whether this server was started with `--join`.
    joinable: bool,
    /// Destination of the persisted `ROUTING` file, if any.
    routing_dir: Option<PathBuf>,
    /// Set when persisting ROUTING at a COMMIT_RESHARD failed: the flip is
    /// already live in RAM, so the commit cannot be failed — instead the
    /// write is re-attempted at every checkpoint barrier until one lands
    /// (crash recovery would otherwise restore a pre-reshard table).
    routing_dirty: AtomicBool,
}

impl ReshardState {
    /// Re-attempt a ROUTING persist that failed at COMMIT_RESHARD. Called
    /// at the checkpoint barriers (PREPARE_CKPT/COMMIT_CKPT) — the next
    /// durable point after the failed write — so a transient disk error
    /// heals instead of silently leaving crash recovery a stale table.
    fn retry_routing_persist(&self) {
        if !self.routing_dirty.load(Ordering::SeqCst) {
            return;
        }
        let Some(dir) = &self.routing_dir else { return };
        let Some(table) = lock_unpoisoned(&self.committed).clone() else { return };
        match crate::recovery::atomic_write(&reshard::routing_path(dir), &table.to_bytes()) {
            Ok(()) => {
                self.routing_dirty.store(false, Ordering::SeqCst);
                eprintln!(
                    "persia serve-ps: ROUTING (epoch {}) persisted on checkpoint-barrier retry",
                    table.epoch
                );
            }
            Err(e) => eprintln!(
                "persia serve-ps: ROUTING persist retry failed (epoch {}), will retry at the \
                 next checkpoint barrier: {e:#}",
                table.epoch
            ),
        }
    }
}

/// Test hook: `PERSIA_MIGRATE_DELAY_MS` stretches the per-node copy window
/// so the chaos drills can land a SIGKILL mid-migration deterministically.
fn migrate_delay() -> Duration {
    let ms = std::env::var("PERSIA_MIGRATE_DELAY_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(0);
    Duration::from_millis(ms)
}

/// One-shot lock-step client to a migration destination. Deliberately NOT a
/// reconnect pool: a failure mid-copy must surface to the coordinator (which
/// aborts the reshard), never retry silently against a restarted process.
fn dial_dest(addr: &str) -> Result<RpcClient<TcpTransport>> {
    let t = TcpTransport::connect(addr)
        .with_context(|| format!("dialing migration dest {addr}"))?;
    t.set_timeouts(Some(Duration::from_secs(30)))?;
    Ok(RpcClient::new(t))
}

/// A bound-but-not-yet-serving PS service.
pub struct PsServer {
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
}

impl PsServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and register the
    /// protocol handlers over `ps`. `cfg`/`seed` must be the config the PS
    /// was built from — they are served in the INFO handshake so clients
    /// can hard-fail on a trainer/server config mismatch instead of
    /// silently diverging. No checkpoint-epoch support; see
    /// [`PsServer::bind_with_epochs`].
    pub fn bind(
        ps: Arc<EmbeddingPs>,
        addr: &str,
        cfg: &EmbeddingConfig,
        seed: u64,
    ) -> Result<PsServer> {
        Self::bind_with_epochs(ps, addr, cfg, seed, None, 0)
    }

    /// [`PsServer::bind`] plus coordinated-checkpoint support: with a
    /// `ckpt` manager the PREPARE_CKPT/COMMIT_CKPT RPCs stage and commit
    /// epoch snapshots of this shard's owned nodes; `restored_step` is the
    /// epoch this process restored at startup (0 = fresh) and is advertised
    /// in INFO so reconnecting clients replay exactly the delta.
    pub fn bind_with_epochs(
        ps: Arc<EmbeddingPs>,
        addr: &str,
        cfg: &EmbeddingConfig,
        seed: u64,
        ckpt: Option<Arc<CheckpointManager>>,
        restored_step: u64,
    ) -> Result<PsServer> {
        Self::bind_with_opts(
            ps,
            addr,
            cfg,
            seed,
            PsBindOpts { ckpt, restored_step, ..PsBindOpts::default() },
        )
    }

    /// The full constructor: [`PsServer::bind_with_epochs`] plus the live
    /// resharding surface — `--join` spares, a recovered routing table, and
    /// the ROUTING/PREPARE_RESHARD/MIGRATE_OUT/COMMIT/ABORT handlers.
    pub fn bind_with_opts(
        ps: Arc<EmbeddingPs>,
        addr: &str,
        cfg: &EmbeddingConfig,
        seed: u64,
        opts: PsBindOpts,
    ) -> Result<PsServer> {
        anyhow::ensure!(
            cfg.n_nodes == ps.n_nodes() && cfg.shards_per_node == ps.shards_per_node(),
            "EmbeddingConfig does not describe this EmbeddingPs"
        );
        let PsBindOpts { ckpt, restored_step, join, routing, routing_dir } = opts;
        if join {
            anyhow::ensure!(
                ps.node_range() == (0..ps.n_nodes()),
                "--join spares must materialize the full node range (got {:?})",
                ps.node_range()
            );
        }
        // Server-level ownership: the physical range by default, or whatever
        // a recovered routing table assigns this shard (possibly empty).
        let (owned, committed, epoch0) = match routing {
            Some((table, self_idx)) => {
                let owned = table.owned_range(self_idx)?;
                anyhow::ensure!(
                    owned.is_empty()
                        || (ps.node_range().start <= owned.start
                            && owned.end <= ps.node_range().end),
                    "recovered owned range {owned:?} outside this PS's physical {:?}",
                    ps.node_range()
                );
                (owned, Some(table.clone()), table.epoch)
            }
            None if join => (0..0, None, 0),
            None => (ps.node_range(), None, 0),
        };
        if let Some(mgr) = &ckpt {
            mgr.set_routing_epoch(epoch0);
        }
        let state = Arc::new(ReshardState {
            owned: RwLock::new(owned),
            epoch: AtomicU64::new(epoch0),
            committed: Mutex::new(committed),
            staged: Mutex::new(None),
            forward: RwLock::new(HashSet::new()),
            queue: Mutex::new(Vec::new()),
            joinable: join,
            routing_dir,
            routing_dirty: AtomicBool::new(false),
        });

        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding PS service on {addr}"))?;
        let local = listener.local_addr()?;
        let mut rpc = RpcServer::new();
        let stop = rpc.stop_flag();

        let dim = ps.dim();
        let nonce = boot_nonce(&listener);
        {
            // INFO is dynamic: the advertised node range and routing epoch
            // change at every reshard commit, and reconnecting clients must
            // see the post-flip layout.
            let ps = ps.clone();
            let st = state.clone();
            let shard_capacity = cfg.shard_capacity;
            let optimizer_code = protocol::optimizer_code(cfg.optimizer);
            let partition_code = protocol::partition_code(cfg.partition);
            let lr_bits = cfg.lr.to_bits();
            rpc.register(
                protocol::KIND_INFO,
                Box::new(move |_msg| {
                    let owned = read_unpoisoned(&st.owned).clone();
                    let info = PsInfo {
                        dim,
                        n_nodes: ps.n_nodes(),
                        shards_per_node: ps.shards_per_node(),
                        seed,
                        shard_capacity,
                        optimizer_code,
                        partition_code,
                        lr_bits,
                        node_start: owned.start,
                        node_end: owned.end,
                        boot_nonce: nonce,
                        restored_step,
                        joinable: st.joinable,
                        routing_epoch: st.epoch.load(Ordering::SeqCst),
                    };
                    Ok(protocol::encode_info_response(&info))
                }),
            );
        }
        // GET/PUT go through the packed-key entry points: each key is routed
        // exactly once, and a key outside this server's OWNED range answers
        // the whole batch with an in-band NOT_OWNER frame (all-or-nothing,
        // before any row materializes) — after a reshard commit that is the
        // re-route signal a stale client refreshes its table on; serving the
        // key anyway would create a row the rest of the deployment never
        // sees. The owned read-lock is held across the PS call so a commit
        // (which takes it for writing) can never interleave with a half-done
        // batch.
        {
            let ps = ps.clone();
            let st = state.clone();
            rpc.register(
                protocol::KIND_GET,
                Box::new(move |msg| {
                    let (packed, compress) = protocol::decode_get_request(msg)?;
                    let owned = read_unpoisoned(&st.owned);
                    if packed.iter().any(|&k| !owned.contains(&ps.route(k).0)) {
                        return Ok(protocol::encode_not_owner(st.epoch.load(Ordering::SeqCst)));
                    }
                    let mut rows = vec![0.0f32; packed.len() * dim];
                    ps.get_packed_into(&packed, &mut rows)?;
                    drop(owned);
                    Ok(protocol::encode_get_response(&rows, dim, compress))
                }),
            );
        }
        {
            // PUT applies locally and, during a copy window, also queues the
            // sub-batch routed to gated (mid-migration) nodes so the commit
            // can replay it onto the destination — the "write both" half of
            // the copy-window rules. The forward read-lock spans apply +
            // queue: the migrator's gate-then-snapshot (under the write
            // lock) therefore sees each put either entirely before the
            // snapshot (captured in it) or entirely after (queued), never
            // half.
            let ps = ps.clone();
            let st = state.clone();
            rpc.register(
                protocol::KIND_PUT,
                Box::new(move |msg| {
                    let (packed, grads) = protocol::decode_put_request(msg, dim)?;
                    let owned = read_unpoisoned(&st.owned);
                    if packed.iter().any(|&k| !owned.contains(&ps.route(k).0)) {
                        return Ok(protocol::encode_not_owner(st.epoch.load(Ordering::SeqCst)));
                    }
                    let fwd = read_unpoisoned(&st.forward);
                    ps.put_grads_packed(&packed, &grads)?;
                    if !fwd.is_empty() {
                        let mut qk = Vec::new();
                        let mut qg = Vec::new();
                        for (i, &k) in packed.iter().enumerate() {
                            if fwd.contains(&ps.route(k).0) {
                                qk.push(k);
                                qg.extend_from_slice(&grads[i * dim..(i + 1) * dim]);
                            }
                        }
                        if !qk.is_empty() {
                            lock_unpoisoned(&st.queue).push((qk, qg));
                        }
                    }
                    drop(fwd);
                    drop(owned);
                    Ok(protocol::encode_put_response(packed.len()))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_STATS,
                Box::new(move |_msg| {
                    Ok(protocol::encode_stats_response(
                        &PsBackend::stats(ps.as_ref())?,
                        &ps.node_traffic(),
                    ))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_SNAPSHOT,
                Box::new(move |msg| {
                    let node = protocol::decode_snapshot_request(msg)?;
                    anyhow::ensure!(
                        ps.node_range().contains(&node),
                        "SNAPSHOT of node {node} outside this server's range {:?}",
                        ps.node_range()
                    );
                    // snapshot_node_full is fallible (cold-tier I/O, node
                    // ownership): failures become wire errors to the client,
                    // never a server panic.
                    Ok(protocol::encode_snapshot_response(&ps.snapshot_node_full(node)?))
                }),
            );
        }
        {
            let ps = ps.clone();
            rpc.register(
                protocol::KIND_RESTORE,
                Box::new(move |msg| {
                    let (node, snap) = protocol::decode_restore_request(msg)?;
                    // restore_node_full re-checks ownership, shard count,
                    // and tier shape (a cold snapshot against an all-hot PS
                    // is a loud error), and the hardened snapshot decoders
                    // reject corrupt blobs without panicking — a bad RESTORE
                    // leaves state intact up to the first failing shard.
                    ps.restore_node_full(node, &snap)?;
                    Ok(protocol::encode_restore_response(snap.hot.len()))
                }),
            );
        }
        {
            // PREPARE_CKPT: stage this shard's OWNED nodes for the epoch —
            // after a reshard that is narrower than the physical range, and
            // the shard manifest must describe what this process actually
            // serves (restore-by-range depends on the file name).
            let ps = ps.clone();
            let st = state.clone();
            let ckpt_prep = ckpt.clone();
            rpc.register(
                protocol::KIND_PREPARE_CKPT,
                Box::new(move |msg| {
                    let step = protocol::decode_ckpt_request(msg, protocol::KIND_PREPARE_CKPT)?;
                    let mgr = ckpt_prep.as_ref().with_context(|| {
                        "PREPARE_CKPT on a PS started without --checkpoint-dir".to_string()
                    })?;
                    st.retry_routing_persist();
                    let owned = read_unpoisoned(&st.owned).clone();
                    mgr.prepare_epoch_range(&ps, step, owned.clone())?;
                    Ok(protocol::encode_ckpt_response(protocol::KIND_PREPARE_CKPT, owned.len()))
                }),
            );
        }
        {
            // COMMIT_CKPT: rename the staged epoch into place + write the
            // shard's commit manifest.
            let ps = ps.clone();
            let st = state.clone();
            let ckpt_commit = ckpt.clone();
            rpc.register(
                protocol::KIND_COMMIT_CKPT,
                Box::new(move |msg| {
                    let step = protocol::decode_ckpt_request(msg, protocol::KIND_COMMIT_CKPT)?;
                    let mgr = ckpt_commit.as_ref().with_context(|| {
                        "COMMIT_CKPT on a PS started without --checkpoint-dir".to_string()
                    })?;
                    let owned = read_unpoisoned(&st.owned).clone();
                    let nodes = mgr.commit_epoch_range(&ps, step, owned)?;
                    // The barrier's last durable act: if a reshard's ROUTING
                    // persist failed, land it now so the committed checkpoint
                    // and the routing table never disagree on disk.
                    st.retry_routing_persist();
                    Ok(protocol::encode_ckpt_response(protocol::KIND_COMMIT_CKPT, nodes))
                }),
            );
        }
        {
            // ROUTING: the committed table, or an empty payload before the
            // first reshard (servers never learn the address list until a
            // PREPARE_RESHARD delivers one).
            let st = state.clone();
            rpc.register(
                protocol::KIND_ROUTING,
                Box::new(move |_msg| {
                    Ok(protocol::encode_routing_response(
                        lock_unpoisoned(&st.committed).as_ref(),
                    ))
                }),
            );
        }
        {
            // PREPARE_RESHARD: validate the plan against this shard's role
            // and stage it. Nothing moves yet; a crash here costs nothing.
            let st = state.clone();
            rpc.register(
                protocol::KIND_PREPARE_RESHARD,
                Box::new(move |msg| {
                    let (plan, table, idx) = protocol::decode_prepare_reshard(msg)?;
                    let cur = st.epoch.load(Ordering::SeqCst);
                    anyhow::ensure!(
                        plan.from_epoch == cur,
                        "PREPARE_RESHARD against epoch {}, this shard is at {cur}",
                        plan.from_epoch
                    );
                    let owned = read_unpoisoned(&st.owned).clone();
                    if idx == plan.dest {
                        anyhow::ensure!(
                            st.joinable,
                            "shard {idx} was not started with --join; only spares that \
                             materialize the full node range can receive a migration"
                        );
                        anyhow::ensure!(
                            owned.is_empty(),
                            "migration dest already owns {owned:?}"
                        );
                        anyhow::ensure!(
                            table.owned_range(idx)? == plan.nodes,
                            "staged table does not hand the migrated range to the dest"
                        );
                    } else if idx == plan.source {
                        anyhow::ensure!(
                            owned.start < plan.nodes.start
                                && plan.nodes.start < plan.nodes.end
                                && plan.nodes.end == owned.end,
                            "plan range {:?} is not a proper suffix of owned {owned:?}",
                            plan.nodes
                        );
                        anyhow::ensure!(
                            table.owned_range(idx)? == (owned.start..plan.nodes.start),
                            "staged table does not narrow the source to the kept prefix"
                        );
                    } else {
                        anyhow::ensure!(
                            table.owned_range(idx)? == owned,
                            "staged table reassigns a bystander shard"
                        );
                    }
                    *lock_unpoisoned(&st.staged) = Some((plan, table, idx));
                    Ok(protocol::encode_reshard_ack(protocol::KIND_PREPARE_RESHARD, 1))
                }),
            );
        }
        {
            // MIGRATE_OUT (source only): per migrating node, atomically gate
            // puts + snapshot (embedding ⊕ optimizer bytes, cold tier rows
            // included), then push the snapshot into the destination over a
            // one-shot connection. Any failure surfaces to the coordinator,
            // which aborts; gates stay up until ABORT clears them.
            let ps = ps.clone();
            let st = state.clone();
            rpc.register(
                protocol::KIND_MIGRATE_OUT,
                Box::new(move |msg| {
                    let epoch = protocol::decode_reshard_ctl(msg, protocol::KIND_MIGRATE_OUT)?;
                    let (plan, table, idx) = lock_unpoisoned(&st.staged)
                        .clone()
                        .context("MIGRATE_OUT with no staged plan")?;
                    anyhow::ensure!(
                        plan.from_epoch == epoch,
                        "MIGRATE_OUT for epoch {epoch}, staged plan is for {}",
                        plan.from_epoch
                    );
                    anyhow::ensure!(
                        idx == plan.source,
                        "MIGRATE_OUT sent to shard {idx}, plan source is {}",
                        plan.source
                    );
                    let dest_addr = table.addrs[plan.dest].clone();
                    let delay = migrate_delay();
                    let dest = dial_dest(&dest_addr)?;
                    for node in plan.nodes.clone() {
                        println!("RESHARD: migrating node {node} -> {dest_addr}");
                        std::io::stdout().flush().ok();
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        let snap = {
                            let mut fwd = write_unpoisoned(&st.forward);
                            fwd.insert(node);
                            // Snapshot INSIDE the gate's write lock: every
                            // put is then either in the snapshot or queued.
                            ps.snapshot_node_full(node)?
                        };
                        let resp = dest
                            .call(&protocol::encode_restore_request(node, &snap))
                            .with_context(|| format!("pushing node {node} to {dest_addr}"))?;
                        protocol::decode_restore_response(&resp)?;
                    }
                    Ok(protocol::encode_reshard_ack(
                        protocol::KIND_MIGRATE_OUT,
                        plan.nodes.len(),
                    ))
                }),
            );
        }
        {
            // COMMIT_RESHARD: flip this shard to the staged table. The
            // coordinator commits dest → source → bystanders, so a migrated
            // node always has an owner: the source drains its queued
            // copy-window puts into the (already-owning) destination before
            // narrowing itself and wiping the moved nodes.
            let ps = ps.clone();
            let st = state.clone();
            let ckpt_reshard = ckpt.clone();
            rpc.register(
                protocol::KIND_COMMIT_RESHARD,
                Box::new(move |msg| {
                    let epoch = protocol::decode_reshard_ctl(msg, protocol::KIND_COMMIT_RESHARD)?;
                    let mut staged_guard = lock_unpoisoned(&st.staged);
                    let (plan, table, idx) =
                        staged_guard.clone().context("COMMIT_RESHARD with no staged plan")?;
                    anyhow::ensure!(
                        plan.from_epoch == epoch,
                        "COMMIT_RESHARD for epoch {epoch}, staged plan is for {}",
                        plan.from_epoch
                    );
                    if idx == plan.dest {
                        *write_unpoisoned(&st.owned) = plan.nodes.clone();
                    } else if idx == plan.source {
                        // Taking the owned write lock waits out every
                        // in-flight put; the queue is final after that.
                        let mut owned = write_unpoisoned(&st.owned);
                        let mut fwd = write_unpoisoned(&st.forward);
                        let drained = std::mem::take(&mut *lock_unpoisoned(&st.queue));
                        if !drained.is_empty() {
                            let dest = dial_dest(&table.addrs[plan.dest])?;
                            for (keys, grads) in &drained {
                                let resp = dest
                                    .call(&protocol::encode_put_request(keys, grads, dim, false))
                                    .context("draining copy-window puts to the dest")?;
                                let applied = protocol::decode_put_response(&resp)?;
                                anyhow::ensure!(
                                    applied == keys.len(),
                                    "dest applied {applied}/{} drained puts",
                                    keys.len()
                                );
                            }
                        }
                        *owned = owned.start..plan.nodes.start;
                        for node in plan.nodes.clone() {
                            ps.wipe_node(node)?;
                        }
                        fwd.clear();
                    }
                    st.epoch.store(table.epoch, Ordering::SeqCst);
                    if let Some(mgr) = &ckpt_reshard {
                        mgr.set_routing_epoch(table.epoch);
                    }
                    if let Some(dir) = &st.routing_dir {
                        // A failed persist must not wedge an already-flipped
                        // deployment — the table survives in RAM — but it is
                        // not silently dropped either: the dirty flag makes
                        // every checkpoint barrier retry until a write lands.
                        match crate::recovery::atomic_write(
                            &reshard::routing_path(dir),
                            &table.to_bytes(),
                        ) {
                            Ok(()) => st.routing_dirty.store(false, Ordering::SeqCst),
                            Err(e) => {
                                st.routing_dirty.store(true, Ordering::SeqCst);
                                eprintln!(
                                    "persia serve-ps: persisting ROUTING (epoch {}) failed, \
                                     will retry at the next checkpoint barrier: {e:#}",
                                    table.epoch
                                );
                            }
                        }
                    }
                    *lock_unpoisoned(&st.committed) = Some(table);
                    *staged_guard = None;
                    Ok(protocol::encode_reshard_ack(protocol::KIND_COMMIT_RESHARD, 1))
                }),
            );
        }
        {
            // ABORT_RESHARD: the coordinator's panic button — idempotent,
            // epoch-tolerant, always safe. The dest wipes half-copied nodes
            // (it never owned them); the source drops its gates and queue
            // (its copy is still authoritative); everyone forgets the plan.
            let ps = ps.clone();
            let st = state.clone();
            rpc.register(
                protocol::KIND_ABORT_RESHARD,
                Box::new(move |msg| {
                    let _epoch = protocol::decode_reshard_ctl(msg, protocol::KIND_ABORT_RESHARD)?;
                    if let Some((plan, _table, idx)) = lock_unpoisoned(&st.staged).take() {
                        if idx == plan.dest {
                            for node in plan.nodes.clone() {
                                if let Err(e) = ps.wipe_node(node) {
                                    eprintln!(
                                        "persia serve-ps: wiping aborted node {node}: {e:#}"
                                    );
                                }
                            }
                        }
                        if idx == plan.source {
                            write_unpoisoned(&st.forward).clear();
                            lock_unpoisoned(&st.queue).clear();
                        }
                    }
                    Ok(protocol::encode_reshard_ack(protocol::KIND_ABORT_RESHARD, 1))
                }),
            );
        }
        {
            let stop = stop.clone();
            rpc.register(
                protocol::KIND_SHUTDOWN,
                Box::new(move |_msg| {
                    stop.store(true, Ordering::SeqCst);
                    // Wake the blocking accept so serve_forever/spawned
                    // accept loops observe the flag without polling.
                    let _ = TcpStream::connect(wake_addr(local));
                    Ok(protocol::encode_shutdown_response())
                }),
            );
        }

        Ok(PsServer { listener, rpc: Arc::new(rpc), stop })
    }

    /// The bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve on the calling thread until a SHUTDOWN RPC arrives.
    pub fn serve_forever(self) -> Result<()> {
        accept_loop(self.listener, self.rpc, self.stop, "serve-ps");
        Ok(())
    }

    /// Serve on a background thread; returns a shutdown handle.
    pub fn spawn(self) -> Result<PsServerHandle> {
        let addr = self.local_addr()?;
        let PsServer { listener, rpc, stop } = self;
        let stop_for_loop = stop.clone();
        let accept = std::thread::Builder::new()
            .name("ps-accept".to_string())
            .spawn(move || accept_loop(listener, rpc, stop_for_loop, "serve-ps"))
            .context("spawning PS accept thread")?;
        Ok(PsServerHandle { addr, stop, accept })
    }
}

/// An address that provably reaches the listener from this host: wildcard
/// binds (0.0.0.0 / ::) are not connectable targets everywhere, so rewrite
/// them to the matching loopback.
pub(super) fn wake_addr(bound: SocketAddr) -> SocketAddr {
    let mut addr = bound;
    if addr.ip().is_unspecified() {
        let loopback: std::net::IpAddr = if addr.is_ipv4() {
            std::net::Ipv4Addr::LOCALHOST.into()
        } else {
            std::net::Ipv6Addr::LOCALHOST.into()
        };
        addr.set_ip(loopback);
    }
    addr
}

/// Serve an arbitrary [`RpcServer`] on the shared service core (the
/// readiness loop on unix, thread-per-connection elsewhere). Blocks the
/// calling thread until `stop` is set (wake it with a no-op connect to the
/// listener) or the listener breaks persistently; `label` names the
/// service in diagnostics. This is the entry point benches and soak tests
/// use to drive the exact server stack `serve-ps` runs in production.
pub fn serve_rpc(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    accept_loop(listener, rpc, stop, label)
}

/// The shared connection core of every `persia` service ([`PsServer`], the
/// embedding-worker tier's
/// [`EmbeddingWorkerServer`](super::embedding_worker::EmbeddingWorkerServer),
/// and [`serve_rpc`]): transient-accept-error tolerance and the sleep-free
/// graceful-shutdown protocol described in the module docs. On unix this
/// delegates to the [`super::event_loop`] readiness loop; elsewhere it
/// falls back to one thread per connection with identical RPC semantics.
pub(super) fn accept_loop(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    #[cfg(unix)]
    super::event_loop::run(listener, rpc, stop, label);
    #[cfg(not(unix))]
    accept_loop_threaded(listener, rpc, stop, label);
}

/// The PR-1 thread-per-connection loop, kept as the portable fallback for
/// hosts without `poll(2)`.
#[cfg(not(unix))]
fn accept_loop_threaded(
    listener: TcpListener,
    rpc: Arc<RpcServer>,
    stop: Arc<AtomicBool>,
    label: &'static str,
) {
    // (thread, read-half handle for shutdown wakeup) per live connection.
    let mut conns: Vec<(JoinHandle<()>, Option<TcpStream>)> = Vec::new();
    let mut consecutive_errors = 0u32;
    for (conn_id, stream) in listener.incoming().enumerate() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match stream {
            Ok(stream) => {
                consecutive_errors = 0;
                stream
            }
            Err(e) => {
                // Transient accept failures (ECONNABORTED, EMFILE bursts)
                // must not kill a long-running PS; only a persistently
                // broken listener ends the loop.
                consecutive_errors += 1;
                if consecutive_errors >= 64 {
                    eprintln!("persia {label}: accept failing persistently ({e}); stopping");
                    break;
                }
                continue;
            }
        };
        // Reap finished connections so a long-running PS stays flat on
        // memory (dropping a finished JoinHandle just detaches it).
        conns.retain(|(h, _)| !h.is_finished());
        let peer = stream.peer_addr().ok();
        let wake_handle = stream.try_clone().ok();
        let rpc = rpc.clone();
        let handle = std::thread::Builder::new()
            .name(format!("ps-conn-{conn_id}"))
            .spawn(move || {
                let transport = TcpTransport::new(stream);
                // Serve until the peer disconnects, stop is set, or the
                // peer sends garbage (malformed frames drop the connection).
                if let Err(e) = rpc.serve(&transport) {
                    eprintln!("persia {label}: connection {peer:?} dropped: {e:#}");
                }
            })
            .expect("spawn PS connection thread");
        conns.push((handle, wake_handle));
    }
    // Unblock readers parked in recv() on idle connections so the joins
    // below cannot hang on clients that never disconnect. Only the read
    // half closes: in-flight responses (including the SHUTDOWN ack) still
    // reach their peers.
    for (_, wake_handle) in &conns {
        if let Some(s) = wake_handle {
            let _ = s.shutdown(std::net::Shutdown::Read);
        }
    }
    for (handle, _) in conns {
        let _ = handle.join();
    }
}

/// Handle to a background PS service.
pub struct PsServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: JoinHandle<()>,
}

impl PsServerHandle {
    /// The service's bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, deliver in-flight responses, unblock idle
    /// connections, and join every server thread. Clients still holding
    /// [`super::RemotePs`] handles see their next call fail.
    pub fn shutdown(self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept (the no-op connection is discarded by the
        // stop check before it is served).
        let _ = TcpStream::connect(wake_addr(self.addr));
        self.accept.join().map_err(|_| anyhow::anyhow!("PS accept thread panicked"))
    }
}
