//! The [`PsBackend`] abstraction: how an embedding worker reaches the
//! embedding parameter server.
//!
//! Three implementations exist:
//! * [`crate::embedding::EmbeddingPs`] — in-process (the simulated-cluster
//!   default): calls go straight into the lock-striped shards;
//! * [`super::RemotePs`] — the TCP client stub talking to one
//!   [`super::PsServer`] over the zero-copy wire format;
//! * [`super::ShardedRemotePs`] — the multi-process deployment: N shard
//!   processes, each owning a node range, scatter-gathered per batch.
//!
//! The trait is deliberately *batched*: workers dedup a batch's keys first
//! (§4.2.3 index compression applied at the source) and issue one get/put
//! per mini-batch, so the remote path costs one round-trip where the naive
//! per-row API would cost thousands.

use std::path::Path;

use anyhow::Result;

use crate::config::EmbeddingConfig;
use crate::embedding::{CheckpointManager, EmbeddingPs};

/// Aggregate PS statistics surfaced through either backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct PsStats {
    /// Materialized rows across all nodes/shards, every tier counted.
    pub total_rows: usize,
    /// Hot-tier evictions since start (= demotions on a tiered PS).
    pub total_evictions: u64,
    /// Max/mean per-node traffic ratio (1.0 = perfectly balanced).
    pub imbalance: f64,
    /// Lookups served by hot tiers.
    pub hot_hits: u64,
    /// Lookups served by cold tiers (0 on an all-hot PS).
    pub cold_hits: u64,
    /// Rows demoted hot → cold.
    pub demotions: u64,
    /// Rows promoted cold → hot.
    pub promotions: u64,
    /// Rows currently resident in cold tiers.
    pub cold_rows: usize,
}

/// Batched get/put access to a (possibly remote) embedding PS.
pub trait PsBackend: Send + Sync {
    /// Embedding dimension per row.
    fn dim(&self) -> usize;

    /// Fetch rows for `keys` into `out` (`keys.len() * dim` floats),
    /// materializing missing rows deterministically.
    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()>;

    /// Apply one gradient row per key (`keys.len() * dim` floats).
    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()>;

    /// Aggregate statistics (row counts, evictions, load balance).
    fn stats(&self) -> Result<PsStats>;

    /// Error if this backend's PS was not built from exactly this config +
    /// seed. In-process backends are compatible by construction (the
    /// trainer built them from the config it is checking); the remote
    /// backend compares against the server's INFO handshake so a
    /// `serve-ps`/`train` flag mismatch fails loudly instead of silently
    /// training against different numerics.
    fn check_compat(&self, _cfg: &EmbeddingConfig, _seed: u64) -> Result<()> {
        Ok(())
    }

    /// Cut checkpoint epoch `step` across every shard behind this backend:
    /// the two-phase PREPARE/COMMIT of [`crate::recovery::coordinator`].
    /// `dir` is the checkpoint root for backends that write locally (the
    /// in-process PS); remote shards use the `--checkpoint-dir` they were
    /// started with and ignore it. Backends without checkpoint support
    /// error — the trainer surfaces that at the first epoch, not at a
    /// failed restore.
    fn checkpoint_epoch(&self, _dir: &Path, _step: u64) -> Result<()> {
        anyhow::bail!("this PS backend does not support coordinated checkpoint epochs")
    }

    /// Notify this backend that epoch `step` is globally committed, so any
    /// client-side put replay log can truncate. Default: nothing to mark.
    fn mark_epoch_committed(&self, _step: u64) {}

    /// Inspect the merged per-node traffic and, if the hottest shard's
    /// load exceeds `threshold` times the mean, drive one live resharding
    /// round (PREPARE → MIGRATE → COMMIT across the shard fleet; see
    /// [`crate::service::reshard`]). Returns the newly committed routing
    /// epoch, or `Ok(None)` when the deployment is balanced or the backend
    /// cannot reshard (the default: in-process and single-shard PSes have
    /// nothing to migrate between).
    fn maybe_reshard(&self, _threshold: f64) -> Result<Option<u64>> {
        Ok(None)
    }

    /// The committed routing epoch of the deployment behind this backend
    /// (0 = the initial static layout; bumped by each committed reshard).
    fn routing_epoch(&self) -> u64 {
        0
    }

    /// Whether this backend keeps a client-side gradient-put replay log
    /// (`--ps-replay`). An embedding worker advertises this in its INFO
    /// handshake: a trainer must refuse to fail over *away* from a worker
    /// whose replay log died with it — the dead log's delta cannot be handed
    /// to the adopter across processes, so a later shard replay would
    /// silently drop those puts. Default: no log.
    fn replay_puts(&self) -> bool {
        false
    }
}

/// In-process backend: direct calls into the sharded PS.
impl PsBackend for EmbeddingPs {
    fn dim(&self) -> usize {
        EmbeddingPs::dim(self)
    }

    fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
        // Through the packed entry point so cold-tier I/O failure is an
        // `Err` to the worker, not a PS panic.
        let packed: Vec<u64> =
            keys.iter().map(|&(g, id)| crate::embedding::ps::pack_key(g, id)).collect();
        self.get_packed_into(&packed, out)
    }

    fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> Result<()> {
        let packed: Vec<u64> =
            keys.iter().map(|&(g, id)| crate::embedding::ps::pack_key(g, id)).collect();
        self.put_grads_packed(&packed, grads)
    }

    fn stats(&self) -> Result<PsStats> {
        let tc = self.tier_counters();
        Ok(PsStats {
            total_rows: self.total_rows(),
            total_evictions: self.total_evictions(),
            imbalance: self.imbalance(),
            hot_hits: tc.hot_hits,
            cold_hits: tc.cold_hits,
            demotions: tc.demotions,
            promotions: tc.promotions,
            cold_rows: self.cold_rows(),
        })
    }

    /// In-process epochs degenerate to prepare+commit against the local
    /// filesystem — same files, same atomicity, no RPC.
    fn checkpoint_epoch(&self, dir: &Path, step: u64) -> Result<()> {
        anyhow::ensure!(
            !dir.as_os_str().is_empty(),
            "checkpoint epochs need a checkpoint dir (--checkpoint-dir)"
        );
        let mgr = CheckpointManager::new(dir)?;
        mgr.prepare_epoch(self, step)?;
        mgr.commit_epoch(self, step)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};
    use std::sync::Arc;

    fn ps() -> EmbeddingPs {
        let cfg = EmbeddingConfig {
            rows_per_group: 1 << 20,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        EmbeddingPs::new(&cfg, 4, 11)
    }

    #[test]
    fn local_backend_delegates() {
        let ps = ps();
        let backend: &dyn PsBackend = &ps;
        assert_eq!(backend.dim(), 4);
        let keys = [(0u32, 1u64), (1, 2)];
        let mut rows = vec![0.0; 8];
        backend.get_many(&keys, &mut rows).unwrap();
        backend.put_grads(&keys, &vec![1.0; 8]).unwrap();
        let mut after = vec![0.0; 8];
        backend.get_many(&keys, &mut after).unwrap();
        for (b, a) in rows.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6, "SGD lr=0.5 step expected");
        }
        let stats = backend.stats().unwrap();
        assert_eq!(stats.total_rows, 2);
        assert!(stats.imbalance >= 1.0);
    }

    #[test]
    fn arc_coerces_to_trait_object() {
        let backend: Arc<dyn PsBackend> = Arc::new(ps());
        assert_eq!(backend.dim(), 4);
    }
}
