//! PS service wire protocol: message kinds + codecs over [`crate::comm::wire`].
//!
//! Requests/responses are zero-copy wire messages (§4.2.3 — no protobuf):
//!
//! | kind       | request sections              | response sections              |
//! |------------|-------------------------------|--------------------------------|
//! | `INFO`     | –                             | u64 fingerprint + node range   |
//! | `GET`      | u64 keys, u8 flags            | u8 flags, values               |
//! | `PUT`      | u64 keys, u8 flags, values    | u64 `[rows applied]`           |
//! | `STATS`    | –                             | u64 `[rows, evic, imb bits, hot hits, cold hits, demo, promo, cold rows]`, u64 per-node traffic |
//! | `SHUTDOWN` | –                             | – (ack)                        |
//! | `SNAPSHOT` | u64 `[node]`                  | u8 flags, u64 hot lens, u8 hot bytes, u64 cold lens, u8 cold bytes |
//! | `RESTORE`  | u64 `[node]`, u8 flags, u64/u8 hot, u64/u8 cold | u64 `[shards restored]` |
//!
//! Keys are `pack_key(group, id)` u64s, already deduplicated by the sender —
//! the paper's lossless index compression. `values` is either one raw f32
//! section (bit-exact) or, when the compress flag is set, an fp16 section
//! plus per-row scales — the paper's lossy value compression
//! ([`CompressedValues`]), halving wire bytes at ~2^-10 relative error.
//!
//! `SNAPSHOT`/`RESTORE` move whole-node [`NodeSnapshot`]s (flat byte blobs,
//! one per shard; on a tiered PS a second blob set for the cold tier, the
//! flags byte says which) over the wire, so the §4.2.4 recovery drill — kill
//! a PS process, restart it, restore its slice — works across process
//! boundaries for both storage engines.
//! The STATS per-node traffic vector is global-length (unowned nodes report
//! 0), letting a sharded client sum vectors across shard processes and
//! compute the *correct* global imbalance instead of averaging per-process
//! ratios.

use anyhow::{ensure, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::wire::{WireReader, WireWriter};
use crate::config::EmbeddingConfig;
use crate::embedding::NodeSnapshot;

use super::backend::PsStats;

/// Handshake: geometry + config fingerprint + owned node range.
/// (PS message kinds are 0x50xx, disjoint from the ring's 0x60xx and the
/// embedding-worker tier's 0x70xx.)
pub const KIND_INFO: u32 = 0x5001;
/// Batched row fetch of deduplicated packed keys.
pub const KIND_GET: u32 = 0x5002;
/// Batched gradient put of deduplicated packed keys.
pub const KIND_PUT: u32 = 0x5003;
/// Aggregate statistics + the global-length per-node traffic vector.
pub const KIND_STATS: u32 = 0x5004;
/// Graceful shutdown (acked before the server stops accepting).
pub const KIND_SHUTDOWN: u32 = 0x5005;
/// Whole-node LRU snapshot fetch (§4.2.4 recovery).
pub const KIND_SNAPSHOT: u32 = 0x5006;
/// Whole-node LRU snapshot restore (§4.2.4 recovery).
pub const KIND_RESTORE: u32 = 0x5007;
/// Checkpoint-epoch phase 1: stage every owned node's snapshot for `step`
/// (the coordinator commits only once every shard staged successfully).
pub const KIND_PREPARE_CKPT: u32 = 0x5008;
/// Checkpoint-epoch phase 2: rename the staged snapshots into place and
/// write the shard's commit manifest.
pub const KIND_COMMIT_CKPT: u32 = 0x5009;
/// Fetch the server's committed routing table (serialized
/// [`RoutingTable`](super::reshard::RoutingTable) bytes). Clients that hit
/// [`KIND_NOT_OWNER`] refresh through this and retry.
pub const KIND_ROUTING: u32 = 0x500A;
/// Reshard phase 1 (PREPARE_CKPT-style barrier): stage a migration plan +
/// the epoch-N+1 routing table on every shard. Nothing moves yet.
pub const KIND_PREPARE_RESHARD: u32 = 0x500B;
/// Reshard copy phase, sent to the *source* shard only: stream every
/// migrating node (embedding ⊕ optimizer bytes, cold rows included) to the
/// destination via RESTORE pushes, gating concurrent puts per node.
pub const KIND_MIGRATE_OUT: u32 = 0x500C;
/// Reshard phase 2: atomically adopt the staged table (dest first, then
/// source — the source drains its gated-put queue to the dest, narrows its
/// owned range and wipes the moved nodes — then bystanders).
pub const KIND_COMMIT_RESHARD: u32 = 0x500D;
/// Reshard rollback: drop the staged plan/table; the dest wipes any
/// half-copied nodes, the source keeps everything and clears its gates.
pub const KIND_ABORT_RESHARD: u32 = 0x500E;
/// In-band "wrong shard" response to GET/PUT, carrying the server's
/// committed routing epoch. This MUST be a structured response, not a
/// handler error: an `Err` tears down the whole pipelined connection,
/// while a stale client only needs to refresh its table and re-route.
pub const KIND_NOT_OWNER: u32 = 0x500F;

/// Flag bit: value payload is fp16 + per-row scales.
const FLAG_COMPRESS: u8 = 1;

fn put_values(w: &mut WireWriter, values: &[f32], dim: usize, compress: bool) {
    if compress {
        let c = CompressedValues::compress(values, dim);
        w.put_f16(&c.vals);
        w.put_f32(&c.scales);
    } else {
        w.put_f32(values);
    }
}

fn read_values(r: &WireReader, section: usize, dim: usize, compressed: bool) -> Result<Vec<f32>> {
    if compressed {
        let vals = r.f16(section)?;
        let scales = r.f32(section + 1)?;
        ensure!(vals.len() == scales.len() * dim, "compressed value shape mismatch");
        Ok(CompressedValues { vals, scales, dim }.decompress())
    } else {
        r.f32(section)
    }
}

// --- INFO ---

/// Everything a client needs to know the server's PS is the one its
/// trainer config describes. Geometry mismatches would corrupt shapes;
/// the rest (seed, optimizer, lr, capacity, partition) would silently
/// change numerics — so all of it rides in the handshake.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PsInfo {
    /// Embedding vector width per row.
    pub dim: usize,
    /// Global PS node count (the routing modulus).
    pub n_nodes: usize,
    /// Lock-striped sub-shards per node.
    pub shards_per_node: usize,
    /// Row-materialization seed.
    pub seed: u64,
    /// LRU capacity per shard.
    pub shard_capacity: usize,
    /// [`OptimizerKind`](crate::config::OptimizerKind) as a stable code.
    pub optimizer_code: u64,
    /// [`PartitionPolicy`](crate::config::PartitionPolicy) as a stable code.
    pub partition_code: u64,
    /// Row-optimizer learning rate (f32 bits).
    pub lr_bits: u32,
    /// First global node this server owns.
    pub node_start: usize,
    /// One past the last global node this server owns.
    pub node_end: usize,
    /// Random nonce minted at server start. A reconnecting client that sees
    /// a *different* nonce knows it reached a new process (killed +
    /// restarted) rather than a transient wire failure — the trigger for
    /// the recovery layer's put-log replay.
    pub boot_nonce: u64,
    /// The checkpoint-epoch step this server restored at startup (0 = fresh
    /// start or legacy flat-file restore). The replay log re-sends exactly
    /// the puts recorded after this epoch.
    pub restored_step: u64,
    /// Whether this server was started with `serve-ps --join`: it holds the
    /// FULL node range physically (so unseen-key materialization is bitwise
    /// identical to any source shard's) but owns nothing until a reshard
    /// commits nodes to it. Only joinable shards are valid migration
    /// destinations.
    pub joinable: bool,
    /// The routing epoch this server is serving (0 until a reshard commits).
    pub routing_epoch: u64,
}

impl PsInfo {
    /// Whether `other` describes the same PS deployment: every numeric and
    /// geometric field must match, but the per-process boot nonce, the
    /// restored epoch, the owned node range, and the routing epoch are
    /// *instance/topology* identity, not deployment identity — a shard
    /// killed and restarted from its checkpoint, or one whose owned range
    /// changed in a live reshard, must still count as "the same PS" so the
    /// client can rejoin it (§4.2.4).
    pub fn same_deployment(&self, other: &PsInfo) -> bool {
        let strip = |i: &PsInfo| {
            let mut i = *i;
            i.boot_nonce = 0;
            i.restored_step = 0;
            i.node_start = 0;
            i.node_end = 0;
            i.joinable = false;
            i.routing_epoch = 0;
            i
        };
        strip(self) == strip(other)
    }
}

/// [`OptimizerKind`](crate::config::OptimizerKind) as a stable wire code.
pub fn optimizer_code(kind: crate::config::OptimizerKind) -> u64 {
    match kind {
        crate::config::OptimizerKind::Sgd => 0,
        crate::config::OptimizerKind::Adagrad => 1,
        crate::config::OptimizerKind::Adam => 2,
    }
}

/// [`PartitionPolicy`](crate::config::PartitionPolicy) as a stable wire code.
pub fn partition_code(policy: crate::config::PartitionPolicy) -> u64 {
    match policy {
        crate::config::PartitionPolicy::FeatureGroup => 0,
        crate::config::PartitionPolicy::ShuffledUniform => 1,
    }
}

/// Inverse of [`partition_code`] (clients need the policy to route).
pub fn partition_from_code(code: u64) -> Option<crate::config::PartitionPolicy> {
    Some(match code {
        0 => crate::config::PartitionPolicy::FeatureGroup,
        1 => crate::config::PartitionPolicy::ShuffledUniform,
        _ => return None,
    })
}

/// Shared trainer-side check that a server's INFO fingerprint describes the
/// PS this trainer's config would build. Used by both the single-address
/// [`RemotePs`](super::RemotePs) and the multi-process
/// [`ShardedRemotePs`](super::ShardedRemotePs), so client and servers cannot
/// drift apart on what "compatible" means. Node-range fields are deployment
/// topology, not numerics, and are deliberately not part of the fingerprint.
pub fn check_fingerprint(info: &PsInfo, cfg: &EmbeddingConfig, seed: u64) -> Result<()> {
    let want = (
        cfg.n_nodes,
        cfg.shards_per_node,
        seed,
        cfg.shard_capacity,
        optimizer_code(cfg.optimizer),
        partition_code(cfg.partition),
        cfg.lr.to_bits(),
    );
    let got = (
        info.n_nodes,
        info.shards_per_node,
        info.seed,
        info.shard_capacity,
        info.optimizer_code,
        info.partition_code,
        info.lr_bits,
    );
    ensure!(
        want == got,
        "remote PS config mismatch: trainer expects \
         (nodes, shards, seed, capacity, opt, partition, lr_bits) = {want:?}, \
         server reports {got:?} — start serve-ps and train with the same \
         --preset/--dense/--shard-capacity/--seed flags"
    );
    Ok(())
}

/// Encode an INFO request (empty body).
pub fn encode_info_request() -> Vec<u8> {
    WireWriter::new(KIND_INFO).finish()
}

/// Encode an INFO response.
pub fn encode_info_response(info: &PsInfo) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_INFO);
    w.put_u64(&[
        info.dim as u64,
        info.n_nodes as u64,
        info.shards_per_node as u64,
        info.seed,
        info.shard_capacity as u64,
        info.optimizer_code,
        info.partition_code,
        info.lr_bits as u64,
        info.node_start as u64,
        info.node_end as u64,
        info.boot_nonce,
        info.restored_step,
        u64::from(info.joinable),
        info.routing_epoch,
    ]);
    w.finish()
}

/// Decode an INFO response (validating the node range). Accepts both the
/// 12-field pre-reshard layout (joinable/routing_epoch default to 0) and
/// the 14-field layout, so mixed-version deployments still handshake.
pub fn decode_info_response(msg: &[u8]) -> Result<PsInfo> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_INFO, "expected INFO response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(
        xs.len() == 12 || xs.len() == 14,
        "malformed INFO response ({} fields)",
        xs.len()
    );
    let info = PsInfo {
        dim: xs[0] as usize,
        n_nodes: xs[1] as usize,
        shards_per_node: xs[2] as usize,
        seed: xs[3],
        shard_capacity: xs[4] as usize,
        optimizer_code: xs[5],
        partition_code: xs[6],
        lr_bits: xs[7] as u32,
        node_start: xs[8] as usize,
        node_end: xs[9] as usize,
        boot_nonce: xs[10],
        restored_step: xs[11],
        joinable: xs.get(12).copied().unwrap_or(0) != 0,
        routing_epoch: xs.get(13).copied().unwrap_or(0),
    };
    // An EMPTY range is legal now: a `--join` spare (and a source that gave
    // everything away) owns nothing while staying a live deployment member.
    ensure!(
        info.node_start <= info.node_end && info.node_end <= info.n_nodes,
        "INFO node range {}..{} invalid for {} nodes",
        info.node_start,
        info.node_end,
        info.n_nodes
    );
    Ok(info)
}

// --- GET ---

/// Encode a GET of already-deduplicated packed keys.
pub fn encode_get_request(keys: &[u64], compress: bool) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GET);
    w.put_u64(keys).put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    w.finish()
}

/// Returns `(packed keys, compress)`.
pub fn decode_get_request(msg: &[u8]) -> Result<(Vec<u64>, bool)> {
    let r = WireReader::parse(msg)?;
    let keys = r.u64(0)?;
    let flags = r.u8(1)?;
    ensure!(flags.len() == 1, "malformed GET flags");
    Ok((keys, flags[0] & FLAG_COMPRESS != 0))
}

/// Encode the fetched rows (raw f32, or fp16+scales when `compress`).
pub fn encode_get_response(rows: &[f32], dim: usize, compress: bool) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GET);
    w.put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    put_values(&mut w, rows, dim, compress);
    w.finish()
}

/// Decode a GET response straight into `out` (`n_rows * dim` floats) —
/// the hot path: no intermediate allocation, zero-copy borrow of the raw
/// f32 section where alignment permits.
pub fn decode_get_response_into(msg: &[u8], dim: usize, out: &mut [f32]) -> Result<()> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_GET, "expected GET response, got kind {}", r.kind());
    let flags = r.u8(0)?;
    ensure!(flags.len() == 1, "malformed GET response flags");
    if flags[0] & FLAG_COMPRESS != 0 {
        let vals = r.f16(1)?;
        let scales = r.f32(2)?;
        ensure!(
            vals.len() == out.len() && scales.len() * dim == vals.len(),
            "GET returned {} compressed floats, want {}",
            vals.len(),
            out.len()
        );
        CompressedValues { vals, scales, dim }.decompress_into(out);
    } else {
        // Borrow in place when the buffer happens to be 4-aligned (the
        // section offset always is); fall back to the copying reader.
        match r.f32_borrowed(1) {
            Ok(rows) => {
                ensure!(
                    rows.len() == out.len(),
                    "GET returned {} floats, want {}",
                    rows.len(),
                    out.len()
                );
                out.copy_from_slice(rows);
            }
            Err(_) => {
                let rows = r.f32(1)?;
                ensure!(
                    rows.len() == out.len(),
                    "GET returned {} floats, want {}",
                    rows.len(),
                    out.len()
                );
                out.copy_from_slice(&rows);
            }
        }
    }
    Ok(())
}

/// Decode `n_rows * dim` floats from a GET response (allocating variant).
pub fn decode_get_response(msg: &[u8], dim: usize, n_rows: usize) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; n_rows * dim];
    decode_get_response_into(msg, dim, &mut out)?;
    Ok(out)
}

// --- PUT ---

/// Encode a gradient PUT (`keys.len() * dim` floats).
pub fn encode_put_request(keys: &[u64], grads: &[f32], dim: usize, compress: bool) -> Vec<u8> {
    debug_assert_eq!(grads.len(), keys.len() * dim);
    let mut w = WireWriter::new(KIND_PUT);
    w.put_u64(keys).put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    put_values(&mut w, grads, dim, compress);
    w.finish()
}

/// Returns `(packed keys, gradient rows)`.
pub fn decode_put_request(msg: &[u8], dim: usize) -> Result<(Vec<u64>, Vec<f32>)> {
    let r = WireReader::parse(msg)?;
    let keys = r.u64(0)?;
    let flags = r.u8(1)?;
    ensure!(flags.len() == 1, "malformed PUT flags");
    let grads = read_values(&r, 2, dim, flags[0] & FLAG_COMPRESS != 0)?;
    ensure!(grads.len() == keys.len() * dim, "PUT shape mismatch");
    Ok((keys, grads))
}

/// Encode the PUT ack (rows applied).
pub fn encode_put_response(rows_applied: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_PUT);
    w.put_u64(&[rows_applied as u64]);
    w.finish()
}

/// Decode the PUT ack.
pub fn decode_put_response(msg: &[u8]) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_PUT, "expected PUT response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed PUT response");
    Ok(xs[0] as usize)
}

// --- STATS ---

/// Encode a STATS request (empty body).
pub fn encode_stats_request() -> Vec<u8> {
    WireWriter::new(KIND_STATS).finish()
}

/// `node_traffic` is the server PS's global-length per-node traffic vector
/// (zeros for nodes it doesn't own) — the mergeable raw data behind
/// `stats.imbalance`.
pub fn encode_stats_response(stats: &PsStats, node_traffic: &[u64]) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_STATS);
    w.put_u64(&[
        stats.total_rows as u64,
        stats.total_evictions,
        stats.imbalance.to_bits(),
        stats.hot_hits,
        stats.cold_hits,
        stats.demotions,
        stats.promotions,
        stats.cold_rows as u64,
    ]);
    w.put_u64(node_traffic);
    w.finish()
}

/// Decode a STATS response (aggregate stats only).
pub fn decode_stats_response(msg: &[u8]) -> Result<PsStats> {
    Ok(decode_stats_full(msg)?.0)
}

/// Decode a STATS response including the per-node traffic vector.
pub fn decode_stats_full(msg: &[u8]) -> Result<(PsStats, Vec<u64>)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_STATS, "expected STATS response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 8, "malformed STATS response");
    let traffic = r.u64(1)?;
    Ok((
        PsStats {
            total_rows: xs[0] as usize,
            total_evictions: xs[1],
            imbalance: f64::from_bits(xs[2]),
            hot_hits: xs[3],
            cold_hits: xs[4],
            demotions: xs[5],
            promotions: xs[6],
            cold_rows: xs[7] as usize,
        },
        traffic,
    ))
}

// --- SNAPSHOT / RESTORE ---
//
// Shard snapshots are opaque byte blobs (hot-tier `LruStore` bytes, and on
// a tiered PS a second set of cold-tier `ColdStore` snapshot bytes), one
// per lock-striped shard of the node. Each set rides as one concatenated u8
// section plus a u64 length-per-shard section; the split is reconstructed on
// the other side with an overflow-checked prefix sum. A flags byte says
// whether the cold sections are meaningful (`FLAG_HAS_COLD`) — they are
// always present on the wire so section indices stay fixed.

/// Flag bit in the SNAPSHOT/RESTORE flags section: the snapshot carries a
/// cold tier (the PS on the other side must have been started with
/// `--cold-dir`).
const FLAG_HAS_COLD: u8 = 1;

/// Encode a SNAPSHOT request for one global node.
pub fn encode_snapshot_request(node: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_SNAPSHOT);
    w.put_u64(&[node as u64]);
    w.finish()
}

/// Decode a SNAPSHOT request.
pub fn decode_snapshot_request(msg: &[u8]) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_SNAPSHOT, "expected SNAPSHOT, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed SNAPSHOT request");
    Ok(xs[0] as usize)
}

fn put_shard_blobs(w: &mut WireWriter, shards: &[Vec<u8>]) {
    let lens: Vec<u64> = shards.iter().map(|s| s.len() as u64).collect();
    let mut bytes = Vec::with_capacity(lens.iter().sum::<u64>() as usize);
    for s in shards {
        bytes.extend_from_slice(s);
    }
    w.put_u64(&lens);
    w.put_u8(&bytes);
}

fn read_shard_blobs(r: &WireReader, section: usize) -> Result<Vec<Vec<u8>>> {
    let lens = r.u64(section)?;
    let bytes = r.u8(section + 1)?;
    let mut out = Vec::with_capacity(lens.len());
    let mut off = 0usize;
    for &len in &lens {
        let len = usize::try_from(len).map_err(|_| anyhow::anyhow!("shard blob too large"))?;
        let end = off.checked_add(len).ok_or_else(|| anyhow::anyhow!("shard lens overflow"))?;
        ensure!(end <= bytes.len(), "shard lens exceed payload");
        out.push(bytes[off..end].to_vec());
        off = end;
    }
    ensure!(off == bytes.len(), "trailing bytes after shard blobs");
    Ok(out)
}

/// Write a [`NodeSnapshot`] as flags + hot sections + cold sections. The
/// cold sections are always emitted (empty when all-hot) so the reader's
/// section numbering never shifts.
fn put_node_snapshot(w: &mut WireWriter, snap: &NodeSnapshot) {
    w.put_u8(&[if snap.cold.is_some() { FLAG_HAS_COLD } else { 0 }]);
    put_shard_blobs(w, &snap.hot);
    match &snap.cold {
        Some(cold) => put_shard_blobs(w, cold),
        None => put_shard_blobs(w, &[]),
    }
}

fn read_node_snapshot(r: &WireReader, section: usize) -> Result<NodeSnapshot> {
    let flags = r.u8(section)?;
    ensure!(flags.len() == 1, "malformed snapshot flags");
    let hot = read_shard_blobs(r, section + 1)?;
    let cold_blobs = read_shard_blobs(r, section + 3)?;
    let cold = if flags[0] & FLAG_HAS_COLD != 0 {
        ensure!(
            cold_blobs.len() == hot.len(),
            "cold snapshot has {} shards, hot has {}",
            cold_blobs.len(),
            hot.len()
        );
        Some(cold_blobs)
    } else {
        ensure!(cold_blobs.is_empty(), "all-hot snapshot carries cold payload");
        None
    };
    Ok(NodeSnapshot { hot, cold })
}

/// Encode a node's snapshot (per-shard hot blobs + optional cold blobs).
pub fn encode_snapshot_response(snap: &NodeSnapshot) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_SNAPSHOT);
    put_node_snapshot(&mut w, snap);
    w.finish()
}

/// Decode a node's snapshot (per-shard hot blobs + optional cold blobs).
pub fn decode_snapshot_response(msg: &[u8]) -> Result<NodeSnapshot> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_SNAPSHOT, "expected SNAPSHOT response, got kind {}", r.kind());
    read_node_snapshot(&r, 0)
}

/// Encode a RESTORE of one node from its snapshot.
pub fn encode_restore_request(node: usize, snap: &NodeSnapshot) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_RESTORE);
    w.put_u64(&[node as u64]);
    put_node_snapshot(&mut w, snap);
    w.finish()
}

/// Returns `(node, node snapshot)`.
pub fn decode_restore_request(msg: &[u8]) -> Result<(usize, NodeSnapshot)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_RESTORE, "expected RESTORE, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed RESTORE request");
    Ok((xs[0] as usize, read_node_snapshot(&r, 1)?))
}

/// Encode the RESTORE ack (shards restored).
pub fn encode_restore_response(shards_restored: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_RESTORE);
    w.put_u64(&[shards_restored as u64]);
    w.finish()
}

/// Decode the RESTORE ack.
pub fn decode_restore_response(msg: &[u8]) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_RESTORE, "expected RESTORE response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed RESTORE response");
    Ok(xs[0] as usize)
}

// --- PREPARE_CKPT / COMMIT_CKPT ---
//
// The two-phase checkpoint-epoch protocol (§4.2.4, coordinated): the
// trainer PREPAREs every shard — each stages its owned nodes' snapshots for
// the given step — and only once every shard acked does it COMMIT, which
// renames the staged files into place and writes the shard's commit
// manifest. A crash between the phases leaves only ignorable staged files;
// a restore can therefore never mix nodes from different steps.

/// Encode a PREPARE_CKPT or COMMIT_CKPT request for epoch `step`.
/// `kind` must be [`KIND_PREPARE_CKPT`] or [`KIND_COMMIT_CKPT`].
pub fn encode_ckpt_request(kind: u32, step: u64) -> Vec<u8> {
    debug_assert!(kind == KIND_PREPARE_CKPT || kind == KIND_COMMIT_CKPT);
    let mut w = WireWriter::new(kind);
    w.put_u64(&[step]);
    w.finish()
}

/// Decode a checkpoint-phase request of the expected `kind` into its step.
pub fn decode_ckpt_request(msg: &[u8], kind: u32) -> Result<u64> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == kind, "expected ckpt kind {kind:#x}, got {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed checkpoint request");
    Ok(xs[0])
}

/// Encode a checkpoint-phase ack (nodes staged/committed by this shard).
pub fn encode_ckpt_response(kind: u32, nodes: usize) -> Vec<u8> {
    debug_assert!(kind == KIND_PREPARE_CKPT || kind == KIND_COMMIT_CKPT);
    let mut w = WireWriter::new(kind);
    w.put_u64(&[nodes as u64]);
    w.finish()
}

/// Decode a checkpoint-phase ack of the expected `kind`.
pub fn decode_ckpt_response(msg: &[u8], kind: u32) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == kind, "expected ckpt ack kind {kind:#x}, got {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed checkpoint ack");
    Ok(xs[0] as usize)
}

// --- SHUTDOWN ---

/// Encode a SHUTDOWN request (empty body).
pub fn encode_shutdown_request() -> Vec<u8> {
    WireWriter::new(KIND_SHUTDOWN).finish()
}

/// Encode the SHUTDOWN ack.
pub fn encode_shutdown_response() -> Vec<u8> {
    WireWriter::new(KIND_SHUTDOWN).finish()
}

// --- ROUTING / PREPARE_RESHARD / MIGRATE_OUT / COMMIT / ABORT / NOT_OWNER ---
//
// Live resharding reuses the two-phase shape of the checkpoint-epoch
// protocol: PREPARE stages the plan + next table everywhere (nothing
// applied), MIGRATE_OUT makes the source stream the moving nodes to the
// destination, COMMIT flips ownership (dest → source → bystanders), ABORT
// rolls back. GET/PUT answered with an in-band NOT_OWNER frame carry the
// server's committed epoch so stale clients can refresh and re-route
// without tearing down their pipelined connections.

use super::reshard::{MigrationPlan, RoutingTable};

/// Encode a ROUTING request (empty body).
pub fn encode_routing_request() -> Vec<u8> {
    WireWriter::new(KIND_ROUTING).finish()
}

/// Encode a ROUTING response carrying the committed table — or an empty
/// payload when this server has none yet (epoch 0, pre-first-reshard:
/// servers never learn the deployment's address list until a PREPARE).
pub fn encode_routing_response(table: Option<&RoutingTable>) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_ROUTING);
    match table {
        Some(t) => w.put_u8(&t.to_bytes()),
        None => w.put_u8(&[]),
    };
    w.finish()
}

/// Decode a ROUTING response into the committed table (`None` = the server
/// has not committed a reshard and knows no table).
pub fn decode_routing_response(msg: &[u8]) -> Result<Option<RoutingTable>> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_ROUTING, "expected ROUTING response, got kind {}", r.kind());
    let bytes = r.u8(0)?;
    if bytes.is_empty() {
        return Ok(None);
    }
    Ok(Some(RoutingTable::from_bytes(bytes)?))
}

/// Encode a PREPARE_RESHARD request staging `plan` and the epoch-N+1
/// `table` it produces. `shard_idx` is the *recipient's* index in
/// `table.addrs` — how each server learns its role (source / destination /
/// bystander) without guessing from address strings.
pub fn encode_prepare_reshard(
    plan: &MigrationPlan,
    table: &RoutingTable,
    shard_idx: usize,
) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_PREPARE_RESHARD);
    w.put_u64(&[shard_idx as u64]);
    w.put_u8(&plan.to_bytes());
    w.put_u8(&table.to_bytes());
    w.finish()
}

/// Decode a PREPARE_RESHARD request into `(plan, staged table, recipient
/// shard index)`.
pub fn decode_prepare_reshard(msg: &[u8]) -> Result<(MigrationPlan, RoutingTable, usize)> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_PREPARE_RESHARD, "expected PREPARE_RESHARD, got {}", r.kind());
    let head = r.u64(0)?;
    ensure!(head.len() == 1, "malformed PREPARE_RESHARD header");
    let shard_idx = head[0] as usize;
    let plan = MigrationPlan::from_bytes(r.u8(1)?)?;
    let table = RoutingTable::from_bytes(r.u8(2)?)?;
    ensure!(
        table.epoch == plan.from_epoch + 1,
        "staged table epoch {} does not follow plan epoch {}",
        table.epoch,
        plan.from_epoch
    );
    ensure!(
        shard_idx < table.addrs.len(),
        "PREPARE_RESHARD shard index {shard_idx} out of range for {} shards",
        table.addrs.len()
    );
    Ok((plan, table, shard_idx))
}

/// Encode a MIGRATE_OUT / COMMIT_RESHARD / ABORT_RESHARD control request,
/// pinned to the plan's `from_epoch` so a stale coordinator cannot drive a
/// phase against the wrong staged plan.
pub fn encode_reshard_ctl(kind: u32, from_epoch: u64) -> Vec<u8> {
    debug_assert!(
        kind == KIND_MIGRATE_OUT || kind == KIND_COMMIT_RESHARD || kind == KIND_ABORT_RESHARD
    );
    let mut w = WireWriter::new(kind);
    w.put_u64(&[from_epoch]);
    w.finish()
}

/// Decode a reshard control request of the expected `kind` into its epoch.
pub fn decode_reshard_ctl(msg: &[u8], kind: u32) -> Result<u64> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == kind, "expected reshard kind {kind:#x}, got {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed reshard control request");
    Ok(xs[0])
}

/// Encode a reshard control ack (`n` = nodes copied for MIGRATE_OUT,
/// otherwise 1).
pub fn encode_reshard_ack(kind: u32, n: usize) -> Vec<u8> {
    let mut w = WireWriter::new(kind);
    w.put_u64(&[n as u64]);
    w.finish()
}

/// Decode a reshard control ack of the expected `kind`.
pub fn decode_reshard_ack(msg: &[u8], kind: u32) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == kind, "expected reshard ack kind {kind:#x}, got {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed reshard ack");
    Ok(xs[0] as usize)
}

/// Encode the in-band NOT_OWNER response (the server's committed epoch).
pub fn encode_not_owner(committed_epoch: u64) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_NOT_OWNER);
    w.put_u64(&[committed_epoch]);
    w.finish()
}

/// If `msg` is a NOT_OWNER frame, return the server's committed epoch.
/// Callers probe this BEFORE their kind-checked decode so a re-route
/// signal is never misreported as a protocol error.
pub fn decode_not_owner(msg: &[u8]) -> Option<u64> {
    let r = WireReader::parse(msg).ok()?;
    if r.kind() != KIND_NOT_OWNER {
        return None;
    }
    let xs = r.u64(0).ok()?;
    if xs.len() == 1 {
        Some(xs[0])
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::compress::lossy_error_bound;

    #[test]
    fn get_roundtrip_raw_is_bit_exact() {
        let keys = vec![1u64, 99, u64::MAX >> 1];
        let msg = encode_get_request(&keys, false);
        let (k2, comp) = decode_get_request(&msg).unwrap();
        assert_eq!(k2, keys);
        assert!(!comp);

        let rows = vec![1.5f32, -2.25, 1e-20, 3e7, 0.0, -0.125];
        let resp = encode_get_response(&rows, 2, false);
        assert_eq!(decode_get_response(&resp, 2, 3).unwrap(), rows);
    }

    #[test]
    fn get_roundtrip_compressed_within_bound() {
        let rows = vec![100.0f32, -250.5, 0.01, 3.25, -9.75, 42.0];
        let dim = 3;
        let resp = encode_get_response(&rows, dim, true);
        let back = decode_get_response(&resp, dim, 2).unwrap();
        for r in 0..2 {
            let row = &rows[r * dim..(r + 1) * dim];
            let norm = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let bound = lossy_error_bound(norm);
            for (a, b) in row.iter().zip(&back[r * dim..(r + 1) * dim]) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn put_roundtrip_and_shape_checks() {
        let keys = vec![7u64, 8];
        let grads = vec![0.5f32; 8];
        let msg = encode_put_request(&keys, &grads, 4, false);
        let (k2, g2) = decode_put_request(&msg, 4).unwrap();
        assert_eq!(k2, keys);
        assert_eq!(g2, grads);
        // Wrong dim makes the shape check fail.
        assert!(decode_put_request(&msg, 3).is_err());
        assert_eq!(decode_put_response(&encode_put_response(2)).unwrap(), 2);
    }

    fn sample_info() -> PsInfo {
        PsInfo {
            dim: 8,
            n_nodes: 4,
            shards_per_node: 2,
            seed: 42,
            shard_capacity: 4096,
            optimizer_code: optimizer_code(crate::config::OptimizerKind::Adagrad),
            partition_code: partition_code(crate::config::PartitionPolicy::ShuffledUniform),
            lr_bits: 0.1f32.to_bits(),
            node_start: 1,
            node_end: 3,
            boot_nonce: 0x5eed_b007,
            restored_step: 12,
            joinable: false,
            routing_epoch: 0,
        }
    }

    #[test]
    fn same_deployment_ignores_instance_identity_only() {
        let a = sample_info();
        // A restarted process: new nonce, restored from some epoch.
        let mut b = a;
        b.boot_nonce ^= 0xffff;
        b.restored_step = 0;
        assert!(a.same_deployment(&b));
        // Any numeric drift is a different deployment.
        let mut c = a;
        c.seed += 1;
        assert!(!a.same_deployment(&c));
        // Since live resharding, the owned range and routing epoch are
        // *topology*, not deployment identity: a redial after a reshard
        // reaches the same PS with a narrower range and a newer epoch.
        let mut d = a;
        d.node_start = 0;
        d.routing_epoch = 3;
        assert!(a.same_deployment(&d), "owned range is topology, not identity");
    }

    #[test]
    fn ckpt_codec_roundtrip_and_kind_checks() {
        for kind in [KIND_PREPARE_CKPT, KIND_COMMIT_CKPT] {
            let req = encode_ckpt_request(kind, 40);
            assert_eq!(decode_ckpt_request(&req, kind).unwrap(), 40);
            let ack = encode_ckpt_response(kind, 3);
            assert_eq!(decode_ckpt_response(&ack, kind).unwrap(), 3);
        }
        // A PREPARE frame must not pass for a COMMIT (and vice versa).
        let req = encode_ckpt_request(KIND_PREPARE_CKPT, 1);
        assert!(decode_ckpt_request(&req, KIND_COMMIT_CKPT).is_err());
    }

    #[test]
    fn info_and_stats_roundtrip() {
        let info = sample_info();
        let back = decode_info_response(&encode_info_response(&info)).unwrap();
        assert_eq!(back, info);
        assert_eq!(f32::from_bits(back.lr_bits), 0.1);

        let stats = PsStats {
            total_rows: 123,
            total_evictions: 7,
            imbalance: 1.25,
            hot_hits: 900,
            cold_hits: 33,
            demotions: 7,
            promotions: 5,
            cold_rows: 64,
        };
        let traffic = vec![10u64, 0, 5, 0];
        let msg = encode_stats_response(&stats, &traffic);
        let back = decode_stats_response(&msg).unwrap();
        assert_eq!(back.total_rows, 123);
        assert_eq!(back.total_evictions, 7);
        assert!((back.imbalance - 1.25).abs() < 1e-12);
        assert_eq!(back.hot_hits, 900);
        assert_eq!(back.cold_hits, 33);
        assert_eq!(back.demotions, 7);
        assert_eq!(back.promotions, 5);
        assert_eq!(back.cold_rows, 64);
        let (full, t2) = decode_stats_full(&msg).unwrap();
        assert_eq!(full.total_rows, 123);
        assert_eq!(t2, traffic);
    }

    #[test]
    fn bad_info_node_range_rejected() {
        let mut info = sample_info();
        info.node_start = 3;
        info.node_end = 3; // empty range: legal since --join spares exist
        let back = decode_info_response(&encode_info_response(&info)).unwrap();
        assert_eq!((back.node_start, back.node_end), (3, 3));
        info.node_start = 0;
        info.node_end = 5; // beyond n_nodes is still malformed
        assert!(decode_info_response(&encode_info_response(&info)).is_err());
        info.node_end = 4;
        info.node_start = 5; // inverted is still malformed
        assert!(decode_info_response(&encode_info_response(&info)).is_err());
    }

    #[test]
    fn legacy_12_field_info_still_decodes() {
        let info = sample_info();
        // Encode by hand with the pre-reshard 12-field header.
        let mut w = WireWriter::new(KIND_INFO);
        w.put_u64(&[
            info.dim as u64,
            info.n_nodes as u64,
            info.shards_per_node as u64,
            info.seed,
            info.shard_capacity as u64,
            info.optimizer_code,
            info.partition_code,
            info.lr_bits as u64,
            info.node_start as u64,
            info.node_end as u64,
            info.boot_nonce,
            info.restored_step,
        ]);
        let back = decode_info_response(&w.finish()).unwrap();
        assert!(!back.joinable);
        assert_eq!(back.routing_epoch, 0);
        assert!(back.same_deployment(&info));
    }

    #[test]
    fn reshard_codecs_roundtrip() {
        let table = RoutingTable::initial(
            4,
            &[0..3, 3..4, 0..0],
            &["a:1".into(), "b:2".into(), "c:3".into()],
        )
        .unwrap();
        let plan = MigrationPlan { from_epoch: 0, source: 0, dest: 2, nodes: 1..3 };
        let staged = crate::service::reshard::apply(&table, &plan).unwrap();

        let back = decode_routing_response(&encode_routing_response(Some(&table))).unwrap();
        assert_eq!(back, Some(table.clone()));
        // A server with no committed table answers with an empty payload.
        assert_eq!(decode_routing_response(&encode_routing_response(None)).unwrap(), None);

        let (p2, t2, idx) =
            decode_prepare_reshard(&encode_prepare_reshard(&plan, &staged, 1)).unwrap();
        assert_eq!(p2, plan);
        assert_eq!(t2, staged);
        assert_eq!(idx, 1);
        // A staged table whose epoch does not follow the plan is rejected,
        // as is a recipient index beyond the deployment.
        assert!(decode_prepare_reshard(&encode_prepare_reshard(&plan, &table, 1)).is_err());
        assert!(decode_prepare_reshard(&encode_prepare_reshard(&plan, &staged, 9)).is_err());

        for kind in [KIND_MIGRATE_OUT, KIND_COMMIT_RESHARD, KIND_ABORT_RESHARD] {
            let req = encode_reshard_ctl(kind, 7);
            assert_eq!(decode_reshard_ctl(&req, kind).unwrap(), 7);
            let ack = encode_reshard_ack(kind, 2);
            assert_eq!(decode_reshard_ack(&ack, kind).unwrap(), 2);
        }
        // Phase confusion is rejected.
        let req = encode_reshard_ctl(KIND_MIGRATE_OUT, 1);
        assert!(decode_reshard_ctl(&req, KIND_COMMIT_RESHARD).is_err());

        // NOT_OWNER probes: a NOT_OWNER frame yields its epoch, anything
        // else (including garbage) yields None.
        assert_eq!(decode_not_owner(&encode_not_owner(5)), Some(5));
        assert_eq!(decode_not_owner(&encode_put_response(1)), None);
        assert_eq!(decode_not_owner(b"garbage"), None);
    }

    #[test]
    fn fingerprint_ignores_node_range() {
        let cfg = crate::config::EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 4096,
            n_nodes: 4,
            shards_per_node: 2,
            optimizer: crate::config::OptimizerKind::Adagrad,
            partition: crate::config::PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let mut info = sample_info();
        check_fingerprint(&info, &cfg, 42).unwrap();
        // Topology (which slice a server owns) is not numerics.
        info.node_start = 0;
        info.node_end = 4;
        check_fingerprint(&info, &cfg, 42).unwrap();
        // Numerics mismatches fail.
        assert!(check_fingerprint(&info, &cfg, 43).is_err());
        info.shard_capacity = 1;
        assert!(check_fingerprint(&info, &cfg, 42).is_err());
    }

    #[test]
    fn partition_code_roundtrip() {
        for p in [
            crate::config::PartitionPolicy::FeatureGroup,
            crate::config::PartitionPolicy::ShuffledUniform,
        ] {
            assert_eq!(partition_from_code(partition_code(p)), Some(p));
        }
        assert_eq!(partition_from_code(99), None);
    }

    #[test]
    fn snapshot_restore_codec_roundtrip() {
        let shards = vec![vec![1u8, 2, 3], vec![], vec![0xff; 70]];
        let all_hot = NodeSnapshot { hot: shards.clone(), cold: None };
        assert_eq!(decode_snapshot_request(&encode_snapshot_request(3)).unwrap(), 3);
        let back = decode_snapshot_response(&encode_snapshot_response(&all_hot)).unwrap();
        assert_eq!(back, all_hot);
        let (node, back) = decode_restore_request(&encode_restore_request(2, &all_hot)).unwrap();
        assert_eq!(node, 2);
        assert_eq!(back, all_hot);
        assert_eq!(decode_restore_response(&encode_restore_response(4)).unwrap(), 4);
        // Lens that overflow the payload are rejected.
        let mut w = crate::comm::wire::WireWriter::new(KIND_SNAPSHOT);
        w.put_u8(&[0]).put_u64(&[100]).put_u8(&[1, 2, 3]);
        w.put_u64(&[]).put_u8(&[]);
        assert!(decode_snapshot_response(&w.finish()).is_err());
    }

    #[test]
    fn tiered_snapshot_codec_roundtrip_and_shape_checks() {
        let hot = vec![vec![1u8, 2], vec![3u8; 5]];
        let cold = vec![vec![9u8; 4], vec![]];
        let snap = NodeSnapshot { hot: hot.clone(), cold: Some(cold.clone()) };
        let back = decode_snapshot_response(&encode_snapshot_response(&snap)).unwrap();
        assert_eq!(back, snap);
        let (node, back) = decode_restore_request(&encode_restore_request(1, &snap)).unwrap();
        assert_eq!(node, 1);
        assert_eq!(back, snap);

        // Cold shard count must match hot shard count.
        let mut w = crate::comm::wire::WireWriter::new(KIND_SNAPSHOT);
        w.put_u8(&[1]);
        w.put_u64(&[2, 2]).put_u8(&[1, 2, 3, 4]);
        w.put_u64(&[1]).put_u8(&[5]); // one cold shard for two hot shards
        assert!(decode_snapshot_response(&w.finish()).is_err());

        // An all-hot flag with a non-empty cold payload is malformed.
        let mut w = crate::comm::wire::WireWriter::new(KIND_SNAPSHOT);
        w.put_u8(&[0]);
        w.put_u64(&[1]).put_u8(&[7]);
        w.put_u64(&[1]).put_u8(&[8]);
        assert!(decode_snapshot_response(&w.finish()).is_err());
    }

    #[test]
    fn wrong_kind_rejected() {
        let msg = encode_info_response(&sample_info());
        assert!(decode_stats_response(&msg).is_err());
        assert!(decode_get_response(&msg, 1, 0).is_err());
    }

    #[test]
    fn empty_batches_are_legal() {
        let msg = encode_get_request(&[], true);
        let (keys, comp) = decode_get_request(&msg).unwrap();
        assert!(keys.is_empty() && comp);
        let resp = encode_get_response(&[], 4, true);
        assert_eq!(decode_get_response(&resp, 4, 0).unwrap(), Vec::<f32>::new());
    }
}
