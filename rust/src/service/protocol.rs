//! PS service wire protocol: message kinds + codecs over [`crate::comm::wire`].
//!
//! Requests/responses are zero-copy wire messages (§4.2.3 — no protobuf):
//!
//! | kind       | request sections            | response sections            |
//! |------------|-----------------------------|------------------------------|
//! | `INFO`     | –                           | u64 `[dim, nodes, shards]`   |
//! | `GET`      | u64 keys, u8 flags          | u8 flags, values             |
//! | `PUT`      | u64 keys, u8 flags, values  | u64 `[rows applied]`         |
//! | `STATS`    | –                           | u64 `[rows, evic, imb bits]` |
//! | `SHUTDOWN` | –                           | – (ack)                      |
//!
//! Keys are `pack_key(group, id)` u64s, already deduplicated by the sender —
//! the paper's lossless index compression. `values` is either one raw f32
//! section (bit-exact) or, when the compress flag is set, an fp16 section
//! plus per-row scales — the paper's lossy value compression
//! ([`CompressedValues`]), halving wire bytes at ~2^-10 relative error.

use anyhow::{ensure, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::wire::{WireReader, WireWriter};

use super::backend::PsStats;

/// Message kinds of the PS service (disjoint from ad-hoc test kinds).
pub const KIND_INFO: u32 = 0x5001;
pub const KIND_GET: u32 = 0x5002;
pub const KIND_PUT: u32 = 0x5003;
pub const KIND_STATS: u32 = 0x5004;
pub const KIND_SHUTDOWN: u32 = 0x5005;

/// Flag bit: value payload is fp16 + per-row scales.
const FLAG_COMPRESS: u8 = 1;

fn put_values(w: &mut WireWriter, values: &[f32], dim: usize, compress: bool) {
    if compress {
        let c = CompressedValues::compress(values, dim);
        w.put_f16(&c.vals);
        w.put_f32(&c.scales);
    } else {
        w.put_f32(values);
    }
}

fn read_values(r: &WireReader, section: usize, dim: usize, compressed: bool) -> Result<Vec<f32>> {
    if compressed {
        let vals = r.f16(section)?;
        let scales = r.f32(section + 1)?;
        ensure!(vals.len() == scales.len() * dim, "compressed value shape mismatch");
        Ok(CompressedValues { vals, scales, dim }.decompress())
    } else {
        r.f32(section)
    }
}

// --- INFO ---

/// Everything a client needs to know the server's PS is the one its
/// trainer config describes. Geometry mismatches would corrupt shapes;
/// the rest (seed, optimizer, lr, capacity, partition) would silently
/// change numerics — so all of it rides in the handshake.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PsInfo {
    pub dim: usize,
    pub n_nodes: usize,
    pub shards_per_node: usize,
    pub seed: u64,
    pub shard_capacity: usize,
    /// [`OptimizerKind`](crate::config::OptimizerKind) as a stable code.
    pub optimizer_code: u64,
    /// [`PartitionPolicy`](crate::config::PartitionPolicy) as a stable code.
    pub partition_code: u64,
    /// Row-optimizer learning rate (f32 bits).
    pub lr_bits: u32,
}

pub fn optimizer_code(kind: crate::config::OptimizerKind) -> u64 {
    match kind {
        crate::config::OptimizerKind::Sgd => 0,
        crate::config::OptimizerKind::Adagrad => 1,
        crate::config::OptimizerKind::Adam => 2,
    }
}

pub fn partition_code(policy: crate::config::PartitionPolicy) -> u64 {
    match policy {
        crate::config::PartitionPolicy::FeatureGroup => 0,
        crate::config::PartitionPolicy::ShuffledUniform => 1,
    }
}

pub fn encode_info_request() -> Vec<u8> {
    WireWriter::new(KIND_INFO).finish()
}

pub fn encode_info_response(info: &PsInfo) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_INFO);
    w.put_u64(&[
        info.dim as u64,
        info.n_nodes as u64,
        info.shards_per_node as u64,
        info.seed,
        info.shard_capacity as u64,
        info.optimizer_code,
        info.partition_code,
        info.lr_bits as u64,
    ]);
    w.finish()
}

pub fn decode_info_response(msg: &[u8]) -> Result<PsInfo> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_INFO, "expected INFO response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 8, "malformed INFO response ({} fields)", xs.len());
    Ok(PsInfo {
        dim: xs[0] as usize,
        n_nodes: xs[1] as usize,
        shards_per_node: xs[2] as usize,
        seed: xs[3],
        shard_capacity: xs[4] as usize,
        optimizer_code: xs[5],
        partition_code: xs[6],
        lr_bits: xs[7] as u32,
    })
}

// --- GET ---

pub fn encode_get_request(keys: &[u64], compress: bool) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GET);
    w.put_u64(keys).put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    w.finish()
}

/// Returns `(packed keys, compress)`.
pub fn decode_get_request(msg: &[u8]) -> Result<(Vec<u64>, bool)> {
    let r = WireReader::parse(msg)?;
    let keys = r.u64(0)?;
    let flags = r.u8(1)?;
    ensure!(flags.len() == 1, "malformed GET flags");
    Ok((keys, flags[0] & FLAG_COMPRESS != 0))
}

pub fn encode_get_response(rows: &[f32], dim: usize, compress: bool) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_GET);
    w.put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    put_values(&mut w, rows, dim, compress);
    w.finish()
}

/// Decode a GET response straight into `out` (`n_rows * dim` floats) —
/// the hot path: no intermediate allocation, zero-copy borrow of the raw
/// f32 section where alignment permits.
pub fn decode_get_response_into(msg: &[u8], dim: usize, out: &mut [f32]) -> Result<()> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_GET, "expected GET response, got kind {}", r.kind());
    let flags = r.u8(0)?;
    ensure!(flags.len() == 1, "malformed GET response flags");
    if flags[0] & FLAG_COMPRESS != 0 {
        let vals = r.f16(1)?;
        let scales = r.f32(2)?;
        ensure!(
            vals.len() == out.len() && scales.len() * dim == vals.len(),
            "GET returned {} compressed floats, want {}",
            vals.len(),
            out.len()
        );
        CompressedValues { vals, scales, dim }.decompress_into(out);
    } else {
        // Borrow in place when the buffer happens to be 4-aligned (the
        // section offset always is); fall back to the copying reader.
        match r.f32_borrowed(1) {
            Ok(rows) => {
                ensure!(
                    rows.len() == out.len(),
                    "GET returned {} floats, want {}",
                    rows.len(),
                    out.len()
                );
                out.copy_from_slice(rows);
            }
            Err(_) => {
                let rows = r.f32(1)?;
                ensure!(
                    rows.len() == out.len(),
                    "GET returned {} floats, want {}",
                    rows.len(),
                    out.len()
                );
                out.copy_from_slice(&rows);
            }
        }
    }
    Ok(())
}

/// Decode `n_rows * dim` floats from a GET response (allocating variant).
pub fn decode_get_response(msg: &[u8], dim: usize, n_rows: usize) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; n_rows * dim];
    decode_get_response_into(msg, dim, &mut out)?;
    Ok(out)
}

// --- PUT ---

pub fn encode_put_request(keys: &[u64], grads: &[f32], dim: usize, compress: bool) -> Vec<u8> {
    debug_assert_eq!(grads.len(), keys.len() * dim);
    let mut w = WireWriter::new(KIND_PUT);
    w.put_u64(keys).put_u8(&[if compress { FLAG_COMPRESS } else { 0 }]);
    put_values(&mut w, grads, dim, compress);
    w.finish()
}

/// Returns `(packed keys, gradient rows)`.
pub fn decode_put_request(msg: &[u8], dim: usize) -> Result<(Vec<u64>, Vec<f32>)> {
    let r = WireReader::parse(msg)?;
    let keys = r.u64(0)?;
    let flags = r.u8(1)?;
    ensure!(flags.len() == 1, "malformed PUT flags");
    let grads = read_values(&r, 2, dim, flags[0] & FLAG_COMPRESS != 0)?;
    ensure!(grads.len() == keys.len() * dim, "PUT shape mismatch");
    Ok((keys, grads))
}

pub fn encode_put_response(rows_applied: usize) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_PUT);
    w.put_u64(&[rows_applied as u64]);
    w.finish()
}

pub fn decode_put_response(msg: &[u8]) -> Result<usize> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_PUT, "expected PUT response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 1, "malformed PUT response");
    Ok(xs[0] as usize)
}

// --- STATS ---

pub fn encode_stats_request() -> Vec<u8> {
    WireWriter::new(KIND_STATS).finish()
}

pub fn encode_stats_response(stats: &PsStats) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_STATS);
    w.put_u64(&[stats.total_rows as u64, stats.total_evictions, stats.imbalance.to_bits()]);
    w.finish()
}

pub fn decode_stats_response(msg: &[u8]) -> Result<PsStats> {
    let r = WireReader::parse(msg)?;
    ensure!(r.kind() == KIND_STATS, "expected STATS response, got kind {}", r.kind());
    let xs = r.u64(0)?;
    ensure!(xs.len() == 3, "malformed STATS response");
    Ok(PsStats {
        total_rows: xs[0] as usize,
        total_evictions: xs[1],
        imbalance: f64::from_bits(xs[2]),
    })
}

// --- SHUTDOWN ---

pub fn encode_shutdown_request() -> Vec<u8> {
    WireWriter::new(KIND_SHUTDOWN).finish()
}

pub fn encode_shutdown_response() -> Vec<u8> {
    WireWriter::new(KIND_SHUTDOWN).finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::compress::lossy_error_bound;

    #[test]
    fn get_roundtrip_raw_is_bit_exact() {
        let keys = vec![1u64, 99, u64::MAX >> 1];
        let msg = encode_get_request(&keys, false);
        let (k2, comp) = decode_get_request(&msg).unwrap();
        assert_eq!(k2, keys);
        assert!(!comp);

        let rows = vec![1.5f32, -2.25, 1e-20, 3e7, 0.0, -0.125];
        let resp = encode_get_response(&rows, 2, false);
        assert_eq!(decode_get_response(&resp, 2, 3).unwrap(), rows);
    }

    #[test]
    fn get_roundtrip_compressed_within_bound() {
        let rows = vec![100.0f32, -250.5, 0.01, 3.25, -9.75, 42.0];
        let dim = 3;
        let resp = encode_get_response(&rows, dim, true);
        let back = decode_get_response(&resp, dim, 2).unwrap();
        for r in 0..2 {
            let row = &rows[r * dim..(r + 1) * dim];
            let norm = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let bound = lossy_error_bound(norm);
            for (a, b) in row.iter().zip(&back[r * dim..(r + 1) * dim]) {
                assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
            }
        }
    }

    #[test]
    fn put_roundtrip_and_shape_checks() {
        let keys = vec![7u64, 8];
        let grads = vec![0.5f32; 8];
        let msg = encode_put_request(&keys, &grads, 4, false);
        let (k2, g2) = decode_put_request(&msg, 4).unwrap();
        assert_eq!(k2, keys);
        assert_eq!(g2, grads);
        // Wrong dim makes the shape check fail.
        assert!(decode_put_request(&msg, 3).is_err());
        assert_eq!(decode_put_response(&encode_put_response(2)).unwrap(), 2);
    }

    fn sample_info() -> PsInfo {
        PsInfo {
            dim: 8,
            n_nodes: 4,
            shards_per_node: 2,
            seed: 42,
            shard_capacity: 4096,
            optimizer_code: optimizer_code(crate::config::OptimizerKind::Adagrad),
            partition_code: partition_code(crate::config::PartitionPolicy::ShuffledUniform),
            lr_bits: 0.1f32.to_bits(),
        }
    }

    #[test]
    fn info_and_stats_roundtrip() {
        let info = sample_info();
        let back = decode_info_response(&encode_info_response(&info)).unwrap();
        assert_eq!(back, info);
        assert_eq!(f32::from_bits(back.lr_bits), 0.1);

        let stats = PsStats { total_rows: 123, total_evictions: 7, imbalance: 1.25 };
        let back = decode_stats_response(&encode_stats_response(&stats)).unwrap();
        assert_eq!(back.total_rows, 123);
        assert_eq!(back.total_evictions, 7);
        assert!((back.imbalance - 1.25).abs() < 1e-12);
    }

    #[test]
    fn wrong_kind_rejected() {
        let msg = encode_info_response(&sample_info());
        assert!(decode_stats_response(&msg).is_err());
        assert!(decode_get_response(&msg, 1, 0).is_err());
    }

    #[test]
    fn empty_batches_are_legal() {
        let msg = encode_get_request(&[], true);
        let (keys, comp) = decode_get_request(&msg).unwrap();
        assert!(keys.is_empty() && comp);
        let resp = encode_get_response(&[], 4, true);
        assert_eq!(decode_get_response(&resp, 4, 0).unwrap(), Vec::<f32>::new());
    }
}
