//! The pluggable embedding storage engine behind every PS shard.
//!
//! The paper's capacity story (100-trillion-parameter tables, §4.2.2) only
//! works if the storage layer is *not* welded to one in-RAM structure. This
//! module defines the seam: [`EmbeddingStore`] is what a
//! [`Shard`](super::Shard) talks to, and two engines implement it today:
//!
//! * [`LruStore`](super::LruStore) — the paper's array-list LRU, all-RAM.
//!   An evicted row is *lost* (it re-materializes from the deterministic
//!   init on the next touch), so training quality silently degrades once
//!   the working set outgrows `shard_capacity`.
//! * [`TieredStore`](super::TieredStore) — ScaleFreeCTR's MixCache design:
//!   a small hot LRU over a disk-backed [`ColdStore`](super::ColdStore).
//!   Eviction *demotes* the exact row bytes (embedding ⊕ optimizer state)
//!   to disk and a cold hit *promotes* them back, so the table can be many
//!   times the hot-tier budget with **bitwise identical** numerics to an
//!   all-hot run — placement moves rows, never changes them.
//!
//! Snapshots are split per tier: [`EmbeddingStore::snapshot_hot`] is the
//! flat LRU memory copy that has always ridden in checkpoint node files and
//! SNAPSHOT/RESTORE wire frames, while [`EmbeddingStore::snapshot_cold`]
//! serializes the cold rows into their own per-shard blob (a separate
//! `ps_node_N.cold` file in each checkpoint epoch — cold data can dwarf hot
//! data, and keeping it out of the hot file preserves the "checkpointing is
//! a memory copy" property for the tier that changes every step).

use std::path::PathBuf;

use anyhow::Result;

use super::cold::ColdStore;
use super::lru::LruStore;
use super::tiered::TieredStore;

/// Hit/movement counters of one store (summed across shards for the STATS
/// wire response and [`PsStats`](crate::service::PsStats)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Lookups served by the hot (in-RAM) tier.
    pub hot_hits: u64,
    /// Lookups served by the cold (disk) tier, including the bypass row.
    pub cold_hits: u64,
    /// Rows moved hot → cold on eviction (exact bytes preserved).
    pub demotions: u64,
    /// Rows moved cold → hot after passing the admission gate.
    pub promotions: u64,
    /// Hot-tier evictions. For a pure LRU these are *lost* rows; for a
    /// tiered store every eviction is a demotion, so this equals
    /// `demotions`.
    pub evictions: u64,
}

impl StoreCounters {
    /// Element-wise accumulate (shard → node → deployment rollups).
    pub fn add(&mut self, other: &StoreCounters) {
        self.hot_hits += other.hot_hits;
        self.cold_hits += other.cold_hits;
        self.demotions += other.demotions;
        self.promotions += other.promotions;
        self.evictions += other.evictions;
    }
}

/// One node's snapshot, split by tier: `hot` always holds one flat LRU blob
/// per lock-striped shard; `cold` is `Some` iff the node's stores are
/// tiered, with one cold blob per shard. This is what SNAPSHOT/RESTORE move
/// over the wire and what checkpoint epochs persist (`ps_node_N.ckpt` +
/// `ps_node_N.cold`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSnapshot {
    /// Per-shard hot-tier blobs ([`LruStore::to_bytes`] output).
    pub hot: Vec<Vec<u8>>,
    /// Per-shard cold-tier blobs, `None` for all-hot stores.
    pub cold: Option<Vec<Vec<u8>>>,
}

/// How a [`Shard`](super::Shard) stores its rows — the construction-time
/// selection threaded from `serve-ps --cold-dir D --hot-capacity N` (and
/// `train --cold-dir/--hot-capacity`) down to every shard.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum StoreConfig {
    /// All-hot array-list LRU at `shard_capacity` rows (the default; the
    /// pre-tiering behavior, bit for bit).
    #[default]
    Hot,
    /// Hot LRU of `hot_capacity` rows over a disk-backed cold store under
    /// `cold_dir` (one slotted, CRC-framed file per shard).
    Tiered {
        /// Hot-tier rows per shard (the RAM budget).
        hot_capacity: usize,
        /// Directory holding each shard's cold file.
        cold_dir: PathBuf,
        /// Touches before a key may enter the hot tier (≥1). With the
        /// default of 2, a one-touch tail key lands in the cold tier via
        /// the bypass row and never evicts a hot row.
        admit_threshold: u8,
    },
}

/// The default hot-tier admission threshold (touch count).
pub const DEFAULT_ADMIT_THRESHOLD: u8 = 2;

impl StoreConfig {
    /// Build one shard's store. `node`/`shard` are *global* indices — they
    /// name the cold file, so a restarted process reopens exactly the files
    /// its predecessor wrote.
    pub fn build(
        &self,
        shard_capacity: usize,
        row_width: usize,
        node: usize,
        shard: usize,
    ) -> Result<Box<dyn EmbeddingStore>> {
        Ok(match self {
            StoreConfig::Hot => Box::new(LruStore::new(shard_capacity, row_width)),
            StoreConfig::Tiered { hot_capacity, cold_dir, admit_threshold } => {
                let path = cold_dir.join(format!("cold_node{node}_shard{shard}.bin"));
                let cold = ColdStore::open(&path, row_width)?;
                Box::new(TieredStore::new(*hot_capacity, cold, *admit_threshold)?)
            }
        })
    }

    /// Whether stores built from this config have a cold tier.
    pub fn has_cold(&self) -> bool {
        matches!(self, StoreConfig::Tiered { .. })
    }
}

/// Row storage behind one PS shard. Implementations are free to place rows
/// wherever they like (RAM, disk, tiers) but must preserve the contract
/// that a row's bytes — embedding vector ⊕ optimizer state — survive any
/// internal movement exactly: the trainer's numerics may never depend on
/// *where* a row currently lives.
///
/// All methods take `&mut self`; a shard serializes access through its lock
/// (the paper's lock-striping), so stores need no internal synchronization.
pub trait EmbeddingStore: Send {
    /// Floats per row (embedding dim ⊕ optimizer state).
    fn row_width(&self) -> usize;

    /// Maximum rows resident in the hot tier.
    fn hot_capacity(&self) -> usize;

    /// Total rows this store can serve without re-materializing (hot +
    /// cold + bypass).
    fn len(&self) -> usize;

    /// True when no rows are resident anywhere.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows currently in the hot tier.
    fn hot_len(&self) -> usize;

    /// Rows currently in the cold tier (0 for all-hot stores).
    fn cold_len(&self) -> usize {
        0
    }

    /// Whether this store has a cold tier (drives checkpoint layout and
    /// the SNAPSHOT/RESTORE wire flags).
    fn has_cold(&self) -> bool {
        false
    }

    /// Get `key`'s row, materializing it via `init` on a true miss. The
    /// returned row is writable in place (the optimizer applies gradients
    /// through it); implementations must persist such writes across any
    /// subsequent tier movement.
    fn get_or_insert_with(
        &mut self,
        key: u64,
        init: &mut dyn FnMut(&mut [f32]),
    ) -> Result<&mut [f32]>;

    /// Hit/movement counters since construction (or the last wipe).
    fn counters(&self) -> StoreCounters;

    /// Serialize the hot tier (flat memory copy). Flushes any internal
    /// bypass state first so hot ∪ cold is the complete row set.
    fn snapshot_hot(&mut self) -> Result<Vec<u8>>;

    /// Serialize the cold tier, `None` for all-hot stores. Deterministic:
    /// equal logical contents yield equal bytes regardless of placement
    /// history.
    fn snapshot_cold(&mut self) -> Result<Option<Vec<u8>>>;

    /// Replace the hot tier from [`EmbeddingStore::snapshot_hot`] bytes.
    fn restore_hot(&mut self, bytes: &[u8]) -> Result<()>;

    /// Replace the cold tier from [`EmbeddingStore::snapshot_cold`] bytes.
    /// Errors on all-hot stores.
    fn restore_cold(&mut self, bytes: &[u8]) -> Result<()>;

    /// Drop all rows in every tier (crash simulation / pre-restore reset).
    fn wipe(&mut self) -> Result<()>;

    /// Verify structural invariants (tests + post-restore validation),
    /// including that no key is resident in two tiers at once.
    fn check_invariants(&mut self) -> Result<()>;
}

impl EmbeddingStore for LruStore {
    fn row_width(&self) -> usize {
        LruStore::row_width(self)
    }

    fn hot_capacity(&self) -> usize {
        self.capacity()
    }

    fn len(&self) -> usize {
        LruStore::len(self)
    }

    fn hot_len(&self) -> usize {
        LruStore::len(self)
    }

    fn get_or_insert_with(
        &mut self,
        key: u64,
        init: &mut dyn FnMut(&mut [f32]),
    ) -> Result<&mut [f32]> {
        let (row, _evicted) = LruStore::get_or_insert_with(self, key, |row| init(row));
        Ok(row)
    }

    fn counters(&self) -> StoreCounters {
        StoreCounters {
            hot_hits: self.hits(),
            evictions: self.evictions(),
            ..StoreCounters::default()
        }
    }

    fn snapshot_hot(&mut self) -> Result<Vec<u8>> {
        Ok(self.to_bytes())
    }

    fn snapshot_cold(&mut self) -> Result<Option<Vec<u8>>> {
        Ok(None)
    }

    fn restore_hot(&mut self, bytes: &[u8]) -> Result<()> {
        let store = LruStore::from_bytes(bytes)?;
        anyhow::ensure!(
            store.row_width() == self.row_width(),
            "snapshot row width {} != store row width {}",
            store.row_width(),
            self.row_width()
        );
        *self = store;
        Ok(())
    }

    fn restore_cold(&mut self, _bytes: &[u8]) -> Result<()> {
        anyhow::bail!("all-hot LRU store has no cold tier to restore")
    }

    fn wipe(&mut self) -> Result<()> {
        *self = LruStore::new(self.capacity(), self.row_width());
        Ok(())
    }

    fn check_invariants(&mut self) -> Result<()> {
        LruStore::check_invariants(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("persia_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn hot_config_builds_plain_lru() {
        let store = StoreConfig::Hot.build(8, 3, 0, 0).unwrap();
        assert!(!store.has_cold());
        assert_eq!(store.hot_capacity(), 8);
        assert_eq!(store.row_width(), 3);
        assert!(!StoreConfig::Hot.has_cold());
    }

    #[test]
    fn tiered_config_builds_cold_backed_store() {
        let dir = tmp("build");
        let cfg = StoreConfig::Tiered {
            hot_capacity: 4,
            cold_dir: dir.clone(),
            admit_threshold: DEFAULT_ADMIT_THRESHOLD,
        };
        assert!(cfg.has_cold());
        let store = cfg.build(64, 3, 1, 2).unwrap();
        assert!(store.has_cold());
        // Hot capacity comes from the tier config, not shard_capacity.
        assert_eq!(store.hot_capacity(), 4);
        assert!(dir.join("cold_node1_shard2.bin").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lru_store_through_trait_roundtrips() {
        let mut store: Box<dyn EmbeddingStore> = Box::new(LruStore::new(4, 2));
        store.get_or_insert_with(7, &mut |row| row.fill(1.5)).unwrap();
        let snap = store.snapshot_hot().unwrap();
        assert_eq!(store.snapshot_cold().unwrap(), None);
        assert!(store.restore_cold(&[]).is_err());
        store.wipe().unwrap();
        assert_eq!(store.len(), 0);
        store.restore_hot(&snap).unwrap();
        assert_eq!(store.len(), 1);
        let mut touched = false;
        let row = store
            .get_or_insert_with(7, &mut |_| {
                touched = true;
            })
            .unwrap();
        assert_eq!(row, &[1.5, 1.5]);
        assert!(!touched, "restored row must not re-materialize");
        assert!(store.counters().hot_hits >= 1);
        store.check_invariants().unwrap();
    }

    #[test]
    fn counters_accumulate() {
        let mut a = StoreCounters { hot_hits: 1, cold_hits: 2, ..Default::default() };
        let b = StoreCounters { hot_hits: 10, demotions: 3, promotions: 4, evictions: 3 };
        a.add(&b);
        assert_eq!(a.hot_hits, 11);
        assert_eq!(a.cold_hits, 2);
        assert_eq!(a.demotions, 3);
        assert_eq!(a.promotions, 4);
        assert_eq!(a.evictions, 3);
    }
}
