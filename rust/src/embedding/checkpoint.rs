//! Periodic checkpointing of the embedding PS (paper §4.2.4).
//!
//! "embedding PS nodes will periodically save the in-memory copy of the
//! embedding parameter shard; with the advance of our LRU implementation,
//! check-pointing is very efficient" — a shard snapshot is `LruStore`'s flat
//! memory copy. Files carry a CRC32 so torn writes are detected on load.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use super::ps::EmbeddingPs;

/// CRC-32 (IEEE) — small table-driven implementation.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Write one framed, checksummed blob.
fn write_blob(w: &mut impl Write, bytes: &[u8]) -> Result<()> {
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(&crc32(bytes).to_le_bytes())?;
    w.write_all(bytes)?;
    Ok(())
}

/// Read one framed blob, verifying the checksum.
fn read_blob(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 8];
    r.read_exact(&mut len_buf)?;
    let len = u64::from_le_bytes(len_buf) as usize;
    ensure!(len < 1 << 34, "implausible blob size {len}");
    let mut crc_buf = [0u8; 4];
    r.read_exact(&mut crc_buf)?;
    let want = u32::from_le_bytes(crc_buf);
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    ensure!(crc32(&bytes) == want, "checkpoint CRC mismatch (torn write?)");
    Ok(bytes)
}

/// Checkpoint manager for a PS: one file per node under `dir`.
pub struct CheckpointManager {
    dir: PathBuf,
}

impl CheckpointManager {
    /// Create a manager rooted at `dir` (created if missing).
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(Self { dir: dir.as_ref().to_path_buf() })
    }

    fn node_path(&self, node: usize) -> PathBuf {
        self.dir.join(format!("ps_node_{node}.ckpt"))
    }

    /// Save every node this PS instance owns (atomic per node: write temp
    /// then rename). A range-owning shard process saves only its own nodes,
    /// so N processes sharing one directory produce one file per global node.
    pub fn save(&self, ps: &EmbeddingPs) -> Result<()> {
        for node in ps.node_range() {
            self.save_node(ps, node)?;
        }
        Ok(())
    }

    /// Save one node's shards.
    pub fn save_node(&self, ps: &EmbeddingPs, node: usize) -> Result<()> {
        let tmp = self.node_path(node).with_extension("tmp");
        {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
            let shards = ps.snapshot_node(node);
            f.write_all(&(shards.len() as u64).to_le_bytes())?;
            for s in &shards {
                write_blob(&mut f, s)?;
            }
            f.flush()?;
        }
        std::fs::rename(&tmp, self.node_path(node))?;
        Ok(())
    }

    /// Restore one node from disk.
    pub fn restore_node(&self, ps: &EmbeddingPs, node: usize) -> Result<()> {
        let path = self.node_path(node);
        let mut f = std::io::BufReader::new(
            std::fs::File::open(&path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut n_buf = [0u8; 8];
        f.read_exact(&mut n_buf)?;
        let n = u64::from_le_bytes(n_buf) as usize;
        let mut shards = Vec::with_capacity(n);
        for _ in 0..n {
            shards.push(read_blob(&mut f)?);
        }
        ps.restore_node(node, &shards)
    }

    /// Restore every node this PS instance owns.
    pub fn restore(&self, ps: &EmbeddingPs) -> Result<()> {
        for node in ps.node_range() {
            self.restore_node(ps, node)?;
        }
        Ok(())
    }

    /// Whether a checkpoint file for `node` exists under the root.
    pub fn exists(&self, node: usize) -> bool {
        self.node_path(node).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};

    fn ps() -> EmbeddingPs {
        let cfg = EmbeddingConfig {
            rows_per_group: 1 << 30,
            shard_capacity: 64,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        EmbeddingPs::new(&cfg, 4, 9)
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn save_restore_roundtrip() {
        let dir = std::env::temp_dir().join(format!("persia_ckpt_{}", std::process::id()));
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        let keys: Vec<(u32, u64)> = (0..30).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; 120];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![0.5; 120]);
        let mut want = vec![0.0; 120];
        ps.get_many(&keys, &mut want);

        mgr.save(&ps).unwrap();
        ps.wipe_node(0);
        ps.wipe_node(1);
        mgr.restore(&ps).unwrap();

        let mut got = vec![0.0; 120];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn range_ps_checkpoints_only_owned_nodes() {
        use crate::embedding::ps::pack_key;
        let dir = std::env::temp_dir().join(format!("persia_ckpt_r_{}", std::process::id()));
        let mgr = CheckpointManager::new(&dir).unwrap();
        let cfg = crate::config::EmbeddingConfig {
            rows_per_group: 1 << 30,
            shard_capacity: 64,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let part = EmbeddingPs::new_range(&cfg, 4, 9, 1..2);
        let mut buf = [0.0; 4];
        let owned: Vec<u64> =
            (0..200).filter(|&i| part.owns_key(pack_key(0, i))).take(20).collect();
        for &id in &owned {
            part.get(0, id, &mut buf);
        }
        mgr.save(&part).unwrap();
        assert!(mgr.exists(1), "owned node not saved");
        assert!(!mgr.exists(0), "unowned node saved");
        let before = part.snapshot_node(1);
        part.wipe_node(1);
        mgr.restore(&part).unwrap();
        assert_eq!(part.snapshot_node(1), before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_checkpoint_detected() {
        let dir = std::env::temp_dir().join(format!("persia_ckpt_c_{}", std::process::id()));
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        mgr.save(&ps).unwrap();
        // Flip a byte in the middle of node 0's file.
        let path = dir.join("ps_node_0.ckpt");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(mgr.restore_node(&ps, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_checkpoint_is_error_not_panic() {
        let dir = std::env::temp_dir().join(format!("persia_ckpt_m_{}", std::process::id()));
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        assert!(!mgr.exists(0));
        assert!(mgr.restore_node(&ps, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
