//! Checkpointing of the embedding PS (paper §4.2.4), in two flavors.
//!
//! "embedding PS nodes will periodically save the in-memory copy of the
//! embedding parameter shard; with the advance of our LRU implementation,
//! check-pointing is very efficient" — a shard snapshot is `LruStore`'s flat
//! memory copy. Files carry a CRC32 so torn or bit-flipped content is
//! detected on load, and **every** write goes through the crash-safe
//! [`atomic_write`](crate::recovery::atomic_write) (temp + fsync + rename),
//! so a crash mid-save can never leave a file that `from_bytes` rejects on
//! restore — the old file simply survives.
//!
//! * **Legacy flat files** (`dir/ps_node_N.ckpt`) — one file per node,
//!   saved on graceful shutdown; uncoordinated across shards.
//! * **Checkpoint epochs** (`dir/step-S/…`) — the coordinated two-phase
//!   flavor driven by the trainer's PREPARE_CKPT/COMMIT_CKPT RPCs (see
//!   [`crate::recovery::coordinator`]). PREPARE stages every owned node as
//!   `ps_node_N.ckpt.prep`; COMMIT renames the stages into place and then
//!   atomically writes this shard's manifest (`shard_A_B.manifest`), whose
//!   *existence* is the commit marker. A restarting `serve-ps` restores the
//!   newest epoch whose shard manifest is valid
//!   ([`CheckpointManager::latest_committed_epoch`]) — it can never pick a
//!   half-written epoch, because the manifest lands only after the node
//!   files are durable.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{ensure, Context, Result};

use crate::comm::wire::{WireReader, WireWriter};
use crate::recovery::atomic_write;

use super::ps::EmbeddingPs;
use super::store::NodeSnapshot;

/// CRC-32 (IEEE) — small table-driven implementation.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xedb88320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut c = 0xffff_ffffu32;
    for &b in bytes {
        c = table[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

/// Write one framed, checksummed blob.
fn write_blob(w: &mut impl Write, bytes: &[u8]) -> Result<()> {
    w.write_all(&(bytes.len() as u64).to_le_bytes())?;
    w.write_all(&crc32(bytes).to_le_bytes())?;
    w.write_all(bytes)?;
    Ok(())
}

/// Read one framed blob, verifying the checksum.
fn read_blob(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut len_buf = [0u8; 8];
    r.read_exact(&mut len_buf)?;
    let len = u64::from_le_bytes(len_buf) as usize;
    ensure!(len < 1 << 34, "implausible blob size {len}");
    let mut crc_buf = [0u8; 4];
    r.read_exact(&mut crc_buf)?;
    let want = u32::from_le_bytes(crc_buf);
    let mut bytes = vec![0u8; len];
    r.read_exact(&mut bytes)?;
    ensure!(crc32(&bytes) == want, "checkpoint CRC mismatch (torn write?)");
    Ok(bytes)
}

/// Serialize one node's per-shard snapshots into the node-file layout
/// (shard count, then framed checksummed blobs).
fn encode_node_snapshot(shards: &[Vec<u8>]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(shards.len() as u64).to_le_bytes());
    for s in shards {
        write_blob(&mut out, s).expect("Vec<u8> writes are infallible");
    }
    out
}

/// Parse a node file back into per-shard snapshots, rejecting (never
/// panicking on) torn or corrupt content.
fn decode_node_snapshot(bytes: &[u8]) -> Result<Vec<Vec<u8>>> {
    let mut r: &[u8] = bytes;
    let mut n_buf = [0u8; 8];
    r.read_exact(&mut n_buf).context("node file shard count")?;
    let n = u64::from_le_bytes(n_buf) as usize;
    ensure!(n < 1 << 20, "implausible shard count {n}");
    let mut shards = Vec::with_capacity(n);
    for _ in 0..n {
        shards.push(read_blob(&mut r)?);
    }
    ensure!(r.is_empty(), "trailing bytes after node snapshot");
    Ok(shards)
}

/// Leading magic of a serialized shard epoch manifest.
const SHARD_MANIFEST_MAGIC: &[u8; 8] = b"PRSASM01";
/// Wire-message kind of the shard manifest body (file-local).
const KIND_SHARD_MANIFEST: u32 = 0x7F02;

/// Serialize a shard's epoch commit marker: the epoch step, the node range
/// whose files this shard just committed, whether each node also has a
/// cold-tier file (`ps_node_N.cold`) in the epoch, and the routing epoch
/// the shard served under when it committed (0 for a never-resharded
/// deployment).
pub fn encode_shard_manifest(
    step: u64,
    range: &std::ops::Range<usize>,
    has_cold: bool,
    routing_epoch: u64,
) -> Vec<u8> {
    let mut w = WireWriter::new(KIND_SHARD_MANIFEST);
    w.put_u64(&[step, range.start as u64, range.end as u64, has_cold as u64, routing_epoch]);
    let body = w.finish();
    let mut out = Vec::with_capacity(12 + body.len());
    out.extend_from_slice(SHARD_MANIFEST_MAGIC);
    out.extend_from_slice(&crc32(&body).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Parse + validate a shard epoch manifest into `(step, node range,
/// has_cold, routing_epoch)`. A 3-field manifest from before the
/// tiered-storage era decodes with `has_cold = false`; a 4-field one from
/// before live resharding decodes with `routing_epoch = 0`. Arbitrary,
/// truncated, or bit-flipped bytes return `Err`, never panic.
pub fn decode_shard_manifest(
    bytes: &[u8],
) -> Result<(u64, std::ops::Range<usize>, bool, u64)> {
    ensure!(bytes.len() >= 12, "shard manifest too short");
    ensure!(&bytes[..8] == SHARD_MANIFEST_MAGIC, "shard manifest magic mismatch");
    let want = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    let body = &bytes[12..];
    ensure!(crc32(body) == want, "shard manifest CRC mismatch");
    let r = WireReader::parse(body)?;
    ensure!(r.kind() == KIND_SHARD_MANIFEST, "shard manifest kind {:#x}", r.kind());
    let xs = r.u64(0)?;
    ensure!(
        (3..=5).contains(&xs.len()),
        "shard manifest has {} fields",
        xs.len()
    );
    let (start, end) = (xs[1] as usize, xs[2] as usize);
    ensure!(start < end && end < 1 << 32, "shard manifest range {start}..{end} invalid");
    let has_cold = match xs.get(3) {
        None => false,
        Some(&0) => false,
        Some(&1) => true,
        Some(&v) => anyhow::bail!("shard manifest cold flag {v} invalid"),
    };
    let routing_epoch = xs.get(4).copied().unwrap_or(0);
    Ok((xs[0], start..end, has_cold, routing_epoch))
}

/// Checkpoint manager for a PS: legacy per-node files plus committed
/// checkpoint epochs, all under `dir`.
pub struct CheckpointManager {
    dir: PathBuf,
    /// The routing epoch stamped into every shard manifest this manager
    /// commits. Starts at 0 (or the persisted table's epoch on restart);
    /// the PS server bumps it when a reshard commits.
    routing_epoch: AtomicU64,
}

impl CheckpointManager {
    /// Create a manager rooted at `dir` (created if missing).
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Self> {
        std::fs::create_dir_all(&dir)?;
        Ok(Self { dir: dir.as_ref().to_path_buf(), routing_epoch: AtomicU64::new(0) })
    }

    /// Set the routing epoch stamped into subsequently committed shard
    /// manifests (called at server start from the persisted table, and at
    /// every committed reshard).
    pub fn set_routing_epoch(&self, epoch: u64) {
        self.routing_epoch.store(epoch, Ordering::SeqCst);
    }

    /// The routing epoch currently stamped into committed manifests.
    pub fn routing_epoch(&self) -> u64 {
        self.routing_epoch.load(Ordering::SeqCst)
    }

    fn node_path(&self, node: usize) -> PathBuf {
        self.dir.join(format!("ps_node_{node}.ckpt"))
    }

    fn node_cold_path(&self, node: usize) -> PathBuf {
        self.dir.join(format!("ps_node_{node}.cold"))
    }

    fn epoch_dir(&self, step: u64) -> PathBuf {
        // The one epoch-layout definition, shared with the coordinator's
        // global manifests (same `step-N/` directories).
        crate::recovery::epoch_dir(&self.dir, step)
    }

    fn epoch_node_path(&self, step: u64, node: usize) -> PathBuf {
        self.epoch_dir(step).join(format!("ps_node_{node}.ckpt"))
    }

    fn epoch_node_cold_path(&self, step: u64, node: usize) -> PathBuf {
        self.epoch_dir(step).join(format!("ps_node_{node}.cold"))
    }

    fn shard_manifest_path(&self, step: u64, range: &std::ops::Range<usize>) -> PathBuf {
        self.epoch_dir(step).join(format!("shard_{}_{}.manifest", range.start, range.end))
    }

    /// Save every node this PS instance owns (atomically, one file per
    /// node). A range-owning shard process saves only its own nodes, so N
    /// processes sharing one directory produce one file per global node.
    pub fn save(&self, ps: &EmbeddingPs) -> Result<()> {
        for node in ps.node_range() {
            self.save_node(ps, node)?;
        }
        Ok(())
    }

    /// Save one node's shards (write temp + fsync + rename — a crash
    /// mid-save leaves the previous file intact, never a torn one). A
    /// tiered PS additionally writes the node's cold tiers to a sibling
    /// `ps_node_N.cold` file.
    pub fn save_node(&self, ps: &EmbeddingPs, node: usize) -> Result<()> {
        let snap = ps.snapshot_node_full(node)?;
        atomic_write(&self.node_path(node), &encode_node_snapshot(&snap.hot))
            .with_context(|| format!("saving node {node} checkpoint"))?;
        match snap.cold {
            Some(cold) => {
                atomic_write(&self.node_cold_path(node), &encode_node_snapshot(&cold))
                    .with_context(|| format!("saving node {node} cold tier"))?;
            }
            None => {
                // Drop any stale cold file so a later restore can't pair the
                // fresh hot tier with an outdated cold one.
                let _ = std::fs::remove_file(self.node_cold_path(node));
            }
        }
        Ok(())
    }

    /// Restore one node from its legacy flat file(s), cold tier included
    /// when this PS is tiered.
    pub fn restore_node(&self, ps: &EmbeddingPs, node: usize) -> Result<()> {
        let path = self.node_path(node);
        let bytes =
            std::fs::read(&path).with_context(|| format!("open {}", path.display()))?;
        let cold_path = self.node_cold_path(node);
        let cold = if ps.has_cold_tier() {
            let cold_bytes = std::fs::read(&cold_path)
                .with_context(|| format!("open {} (tiered PS)", cold_path.display()))?;
            Some(decode_node_snapshot(&cold_bytes)?)
        } else {
            ensure!(
                !cold_path.exists(),
                "checkpoint for node {node} has a cold tier ({}); restart with --cold-dir",
                cold_path.display()
            );
            None
        };
        ps.restore_node_full(node, &NodeSnapshot { hot: decode_node_snapshot(&bytes)?, cold })
    }

    /// Restore every node this PS instance owns from legacy flat files.
    pub fn restore(&self, ps: &EmbeddingPs) -> Result<()> {
        for node in ps.node_range() {
            self.restore_node(ps, node)?;
        }
        Ok(())
    }

    /// Whether a legacy checkpoint file for `node` exists under the root.
    pub fn exists(&self, node: usize) -> bool {
        self.node_path(node).exists()
    }

    /// Epoch phase 1 (PREPARE_CKPT): stage every owned node's snapshot as
    /// `step-S/ps_node_N.ckpt.prep`. Staged files are invisible to restore
    /// until [`CheckpointManager::commit_epoch`] renames them; an epoch that
    /// never commits leaves only ignorable `.prep` garbage.
    pub fn prepare_epoch(&self, ps: &EmbeddingPs, step: u64) -> Result<()> {
        self.prepare_epoch_range(ps, step, ps.node_range())
    }

    /// [`CheckpointManager::prepare_epoch`] over an explicit node `range` —
    /// the *served* range when it differs from the PS's physical one (a
    /// resharded server checkpoints what it currently owns, not what it
    /// materialized at boot). An empty range (a `--join` spare owning
    /// nothing yet) stages nothing and is not an error.
    pub fn prepare_epoch_range(
        &self,
        ps: &EmbeddingPs,
        step: u64,
        range: std::ops::Range<usize>,
    ) -> Result<()> {
        if range.is_empty() {
            return Ok(());
        }
        let edir = self.epoch_dir(step);
        std::fs::create_dir_all(&edir)
            .with_context(|| format!("creating epoch dir {}", edir.display()))?;
        for node in range {
            let snap = ps.snapshot_node_full(node)?;
            let staged = self.epoch_node_path(step, node).with_extension("ckpt.prep");
            atomic_write(&staged, &encode_node_snapshot(&snap.hot))
                .with_context(|| format!("staging node {node} for epoch {step}"))?;
            if let Some(cold) = snap.cold {
                let staged_cold =
                    self.epoch_node_cold_path(step, node).with_extension("cold.prep");
                atomic_write(&staged_cold, &encode_node_snapshot(&cold))
                    .with_context(|| format!("staging node {node} cold tier, epoch {step}"))?;
            }
        }
        Ok(())
    }

    /// Epoch phase 2 (COMMIT_CKPT): rename every staged node file into
    /// place, then atomically write this shard's manifest — the commit
    /// marker [`CheckpointManager::latest_committed_epoch`] looks for.
    /// Returns the number of nodes committed.
    ///
    /// Idempotent per node: a COMMIT retried after a lost ack (the wire
    /// died mid-RPC, §4.2.4's bread and butter) finds the file already
    /// renamed and just rewrites the manifest. Only a commit with *neither*
    /// a staged nor a committed file — no PREPARE ever ran — errors.
    pub fn commit_epoch(&self, ps: &EmbeddingPs, step: u64) -> Result<usize> {
        self.commit_epoch_range(ps, step, ps.node_range())
    }

    /// [`CheckpointManager::commit_epoch`] over an explicit node `range`
    /// (the served range of a resharded server). An empty range commits
    /// nothing and writes no manifest — a spare that owns nothing simply
    /// has no epoch state. The manifest is stamped with the current
    /// [`CheckpointManager::routing_epoch`].
    pub fn commit_epoch_range(
        &self,
        ps: &EmbeddingPs,
        step: u64,
        range: std::ops::Range<usize>,
    ) -> Result<usize> {
        if range.is_empty() {
            return Ok(0);
        }
        let has_cold = ps.has_cold_tier();
        for node in range.clone() {
            let staged = self.epoch_node_path(step, node).with_extension("ckpt.prep");
            let committed = self.epoch_node_path(step, node);
            if staged.exists() {
                std::fs::rename(&staged, &committed)
                    .with_context(|| format!("committing node {node} of epoch {step}"))?;
            } else {
                ensure!(
                    committed.exists(),
                    "COMMIT_CKPT for epoch {step} without a PREPARE_CKPT \
                     (node {node} not staged)"
                );
            }
            if has_cold {
                let staged_cold =
                    self.epoch_node_cold_path(step, node).with_extension("cold.prep");
                let committed_cold = self.epoch_node_cold_path(step, node);
                if staged_cold.exists() {
                    std::fs::rename(&staged_cold, &committed_cold).with_context(|| {
                        format!("committing node {node} cold tier of epoch {step}")
                    })?;
                } else {
                    ensure!(
                        committed_cold.exists(),
                        "COMMIT_CKPT for epoch {step} without a staged cold tier \
                         (node {node})"
                    );
                }
            }
        }
        atomic_write(
            &self.shard_manifest_path(step, &range),
            &encode_shard_manifest(step, &range, has_cold, self.routing_epoch()),
        )
        .with_context(|| format!("writing shard manifest for epoch {step}"))?;
        Ok(range.len())
    }

    /// The newest epoch this shard (identified by its node `range`) fully
    /// committed: its shard manifest must parse, agree with the directory
    /// name, and every node file of the range must be present AND decode
    /// (CRC-clean) — a bit-flipped node file un-commits the epoch here, so
    /// an auto-restoring restart falls back to the previous committed epoch
    /// instead of hard-failing on it. Corrupt or half-written epochs are
    /// skipped, never errors — this is the restart path of a process that
    /// just crashed.
    pub fn latest_committed_epoch(&self, range: &std::ops::Range<usize>) -> Option<u64> {
        let entries = std::fs::read_dir(&self.dir).ok()?;
        let mut best: Option<u64> = None;
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(step) = name.to_str().and_then(crate::recovery::parse_epoch_dir_name)
            else {
                continue;
            };
            if matches!(best, Some(b) if step <= b) {
                continue;
            }
            let Ok(bytes) = std::fs::read(self.shard_manifest_path(step, range)) else {
                continue;
            };
            let Ok((mstep, mrange, mcold, _)) = decode_shard_manifest(&bytes) else { continue };
            if mstep != step || mrange != *range {
                continue;
            }
            let nodes_valid = range.clone().all(|node| {
                let hot_ok = std::fs::read(self.epoch_node_path(step, node))
                    .ok()
                    .and_then(|bytes| decode_node_snapshot(&bytes).ok())
                    .is_some();
                let cold_ok = !mcold
                    || std::fs::read(self.epoch_node_cold_path(step, node))
                        .ok()
                        .and_then(|bytes| decode_node_snapshot(&bytes).ok())
                        .is_some();
                hot_ok && cold_ok
            });
            if nodes_valid {
                best = Some(step);
            }
        }
        best
    }

    /// Restore every owned node from committed epoch `step`, both tiers
    /// when the epoch was written by a tiered PS. The manifest's cold flag
    /// must match this PS's tier shape — resuming a tiered run without
    /// `--cold-dir` (or vice versa) is a loud error, not silent row loss.
    pub fn restore_epoch(&self, ps: &EmbeddingPs, step: u64) -> Result<()> {
        self.restore_epoch_range(ps, step, ps.node_range()).map(|_| ())
    }

    /// [`CheckpointManager::restore_epoch`] over an explicit node `range`
    /// (the served range recorded in a resharded deployment's routing
    /// table). An empty range restores nothing. Returns the routing epoch
    /// the manifest was committed under, so a restarting server can
    /// cross-check it against the persisted routing table.
    pub fn restore_epoch_range(
        &self,
        ps: &EmbeddingPs,
        step: u64,
        range: std::ops::Range<usize>,
    ) -> Result<u64> {
        if range.is_empty() {
            return Ok(self.routing_epoch());
        }
        let bytes = std::fs::read(self.shard_manifest_path(step, &range))
            .with_context(|| format!("epoch {step} was never committed by shard {range:?}"))?;
        let (mstep, mrange, mcold, mrouting) = decode_shard_manifest(&bytes)?;
        ensure!(
            mstep == step && mrange == range,
            "shard manifest records (step {mstep}, nodes {mrange:?}), expected \
             (step {step}, nodes {range:?})"
        );
        ensure!(
            mcold == ps.has_cold_tier(),
            "epoch {step} was written {} a cold tier but this PS runs {} one; \
             restart {} --cold-dir",
            if mcold { "with" } else { "without" },
            if ps.has_cold_tier() { "with" } else { "without" },
            if mcold { "with" } else { "without" },
        );
        for node in range {
            let path = self.epoch_node_path(step, node);
            let bytes =
                std::fs::read(&path).with_context(|| format!("open {}", path.display()))?;
            let cold = if mcold {
                let cpath = self.epoch_node_cold_path(step, node);
                let cbytes = std::fs::read(&cpath)
                    .with_context(|| format!("open {}", cpath.display()))?;
                Some(decode_node_snapshot(&cbytes)?)
            } else {
                None
            };
            ps.restore_node_full(
                node,
                &NodeSnapshot { hot: decode_node_snapshot(&bytes)?, cold },
            )
            .with_context(|| format!("restoring node {node} from epoch {step}"))?;
        }
        Ok(mrouting)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind, PartitionPolicy};

    fn ps() -> EmbeddingPs {
        let cfg = EmbeddingConfig {
            rows_per_group: 1 << 30,
            shard_capacity: 64,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        EmbeddingPs::new(&cfg, 4, 9)
    }

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("persia_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn crc32_known_vector() {
        assert_eq!(crc32(b"123456789"), 0xcbf43926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn save_restore_roundtrip() {
        let dir = tmp("flat");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        let keys: Vec<(u32, u64)> = (0..30).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; 120];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![0.5; 120]);
        let mut want = vec![0.0; 120];
        ps.get_many(&keys, &mut want);

        mgr.save(&ps).unwrap();
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        mgr.restore(&ps).unwrap();

        let mut got = vec![0.0; 120];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn range_ps_checkpoints_only_owned_nodes() {
        use crate::embedding::ps::pack_key;
        let dir = tmp("range");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let cfg = crate::config::EmbeddingConfig {
            rows_per_group: 1 << 30,
            shard_capacity: 64,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let part = EmbeddingPs::new_range(&cfg, 4, 9, 1..2);
        let mut buf = [0.0; 4];
        let owned: Vec<u64> =
            (0..200).filter(|&i| part.owns_key(pack_key(0, i))).take(20).collect();
        for &id in &owned {
            part.get(0, id, &mut buf);
        }
        mgr.save(&part).unwrap();
        assert!(mgr.exists(1), "owned node not saved");
        assert!(!mgr.exists(0), "unowned node saved");
        let before = part.snapshot_node(1).unwrap();
        part.wipe_node(1).unwrap();
        mgr.restore(&part).unwrap();
        assert_eq!(part.snapshot_node(1).unwrap(), before);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_checkpoint_detected() {
        let dir = tmp("corrupt");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        mgr.save(&ps).unwrap();
        // Flip a byte in the middle of node 0's file.
        let path = dir.join("ps_node_0.ckpt");
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(mgr.restore_node(&ps, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_checkpoint_is_error_not_panic() {
        let dir = tmp("missing");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        assert!(!mgr.exists(0));
        assert!(mgr.restore_node(&ps, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn epoch_prepare_commit_restore_cycle() {
        let dir = tmp("epoch");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        let keys: Vec<(u32, u64)> = (0..20).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; 80];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![0.25; 80]);
        let snapshot_state = ps.snapshot_node(0).unwrap();

        // PREPARE alone is not a committed epoch.
        mgr.prepare_epoch(&ps, 4).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), None);
        // COMMIT makes it visible.
        assert_eq!(mgr.commit_epoch(&ps, 4).unwrap(), 2);
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(4));

        // Later updates + a second epoch.
        ps.put_grads(&keys, &vec![0.25; 80]);
        mgr.prepare_epoch(&ps, 8).unwrap();
        mgr.commit_epoch(&ps, 8).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(8));

        // Restoring epoch 4 reproduces the exact state at its boundary.
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        mgr.restore_epoch(&ps, 4).unwrap();
        assert_eq!(ps.snapshot_node(0).unwrap(), snapshot_state);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn commit_without_prepare_is_rejected() {
        let dir = tmp("noprep");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        let err = mgr.commit_epoch(&ps, 3).unwrap_err();
        assert!(format!("{err:#}").contains("PREPARE"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retried_commit_is_idempotent() {
        let dir = tmp("recommit");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        mgr.prepare_epoch(&ps, 7).unwrap();
        assert_eq!(mgr.commit_epoch(&ps, 7).unwrap(), 2);
        // A retry after a lost ack must succeed without a fresh PREPARE.
        assert_eq!(mgr.commit_epoch(&ps, 7).unwrap(), 2);
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_shard_manifest_uncommits_the_epoch() {
        let dir = tmp("badmanifest");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        mgr.prepare_epoch(&ps, 6).unwrap();
        mgr.commit_epoch(&ps, 6).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(6));
        let mpath = dir.join("step-6").join("shard_0_2.manifest");
        let mut bytes = std::fs::read(&mpath).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&mpath, &bytes).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), None);
        assert!(mgr.restore_epoch(&ps, 6).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_node_file_uncommits_the_epoch_and_falls_back() {
        let dir = tmp("badnode");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        mgr.prepare_epoch(&ps, 4).unwrap();
        mgr.commit_epoch(&ps, 4).unwrap();
        ps.put_grads(&[(0, 1)], &[0.5; 4]);
        mgr.prepare_epoch(&ps, 8).unwrap();
        mgr.commit_epoch(&ps, 8).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(8));
        // Flip a bit in one of epoch 8's NODE files (manifest stays valid):
        // the restart path must fall back to epoch 4 instead of choosing 8
        // and then hard-failing its restore.
        let npath = dir.join("step-8").join("ps_node_0.ckpt");
        let mut bytes = std::fs::read(&npath).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x04;
        std::fs::write(&npath, &bytes).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(4));
        mgr.restore_epoch(&ps, 4).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_manifest_codec_rejects_garbage() {
        let good = encode_shard_manifest(12, &(1..3), false, 0);
        assert_eq!(decode_shard_manifest(&good).unwrap(), (12, 1..3, false, 0));
        let cold = encode_shard_manifest(12, &(1..3), true, 2);
        assert_eq!(decode_shard_manifest(&cold).unwrap(), (12, 1..3, true, 2));
        assert!(decode_shard_manifest(&[]).is_err());
        assert!(decode_shard_manifest(&good[..good.len() - 1]).is_err());
        let mut bad = good.clone();
        bad[13] ^= 0x01;
        assert!(decode_shard_manifest(&bad).is_err());
        // A 4-field manifest from before live resharding still decodes,
        // with routing epoch 0.
        let mut w = WireWriter::new(KIND_SHARD_MANIFEST);
        w.put_u64(&[12, 1, 3, 1]);
        let body = w.finish();
        let mut legacy = Vec::new();
        legacy.extend_from_slice(SHARD_MANIFEST_MAGIC);
        legacy.extend_from_slice(&crc32(&body).to_le_bytes());
        legacy.extend_from_slice(&body);
        assert_eq!(decode_shard_manifest(&legacy).unwrap(), (12, 1..3, true, 0));
    }

    #[test]
    fn range_epoch_apis_stamp_routing_and_skip_empty_ranges() {
        let dir = tmp("rangeepoch");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = ps();
        ps.get(0, 1, &mut [0.0; 4]);
        // An empty served range (a --join spare) stages and commits nothing.
        mgr.prepare_epoch_range(&ps, 4, 0..0).unwrap();
        assert_eq!(mgr.commit_epoch_range(&ps, 4, 0..0).unwrap(), 0);
        assert_eq!(mgr.latest_committed_epoch(&(0..0)), None);
        // A sub-range of the physical PS commits only that slice, stamped
        // with the manager's routing epoch.
        mgr.set_routing_epoch(3);
        mgr.prepare_epoch_range(&ps, 4, 0..1).unwrap();
        assert_eq!(mgr.commit_epoch_range(&ps, 4, 0..1).unwrap(), 1);
        assert_eq!(mgr.latest_committed_epoch(&(0..1)), Some(4));
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), None);
        let bytes = std::fs::read(dir.join("step-4").join("shard_0_1.manifest")).unwrap();
        assert_eq!(decode_shard_manifest(&bytes).unwrap(), (4, 0..1, false, 3));
        // Wipe and restore just the committed slice; the manifest's routing
        // epoch rides back for the restart cross-check.
        ps.wipe_node(0).unwrap();
        assert_eq!(mgr.restore_epoch_range(&ps, 4, 0..1).unwrap(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    fn tiered_ps(cold_dir: &Path) -> EmbeddingPs {
        let cfg = EmbeddingConfig {
            rows_per_group: 1 << 30,
            shard_capacity: 64,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let store = crate::embedding::StoreConfig::Tiered {
            hot_capacity: 4,
            cold_dir: cold_dir.to_path_buf(),
            admit_threshold: 1,
        };
        EmbeddingPs::new_with_store(&cfg, 4, 9, &store).unwrap()
    }

    #[test]
    fn tiered_epoch_cycle_restores_both_tiers() {
        let dir = tmp("tiered_epoch");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = tiered_ps(&dir.join("cold"));
        let keys: Vec<(u32, u64)> = (0..120).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![0.25; keys.len() * 4]);
        assert!(ps.cold_rows() > 0, "working set must cross the tier boundary");
        let mut want = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut want);

        mgr.prepare_epoch(&ps, 5).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), None);
        mgr.commit_epoch(&ps, 5).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), Some(5));
        assert!(dir.join("step-5").join("ps_node_0.cold").exists());

        // Scribble on the live state, then restore the epoch exactly.
        ps.put_grads(&keys, &vec![1.0; keys.len() * 4]);
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        mgr.restore_epoch(&ps, 5).unwrap();
        let mut got = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
        assert_eq!(ps.total_rows(), keys.len());

        // A corrupt COLD file un-commits the epoch (fallback behavior).
        let cpath = dir.join("step-5").join("ps_node_0.cold");
        let mut bytes = std::fs::read(&cpath).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&cpath, &bytes).unwrap();
        assert_eq!(mgr.latest_committed_epoch(&(0..2)), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tier_shape_mismatch_on_restore_is_loud() {
        let dir = tmp("tiershape");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let tiered = tiered_ps(&dir.join("cold"));
        tiered.get(0, 1, &mut [0.0; 4]);
        mgr.prepare_epoch(&tiered, 3).unwrap();
        mgr.commit_epoch(&tiered, 3).unwrap();
        // An all-hot PS (same geometry) cannot restore a tiered epoch.
        let err = mgr.restore_epoch(&ps(), 3).unwrap_err();
        assert!(format!("{err:#}").contains("--cold-dir"), "{err:#}");
        // Legacy flat files enforce the same shape check.
        mgr.save(&tiered).unwrap();
        let err = mgr.restore_node(&ps(), 0).unwrap_err();
        assert!(format!("{err:#}").contains("--cold-dir"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tiered_flat_save_restore_roundtrip() {
        let dir = tmp("tieredflat");
        let mgr = CheckpointManager::new(&dir).unwrap();
        let ps = tiered_ps(&dir.join("cold"));
        let keys: Vec<(u32, u64)> = (0..100).map(|i| (0, i)).collect();
        let mut buf = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![0.5; keys.len() * 4]);
        let mut want = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut want);
        mgr.save(&ps).unwrap();
        ps.wipe_node(0).unwrap();
        ps.wipe_node(1).unwrap();
        mgr.restore(&ps).unwrap();
        let mut got = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
        std::fs::remove_dir_all(&dir).ok();
    }
}
