//! Tiered store: a hot in-RAM LRU over a disk-backed cold tier.
//!
//! This is ScaleFreeCTR's MixCache shape grafted onto the paper's
//! array-list LRU (§4.2.2). Movement between tiers is *lossless*:
//!
//! * **demotion** — when the hot tier is full, the LRU victim's exact row
//!   bytes (embedding ⊕ optimizer state) are written to the cold tier
//!   before its slot is reused;
//! * **promotion** — a cold hit whose key has passed the admission gate
//!   moves back into the hot tier, bytes unchanged.
//!
//! Because placement never changes a row's contents, a tiered run is
//! bitwise identical to an all-hot run in deterministic FullSync — the only
//! difference is *where* a row waits between touches.
//!
//! ## Admission: the Zipf gate
//!
//! The PS already counts per-node traffic because the workload is Zipf
//! (PR 2's imbalance stats); this store extends that idea to per-key
//! admission, the way TinyLFU/MixCache gate their hot tiers. A compact
//! frequency sketch (power-of-two array of saturating byte counters,
//! splitmix64-indexed) counts touches; a key enters the hot tier only once
//! its counter reaches `admit_threshold`. One-touch tail keys — the long
//! Zipf tail that would otherwise cycle the LRU — are served through a
//! one-row *bypass* buffer and written straight to cold, so they never
//! evict a warm row. The sketch is deterministic (pure function of the key
//! sequence), which keeps replays and parity tests exact.

use anyhow::{ensure, Result};

use super::cold::ColdStore;
use super::lru::LruStore;
use super::store::{EmbeddingStore, StoreCounters};

/// Minimum sketch size; below this aliasing would defeat the gate. Sized
/// for the *key population*, not the hot tier: a small hot tier (say 8
/// rows) still sees the full Zipf tail, and at the old 1024-counter floor
/// a few hundred distinct one-touch keys alias into shared counters,
/// falsely pass the admission gate, and thrash the LRU they were supposed
/// to protect. 64 Ki single-byte counters is cheap and keeps the collision
/// rate negligible at reproduction scale.
const MIN_SKETCH: usize = 1 << 16;
/// Maximum sketch size (1 MiB of counters is plenty at reproduction scale).
const MAX_SKETCH: usize = 1 << 20;

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Hot LRU + cold disk store + admission sketch. See the module docs for
/// the movement rules.
pub struct TieredStore {
    hot: LruStore,
    cold: ColdStore,
    /// Saturating per-key touch counters (aliased; power-of-two length).
    freq: Vec<u8>,
    freq_mask: u64,
    admit_threshold: u8,
    /// One-row bypass: the most recent below-threshold row, served writable
    /// without entering the hot tier. Flushed to cold before any other key
    /// is served, so at most one row is ever in flight outside the tiers.
    bypass_key: Option<u64>,
    bypass_row: Vec<f32>,
    c: StoreCounters,
}

impl TieredStore {
    /// Compose a fresh hot LRU of `hot_capacity` rows over `cold`.
    /// `admit_threshold` is the touch count at which a key may enter the
    /// hot tier (≥1; 1 admits everything, i.e. no gate).
    pub fn new(hot_capacity: usize, cold: ColdStore, admit_threshold: u8) -> Result<Self> {
        ensure!(hot_capacity > 0, "tiered store needs hot_capacity > 0");
        ensure!(admit_threshold >= 1, "admit_threshold must be >= 1");
        let row_width = cold.row_width();
        let sketch = hot_capacity
            .saturating_mul(8)
            .next_power_of_two()
            .clamp(MIN_SKETCH, MAX_SKETCH);
        Ok(Self {
            hot: LruStore::new(hot_capacity, row_width),
            cold,
            freq: vec![0; sketch],
            freq_mask: (sketch - 1) as u64,
            admit_threshold,
            bypass_key: None,
            bypass_row: vec![0.0; row_width],
            c: StoreCounters::default(),
        })
    }

    fn touch(&mut self, key: u64) -> u8 {
        let idx = (splitmix64(key) & self.freq_mask) as usize;
        self.freq[idx] = self.freq[idx].saturating_add(1);
        self.freq[idx]
    }

    /// Write the bypass row (if any) back to the cold tier.
    fn flush_bypass(&mut self) -> Result<()> {
        if let Some(key) = self.bypass_key.take() {
            let row = std::mem::take(&mut self.bypass_row);
            self.cold.put(key, &row)?;
            self.bypass_row = row;
        }
        Ok(())
    }

    /// Insert `key` with `row` bytes into the hot tier, demoting the LRU
    /// victim to cold first if the hot tier is full.
    fn insert_hot(&mut self, key: u64, row: &[f32]) -> Result<()> {
        if self.hot.len() == self.hot.capacity() {
            let (victim_key, victim_row) =
                self.hot.evict_lru().expect("full hot tier has an LRU tail");
            self.cold.put(victim_key, &victim_row)?;
            self.c.demotions += 1;
            self.c.evictions += 1;
        }
        let (slot, evicted) = self.hot.get_or_insert_with(key, |dst| dst.copy_from_slice(row));
        debug_assert!(evicted.is_none(), "insert after explicit demotion cannot evict");
        debug_assert_eq!(slot.len(), row.len());
        Ok(())
    }

    /// Borrow of the cold tier (tests/diagnostics).
    pub fn cold(&self) -> &ColdStore {
        &self.cold
    }

    /// Number of counters in the admission sketch (tests/diagnostics pin
    /// the sizing floor through this).
    pub fn sketch_len(&self) -> usize {
        self.freq.len()
    }
}

impl EmbeddingStore for TieredStore {
    fn row_width(&self) -> usize {
        self.hot.row_width()
    }

    fn hot_capacity(&self) -> usize {
        self.hot.capacity()
    }

    fn len(&self) -> usize {
        self.hot.len() + self.cold_len()
    }

    fn hot_len(&self) -> usize {
        self.hot.len()
    }

    fn cold_len(&self) -> usize {
        // The bypass row counts unless it merely shadows a (stale) cold
        // copy awaiting write-back.
        let bypass_only = self.bypass_key.is_some_and(|k| !self.cold.contains(k));
        self.cold.len() + usize::from(bypass_only)
    }

    fn has_cold(&self) -> bool {
        true
    }

    fn get_or_insert_with(
        &mut self,
        key: u64,
        init: &mut dyn FnMut(&mut [f32]),
    ) -> Result<&mut [f32]> {
        // At most one row lives outside the tiers; park it back first.
        if self.bypass_key.is_some() && self.bypass_key != Some(key) {
            self.flush_bypass()?;
        }
        if self.hot.contains(key) {
            self.c.hot_hits += 1;
            self.touch(key);
            return Ok(self.hot.get(key).expect("checked contains"));
        }
        let count = self.touch(key);
        let admit = count >= self.admit_threshold;
        if self.bypass_key == Some(key) {
            self.c.cold_hits += 1;
            if admit {
                self.bypass_key = None;
                let row = std::mem::take(&mut self.bypass_row);
                self.insert_hot(key, &row)?;
                self.bypass_row = row;
                self.c.promotions += 1;
                return Ok(self.hot.get(key).expect("just inserted"));
            }
            return Ok(&mut self.bypass_row);
        }
        if self.cold.contains(key) {
            let mut row = vec![0.0f32; self.hot.row_width()];
            if self.cold.get_into(key, &mut row)? {
                self.c.cold_hits += 1;
                if admit {
                    self.cold.remove(key)?;
                    self.insert_hot(key, &row)?;
                    self.c.promotions += 1;
                    return Ok(self.hot.get(key).expect("just inserted"));
                }
                // Below threshold: serve from the bypass row; the cold copy
                // is refreshed when the bypass flushes.
                self.bypass_row.copy_from_slice(&row);
                self.bypass_key = Some(key);
                return Ok(&mut self.bypass_row);
            }
            // CRC failure dropped the row; fall through to a true miss.
        }
        if admit {
            let mut row = vec![0.0f32; self.hot.row_width()];
            init(&mut row);
            self.insert_hot(key, &row)?;
            return Ok(self.hot.get(key).expect("just inserted"));
        }
        init(&mut self.bypass_row);
        self.bypass_key = Some(key);
        Ok(&mut self.bypass_row)
    }

    fn counters(&self) -> StoreCounters {
        self.c
    }

    fn snapshot_hot(&mut self) -> Result<Vec<u8>> {
        self.flush_bypass()?;
        Ok(self.hot.to_bytes())
    }

    fn snapshot_cold(&mut self) -> Result<Option<Vec<u8>>> {
        self.flush_bypass()?;
        Ok(Some(self.cold.snapshot_bytes()?))
    }

    fn restore_hot(&mut self, bytes: &[u8]) -> Result<()> {
        let store = LruStore::from_bytes(bytes)?;
        ensure!(
            store.row_width() == self.hot.row_width(),
            "hot snapshot row width {} != store row width {}",
            store.row_width(),
            self.hot.row_width()
        );
        self.bypass_key = None;
        self.hot = store;
        Ok(())
    }

    fn restore_cold(&mut self, bytes: &[u8]) -> Result<()> {
        self.bypass_key = None;
        self.cold.restore_bytes(bytes)
    }

    fn wipe(&mut self) -> Result<()> {
        self.hot = LruStore::new(self.hot.capacity(), self.hot.row_width());
        self.cold.wipe()?;
        self.freq.fill(0);
        self.bypass_key = None;
        self.c = StoreCounters::default();
        Ok(())
    }

    fn check_invariants(&mut self) -> Result<()> {
        self.hot.check_invariants()?;
        // A key lives in at most one tier. (The bypass row may shadow a
        // stale cold copy of the same key until write-back; that is the one
        // sanctioned overlap.)
        for key in self.hot.keys_mru_order() {
            ensure!(!self.cold.contains(key), "key {key:#x} resident in both tiers");
            ensure!(self.bypass_key != Some(key), "key {key:#x} in hot tier and bypass");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiered(tag: &str, hot_cap: usize, row_width: usize, threshold: u8) -> (TieredStore, PathBuf) {
        let dir = std::env::temp_dir().join(format!("persia_tiered_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cold = ColdStore::open(&dir.join("cold.bin"), row_width).unwrap();
        (TieredStore::new(hot_cap, cold, threshold).unwrap(), dir)
    }

    fn get(ts: &mut TieredStore, key: u64, fill: f32) -> Vec<f32> {
        ts.get_or_insert_with(key, &mut |row| row.fill(fill)).unwrap().to_vec()
    }

    #[test]
    fn demotion_preserves_exact_bytes() {
        // threshold 1 = admit everything: pure capacity spill.
        let (mut ts, dir) = tiered("demote", 2, 2, 1);
        for k in 0..5u64 {
            let row = get(&mut ts, k, k as f32);
            assert_eq!(row, vec![k as f32; 2]);
        }
        assert_eq!(ts.hot_len(), 2);
        assert_eq!(ts.counters().demotions, 3);
        assert_eq!(ts.len(), 5, "demoted rows are kept, not dropped");
        // Demoted keys come back with their exact bytes (init must not run).
        for k in 0..5u64 {
            let row = ts
                .get_or_insert_with(k, &mut |_| panic!("resident key re-materialized"))
                .unwrap();
            assert_eq!(row, &[k as f32; 2][..]);
        }
        ts.check_invariants().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn updates_survive_demotion_and_promotion() {
        let (mut ts, dir) = tiered("update", 1, 2, 1);
        ts.get_or_insert_with(10, &mut |r| r.fill(1.0)).unwrap()[0] = 42.0;
        get(&mut ts, 20, 2.0); // demotes 10
        assert_eq!(ts.counters().demotions, 1);
        let row = ts.get_or_insert_with(10, &mut |_| panic!("lost row")).unwrap();
        assert_eq!(row, &[42.0, 1.0][..]);
        assert!(ts.counters().promotions >= 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn one_touch_tail_keys_never_evict_hot_rows() {
        let (mut ts, dir) = tiered("gate", 2, 1, 2);
        // Warm two keys past the gate: touch twice each.
        for _ in 0..2 {
            get(&mut ts, 100, 1.0);
            get(&mut ts, 200, 2.0);
        }
        assert_eq!(ts.hot_len(), 2);
        let demotions_before = ts.counters().demotions;
        // A storm of one-touch tail keys (all distinct → all below gate).
        for k in 0..50u64 {
            get(&mut ts, 1000 + k, k as f32);
        }
        assert_eq!(ts.counters().demotions, demotions_before, "tail keys thrashed the hot tier");
        assert!(ts.hot.contains(100) && ts.hot.contains(200));
        // Tail keys are still resident — in the cold tier.
        let row = ts.get_or_insert_with(1000, &mut |_| panic!("tail row dropped")).unwrap();
        assert_eq!(row, &[0.0][..]);
        ts.check_invariants().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bypass_row_is_writable_and_flushes_to_cold() {
        let (mut ts, dir) = tiered("bypass", 2, 2, 2);
        // First touch of a key: below gate, served via bypass.
        ts.get_or_insert_with(5, &mut |r| r.fill(0.0)).unwrap()[1] = 7.0;
        assert_eq!(ts.cold_len(), 1); // counts the parked bypass row
        // Serving another key flushes the write-back.
        get(&mut ts, 6, 1.0);
        let row = ts.get_or_insert_with(5, &mut |_| panic!("bypass write lost")).unwrap();
        assert_eq!(row, &[0.0, 7.0][..]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_restore_roundtrips_both_tiers() {
        let (mut ts, dir) = tiered("snap", 2, 2, 1);
        for k in 0..6u64 {
            ts.get_or_insert_with(k, &mut |r| r.fill(k as f32)).unwrap()[1] = -(k as f32);
        }
        let hot = ts.snapshot_hot().unwrap();
        let cold = ts.snapshot_cold().unwrap().expect("tiered store has a cold tier");
        ts.wipe().unwrap();
        assert_eq!(ts.len(), 0);
        ts.restore_cold(&cold).unwrap();
        ts.restore_hot(&hot).unwrap();
        assert_eq!(ts.len(), 6);
        for k in 0..6u64 {
            let row = ts.get_or_insert_with(k, &mut |_| panic!("row lost")).unwrap();
            assert_eq!(row, &[k as f32, -(k as f32)][..], "key {k}");
        }
        ts.check_invariants().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn admission_sketch_floor_protects_small_hot_tiers_from_tail_aliasing() {
        // Regression: the sketch used to size from the hot tier
        // (hot_capacity * 8, floored at 1024), but the sketch's job is to
        // count the whole key population. With an 8-row hot tier the old
        // floor gave 1024 counters; a ~300-key one-touch tail then aliases
        // into shared counters (dozens of collisions), falsely passes the
        // `admit_threshold = 2` gate, and evicts every warm row.
        let (mut ts, dir) = tiered("floor", 8, 1, 2);
        assert!(ts.sketch_len() >= 1 << 16, "sketch floor regressed to {}", ts.sketch_len());
        // Warm 8 keys past the gate (two touches each).
        for _ in 0..2 {
            for k in 0..8u64 {
                get(&mut ts, k, k as f32);
            }
        }
        assert_eq!(ts.hot_len(), 8);
        let demotions_before = ts.counters().demotions;
        // A calibrated 300-key one-touch tail: candidates are filtered
        // (deterministically — the sketch hash is a pure function) so no
        // two land in the same counter at the CURRENT sketch size, which
        // makes "no demotions" the exact expected behavior. The same keys
        // must provably alias under the old 1024-slot floor, or the test
        // would not witness the bug it pins.
        let mask = (ts.sketch_len() - 1) as u64;
        let mut used: std::collections::HashSet<u64> =
            (0..8u64).map(|k| splitmix64(k) & mask).collect();
        let mut old_used: std::collections::HashSet<u64> =
            (0..8u64).map(|k| splitmix64(k) & 1023).collect();
        let mut tail = Vec::new();
        let mut old_collisions = 0usize;
        let mut cand = 1_000u64;
        while tail.len() < 300 {
            if used.insert(splitmix64(cand) & mask) {
                tail.push(cand);
                if !old_used.insert(splitmix64(cand) & 1023) {
                    old_collisions += 1;
                }
            }
            cand += 1;
        }
        assert!(old_collisions > 0, "tail never aliases at the old floor; test is vacuous");
        for (i, &k) in tail.iter().enumerate() {
            get(&mut ts, k, i as f32);
        }
        assert_eq!(
            ts.counters().demotions,
            demotions_before,
            "one-touch tail keys thrashed the 8-row hot tier"
        );
        for k in 0..8u64 {
            assert!(ts.hot.contains(k), "warm key {k} evicted by tail aliasing");
        }
        ts.check_invariants().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn counters_split_hot_and_cold_hits() {
        let (mut ts, dir) = tiered("counters", 1, 1, 1);
        get(&mut ts, 1, 1.0); // miss
        get(&mut ts, 1, 1.0); // hot hit
        get(&mut ts, 2, 2.0); // miss, demotes 1
        get(&mut ts, 1, 1.0); // cold hit + promotion (demotes 2)
        let c = ts.counters();
        assert_eq!(c.hot_hits, 1);
        assert_eq!(c.cold_hits, 1);
        assert_eq!(c.demotions, 2);
        assert_eq!(c.promotions, 1);
        assert_eq!(c.evictions, c.demotions);
        std::fs::remove_dir_all(&dir).ok();
    }
}
