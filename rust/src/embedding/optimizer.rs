//! Row-wise embedding optimizers (Algorithm 1's Ω^emb).
//!
//! The optimizer state lives *inside the LRU row* next to the embedding
//! vector (paper §4.2.2: "each item in the array also includes two fields:
//! the embedding vector and the optimizer states"), so one row fetch serves
//! both the forward lookup and the backward update.

use crate::config::OptimizerKind;

/// Stateless descriptor; all state is in the row's tail floats.
#[derive(Clone, Copy, Debug)]
pub struct RowOptimizer {
    /// Which update rule the row's tail state encodes.
    pub kind: OptimizerKind,
    /// Row-wise learning rate.
    pub lr: f32,
    /// Embedding vector width (state floats live after it).
    pub dim: usize,
}

impl RowOptimizer {
    /// Descriptor for `dim`-wide rows under `kind` with learning rate `lr`.
    pub fn new(kind: OptimizerKind, lr: f32, dim: usize) -> Self {
        Self { kind, lr, dim }
    }

    /// Extra floats stored per row after the embedding vector.
    pub fn state_width(&self) -> usize {
        match self.kind {
            OptimizerKind::Sgd => 0,
            OptimizerKind::Adagrad => self.dim,
            // Adam: m, v per element + one shared step counter.
            OptimizerKind::Adam => 2 * self.dim + 1,
        }
    }

    /// Total row width (embedding + state).
    pub fn row_width(&self) -> usize {
        self.dim + self.state_width()
    }

    /// Initialize a fresh row in place: embedding ~ N(0, 0.01), zero state.
    pub fn init_row(&self, row: &mut [f32], rng: &mut crate::util::Rng) {
        debug_assert_eq!(row.len(), self.row_width());
        for x in row[..self.dim].iter_mut() {
            *x = rng.normal() * 0.1;
        }
        for x in row[self.dim..].iter_mut() {
            *x = 0.0;
        }
    }

    /// Apply one gradient to a row in place.
    pub fn apply(&self, row: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(row.len(), self.row_width());
        debug_assert_eq!(grad.len(), self.dim);
        let (emb, state) = row.split_at_mut(self.dim);
        match self.kind {
            OptimizerKind::Sgd => {
                for (w, g) in emb.iter_mut().zip(grad) {
                    *w -= self.lr * g;
                }
            }
            OptimizerKind::Adagrad => {
                for ((w, acc), g) in emb.iter_mut().zip(state.iter_mut()).zip(grad) {
                    *acc += g * g;
                    *w -= self.lr * g / (acc.sqrt() + 1e-8);
                }
            }
            OptimizerKind::Adam => {
                const B1: f32 = 0.9;
                const B2: f32 = 0.999;
                let (mv, t_slot) = state.split_at_mut(2 * self.dim);
                let (m, v) = mv.split_at_mut(self.dim);
                t_slot[0] += 1.0;
                let t = t_slot[0];
                let bc1 = 1.0 - B1.powf(t);
                let bc2 = 1.0 - B2.powf(t);
                for i in 0..self.dim {
                    m[i] = B1 * m[i] + (1.0 - B1) * grad[i];
                    v[i] = B2 * v[i] + (1.0 - B2) * grad[i] * grad[i];
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    emb[i] -= self.lr * mhat / (vhat.sqrt() + 1e-8);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn widths() {
        assert_eq!(RowOptimizer::new(OptimizerKind::Sgd, 0.1, 8).row_width(), 8);
        assert_eq!(RowOptimizer::new(OptimizerKind::Adagrad, 0.1, 8).row_width(), 16);
        assert_eq!(RowOptimizer::new(OptimizerKind::Adam, 0.1, 8).row_width(), 25);
    }

    #[test]
    fn sgd_step_exact() {
        let opt = RowOptimizer::new(OptimizerKind::Sgd, 0.5, 3);
        let mut row = vec![1.0, 2.0, 3.0];
        opt.apply(&mut row, &[1.0, -2.0, 0.0]);
        assert_eq!(row, vec![0.5, 3.0, 3.0]);
    }

    #[test]
    fn adagrad_shrinks_effective_lr() {
        let opt = RowOptimizer::new(OptimizerKind::Adagrad, 1.0, 1);
        let mut row = vec![0.0, 0.0];
        opt.apply(&mut row, &[1.0]);
        let first_step = -row[0];
        let before = row[0];
        opt.apply(&mut row, &[1.0]);
        let second_step = before - row[0];
        assert!(second_step < first_step, "{second_step} !< {first_step}");
        assert!((row[1] - 2.0).abs() < 1e-6); // accumulated g^2
    }

    #[test]
    fn adam_converges_on_quadratic() {
        // Minimize (w - 3)^2 with gradient 2(w-3).
        let opt = RowOptimizer::new(OptimizerKind::Adam, 0.1, 1);
        let mut row = vec![0.0; opt.row_width()];
        for _ in 0..500 {
            let g = 2.0 * (row[0] - 3.0);
            opt.apply(&mut row, &[g]);
        }
        assert!((row[0] - 3.0).abs() < 0.05, "w={}", row[0]);
    }

    #[test]
    fn all_kinds_descend_on_quadratic() {
        for kind in [OptimizerKind::Sgd, OptimizerKind::Adagrad, OptimizerKind::Adam] {
            let opt = RowOptimizer::new(kind, 0.05, 4);
            let mut rng = Rng::new(3);
            let mut row = vec![0.0; opt.row_width()];
            opt.init_row(&mut row, &mut rng);
            let loss = |w: &[f32]| -> f32 { w.iter().map(|x| (x - 1.0) * (x - 1.0)).sum() };
            let l0 = loss(&row[..4]);
            for _ in 0..200 {
                let g: Vec<f32> = row[..4].iter().map(|x| 2.0 * (x - 1.0)).collect();
                opt.apply(&mut row, &g);
            }
            let l1 = loss(&row[..4]);
            assert!(l1 < l0 * 0.1, "{kind:?}: {l0} -> {l1}");
        }
    }

    #[test]
    fn init_row_zeroes_state() {
        let opt = RowOptimizer::new(OptimizerKind::Adam, 0.1, 4);
        let mut rng = Rng::new(1);
        let mut row = vec![9.0; opt.row_width()];
        opt.init_row(&mut row, &mut rng);
        assert!(row[4..].iter().all(|&x| x == 0.0));
        assert!(row[..4].iter().any(|&x| x != 0.0));
    }
}
