//! The sharded embedding parameter server (paper Fig. 4/5).
//!
//! Keys are `(feature group, id)` pairs packed into a u64. An embedding
//! worker "first runs an identical global hashing function to locate the
//! embedding PS node that stores the parameters" (§4.2.2); within a node the
//! key selects a lock-striped shard.
//!
//! Two placement policies (§4.2.3 "Workload balance of embedding PS"):
//! * `FeatureGroup` — nodes own whole semantic groups; congests when traffic
//!   leans toward one group (the ablation baseline);
//! * `ShuffledUniform` — ids hashed uniformly over all nodes (Persia's fix).

use crate::config::{EmbeddingConfig, PartitionPolicy};

use super::optimizer::RowOptimizer;
use super::shard::Shard;
use super::store::{NodeSnapshot, StoreConfig, StoreCounters};

#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Pack (group, id) into the PS key space. Ids up to 2^48 (281T rows/group).
#[inline]
pub fn pack_key(group: u32, id: u64) -> u64 {
    debug_assert!(id < (1u64 << 48), "id {id} exceeds 48-bit key space");
    ((group as u64) << 48) | id
}

/// Unpack a PS key.
#[inline]
pub fn unpack_key(key: u64) -> (u32, u64) {
    ((key >> 48) as u32, key & 0x0000_ffff_ffff_ffff)
}

/// The global hash placement: key -> (node, shard).
///
/// This is *the* function every participant of a deployment must agree on:
/// the in-process PS, each `serve-ps` shard process, and the
/// [`ShardedRemotePs`](crate::service::ShardedRemotePs) client all call this
/// one implementation, so a key provably routes to the same logical node on
/// both sides of the wire (§4.2.2: "an identical global hashing function").
#[inline]
pub fn route(
    policy: PartitionPolicy,
    n_nodes: usize,
    shards_per_node: usize,
    key: u64,
) -> (usize, usize) {
    let (group, id) = unpack_key(key);
    match policy {
        PartitionPolicy::ShuffledUniform => {
            let h = splitmix64(key);
            ((h % n_nodes as u64) as usize, ((h >> 32) % shards_per_node as u64) as usize)
        }
        PartitionPolicy::FeatureGroup => {
            let node = group as usize % n_nodes;
            let h = splitmix64(id);
            (node, (h % shards_per_node as u64) as usize)
        }
    }
}

/// Max/mean traffic imbalance over a per-node traffic vector (1.0 =
/// perfectly balanced; 1.0 for an idle PS). Like [`route`], this is shared
/// by the in-process PS and the sharded client (which feeds it the
/// element-wise sum of every shard process's traffic vector), so "merged
/// imbalance equals in-process imbalance" holds by construction.
pub fn imbalance_of(traffic: &[u64]) -> f64 {
    let max = *traffic.iter().max().unwrap_or(&0) as f64;
    let mean = traffic.iter().sum::<u64>() as f64 / traffic.len().max(1) as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// The embedding PS: `n_nodes x shards_per_node` locked shards.
///
/// A PS instance may *own* only a contiguous range of the logical nodes
/// (`new_range`) while still routing over the full global geometry — that is
/// how one `serve-ps` process hosts its slice of a multi-process deployment
/// without allocating the other processes' shards.
pub struct EmbeddingPs {
    /// Shards of the owned nodes only: `nodes[i]` is global node
    /// `node_start + i`.
    nodes: Vec<Vec<Shard>>,
    /// First owned global node index.
    node_start: usize,
    /// Global node count (the routing modulus; >= nodes.len()).
    n_nodes_global: usize,
    policy: PartitionPolicy,
    dim: usize,
}

impl EmbeddingPs {
    /// A PS owning every logical node (the in-process default).
    pub fn new(cfg: &EmbeddingConfig, dim: usize, seed: u64) -> Self {
        Self::new_range(cfg, dim, seed, 0..cfg.n_nodes)
    }

    /// A PS owning every logical node, with an explicit storage engine.
    pub fn new_with_store(
        cfg: &EmbeddingConfig,
        dim: usize,
        seed: u64,
        store: &StoreConfig,
    ) -> anyhow::Result<Self> {
        Self::new_range_with_store(cfg, dim, seed, 0..cfg.n_nodes, store)
    }

    /// A PS owning only global nodes `range` out of `cfg.n_nodes`. Shard
    /// seeds are derived from the *global* node index, so a node's rows
    /// materialize identically whether it lives in a full in-process PS or
    /// in the shard process that owns it.
    pub fn new_range(
        cfg: &EmbeddingConfig,
        dim: usize,
        seed: u64,
        range: std::ops::Range<usize>,
    ) -> Self {
        Self::new_range_with_store(cfg, dim, seed, range, &StoreConfig::Hot)
            .expect("all-hot store construction is infallible")
    }

    /// Like [`Self::new_range`] but constructing each shard's store through
    /// `store` ([`StoreConfig::Tiered`] may fail on cold-file I/O). Cold
    /// files are named by *global* node/shard indices, so a restarted
    /// process reopens exactly the files its predecessor wrote.
    pub fn new_range_with_store(
        cfg: &EmbeddingConfig,
        dim: usize,
        seed: u64,
        range: std::ops::Range<usize>,
        store: &StoreConfig,
    ) -> anyhow::Result<Self> {
        assert!(
            range.start < range.end && range.end <= cfg.n_nodes,
            "node range {range:?} invalid for {} nodes",
            cfg.n_nodes
        );
        let opt = RowOptimizer::new(cfg.optimizer, cfg.lr, dim);
        let nodes = range
            .clone()
            .map(|n| {
                (0..cfg.shards_per_node)
                    .map(|s| {
                        let shard_seed = seed ^ ((n as u64) << 32) ^ s as u64;
                        let engine = store.build(cfg.shard_capacity, opt.row_width(), n, s)?;
                        Ok(Shard::with_store(engine, opt, shard_seed))
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Self {
            nodes,
            node_start: range.start,
            n_nodes_global: cfg.n_nodes,
            policy: cfg.partition,
            dim,
        })
    }

    /// Embedding vector width per row.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Global node count (the routing modulus), not the owned count.
    pub fn n_nodes(&self) -> usize {
        self.n_nodes_global
    }

    /// Lock-striped sub-shards per node.
    pub fn shards_per_node(&self) -> usize {
        self.nodes[0].len()
    }

    /// The contiguous range of global node indices this instance owns.
    pub fn node_range(&self) -> std::ops::Range<usize> {
        self.node_start..self.node_start + self.nodes.len()
    }

    /// The row-placement policy this PS routes with.
    pub fn partition_policy(&self) -> PartitionPolicy {
        self.policy
    }

    /// The global hash placement: key -> (node, shard).
    #[inline]
    pub fn route(&self, key: u64) -> (usize, usize) {
        route(self.policy, self.n_nodes_global, self.nodes[0].len(), key)
    }

    /// Whether `key` routes to a node this instance owns.
    #[inline]
    pub fn owns_key(&self, key: u64) -> bool {
        let (n, _) = self.route(key);
        n >= self.node_start && n < self.node_start + self.nodes.len()
    }

    #[inline]
    fn shard(&self, key: u64) -> &Shard {
        let (n, s) = self.route(key);
        assert!(
            n >= self.node_start && n < self.node_start + self.nodes.len(),
            "key {key:#x} routes to node {n}, outside owned range {:?}",
            self.node_range()
        );
        &self.nodes[n - self.node_start][s]
    }

    /// Like [`Self::shard`] but fallible: an unowned key is an `Err`, not a
    /// panic — the PS service handles hostile/misrouted traffic through
    /// this, routing each key exactly once.
    #[inline]
    fn shard_checked(&self, key: u64) -> anyhow::Result<&Shard> {
        let (n, s) = self.route(key);
        // Keys below node_start wrap to a huge index and fail the `get`.
        self.nodes
            .get(n.wrapping_sub(self.node_start))
            .map(|shards| &shards[s])
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "key {key:#x} routes to node {n}, outside owned range {:?}",
                    self.node_range()
                )
            })
    }

    /// Batched lookup of already-packed keys into `out`, routing each key
    /// once and rejecting (all-or-nothing, before any row materializes)
    /// keys this instance does not own. The PS service's GET entry point.
    pub fn get_packed_into(&self, packed: &[u64], out: &mut [f32]) -> anyhow::Result<()> {
        anyhow::ensure!(out.len() == packed.len() * self.dim, "GET output shape mismatch");
        let shards: Vec<&Shard> =
            packed.iter().map(|&k| self.shard_checked(k)).collect::<anyhow::Result<_>>()?;
        for (i, (shard, &key)) in shards.iter().zip(packed).enumerate() {
            shard.get(key, &mut out[i * self.dim..(i + 1) * self.dim])?;
        }
        Ok(())
    }

    /// Batched gradient put of already-packed keys, routing each key once
    /// and rejecting unowned keys before any gradient is applied. The PS
    /// service's PUT entry point.
    pub fn put_grads_packed(&self, packed: &[u64], grads: &[f32]) -> anyhow::Result<()> {
        anyhow::ensure!(grads.len() == packed.len() * self.dim, "PUT gradient shape mismatch");
        let shards: Vec<&Shard> =
            packed.iter().map(|&k| self.shard_checked(k)).collect::<anyhow::Result<_>>()?;
        for (i, (shard, &key)) in shards.iter().zip(packed).enumerate() {
            shard.put_grad(key, &grads[i * self.dim..(i + 1) * self.dim])?;
        }
        Ok(())
    }

    /// Fetch one embedding row into `out`.
    ///
    /// # Panics
    /// On unowned keys, and on cold-tier I/O failure (the fallible service
    /// entry point is [`Self::get_packed_into`]).
    pub fn get(&self, group: u32, id: u64, out: &mut [f32]) {
        let key = pack_key(group, id);
        self.shard(key).get(key, out).expect("embedding store I/O");
    }

    /// Batched lookup: rows for `keys`, flattened `[len, dim]` into `out`.
    ///
    /// # Panics
    /// Like [`Self::get`].
    pub fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) {
        assert_eq!(out.len(), keys.len() * self.dim);
        for (i, &(g, id)) in keys.iter().enumerate() {
            let key = pack_key(g, id);
            self.shard(key)
                .get(key, &mut out[i * self.dim..(i + 1) * self.dim])
                .expect("embedding store I/O");
        }
    }

    /// Apply one gradient row.
    ///
    /// # Panics
    /// Like [`Self::get`].
    pub fn put_grad(&self, group: u32, id: u64, grad: &[f32]) {
        let key = pack_key(group, id);
        self.shard(key).put_grad(key, grad).expect("embedding store I/O");
    }

    /// Batched gradient put, rows flattened like [`Self::get_many`].
    pub fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) {
        assert_eq!(grads.len(), keys.len() * self.dim);
        for (i, &(g, id)) in keys.iter().enumerate() {
            self.put_grad(g, id, &grads[i * self.dim..(i + 1) * self.dim]);
        }
    }

    /// Materialized rows in total.
    pub fn total_rows(&self) -> usize {
        self.nodes.iter().flatten().map(|s| s.len()).sum()
    }

    /// Hot-tier evictions across all owned shards.
    pub fn total_evictions(&self) -> u64 {
        self.nodes.iter().flatten().map(|s| s.evictions()).sum()
    }

    /// Rows resident in cold tiers across all owned shards.
    pub fn cold_rows(&self) -> usize {
        self.nodes.iter().flatten().map(|s| s.cold_len()).sum()
    }

    /// Hit/movement counters summed over all owned shards.
    pub fn tier_counters(&self) -> StoreCounters {
        let mut total = StoreCounters::default();
        for s in self.nodes.iter().flatten() {
            total.add(&s.counters());
        }
        total
    }

    /// Whether this PS's shards have a cold tier (all shards share one
    /// [`StoreConfig`], so the first shard answers for everyone).
    pub fn has_cold_tier(&self) -> bool {
        self.nodes[0][0].has_cold()
    }

    /// Per-node traffic (gets+puts) — the load-balance ablation metric.
    ///
    /// Always global-length: unowned nodes report 0, so a sharded deployment
    /// can element-wise sum the vectors from every shard process and get the
    /// true global per-node traffic (the merged-imbalance input).
    pub fn node_traffic(&self) -> Vec<u64> {
        let mut out = vec![0u64; self.n_nodes_global];
        for (i, shards) in self.nodes.iter().enumerate() {
            out[self.node_start + i] = shards
                .iter()
                .map(|s| {
                    let (g, p) = s.traffic();
                    g + p
                })
                .sum();
        }
        out
    }

    /// Max/mean traffic imbalance across nodes (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        imbalance_of(&self.node_traffic())
    }

    #[inline]
    fn owned_node(&self, node: usize) -> anyhow::Result<&[Shard]> {
        anyhow::ensure!(
            node >= self.node_start && node < self.node_start + self.nodes.len(),
            "node {node} outside owned range {:?}",
            self.node_range()
        );
        Ok(&self.nodes[node - self.node_start])
    }

    /// Snapshot one node's hot tiers (all its shards) — periodic
    /// checkpointing (§4.2.4). `node` is a *global* index; an unowned node
    /// is an `Err`, not a panic — the SNAPSHOT RPC handler reaches this
    /// with remote-supplied indices and must survive hostile ones.
    pub fn snapshot_node(&self, node: usize) -> anyhow::Result<Vec<Vec<u8>>> {
        self.owned_node(node)?.iter().map(|s| s.snapshot()).collect()
    }

    /// Snapshot one node's cold tiers: `Some(blob per shard)` when the
    /// stores are tiered, `None` when all-hot. A node with a mix of tiered
    /// and all-hot shards is a construction-time impossibility and reports
    /// as corruption here.
    pub fn snapshot_node_cold(&self, node: usize) -> anyhow::Result<Option<Vec<Vec<u8>>>> {
        let shards = self.owned_node(node)?;
        let blobs: Vec<Option<Vec<u8>>> =
            shards.iter().map(|s| s.snapshot_cold()).collect::<anyhow::Result<_>>()?;
        let n_cold = blobs.iter().filter(|b| b.is_some()).count();
        anyhow::ensure!(
            n_cold == 0 || n_cold == shards.len(),
            "node {node} mixes tiered and all-hot shards ({n_cold}/{})",
            shards.len()
        );
        Ok(if n_cold == 0 { None } else { Some(blobs.into_iter().flatten().collect()) })
    }

    /// Snapshot one node across all tiers.
    pub fn snapshot_node_full(&self, node: usize) -> anyhow::Result<NodeSnapshot> {
        Ok(NodeSnapshot {
            hot: self.snapshot_node(node)?,
            cold: self.snapshot_node_cold(node)?,
        })
    }

    /// Restore one (owned, global-indexed) node's hot tiers from a snapshot.
    pub fn restore_node(&self, node: usize, shards: &[Vec<u8>]) -> anyhow::Result<()> {
        let owned = self.owned_node(node)?;
        anyhow::ensure!(shards.len() == owned.len(), "shard count mismatch");
        for (shard, bytes) in owned.iter().zip(shards) {
            shard.restore(bytes)?;
        }
        Ok(())
    }

    /// Restore one node's cold tiers. Errs if this PS has no cold tier.
    pub fn restore_node_cold(&self, node: usize, shards: &[Vec<u8>]) -> anyhow::Result<()> {
        let owned = self.owned_node(node)?;
        anyhow::ensure!(shards.len() == owned.len(), "cold shard count mismatch");
        for (shard, bytes) in owned.iter().zip(shards) {
            shard.restore_cold(bytes)?;
        }
        Ok(())
    }

    /// Restore one node across tiers, enforcing that the snapshot's tier
    /// shape matches this PS's (a tiered PS cannot accept an all-hot
    /// snapshot without silently resurrecting stale cold rows, and vice
    /// versa an all-hot PS would silently *drop* the snapshot's cold rows).
    pub fn restore_node_full(&self, node: usize, snap: &NodeSnapshot) -> anyhow::Result<()> {
        match (&snap.cold, self.has_cold_tier()) {
            (Some(cold), true) => {
                // Cold first: a failure here leaves the hot tier untouched.
                self.restore_node_cold(node, cold)?;
                self.restore_node(node, &snap.hot)
            }
            (None, false) => self.restore_node(node, &snap.hot),
            (Some(_), false) => anyhow::bail!(
                "snapshot has a cold tier but this PS is all-hot; restart with --cold-dir"
            ),
            (None, true) => anyhow::bail!(
                "snapshot is all-hot but this PS has a cold tier; restart without --cold-dir"
            ),
        }
    }

    /// Simulate a node crash that loses in-memory state (used by fault tests
    /// to contrast with the shared-memory + checkpoint recovery path).
    /// Unowned nodes are an `Err` like every other node-indexed entry point.
    pub fn wipe_node(&self, node: usize) -> anyhow::Result<()> {
        for s in self.owned_node(node)? {
            s.wipe()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind};
    use crate::util::quickcheck::forall;
    use crate::util::{Rng, Zipf};

    fn cfg(policy: PartitionPolicy) -> EmbeddingConfig {
        EmbeddingConfig {
            rows_per_group: 1 << 40,
            shard_capacity: 512,
            n_nodes: 4,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: policy,
            lr: 0.5,
        }
    }

    #[test]
    fn key_packing_roundtrip() {
        forall(
            61,
            500,
            |rng: &mut Rng| (rng.below(256), rng.below(1 << 48)),
            |&(g, id)| unpack_key(pack_key(g as u32, id)) == (g as u32, id),
        );
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let key = pack_key(rng.below(8) as u32, rng.below(1 << 40));
            let (n, s) = ps.route(key);
            assert_eq!((n, s), ps.route(key));
            assert!(n < 4 && s < 2);
        }
    }

    #[test]
    fn get_put_roundtrip_through_routing() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut before = vec![0.0; 4];
        ps.get(3, 12345, &mut before);
        ps.put_grad(3, 12345, &[1.0; 4]);
        let mut after = vec![0.0; 4];
        ps.get(3, 12345, &mut after);
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6);
        }
    }

    #[test]
    fn get_many_matches_singles() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let keys: Vec<(u32, u64)> = (0..10).map(|i| (i % 3, i as u64 * 17)).collect();
        let mut batch = vec![0.0; 40];
        ps.get_many(&keys, &mut batch);
        for (i, &(g, id)) in keys.iter().enumerate() {
            let mut single = vec![0.0; 4];
            ps.get(g, id, &mut single);
            assert_eq!(&batch[i * 4..(i + 1) * 4], single.as_slice());
        }
    }

    #[test]
    fn shuffled_uniform_balances_skewed_traffic() {
        // Zipf traffic on one feature group: FeatureGroup placement sends
        // everything to one node; ShuffledUniform spreads it.
        let dim = 4;
        let zipf = Zipf::new(100_000, 1.05);
        for (policy, expect_balanced) in [
            (PartitionPolicy::FeatureGroup, false),
            (PartitionPolicy::ShuffledUniform, true),
        ] {
            let ps = EmbeddingPs::new(&cfg(policy), dim, 1);
            let mut rng = Rng::new(3);
            let mut buf = vec![0.0; dim];
            for _ in 0..4000 {
                ps.get(0, zipf.sample(&mut rng), &mut buf);
            }
            let imb = ps.imbalance();
            if expect_balanced {
                assert!(imb < 1.3, "{policy:?} imbalance={imb}");
            } else {
                assert!(imb > 3.0, "{policy:?} imbalance={imb}");
            }
        }
    }

    #[test]
    fn node_snapshot_restore() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let keys: Vec<(u32, u64)> = (0..50).map(|i| (0, i as u64)).collect();
        let mut buf = vec![0.0; 200];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![1.0; 200]);
        let mut want = vec![0.0; 200];
        ps.get_many(&keys, &mut want);

        let snaps: Vec<_> = (0..4).map(|n| ps.snapshot_node(n).unwrap()).collect();
        for n in 0..4 {
            ps.wipe_node(n).unwrap();
        }
        assert_eq!(ps.total_rows(), 0);
        for (n, snap) in snaps.iter().enumerate() {
            ps.restore_node(n, snap).unwrap();
        }
        let mut got = vec![0.0; 200];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn range_ps_matches_full_ps_on_owned_keys() {
        // Split the 4 nodes across three "processes" (0..2, 2..3, 3..4):
        // every key must route identically everywhere, materialize the same
        // row as the full PS, and apply gradients to the same effect.
        let c = cfg(PartitionPolicy::ShuffledUniform);
        let full = EmbeddingPs::new(&c, 4, 1);
        let parts = [
            EmbeddingPs::new_range(&c, 4, 1, 0..2),
            EmbeddingPs::new_range(&c, 4, 1, 2..3),
            EmbeddingPs::new_range(&c, 4, 1, 3..4),
        ];
        assert_eq!(parts[0].node_range(), 0..2);
        assert_eq!(parts[1].n_nodes(), 4);

        let mut rng = Rng::new(9);
        for _ in 0..500 {
            let (g, id) = (rng.below(8) as u32, rng.below(1 << 40));
            let key = pack_key(g, id);
            let (node, shard) = full.route(key);
            let owner = parts.iter().find(|p| p.owns_key(key)).expect("uncovered key");
            assert_eq!(owner.route(key), (node, shard), "route disagrees");
            assert_eq!(
                route(c.partition, c.n_nodes, c.shards_per_node, key),
                (node, shard),
                "free route() disagrees with method"
            );
            let mut a = vec![0.0; 4];
            let mut b = vec![0.0; 4];
            full.get(g, id, &mut a);
            owner.get(g, id, &mut b);
            assert_eq!(a, b, "materialization differs for ({g},{id})");
            full.put_grad(g, id, &[1.0; 4]);
            owner.put_grad(g, id, &[1.0; 4]);
            full.get(g, id, &mut a);
            owner.get(g, id, &mut b);
            assert_eq!(a, b, "post-gradient rows differ for ({g},{id})");
        }
        // Summed partial row counts equal the full PS's.
        let part_rows: usize = parts.iter().map(|p| p.total_rows()).sum();
        assert_eq!(part_rows, full.total_rows());
        // Traffic vectors are global-length, zero outside the owned range,
        // and sum to the full PS's vector.
        let mut summed = vec![0u64; 4];
        for p in &parts {
            let t = p.node_traffic();
            assert_eq!(t.len(), 4);
            for n in 0..4 {
                if !p.node_range().contains(&n) {
                    assert_eq!(t[n], 0, "unowned node {n} reported traffic");
                }
                summed[n] += t[n];
            }
        }
        assert_eq!(summed, full.node_traffic());
    }

    #[test]
    fn packed_entry_points_match_unpacked_and_reject_unowned() {
        let c = cfg(PartitionPolicy::ShuffledUniform);
        let full = EmbeddingPs::new(&c, 4, 1);
        let part = EmbeddingPs::new_range(&c, 4, 1, 0..1);
        let keys: Vec<(u32, u64)> = (0..40).map(|i| (i % 3, i as u64 * 31)).collect();
        let packed: Vec<u64> = keys.iter().map(|&(g, id)| pack_key(g, id)).collect();

        let mut via_packed = vec![0.0; 160];
        full.get_packed_into(&packed, &mut via_packed).unwrap();
        let mut via_pairs = vec![0.0; 160];
        full.get_many(&keys, &mut via_pairs);
        assert_eq!(via_packed, via_pairs);
        full.put_grads_packed(&packed, &vec![1.0; 160]).unwrap();

        // A batch containing any unowned key is rejected whole, before any
        // row materializes or any gradient lands.
        let pool: Vec<u64> = (0..200).map(|i| pack_key(0, i * 7)).collect();
        let owned: Vec<u64> = pool.iter().copied().filter(|&k| part.owns_key(k)).take(8).collect();
        let stray = pool.iter().copied().find(|&k| !part.owns_key(k)).unwrap();
        let mixed: Vec<u64> = owned.iter().copied().chain([stray]).collect();
        assert!(mixed.len() > 1, "need both owned and unowned keys");
        let rows_before = part.total_rows();
        let mut buf = vec![0.0; mixed.len() * 4];
        assert!(part.get_packed_into(&mixed, &mut buf).is_err());
        assert!(part.put_grads_packed(&mixed, &vec![1.0; mixed.len() * 4]).is_err());
        assert_eq!(part.total_rows(), rows_before, "rejected batch touched state");
    }

    #[test]
    fn range_ps_snapshot_uses_global_node_indices() {
        let c = cfg(PartitionPolicy::ShuffledUniform);
        let full = EmbeddingPs::new(&c, 4, 1);
        let part = EmbeddingPs::new_range(&c, 4, 1, 2..4);
        let mut buf = vec![0.0; 4];
        for id in 0..200u64 {
            let key = pack_key(0, id);
            if part.owns_key(key) {
                full.get(0, id, &mut buf);
                part.get(0, id, &mut buf);
            }
        }
        // Node 3 snapshots must agree between the full PS and the part.
        assert_eq!(part.snapshot_node(3).unwrap(), full.snapshot_node(3).unwrap());
        // Restore through the global index roundtrips.
        let snap = part.snapshot_node(2).unwrap();
        part.wipe_node(2).unwrap();
        part.restore_node(2, &snap).unwrap();
        assert_eq!(part.snapshot_node(2).unwrap(), snap);
        // Unowned nodes are a loud error, not silent corruption — on every
        // node-indexed entry point (snapshot/wipe used to panic here).
        assert!(part.restore_node(0, &snap).is_err());
        assert!(part.snapshot_node(0).is_err());
        assert!(part.snapshot_node_cold(0).is_err());
        assert!(part.wipe_node(0).is_err());
        // All-hot shards report no cold tier.
        assert!(!part.has_cold_tier());
        assert_eq!(part.snapshot_node_cold(2).unwrap(), None);
        assert_eq!(part.cold_rows(), 0);
    }

    #[test]
    fn tiered_ps_roundtrips_and_counts_both_tiers() {
        let c = cfg(PartitionPolicy::ShuffledUniform);
        let dir =
            std::env::temp_dir().join(format!("persia_ps_tiered_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = StoreConfig::Tiered {
            hot_capacity: 8,
            cold_dir: dir.clone(),
            admit_threshold: 1,
        };
        let ps = EmbeddingPs::new_with_store(&c, 4, 1, &store).unwrap();
        assert!(ps.has_cold_tier());
        let keys: Vec<(u32, u64)> = (0..400).map(|i| (0, i as u64)).collect();
        let mut buf = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![1.0; keys.len() * 4]);
        // 400 keys over 8 shards of hot capacity 8: far past the hot budget,
        // yet nothing is lost.
        assert_eq!(ps.total_rows(), 400, "tiered PS dropped rows");
        assert!(ps.cold_rows() > 0);
        let tc = ps.tier_counters();
        assert!(tc.demotions > 0);
        assert_eq!(tc.demotions, tc.evictions);
        let mut want = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut want);
        // Full-tier snapshot/restore roundtrip on every node.
        let snaps: Vec<_> = (0..4).map(|n| ps.snapshot_node_full(n).unwrap()).collect();
        for (n, s) in snaps.iter().enumerate() {
            assert!(s.cold.is_some());
            ps.wipe_node(n).unwrap();
            ps.restore_node_full(n, s).unwrap();
        }
        let mut got = vec![0.0; keys.len() * 4];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
        // Tier-shape mismatch is a loud error on restore.
        let all_hot = EmbeddingPs::new(&c, 4, 1);
        assert!(all_hot.restore_node_full(0, &snaps[0]).is_err());
        let hot_snap = all_hot.snapshot_node_full(0).unwrap();
        assert!(ps.restore_node_full(0, &hot_snap).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn virtual_capacity_bounded_by_lru() {
        // Touch far more distinct ids than physical capacity; materialized
        // rows stay bounded (the 100T substitution mechanism).
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut rng = Rng::new(4);
        let mut buf = vec![0.0; 4];
        for _ in 0..20_000 {
            ps.get(0, rng.below(1 << 40), &mut buf);
        }
        let max_physical = 4 * 2 * 512;
        assert!(ps.total_rows() <= max_physical);
        assert!(ps.total_evictions() > 0);
    }
}
