//! The sharded embedding parameter server (paper Fig. 4/5).
//!
//! Keys are `(feature group, id)` pairs packed into a u64. An embedding
//! worker "first runs an identical global hashing function to locate the
//! embedding PS node that stores the parameters" (§4.2.2); within a node the
//! key selects a lock-striped shard.
//!
//! Two placement policies (§4.2.3 "Workload balance of embedding PS"):
//! * `FeatureGroup` — nodes own whole semantic groups; congests when traffic
//!   leans toward one group (the ablation baseline);
//! * `ShuffledUniform` — ids hashed uniformly over all nodes (Persia's fix).

use crate::config::{EmbeddingConfig, PartitionPolicy};

use super::optimizer::RowOptimizer;
use super::shard::Shard;

#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Pack (group, id) into the PS key space. Ids up to 2^48 (281T rows/group).
#[inline]
pub fn pack_key(group: u32, id: u64) -> u64 {
    debug_assert!(id < (1u64 << 48), "id {id} exceeds 48-bit key space");
    ((group as u64) << 48) | id
}

/// Unpack a PS key.
#[inline]
pub fn unpack_key(key: u64) -> (u32, u64) {
    ((key >> 48) as u32, key & 0x0000_ffff_ffff_ffff)
}

/// The embedding PS: `n_nodes x shards_per_node` locked shards.
pub struct EmbeddingPs {
    nodes: Vec<Vec<Shard>>,
    policy: PartitionPolicy,
    dim: usize,
}

impl EmbeddingPs {
    pub fn new(cfg: &EmbeddingConfig, dim: usize, seed: u64) -> Self {
        let opt = RowOptimizer::new(cfg.optimizer, cfg.lr, dim);
        let nodes = (0..cfg.n_nodes)
            .map(|n| {
                (0..cfg.shards_per_node)
                    .map(|s| Shard::new(cfg.shard_capacity, opt, seed ^ ((n as u64) << 32) ^ s as u64))
                    .collect()
            })
            .collect();
        Self { nodes, policy: cfg.partition, dim }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn shards_per_node(&self) -> usize {
        self.nodes[0].len()
    }

    /// The global hash placement: key -> (node, shard).
    #[inline]
    pub fn route(&self, key: u64) -> (usize, usize) {
        let (group, id) = unpack_key(key);
        let n_nodes = self.nodes.len();
        let n_shards = self.nodes[0].len();
        match self.policy {
            PartitionPolicy::ShuffledUniform => {
                let h = splitmix64(key);
                ((h % n_nodes as u64) as usize, ((h >> 32) % n_shards as u64) as usize)
            }
            PartitionPolicy::FeatureGroup => {
                let node = group as usize % n_nodes;
                let h = splitmix64(id);
                (node, (h % n_shards as u64) as usize)
            }
        }
    }

    #[inline]
    fn shard(&self, key: u64) -> &Shard {
        let (n, s) = self.route(key);
        &self.nodes[n][s]
    }

    /// Fetch one embedding row into `out`.
    pub fn get(&self, group: u32, id: u64, out: &mut [f32]) {
        self.shard(pack_key(group, id)).get(pack_key(group, id), out);
    }

    /// Batched lookup: rows for `keys`, flattened `[len, dim]` into `out`.
    pub fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) {
        assert_eq!(out.len(), keys.len() * self.dim);
        for (i, &(g, id)) in keys.iter().enumerate() {
            let key = pack_key(g, id);
            self.shard(key).get(key, &mut out[i * self.dim..(i + 1) * self.dim]);
        }
    }

    /// Apply one gradient row.
    pub fn put_grad(&self, group: u32, id: u64, grad: &[f32]) {
        let key = pack_key(group, id);
        self.shard(key).put_grad(key, grad);
    }

    /// Batched gradient put, rows flattened like [`Self::get_many`].
    pub fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) {
        assert_eq!(grads.len(), keys.len() * self.dim);
        for (i, &(g, id)) in keys.iter().enumerate() {
            self.put_grad(g, id, &grads[i * self.dim..(i + 1) * self.dim]);
        }
    }

    /// Materialized rows in total.
    pub fn total_rows(&self) -> usize {
        self.nodes.iter().flatten().map(|s| s.len()).sum()
    }

    pub fn total_evictions(&self) -> u64 {
        self.nodes.iter().flatten().map(|s| s.evictions()).sum()
    }

    /// Per-node traffic (gets+puts) — the load-balance ablation metric.
    pub fn node_traffic(&self) -> Vec<u64> {
        self.nodes
            .iter()
            .map(|shards| shards.iter().map(|s| {
                let (g, p) = s.traffic();
                g + p
            }).sum())
            .collect()
    }

    /// Max/mean traffic imbalance across nodes (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let t = self.node_traffic();
        let max = *t.iter().max().unwrap_or(&0) as f64;
        let mean = t.iter().sum::<u64>() as f64 / t.len().max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Snapshot one node (all its shards) — periodic checkpointing (§4.2.4).
    pub fn snapshot_node(&self, node: usize) -> Vec<Vec<u8>> {
        self.nodes[node].iter().map(|s| s.snapshot()).collect()
    }

    /// Restore one node from a snapshot.
    pub fn restore_node(&self, node: usize, shards: &[Vec<u8>]) -> anyhow::Result<()> {
        anyhow::ensure!(shards.len() == self.nodes[node].len(), "shard count mismatch");
        for (shard, bytes) in self.nodes[node].iter().zip(shards) {
            shard.restore(bytes)?;
        }
        Ok(())
    }

    /// Simulate a node crash that loses in-memory state (used by fault tests
    /// to contrast with the shared-memory + checkpoint recovery path).
    pub fn wipe_node(&self, node: usize) {
        for s in &self.nodes[node] {
            s.wipe();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EmbeddingConfig, OptimizerKind};
    use crate::util::quickcheck::forall;
    use crate::util::{Rng, Zipf};

    fn cfg(policy: PartitionPolicy) -> EmbeddingConfig {
        EmbeddingConfig {
            rows_per_group: 1 << 40,
            shard_capacity: 512,
            n_nodes: 4,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: policy,
            lr: 0.5,
        }
    }

    #[test]
    fn key_packing_roundtrip() {
        forall(
            61,
            500,
            |rng: &mut Rng| (rng.below(256), rng.below(1 << 48)),
            |&(g, id)| unpack_key(pack_key(g as u32, id)) == (g as u32, id),
        );
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            let key = pack_key(rng.below(8) as u32, rng.below(1 << 40));
            let (n, s) = ps.route(key);
            assert_eq!((n, s), ps.route(key));
            assert!(n < 4 && s < 2);
        }
    }

    #[test]
    fn get_put_roundtrip_through_routing() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut before = vec![0.0; 4];
        ps.get(3, 12345, &mut before);
        ps.put_grad(3, 12345, &[1.0; 4]);
        let mut after = vec![0.0; 4];
        ps.get(3, 12345, &mut after);
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6);
        }
    }

    #[test]
    fn get_many_matches_singles() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let keys: Vec<(u32, u64)> = (0..10).map(|i| (i % 3, i as u64 * 17)).collect();
        let mut batch = vec![0.0; 40];
        ps.get_many(&keys, &mut batch);
        for (i, &(g, id)) in keys.iter().enumerate() {
            let mut single = vec![0.0; 4];
            ps.get(g, id, &mut single);
            assert_eq!(&batch[i * 4..(i + 1) * 4], single.as_slice());
        }
    }

    #[test]
    fn shuffled_uniform_balances_skewed_traffic() {
        // Zipf traffic on one feature group: FeatureGroup placement sends
        // everything to one node; ShuffledUniform spreads it.
        let dim = 4;
        let zipf = Zipf::new(100_000, 1.05);
        for (policy, expect_balanced) in [
            (PartitionPolicy::FeatureGroup, false),
            (PartitionPolicy::ShuffledUniform, true),
        ] {
            let ps = EmbeddingPs::new(&cfg(policy), dim, 1);
            let mut rng = Rng::new(3);
            let mut buf = vec![0.0; dim];
            for _ in 0..4000 {
                ps.get(0, zipf.sample(&mut rng), &mut buf);
            }
            let imb = ps.imbalance();
            if expect_balanced {
                assert!(imb < 1.3, "{policy:?} imbalance={imb}");
            } else {
                assert!(imb > 3.0, "{policy:?} imbalance={imb}");
            }
        }
    }

    #[test]
    fn node_snapshot_restore() {
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let keys: Vec<(u32, u64)> = (0..50).map(|i| (0, i as u64)).collect();
        let mut buf = vec![0.0; 200];
        ps.get_many(&keys, &mut buf);
        ps.put_grads(&keys, &vec![1.0; 200]);
        let mut want = vec![0.0; 200];
        ps.get_many(&keys, &mut want);

        let snaps: Vec<_> = (0..4).map(|n| ps.snapshot_node(n)).collect();
        for n in 0..4 {
            ps.wipe_node(n);
        }
        assert_eq!(ps.total_rows(), 0);
        for (n, snap) in snaps.iter().enumerate() {
            ps.restore_node(n, snap).unwrap();
        }
        let mut got = vec![0.0; 200];
        ps.get_many(&keys, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn virtual_capacity_bounded_by_lru() {
        // Touch far more distinct ids than physical capacity; materialized
        // rows stay bounded (the 100T substitution mechanism).
        let ps = EmbeddingPs::new(&cfg(PartitionPolicy::ShuffledUniform), 4, 1);
        let mut rng = Rng::new(4);
        let mut buf = vec![0.0; 4];
        for _ in 0..20_000 {
            ps.get(0, rng.below(1 << 40), &mut buf);
        }
        let max_physical = 4 * 2 * 512;
        assert!(ps.total_rows() <= max_physical);
        assert!(ps.total_evictions() > 0);
    }
}
