//! The embedding parameter server (paper §4.2.2) and its storage substrate.
//!
//! * [`store`] — the pluggable storage-engine seam: the [`EmbeddingStore`]
//!   trait every shard talks to, plus [`StoreConfig`] for selecting an
//!   engine at construction time.
//! * [`lru`] — the array-list LRU cache: hash-map + index-linked array,
//!   entries hold the embedding vector ⊕ optimizer state, serialization is a
//!   flat memory copy. The all-hot engine, and the hot tier of the tiered
//!   one.
//! * [`cold`] — the disk-backed cold tier: one slotted, CRC-framed file per
//!   shard, pread/pwrite, no new deps.
//! * [`tiered`] — hot LRU over cold store with Zipf-gated admission:
//!   eviction demotes exact row bytes, cold hits promote back.
//! * [`optimizer`] — row-wise SGD / Adagrad / Adam (Alg. 1's Ω^emb).
//! * [`shard`] — one locked store per shard (the paper's thread-per-sub-map).
//! * [`ps`] — the sharded PS: global hash placement, feature-group vs
//!   shuffled-uniform partitioning, get/put API, checkpointing.

pub mod checkpoint;
pub mod cold;
pub mod lru;
pub mod optimizer;
pub mod ps;
pub mod shard;
pub mod store;
pub mod tiered;

pub use checkpoint::CheckpointManager;
pub use cold::ColdStore;
pub use lru::LruStore;
pub use optimizer::RowOptimizer;
pub use ps::EmbeddingPs;
pub use shard::Shard;
pub use store::{EmbeddingStore, NodeSnapshot, StoreConfig, StoreCounters};
pub use tiered::TieredStore;
