//! The embedding parameter server (paper §4.2.2) and its storage substrate.
//!
//! * [`lru`] — the array-list LRU cache: hash-map + index-linked array,
//!   entries hold the embedding vector ⊕ optimizer state, serialization is a
//!   flat memory copy.
//! * [`optimizer`] — row-wise SGD / Adagrad / Adam (Alg. 1's Ω^emb).
//! * [`shard`] — one locked LRU per shard (the paper's thread-per-sub-map).
//! * [`ps`] — the sharded PS: global hash placement, feature-group vs
//!   shuffled-uniform partitioning, get/put API, checkpointing.

pub mod checkpoint;
pub mod lru;
pub mod optimizer;
pub mod ps;
pub mod shard;

pub use checkpoint::CheckpointManager;
pub use lru::LruStore;
pub use optimizer::RowOptimizer;
pub use ps::EmbeddingPs;
pub use shard::Shard;
