//! Disk-backed cold tier: one slotted, CRC-framed file per PS shard.
//!
//! This is the capacity floor under the hot LRU (ScaleFreeCTR's MixCache
//! design): rows evicted from RAM are *demoted* here with their exact bytes
//! (embedding vector ⊕ optimizer state) instead of being dropped, so the
//! table can grow far past the hot budget without changing any numerics.
//!
//! ## File format
//!
//! ```text
//! header  (24 B): magic "PCLD0001" | row_width u64 | reserved u64
//! slot    (16 B + 4·row_width B), repeated:
//!         key u64 | occupied u32 | crc u32 | row f32 × row_width
//! ```
//!
//! The CRC covers `key bytes ‖ row bytes`, so a torn write, a bit flip, or
//! a slot read against the wrong key is detected on every read — a row with
//! a bad CRC is **never surfaced**; it is treated as absent (the caller
//! re-materializes it deterministically, degrading exactly like a pre-tier
//! eviction would have). The file is plain pread/pwrite I/O with no mmap
//! and no new dependencies; per-write fsync is deliberately omitted because
//! durability comes from the checkpoint epoch files (written through
//! `recovery::atomic_write` under the two-phase PREPARE/COMMIT protocol),
//! not from the live working file.
//!
//! An in-memory index (key → slot) is rebuilt by scanning the file on
//! [`ColdStore::open`]; corrupt or free slots land on the free list and are
//! reused by later writes. A trailing partial slot (torn final append) is
//! ignored and overwritten by the next append.

use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::hash::BuildHasherDefault;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use super::checkpoint::crc32;
use super::lru::IdHasher;

const MAGIC: &[u8; 8] = b"PCLD0001";
const HEADER_LEN: u64 = 24;
/// Snapshot-blob magic ([`ColdStore::snapshot_bytes`]), distinct from the
/// live-file magic so the two can never be confused.
const SNAP_MAGIC: &[u8; 8] = b"PCSN0001";
/// Sanity ceiling on row widths accepted from disk (a corrupt header must
/// not drive a multi-gigabyte allocation).
const MAX_ROW_WIDTH: u64 = 1 << 20;

type SlotIndex = HashMap<u64, u64, BuildHasherDefault<IdHasher>>;

/// Disk-backed row store for one shard's cold tier.
pub struct ColdStore {
    file: File,
    path: PathBuf,
    row_width: usize,
    /// key → slot number (slot 0 starts right after the header).
    index: SlotIndex,
    free: Vec<u64>,
    n_slots: u64,
}

impl ColdStore {
    fn slot_size(row_width: usize) -> u64 {
        16 + 4 * row_width as u64
    }

    fn slot_offset(&self, slot: u64) -> u64 {
        HEADER_LEN + slot * Self::slot_size(self.row_width)
    }

    fn read_at(&self, buf: &mut [u8], off: u64) -> std::io::Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(buf, off)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut f = &self.file;
            f.seek(SeekFrom::Start(off))?;
            f.read_exact(buf)
        }
    }

    fn write_at(&self, buf: &[u8], off: u64) -> std::io::Result<()> {
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.write_all_at(buf, off)
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let mut f = &self.file;
            f.seek(SeekFrom::Start(off))?;
            f.write_all(buf)
        }
    }

    /// Open (or create) the cold file at `path` for `row_width`-float rows,
    /// rebuilding the key index by scanning every slot. Corruption is
    /// contained, never fatal to valid data: a slot with a bad CRC is
    /// reclaimed as free space, and a trailing partial slot is ignored. A
    /// file whose *header* is wrong (different magic or row width) is an
    /// error — that is a misconfiguration, not bit rot.
    pub fn open(path: &Path, row_width: usize) -> Result<Self> {
        assert!(row_width > 0);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating cold dir {}", parent.display()))?;
            }
        }
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .with_context(|| format!("opening cold store {}", path.display()))?;
        let mut store = Self {
            file,
            path: path.to_path_buf(),
            row_width,
            index: SlotIndex::default(),
            free: Vec::new(),
            n_slots: 0,
        };
        let len = store.file.metadata()?.len();
        if len == 0 {
            let mut header = [0u8; HEADER_LEN as usize];
            header[..8].copy_from_slice(MAGIC);
            header[8..16].copy_from_slice(&(row_width as u64).to_le_bytes());
            store.write_at(&header, 0)?;
            return Ok(store);
        }
        ensure!(
            len >= HEADER_LEN,
            "cold store {} too short for a header ({len} bytes)",
            path.display()
        );
        let mut header = [0u8; HEADER_LEN as usize];
        store.read_at(&mut header, 0)?;
        ensure!(&header[..8] == MAGIC, "cold store {} has bad magic", path.display());
        let file_w = u64::from_le_bytes(header[8..16].try_into().expect("8 bytes"));
        ensure!(
            file_w == row_width as u64,
            "cold store {} row width {file_w} != configured {row_width}",
            path.display()
        );
        let slot_size = Self::slot_size(row_width);
        store.n_slots = (len - HEADER_LEN) / slot_size; // trailing partial slot ignored
        let mut buf = vec![0u8; slot_size as usize];
        for slot in 0..store.n_slots {
            store.read_at(&mut buf, store.slot_offset(slot))?;
            let key = u64::from_le_bytes(buf[..8].try_into().expect("8 bytes"));
            let occupied = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
            let crc = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes"));
            let good = occupied == 1
                && crc == Self::slot_crc(key, &buf[16..])
                && !store.index.contains_key(&key);
            if good {
                store.index.insert(key, slot);
            } else {
                store.free.push(slot);
            }
        }
        Ok(store)
    }

    fn slot_crc(key: u64, row_bytes: &[u8]) -> u32 {
        let mut framed = Vec::with_capacity(8 + row_bytes.len());
        framed.extend_from_slice(&key.to_le_bytes());
        framed.extend_from_slice(row_bytes);
        crc32(&framed)
    }

    /// Rows currently resident (with valid CRCs as of their last access).
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True when no rows are resident.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Floats per row.
    pub fn row_width(&self) -> usize {
        self.row_width
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Whether `key` is indexed (its CRC is only re-verified on read).
    pub fn contains(&self, key: u64) -> bool {
        self.index.contains_key(&key)
    }

    /// Read `key`'s row into `out`. Returns `Ok(false)` if absent — or if
    /// the slot's CRC no longer matches (the row is dropped from the index
    /// and never surfaced; bit rot degrades to a re-materialization, not a
    /// wrong answer).
    pub fn get_into(&mut self, key: u64, out: &mut [f32]) -> Result<bool> {
        ensure!(out.len() == self.row_width, "output width {} != {}", out.len(), self.row_width);
        let Some(&slot) = self.index.get(&key) else {
            return Ok(false);
        };
        let mut buf = vec![0u8; Self::slot_size(self.row_width) as usize];
        self.read_at(&mut buf, self.slot_offset(slot))
            .with_context(|| format!("reading cold slot {slot} of {}", self.path.display()))?;
        let disk_key = u64::from_le_bytes(buf[..8].try_into().expect("8 bytes"));
        let occupied = u32::from_le_bytes(buf[8..12].try_into().expect("4 bytes"));
        let crc = u32::from_le_bytes(buf[12..16].try_into().expect("4 bytes"));
        if occupied != 1 || disk_key != key || crc != Self::slot_crc(key, &buf[16..]) {
            self.index.remove(&key);
            self.free.push(slot);
            return Ok(false);
        }
        for (i, chunk) in buf[16..].chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(chunk.try_into().expect("4 bytes"));
        }
        Ok(true)
    }

    /// Write `key`'s row (insert or overwrite), reusing its slot, then a
    /// free slot, then appending.
    pub fn put(&mut self, key: u64, row: &[f32]) -> Result<()> {
        ensure!(row.len() == self.row_width, "row width {} != {}", row.len(), self.row_width);
        let slot = match self.index.get(&key) {
            Some(&s) => s,
            None => {
                let s = self.free.pop().unwrap_or_else(|| {
                    let s = self.n_slots;
                    self.n_slots += 1;
                    s
                });
                self.index.insert(key, s);
                s
            }
        };
        let mut buf = Vec::with_capacity(Self::slot_size(self.row_width) as usize);
        buf.extend_from_slice(&key.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        let mut row_bytes = Vec::with_capacity(4 * row.len());
        for &v in row {
            row_bytes.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&Self::slot_crc(key, &row_bytes).to_le_bytes());
        buf.extend_from_slice(&row_bytes);
        self.write_at(&buf, self.slot_offset(slot))
            .with_context(|| format!("writing cold slot {slot} of {}", self.path.display()))?;
        Ok(())
    }

    /// Remove `key`, freeing its slot. Returns true if it was present.
    pub fn remove(&mut self, key: u64) -> Result<bool> {
        let Some(slot) = self.index.remove(&key) else {
            return Ok(false);
        };
        // Zeroing the 16-byte slot header (key, occupied, crc) is enough:
        // occupied=0 makes the open() scan skip it.
        self.write_at(&[0u8; 16], self.slot_offset(slot))?;
        self.free.push(slot);
        Ok(true)
    }

    /// Resident keys in ascending order (snapshots must be deterministic:
    /// equal contents ⇒ equal bytes, whatever the slot placement history).
    pub fn keys_sorted(&self) -> Vec<u64> {
        let mut keys: Vec<u64> = self.index.keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Serialize all resident rows, sorted by key. Rows whose CRC fails
    /// during the sweep are dropped (not surfaced), same as `get_into`.
    pub fn snapshot_bytes(&mut self) -> Result<Vec<u8>> {
        let keys = self.keys_sorted();
        let mut rows = Vec::with_capacity(keys.len());
        let mut row = vec![0.0f32; self.row_width];
        for key in keys {
            if self.get_into(key, &mut row)? {
                rows.push((key, row.clone()));
            }
        }
        let mut out = Vec::with_capacity(24 + rows.len() * (8 + 4 * self.row_width));
        out.extend_from_slice(SNAP_MAGIC);
        out.extend_from_slice(&(self.row_width as u64).to_le_bytes());
        out.extend_from_slice(&(rows.len() as u64).to_le_bytes());
        for (key, row) in rows {
            out.extend_from_slice(&key.to_le_bytes());
            for v in row {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// Decode a [`Self::snapshot_bytes`] blob into (row_width, rows).
    /// Validates shape exactly; corrupt input is `Err`, never a panic.
    pub fn decode_snapshot(bytes: &[u8]) -> Result<(usize, Vec<(u64, Vec<f32>)>)> {
        ensure!(bytes.len() >= 24 && &bytes[..8] == SNAP_MAGIC, "bad cold snapshot header");
        let row_width_raw = u64::from_le_bytes(bytes[8..16].try_into().expect("8 bytes"));
        let count = u64::from_le_bytes(bytes[16..24].try_into().expect("8 bytes"));
        ensure!(
            row_width_raw > 0 && row_width_raw <= MAX_ROW_WIDTH,
            "cold snapshot row width {row_width_raw} out of range"
        );
        let row_width = row_width_raw as usize;
        let entry = 8 + 4 * row_width;
        let body = (bytes.len() - 24) as u64;
        ensure!(
            count.checked_mul(entry as u64) == Some(body),
            "cold snapshot size mismatch: {count} rows of {entry} bytes vs {body} body bytes"
        );
        let mut rows = Vec::with_capacity(count as usize);
        let mut prev: Option<u64> = None;
        for chunk in bytes[24..].chunks_exact(entry) {
            let key = u64::from_le_bytes(chunk[..8].try_into().expect("8 bytes"));
            ensure!(prev.map_or(true, |p| p < key), "cold snapshot keys not strictly ascending");
            prev = Some(key);
            let row: Vec<f32> = chunk[8..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
                .collect();
            rows.push((key, row));
        }
        Ok((row_width, rows))
    }

    /// Replace the store's contents from a [`Self::snapshot_bytes`] blob,
    /// rewriting the live file.
    pub fn restore_bytes(&mut self, bytes: &[u8]) -> Result<()> {
        let (row_width, rows) = Self::decode_snapshot(bytes)?;
        ensure!(
            row_width == self.row_width,
            "cold snapshot row width {row_width} != store row width {}",
            self.row_width
        );
        self.wipe()?;
        for (key, row) in rows {
            self.put(key, &row)?;
        }
        Ok(())
    }

    /// Drop every row and truncate the live file back to its header.
    pub fn wipe(&mut self) -> Result<()> {
        self.file.set_len(HEADER_LEN)?;
        self.index.clear();
        self.free.clear();
        self.n_slots = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_file(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("persia_cold_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d.join("shard.bin")
    }

    #[test]
    fn put_get_remove_roundtrip() {
        let path = tmp_file("roundtrip");
        let mut cs = ColdStore::open(&path, 3).unwrap();
        assert!(cs.is_empty());
        cs.put(7, &[1.0, 2.0, 3.0]).unwrap();
        cs.put(9, &[4.0, 5.0, 6.0]).unwrap();
        cs.put(7, &[7.0, 8.0, 9.0]).unwrap(); // overwrite reuses the slot
        assert_eq!(cs.len(), 2);
        let mut row = [0.0f32; 3];
        assert!(cs.get_into(7, &mut row).unwrap());
        assert_eq!(row, [7.0, 8.0, 9.0]);
        assert!(cs.get_into(9, &mut row).unwrap());
        assert_eq!(row, [4.0, 5.0, 6.0]);
        assert!(!cs.get_into(8, &mut row).unwrap());
        assert!(cs.remove(7).unwrap());
        assert!(!cs.remove(7).unwrap());
        assert!(!cs.get_into(7, &mut row).unwrap());
        // Freed slot is reused: file does not grow.
        let len_before = std::fs::metadata(&path).unwrap().len();
        cs.put(11, &[0.5; 3]).unwrap();
        assert_eq!(std::fs::metadata(&path).unwrap().len(), len_before);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn reopen_rebuilds_index_from_disk() {
        let path = tmp_file("reopen");
        {
            let mut cs = ColdStore::open(&path, 2).unwrap();
            for k in 0..10u64 {
                cs.put(k, &[k as f32, -(k as f32)]).unwrap();
            }
            cs.remove(4).unwrap();
        }
        let mut cs = ColdStore::open(&path, 2).unwrap();
        assert_eq!(cs.len(), 9);
        let mut row = [0.0f32; 2];
        for k in (0..10u64).filter(|&k| k != 4) {
            assert!(cs.get_into(k, &mut row).unwrap(), "key {k} lost across reopen");
            assert_eq!(row, [k as f32, -(k as f32)]);
        }
        assert!(!cs.get_into(4, &mut row).unwrap());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn reopen_with_wrong_row_width_errors() {
        let path = tmp_file("width");
        drop(ColdStore::open(&path, 2).unwrap());
        assert!(ColdStore::open(&path, 3).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn corrupted_slot_is_never_surfaced() {
        let path = tmp_file("corrupt");
        let mut cs = ColdStore::open(&path, 2).unwrap();
        cs.put(1, &[1.0, 1.0]).unwrap();
        cs.put(2, &[2.0, 2.0]).unwrap();
        // Flip one byte inside key 1's row region on disk.
        let mut raw = std::fs::read(&path).unwrap();
        let slot = 24 + 16; // header + slot 0 header → first row byte
        raw[slot] ^= 0xff;
        std::fs::write(&path, &raw).unwrap();
        let mut cs = ColdStore::open(&path, 2).unwrap();
        assert_eq!(cs.len(), 1, "corrupt slot must be reclaimed, not surfaced");
        let mut row = [0.0f32; 2];
        assert!(!cs.get_into(1, &mut row).unwrap());
        assert!(cs.get_into(2, &mut row).unwrap());
        assert_eq!(row, [2.0, 2.0]);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn trailing_partial_slot_is_ignored() {
        let path = tmp_file("partial");
        {
            let mut cs = ColdStore::open(&path, 2).unwrap();
            cs.put(1, &[1.0, 1.0]).unwrap();
        }
        // Simulate a torn append: half a slot of garbage at the tail.
        use std::io::Write;
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0xabu8; 10]).unwrap();
        drop(f);
        let mut cs = ColdStore::open(&path, 2).unwrap();
        assert_eq!(cs.len(), 1);
        // The next append overwrites the torn region cleanly.
        cs.put(2, &[2.0, 2.0]).unwrap();
        let mut row = [0.0f32; 2];
        assert!(cs.get_into(2, &mut row).unwrap());
        assert_eq!(row, [2.0, 2.0]);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn snapshot_restore_roundtrip_and_determinism() {
        let path = tmp_file("snap");
        let mut cs = ColdStore::open(&path, 2).unwrap();
        for k in [9u64, 3, 7, 1] {
            cs.put(k, &[k as f32, 0.25]).unwrap();
        }
        let snap = cs.snapshot_bytes().unwrap();
        // Same logical contents with different placement history ⇒ same bytes.
        let path2 = tmp_file("snap2");
        let mut cs2 = ColdStore::open(&path2, 2).unwrap();
        for k in [1u64, 7, 3, 9, 100] {
            cs2.put(k, &[k as f32, 0.25]).unwrap();
        }
        cs2.remove(100).unwrap();
        assert_eq!(cs2.snapshot_bytes().unwrap(), snap);
        // Restore into a wiped store.
        cs2.wipe().unwrap();
        assert!(cs2.is_empty());
        cs2.restore_bytes(&snap).unwrap();
        assert_eq!(cs2.snapshot_bytes().unwrap(), snap);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
        std::fs::remove_dir_all(path2.parent().unwrap()).ok();
    }

    #[test]
    fn decode_snapshot_rejects_malformed_input() {
        assert!(ColdStore::decode_snapshot(b"").is_err());
        assert!(ColdStore::decode_snapshot(b"PCSN0001").is_err());
        let path = tmp_file("badsnap");
        let mut cs = ColdStore::open(&path, 2).unwrap();
        cs.put(5, &[1.0, 2.0]).unwrap();
        let good = cs.snapshot_bytes().unwrap();
        // Truncation.
        assert!(ColdStore::decode_snapshot(&good[..good.len() - 1]).is_err());
        // Count larger than the body.
        let mut b = good.clone();
        b[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(ColdStore::decode_snapshot(&b).is_err());
        // Implausible row width.
        let mut b = good;
        b[8..16].copy_from_slice(&(MAX_ROW_WIDTH + 1).to_le_bytes());
        assert!(ColdStore::decode_snapshot(&b).is_err());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
