//! One PS shard: a lock + an [`EmbeddingStore`] + the row optimizer.
//!
//! Paper §4.2.2: "we utilize multiple threads in the LRU implementation.
//! Each thread manages a subset of the local hash-map and the corresponding
//! array-list; when there is a request of get or put, the corresponding
//! thread will lock its hash-map and array-list until the execution is
//! completed." — i.e. lock striping at shard granularity, which is exactly
//! the `Mutex<Box<dyn EmbeddingStore>>` here. The store behind the lock is
//! pluggable ([`StoreConfig`](super::StoreConfig)): the all-hot array-list
//! LRU by default, or a hot-over-cold [`TieredStore`](super::TieredStore)
//! when a `--cold-dir` is configured.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::Rng;

use super::lru::LruStore;
use super::optimizer::RowOptimizer;
use super::store::{EmbeddingStore, StoreCounters};

#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// A locked shard of embedding rows.
pub struct Shard {
    store: Mutex<Box<dyn EmbeddingStore>>,
    opt: RowOptimizer,
    seed: u64,
    gets: AtomicU64,
    puts: AtomicU64,
}

impl Shard {
    /// One locked all-hot LRU of `capacity` rows under `opt`, materializing
    /// rows deterministically from `seed`.
    pub fn new(capacity: usize, opt: RowOptimizer, seed: u64) -> Self {
        Self::with_store(Box::new(LruStore::new(capacity, opt.row_width())), opt, seed)
    }

    /// A shard over an explicit storage engine (built via
    /// [`StoreConfig::build`](super::StoreConfig::build)).
    pub fn with_store(store: Box<dyn EmbeddingStore>, opt: RowOptimizer, seed: u64) -> Self {
        assert_eq!(
            store.row_width(),
            opt.row_width(),
            "store row width must match optimizer row width"
        );
        Self {
            store: Mutex::new(store),
            opt,
            seed,
            gets: AtomicU64::new(0),
            puts: AtomicU64::new(0),
        }
    }

    /// Embedding vector width served by this shard.
    pub fn dim(&self) -> usize {
        self.opt.dim
    }

    /// Fetch the embedding vector for `key`, materializing deterministically
    /// on first touch (same key ⇒ same init, so a dropped row re-enters in
    /// its initial state rather than a random one). Errs only on cold-tier
    /// I/O failure; the all-hot store is infallible.
    pub fn get(&self, key: u64, out: &mut [f32]) -> anyhow::Result<()> {
        debug_assert_eq!(out.len(), self.opt.dim);
        self.gets.fetch_add(1, Ordering::Relaxed);
        let mut store = self.store.lock().unwrap();
        let opt = self.opt;
        let seed = self.seed;
        let row = store.get_or_insert_with(key, &mut |row| {
            let mut rng = Rng::new(splitmix64(key ^ seed));
            opt.init_row(row, &mut rng);
        })?;
        out.copy_from_slice(&row[..opt.dim]);
        Ok(())
    }

    /// Apply a gradient to `key`'s row (Alg. 1 backward task, lock-free
    /// across shards, locked within).
    pub fn put_grad(&self, key: u64, grad: &[f32]) -> anyhow::Result<()> {
        debug_assert_eq!(grad.len(), self.opt.dim);
        self.puts.fetch_add(1, Ordering::Relaxed);
        let mut store = self.store.lock().unwrap();
        let opt = self.opt;
        let seed = self.seed;
        let row = store.get_or_insert_with(key, &mut |row| {
            let mut rng = Rng::new(splitmix64(key ^ seed));
            opt.init_row(row, &mut rng);
        })?;
        opt.apply(row, grad);
        Ok(())
    }

    /// Number of materialized rows across all tiers.
    pub fn len(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    /// True when no rows have materialized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rows resident in the hot tier.
    pub fn hot_len(&self) -> usize {
        self.store.lock().unwrap().hot_len()
    }

    /// Rows resident in the cold tier (0 for all-hot stores).
    pub fn cold_len(&self) -> usize {
        self.store.lock().unwrap().cold_len()
    }

    /// Hot-tier evictions since construction (= demotions when tiered).
    pub fn evictions(&self) -> u64 {
        self.store.lock().unwrap().counters().evictions
    }

    /// Hit/movement counters of the underlying store.
    pub fn counters(&self) -> StoreCounters {
        self.store.lock().unwrap().counters()
    }

    /// Whether the shard's store has a cold tier.
    pub fn has_cold(&self) -> bool {
        self.store.lock().unwrap().has_cold()
    }

    /// (gets, puts) served by this shard — the load-balance metric.
    pub fn traffic(&self) -> (u64, u64) {
        (self.gets.load(Ordering::Relaxed), self.puts.load(Ordering::Relaxed))
    }

    /// Flat snapshot of the shard's hot tier (paper: checkpointing is a
    /// memory copy).
    pub fn snapshot(&self) -> anyhow::Result<Vec<u8>> {
        self.store.lock().unwrap().snapshot_hot()
    }

    /// Snapshot of the shard's cold tier, `None` for all-hot stores.
    pub fn snapshot_cold(&self) -> anyhow::Result<Option<Vec<u8>>> {
        self.store.lock().unwrap().snapshot_cold()
    }

    /// Restore the hot tier from a snapshot; replaces current contents.
    pub fn restore(&self, bytes: &[u8]) -> anyhow::Result<()> {
        self.store.lock().unwrap().restore_hot(bytes)
    }

    /// Restore the cold tier from a [`Self::snapshot_cold`] blob.
    pub fn restore_cold(&self, bytes: &[u8]) -> anyhow::Result<()> {
        self.store.lock().unwrap().restore_cold(bytes)
    }

    /// Drop all rows (process-level failure without shared-memory rescue).
    pub fn wipe(&self) -> anyhow::Result<()> {
        self.store.lock().unwrap().wipe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimizerKind;
    use crate::embedding::cold::ColdStore;
    use crate::embedding::tiered::TieredStore;

    fn shard(cap: usize) -> Shard {
        Shard::new(cap, RowOptimizer::new(OptimizerKind::Sgd, 0.5, 4), 7)
    }

    fn tiered_shard(hot_cap: usize, tag: &str) -> (Shard, std::path::PathBuf) {
        let opt = RowOptimizer::new(OptimizerKind::Sgd, 0.5, 4);
        let dir = std::env::temp_dir().join(format!("persia_shard_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cold = ColdStore::open(&dir.join("cold.bin"), opt.row_width()).unwrap();
        // Threshold 1: admit everything, pure capacity spill.
        let store = Box::new(TieredStore::new(hot_cap, cold, 1).unwrap());
        (Shard::with_store(store, opt, 7), dir)
    }

    #[test]
    fn deterministic_materialization() {
        let s = shard(16);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        s.get(42, &mut a).unwrap();
        s.get(42, &mut b).unwrap();
        assert_eq!(a, b);
        // A different shard with the same seed materializes identically.
        let s2 = shard(16);
        s2.get(42, &mut b).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn grads_update_rows() {
        let s = shard(16);
        let mut before = vec![0.0; 4];
        s.get(1, &mut before).unwrap();
        s.put_grad(1, &[1.0, 0.0, -1.0, 2.0]).unwrap();
        let mut after = vec![0.0; 4];
        s.get(1, &mut after).unwrap();
        assert!((before[0] - 0.5 - after[0]).abs() < 1e-6);
        assert!((before[2] + 0.5 - after[2]).abs() < 1e-6);
    }

    #[test]
    fn eviction_resets_to_initial_state() {
        let s = shard(2);
        let mut init = vec![0.0; 4];
        s.get(1, &mut init).unwrap();
        s.put_grad(1, &[1.0; 4]).unwrap();
        // Evict key 1 by touching 2 fresh keys.
        s.get(2, &mut [0.0; 4]).unwrap();
        s.get(3, &mut [0.0; 4]).unwrap();
        let mut again = vec![0.0; 4];
        s.get(1, &mut again).unwrap();
        assert_eq!(init, again, "re-materialized row must equal original init");
        assert!(s.evictions() >= 1);
    }

    #[test]
    fn tiered_shard_keeps_updates_across_demotion() {
        // Same scenario as eviction_resets_to_initial_state, but with a
        // cold tier: the updated row must come back *updated*.
        let (s, dir) = tiered_shard(2, "demote");
        let mut init = vec![0.0; 4];
        s.get(1, &mut init).unwrap();
        s.put_grad(1, &[1.0; 4]).unwrap();
        let mut updated = vec![0.0; 4];
        s.get(1, &mut updated).unwrap();
        assert_ne!(init, updated);
        s.get(2, &mut [0.0; 4]).unwrap();
        s.get(3, &mut [0.0; 4]).unwrap();
        assert!(s.counters().demotions >= 1);
        let mut again = vec![0.0; 4];
        s.get(1, &mut again).unwrap();
        assert_eq!(updated, again, "demotion must preserve exact row bytes");
        assert!(s.has_cold());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tiered_shard_snapshot_restores_both_tiers() {
        let (s, dir) = tiered_shard(2, "snap");
        for k in 0..6u64 {
            s.get(k, &mut [0.0; 4]).unwrap();
            s.put_grad(k, &[k as f32; 4]).unwrap();
        }
        let mut want = vec![vec![0.0; 4]; 6];
        for k in 0..6u64 {
            s.get(k, &mut want[k as usize]).unwrap();
        }
        let hot = s.snapshot().unwrap();
        let cold = s.snapshot_cold().unwrap().expect("tiered shard has a cold tier");
        s.wipe().unwrap();
        assert_eq!(s.len(), 0);
        s.restore_cold(&cold).unwrap();
        s.restore(&hot).unwrap();
        for k in 0..6u64 {
            let mut got = vec![0.0; 4];
            s.get(k, &mut got).unwrap();
            assert_eq!(got, want[k as usize], "key {k}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = shard(8);
        s.get(1, &mut [0.0; 4]).unwrap();
        s.put_grad(1, &[1.0; 4]).unwrap();
        let mut want = vec![0.0; 4];
        s.get(1, &mut want).unwrap();
        let snap = s.snapshot().unwrap();
        s.wipe().unwrap();
        assert_eq!(s.len(), 0);
        s.restore(&snap).unwrap();
        let mut got = vec![0.0; 4];
        s.get(1, &mut got).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn traffic_counters() {
        let s = shard(8);
        s.get(1, &mut [0.0; 4]).unwrap();
        s.get(2, &mut [0.0; 4]).unwrap();
        s.put_grad(1, &[0.0; 4]).unwrap();
        assert_eq!(s.traffic(), (2, 1));
    }

    #[test]
    fn concurrent_access_is_safe() {
        let s = std::sync::Arc::new(shard(64));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut buf = vec![0.0; 4];
                    for i in 0..500u64 {
                        let k = (i * 7 + t) % 100;
                        s.get(k, &mut buf).unwrap();
                        s.put_grad(k, &[0.1; 4]).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.traffic().0, 2000);
        assert_eq!(s.traffic().1, 2000);
    }
}
