//! One PS shard: a lock + an [`LruStore`] + the row optimizer.
//!
//! Paper §4.2.2: "we utilize multiple threads in the LRU implementation.
//! Each thread manages a subset of the local hash-map and the corresponding
//! array-list; when there is a request of get or put, the corresponding
//! thread will lock its hash-map and array-list until the execution is
//! completed." — i.e. lock striping at shard granularity, which is exactly
//! the `Mutex<LruStore>` here.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::Rng;

use super::lru::LruStore;
use super::optimizer::RowOptimizer;

#[inline]
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// A locked shard of embedding rows.
pub struct Shard {
    lru: Mutex<LruStore>,
    opt: RowOptimizer,
    seed: u64,
    gets: AtomicU64,
    puts: AtomicU64,
}

impl Shard {
    /// One locked LRU of `capacity` rows under `opt`, materializing rows
    /// deterministically from `seed`.
    pub fn new(capacity: usize, opt: RowOptimizer, seed: u64) -> Self {
        Self {
            lru: Mutex::new(LruStore::new(capacity, opt.row_width())),
            opt,
            seed,
            gets: AtomicU64::new(0),
            puts: AtomicU64::new(0),
        }
    }

    /// Embedding vector width served by this shard.
    pub fn dim(&self) -> usize {
        self.opt.dim
    }

    /// Fetch the embedding vector for `key`, materializing deterministically
    /// on first touch (same key ⇒ same init, so an evicted row re-enters in
    /// its initial state rather than a random one).
    pub fn get(&self, key: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.opt.dim);
        self.gets.fetch_add(1, Ordering::Relaxed);
        let mut lru = self.lru.lock().unwrap();
        let opt = self.opt;
        let seed = self.seed;
        let (row, _evicted) = lru.get_or_insert_with(key, |row| {
            let mut rng = Rng::new(splitmix64(key ^ seed));
            opt.init_row(row, &mut rng);
        });
        out.copy_from_slice(&row[..opt.dim]);
    }

    /// Apply a gradient to `key`'s row (Alg. 1 backward task, lock-free
    /// across shards, locked within).
    pub fn put_grad(&self, key: u64, grad: &[f32]) {
        debug_assert_eq!(grad.len(), self.opt.dim);
        self.puts.fetch_add(1, Ordering::Relaxed);
        let mut lru = self.lru.lock().unwrap();
        let opt = self.opt;
        let seed = self.seed;
        let (row, _evicted) = lru.get_or_insert_with(key, |row| {
            let mut rng = Rng::new(splitmix64(key ^ seed));
            opt.init_row(row, &mut rng);
        });
        opt.apply(row, grad);
    }

    /// Number of materialized rows.
    pub fn len(&self) -> usize {
        self.lru.lock().unwrap().len()
    }

    /// True when no rows have materialized yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// LRU evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.lru.lock().unwrap().evictions()
    }

    /// (gets, puts) served by this shard — the load-balance metric.
    pub fn traffic(&self) -> (u64, u64) {
        (self.gets.load(Ordering::Relaxed), self.puts.load(Ordering::Relaxed))
    }

    /// Flat snapshot of the shard (paper: checkpointing is a memory copy).
    pub fn snapshot(&self) -> Vec<u8> {
        self.lru.lock().unwrap().to_bytes()
    }

    /// Restore from a snapshot; replaces current contents.
    pub fn restore(&self, bytes: &[u8]) -> anyhow::Result<()> {
        let store = LruStore::from_bytes(bytes)?;
        anyhow::ensure!(
            store.row_width() == self.opt.row_width(),
            "snapshot row width {} != shard row width {}",
            store.row_width(),
            self.opt.row_width()
        );
        *self.lru.lock().unwrap() = store;
        Ok(())
    }

    /// Drop all rows (process-level failure without shared-memory rescue).
    pub fn wipe(&self) {
        let cap = {
            let lru = self.lru.lock().unwrap();
            lru.capacity()
        };
        *self.lru.lock().unwrap() = LruStore::new(cap, self.opt.row_width());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OptimizerKind;

    fn shard(cap: usize) -> Shard {
        Shard::new(cap, RowOptimizer::new(OptimizerKind::Sgd, 0.5, 4), 7)
    }

    #[test]
    fn deterministic_materialization() {
        let s = shard(16);
        let mut a = vec![0.0; 4];
        let mut b = vec![0.0; 4];
        s.get(42, &mut a);
        s.get(42, &mut b);
        assert_eq!(a, b);
        // A different shard with the same seed materializes identically.
        let s2 = shard(16);
        s2.get(42, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn grads_update_rows() {
        let s = shard(16);
        let mut before = vec![0.0; 4];
        s.get(1, &mut before);
        s.put_grad(1, &[1.0, 0.0, -1.0, 2.0]);
        let mut after = vec![0.0; 4];
        s.get(1, &mut after);
        assert!((before[0] - 0.5 - after[0]).abs() < 1e-6);
        assert!((before[2] + 0.5 - after[2]).abs() < 1e-6);
    }

    #[test]
    fn eviction_resets_to_initial_state() {
        let s = shard(2);
        let mut init = vec![0.0; 4];
        s.get(1, &mut init);
        s.put_grad(1, &[1.0; 4]);
        // Evict key 1 by touching 2 fresh keys.
        s.get(2, &mut [0.0; 4]);
        s.get(3, &mut [0.0; 4]);
        let mut again = vec![0.0; 4];
        s.get(1, &mut again);
        assert_eq!(init, again, "re-materialized row must equal original init");
        assert!(s.evictions() >= 1);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = shard(8);
        s.get(1, &mut [0.0; 4]);
        s.put_grad(1, &[1.0; 4]);
        let mut want = vec![0.0; 4];
        s.get(1, &mut want);
        let snap = s.snapshot();
        s.wipe();
        assert_eq!(s.len(), 0);
        s.restore(&snap).unwrap();
        let mut got = vec![0.0; 4];
        s.get(1, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn traffic_counters() {
        let s = shard(8);
        s.get(1, &mut [0.0; 4]);
        s.get(2, &mut [0.0; 4]);
        s.put_grad(1, &[0.0; 4]);
        assert_eq!(s.traffic(), (2, 1));
    }

    #[test]
    fn concurrent_access_is_safe() {
        let s = std::sync::Arc::new(shard(64));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut buf = vec![0.0; 4];
                    for i in 0..500u64 {
                        let k = (i * 7 + t) % 100;
                        s.get(k, &mut buf);
                        s.put_grad(k, &[0.1; 4]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.traffic().0, 2000);
        assert_eq!(s.traffic().1, 2000);
    }
}
