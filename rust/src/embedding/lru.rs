//! Array-list LRU cache — the paper's §4.2.2 design, exactly:
//!
//! > "Instead of a doubly linked list where the pointer stores a memory
//! > address, we adopt an array-list design where the pointer stores the
//! > index of the pre- or post- entrance in the array; similarly, the
//! > hash-map's value also stores the corresponding embedding parameter's
//! > index in the array instead of the memory address."
//!
//! Two advantages the paper calls out, both realized here:
//! 1. no per-entry allocation/deallocation — all rows live in one flat
//!    `Vec<f32>` sized at construction (billions of entries would otherwise
//!    fragment the allocator);
//! 2. serialization/deserialization is a straight memory copy, because no
//!    machine pointers exist in the data — the basis of cheap checkpointing
//!    (`to_bytes`/`from_bytes`, used by [`super::checkpoint`]).
//!
//! Each row stores `embedding dim + optimizer state` f32s side by side, so a
//! get+update touches one cache-resident stripe.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

const NIL: u32 = u32::MAX;

/// Fast 64-bit hasher for the id keyspace (std's SipHash costs ~10x more
/// per lookup than the whole rest of a cache hit; ids are already
/// high-entropy after the router's splitmix, so a single mix is plenty).
#[derive(Default)]
pub struct IdHasher(u64);

impl Hasher for IdHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100000001b3);
        }
    }

    #[inline]
    fn write_u64(&mut self, x: u64) {
        // splitmix64 finalizer
        let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        self.0 = z ^ (z >> 31);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
}

type IdMap = HashMap<u64, u32, BuildHasherDefault<IdHasher>>;

/// Linkage + key of one slot (flat, pointer-free).
#[derive(Clone, Copy, Debug)]
#[repr(C)]
struct Slot {
    key: u64,
    prev: u32,
    next: u32,
    occupied: u32,
    _pad: u32,
}

impl Slot {
    fn empty() -> Self {
        Slot { key: 0, prev: NIL, next: NIL, occupied: 0, _pad: 0 }
    }
}

/// Fixed-capacity LRU keyed by u64, each entry one `row_width`-float row.
pub struct LruStore {
    slots: Vec<Slot>,
    /// Flat row storage: slot i owns `values[i*row_width .. (i+1)*row_width]`.
    values: Vec<f32>,
    map: IdMap,
    head: u32, // MRU
    tail: u32, // LRU
    free: Vec<u32>,
    row_width: usize,
    evictions: u64,
    hits: u64,
}

impl LruStore {
    /// An empty store holding up to `capacity` rows of `row_width` floats.
    pub fn new(capacity: usize, row_width: usize) -> Self {
        assert!(capacity > 0 && capacity < NIL as usize);
        assert!(row_width > 0);
        Self {
            slots: vec![Slot::empty(); capacity],
            values: vec![0.0; capacity * row_width],
            map: IdMap::with_capacity_and_hasher(capacity, Default::default()),
            head: NIL,
            tail: NIL,
            free: (0..capacity as u32).rev().collect(),
            row_width,
            evictions: 0,
            hits: 0,
        }
    }

    /// Materialized rows currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no rows are resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Maximum resident rows before LRU eviction kicks in.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Floats per row (embedding vector ⊕ optimizer state).
    pub fn row_width(&self) -> usize {
        self.row_width
    }

    /// Total evictions since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Total resident-row hits since construction (like `evictions`, a
    /// runtime counter — not serialized by [`Self::to_bytes`]).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Whether `key` is resident, without touching recency.
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    #[inline]
    fn detach(&mut self, idx: u32) {
        let (prev, next) = {
            let s = &self.slots[idx as usize];
            (s.prev, s.next)
        };
        if prev != NIL {
            self.slots[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slots[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    #[inline]
    fn push_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let s = &mut self.slots[idx as usize];
            s.prev = NIL;
            s.next = old_head;
        }
        if old_head != NIL {
            self.slots[old_head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Look up `key`, marking it most-recently-used. Returns the row.
    pub fn get(&mut self, key: u64) -> Option<&mut [f32]> {
        let idx = *self.map.get(&key)?;
        self.hits += 1;
        if self.head != idx {
            self.detach(idx);
            self.push_front(idx);
        }
        let w = self.row_width;
        Some(&mut self.values[idx as usize * w..(idx as usize + 1) * w])
    }

    /// Peek without touching recency (used by checkpointing/tests).
    pub fn peek(&self, key: u64) -> Option<&[f32]> {
        let idx = *self.map.get(&key)? as usize;
        Some(&self.values[idx * self.row_width..(idx + 1) * self.row_width])
    }

    /// Get or materialize a row; `init` fills a fresh row (paper: rows of the
    /// virtual 100T table come into existence on first touch). Returns
    /// (row, evicted_key_if_any).
    pub fn get_or_insert_with<F: FnOnce(&mut [f32])>(
        &mut self,
        key: u64,
        init: F,
    ) -> (&mut [f32], Option<u64>) {
        let w = self.row_width;
        if let Some(&idx) = self.map.get(&key) {
            self.hits += 1;
            if self.head != idx {
                self.detach(idx);
                self.push_front(idx);
            }
            return (
                &mut self.values[idx as usize * w..(idx as usize + 1) * w],
                None,
            );
        }
        let mut evicted = None;
        let idx = if let Some(idx) = self.free.pop() {
            idx
        } else {
            // Evict the LRU tail.
            let victim = self.tail;
            debug_assert_ne!(victim, NIL, "capacity>0 but no tail");
            let old_key = self.slots[victim as usize].key;
            self.detach(victim);
            self.map.remove(&old_key);
            self.evictions += 1;
            evicted = Some(old_key);
            victim
        };
        {
            let s = &mut self.slots[idx as usize];
            s.key = key;
            s.occupied = 1;
        }
        self.map.insert(key, idx);
        self.push_front(idx);
        let row = &mut self.values[idx as usize * w..(idx as usize + 1) * w];
        init(row);
        (row, evicted)
    }

    /// Remove a key (used by failure injection). Returns true if present.
    pub fn remove(&mut self, key: u64) -> bool {
        if let Some(idx) = self.map.remove(&key) {
            self.detach(idx);
            self.slots[idx as usize] = Slot::empty();
            self.free.push(idx);
            true
        } else {
            false
        }
    }

    /// Evict the LRU tail, returning its key and a copy of its row bytes.
    ///
    /// This is the demotion hook for tiered storage: unlike the implicit
    /// eviction inside [`Self::get_or_insert_with`] (which reuses the
    /// victim's slot in place and discards its contents), the caller gets
    /// the exact row back so it can be persisted in a colder tier.
    pub fn evict_lru(&mut self) -> Option<(u64, Vec<f32>)> {
        let victim = self.tail;
        if victim == NIL {
            return None;
        }
        let key = self.slots[victim as usize].key;
        let w = self.row_width;
        let row = self.values[victim as usize * w..(victim as usize + 1) * w].to_vec();
        self.detach(victim);
        self.map.remove(&key);
        self.slots[victim as usize] = Slot::empty();
        self.free.push(victim);
        self.evictions += 1;
        Some((key, row))
    }

    /// Keys from MRU to LRU (test/diagnostic; O(len)).
    pub fn keys_mru_order(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.len());
        let mut cur = self.head;
        while cur != NIL {
            out.push(self.slots[cur as usize].key);
            cur = self.slots[cur as usize].next;
        }
        out
    }

    /// Verify structural invariants (tests + post-restore validation).
    ///
    /// Defensive by construction: snapshots restored via [`Self::from_bytes`]
    /// may carry hostile `head`/`tail`/`prev`/`next` indices, so every slot
    /// index is bounds-checked before it is dereferenced and both walks are
    /// cycle-guarded — corruption yields `Err`, never a panic or a hang.
    pub fn check_invariants(&self) -> anyhow::Result<()> {
        use anyhow::ensure;
        let cap = self.slots.len();
        let in_bounds = |idx: u32| (idx as usize) < cap;
        ensure!(self.head == NIL || in_bounds(self.head), "head {} out of bounds", self.head);
        ensure!(self.tail == NIL || in_bounds(self.tail), "tail {} out of bounds", self.tail);

        // Forward (MRU -> LRU) walk: every visited index must be in bounds,
        // occupied, mapped back to itself, and the walk must terminate.
        let mut forward = Vec::with_capacity(self.map.len());
        let mut cur = self.head;
        while cur != NIL {
            ensure!(in_bounds(cur), "next link {cur} out of bounds");
            ensure!(forward.len() < cap, "cycle in next links");
            let s = &self.slots[cur as usize];
            ensure!(s.occupied == 1, "linked slot {cur} not occupied");
            ensure!(
                self.map.get(&s.key) == Some(&cur),
                "slot {cur} key {:#x} not mapped back to it",
                s.key
            );
            forward.push(s.key);
            cur = s.next;
        }
        ensure!(forward.len() == self.map.len(), "list len != map len");

        // Backward walk must mirror the forward walk exactly.
        let mut backward = Vec::with_capacity(forward.len());
        let mut cur = self.tail;
        while cur != NIL {
            ensure!(in_bounds(cur), "prev link {cur} out of bounds");
            ensure!(backward.len() < cap, "cycle in prev links");
            backward.push(self.slots[cur as usize].key);
            cur = self.slots[cur as usize].prev;
        }
        backward.reverse();
        ensure!(forward == backward, "prev/next links disagree");

        for &idx in &self.free {
            ensure!(in_bounds(idx), "free-list index {idx} out of bounds");
        }
        ensure!(self.map.len() + self.free.len() == cap, "slot leak");
        Ok(())
    }

    // --- flat serialization (paper: "a straightforward memory copy") ---

    /// Serialize to bytes: header + raw slot array + raw value array.
    pub fn to_bytes(&self) -> Vec<u8> {
        let slot_bytes = std::mem::size_of::<Slot>() * self.slots.len();
        let val_bytes = 4 * self.values.len();
        let mut out = Vec::with_capacity(40 + slot_bytes + val_bytes);
        out.extend_from_slice(b"PLRU0001");
        out.extend_from_slice(&(self.slots.len() as u64).to_le_bytes());
        out.extend_from_slice(&(self.row_width as u64).to_le_bytes());
        out.extend_from_slice(&(self.head as u64).to_le_bytes());
        out.extend_from_slice(&(self.tail as u64).to_le_bytes());
        // SAFETY: Slot is repr(C) POD; values are f32.
        unsafe {
            out.extend_from_slice(std::slice::from_raw_parts(
                self.slots.as_ptr() as *const u8,
                slot_bytes,
            ));
            out.extend_from_slice(std::slice::from_raw_parts(
                self.values.as_ptr() as *const u8,
                val_bytes,
            ));
        }
        out
    }

    /// Restore from [`Self::to_bytes`] output. The hash-map (the only
    /// non-flat structure) is rebuilt from the slot array.
    ///
    /// Every header field is validated before any index derived from it is
    /// used: arbitrary (corrupt, truncated, or hostile) bytes yield `Err`,
    /// never a panic — checkpoint restore is a failure-recovery path and must
    /// not take the process down with it.
    pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Self> {
        use anyhow::ensure;
        ensure!(bytes.len() >= 8 && &bytes[..8] == b"PLRU0001", "bad LRU snapshot header");
        // Every header read goes through one checked reader: a short buffer
        // is an Err, never a slice-index panic.
        let rd_u64 = |off: usize| -> anyhow::Result<u64> {
            let end = off
                .checked_add(8)
                .ok_or_else(|| anyhow::anyhow!("snapshot header offset overflow"))?;
            let raw = bytes
                .get(off..end)
                .ok_or_else(|| anyhow::anyhow!("snapshot truncated in header at byte {off}"))?;
            Ok(u64::from_le_bytes(raw.try_into().expect("8-byte slice")))
        };
        let capacity_raw = rd_u64(8)?;
        let row_width_raw = rd_u64(16)?;
        // The constructor's own bounds: 0 < capacity < NIL, row_width > 0.
        ensure!(
            capacity_raw > 0 && capacity_raw < NIL as u64,
            "snapshot capacity {capacity_raw} out of range"
        );
        ensure!(row_width_raw > 0, "snapshot row_width 0");
        let capacity = capacity_raw as usize;
        let row_width = usize::try_from(row_width_raw)
            .map_err(|_| anyhow::anyhow!("snapshot row_width {row_width_raw} out of range"))?;
        // Overflow-safe size accounting: a corrupt header must not wrap the
        // expected length into something the real buffer happens to satisfy.
        let slot_bytes = capacity
            .checked_mul(std::mem::size_of::<Slot>())
            .ok_or_else(|| anyhow::anyhow!("snapshot slot size overflow"))?;
        let val_bytes = capacity
            .checked_mul(row_width)
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| anyhow::anyhow!("snapshot value size overflow"))?;
        let total = 40usize
            .checked_add(slot_bytes)
            .and_then(|n| n.checked_add(val_bytes))
            .ok_or_else(|| anyhow::anyhow!("snapshot size overflow"))?;
        ensure!(bytes.len() == total, "snapshot size mismatch");
        // head/tail travel as u64; reject anything that would truncate when
        // narrowed back to a slot index instead of silently wrapping.
        let head_raw = rd_u64(24)?;
        let tail_raw = rd_u64(32)?;
        ensure!(
            head_raw == NIL as u64 || head_raw < capacity_raw,
            "snapshot head {head_raw} out of bounds"
        );
        ensure!(
            tail_raw == NIL as u64 || tail_raw < capacity_raw,
            "snapshot tail {tail_raw} out of bounds"
        );
        let head = head_raw as u32;
        let tail = tail_raw as u32;

        let mut slots = vec![Slot::empty(); capacity];
        let mut values = vec![0.0f32; capacity * row_width];
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes[40..].as_ptr(),
                slots.as_mut_ptr() as *mut u8,
                slot_bytes,
            );
            std::ptr::copy_nonoverlapping(
                bytes[40 + slot_bytes..].as_ptr(),
                values.as_mut_ptr() as *mut u8,
                val_bytes,
            );
        }
        let mut map = IdMap::with_capacity_and_hasher(capacity, Default::default());
        let mut free = Vec::new();
        for (i, s) in slots.iter().enumerate() {
            if s.occupied == 1 {
                ensure!(
                    map.insert(s.key, i as u32).is_none(),
                    "snapshot has duplicate key {:#x}",
                    s.key
                );
            } else {
                free.push(i as u32);
            }
        }
        free.reverse();
        let store =
            Self { slots, values, map, head, tail, free, row_width, evictions: 0, hits: 0 };
        // The bounds/cycle-hardened walk rejects corrupt prev/next links.
        store.check_invariants()?;
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;
    use crate::util::Rng;

    fn init_row(v: f32) -> impl FnOnce(&mut [f32]) {
        move |row| row.fill(v)
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut lru = LruStore::new(4, 3);
        lru.get_or_insert_with(10, init_row(1.0));
        lru.get_or_insert_with(20, init_row(2.0));
        assert_eq!(lru.get(10).unwrap(), &[1.0, 1.0, 1.0]);
        assert_eq!(lru.get(20).unwrap(), &[2.0, 2.0, 2.0]);
        assert!(lru.get(30).is_none());
        assert_eq!(lru.len(), 2);
    }

    #[test]
    fn eviction_is_lru_order() {
        let mut lru = LruStore::new(3, 1);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        lru.get_or_insert_with(3, init_row(3.0));
        // Touch 1 so 2 becomes LRU.
        lru.get(1);
        let (_, evicted) = lru.get_or_insert_with(4, init_row(4.0));
        assert_eq!(evicted, Some(2));
        assert!(lru.get(2).is_none());
        assert_eq!(lru.keys_mru_order(), vec![4, 1, 3]);
        assert_eq!(lru.evictions(), 1);
    }

    #[test]
    fn capacity_never_exceeded() {
        let mut lru = LruStore::new(8, 2);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let k = rng.below(100);
            lru.get_or_insert_with(k, init_row(k as f32));
            assert!(lru.len() <= 8);
        }
        lru.check_invariants().unwrap();
    }

    #[test]
    fn updates_persist_across_touches() {
        let mut lru = LruStore::new(4, 2);
        lru.get_or_insert_with(5, init_row(0.0));
        lru.get(5).unwrap()[0] = 42.0;
        lru.get_or_insert_with(6, init_row(0.0));
        assert_eq!(lru.get(5).unwrap()[0], 42.0);
    }

    #[test]
    fn remove_frees_slot() {
        let mut lru = LruStore::new(2, 1);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        assert!(lru.remove(1));
        assert!(!lru.remove(1));
        // Slot is reusable without eviction.
        let (_, ev) = lru.get_or_insert_with(3, init_row(3.0));
        assert!(ev.is_none());
        lru.check_invariants().unwrap();
    }

    #[test]
    fn snapshot_roundtrip_preserves_everything() {
        let mut lru = LruStore::new(16, 4);
        let mut rng = Rng::new(2);
        for _ in 0..200 {
            let k = rng.below(40);
            let (row, _) = lru.get_or_insert_with(k, init_row(0.0));
            row[0] += 1.0;
        }
        let order_before = lru.keys_mru_order();
        let bytes = lru.to_bytes();
        let mut back = LruStore::from_bytes(&bytes).unwrap();
        assert_eq!(back.len(), lru.len());
        assert_eq!(back.keys_mru_order(), order_before);
        for &k in &order_before {
            assert_eq!(back.get(k).map(|r| r.to_vec()), lru.get(k).map(|r| r.to_vec()));
        }
        back.check_invariants().unwrap();
    }

    #[test]
    fn snapshot_rejects_corruption() {
        let mut lru = LruStore::new(4, 2);
        lru.get_or_insert_with(1, init_row(1.0));
        let mut bytes = lru.to_bytes();
        bytes[0] ^= 0xff;
        assert!(LruStore::from_bytes(&bytes).is_err());
        let mut bytes2 = lru.to_bytes();
        bytes2.truncate(bytes2.len() - 1);
        assert!(LruStore::from_bytes(&bytes2).is_err());
    }

    #[test]
    fn corrupt_indices_error_instead_of_panicking() {
        // Fill a store so head/tail/links are all live, snapshot it, then
        // corrupt each index field in turn: restore must return Err (it used
        // to index out of bounds and panic).
        let mut lru = LruStore::new(4, 2);
        for k in 0..4u64 {
            lru.get_or_insert_with(k, init_row(k as f32));
        }
        let good = lru.to_bytes();
        let patch_u64 = |bytes: &mut [u8], off: usize, v: u64| {
            bytes[off..off + 8].copy_from_slice(&v.to_le_bytes());
        };
        let patch_u32 = |bytes: &mut [u8], off: usize, v: u32| {
            bytes[off..off + 4].copy_from_slice(&v.to_le_bytes());
        };

        // head / tail out of bounds (both u32-range and u64-truncating).
        for off in [24usize, 32] {
            for v in [4u64, 1 << 20, (1u64 << 32) + 1] {
                let mut b = good.clone();
                patch_u64(&mut b, off, v);
                assert!(LruStore::from_bytes(&b).is_err(), "off={off} v={v}");
            }
        }
        // prev/next of slot 0 out of bounds (slot layout: key 8, prev 4,
        // next 4, occupied 4, pad 4 = 24 bytes starting at byte 40).
        for field_off in [48usize, 52] {
            let mut b = good.clone();
            patch_u32(&mut b, field_off, 999);
            assert!(LruStore::from_bytes(&b).is_err(), "field_off={field_off}");
        }
        // A next link forming a cycle (slot 0 points at itself).
        let mut b = good.clone();
        patch_u32(&mut b, 52, 0);
        assert!(LruStore::from_bytes(&b).is_err(), "self-cycle accepted");
        // Implausible capacity that would overflow size arithmetic.
        let mut b = good.clone();
        patch_u64(&mut b, 8, u64::MAX / 2);
        assert!(LruStore::from_bytes(&b).is_err(), "overflow capacity accepted");
        // Zero row width.
        let mut b = good;
        patch_u64(&mut b, 16, 0);
        assert!(LruStore::from_bytes(&b).is_err(), "zero row_width accepted");
    }

    #[test]
    fn duplicate_snapshot_keys_rejected() {
        let mut lru = LruStore::new(2, 1);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        let mut bytes = lru.to_bytes();
        // Overwrite slot 1's key with slot 0's key (key field at slot start).
        let k0 = u64::from_le_bytes(bytes[40..48].try_into().unwrap());
        bytes[64..72].copy_from_slice(&k0.to_le_bytes());
        assert!(LruStore::from_bytes(&bytes).is_err());
    }

    #[test]
    fn evict_lru_returns_exact_row_bytes() {
        let mut lru = LruStore::new(3, 2);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        lru.get(1).unwrap()[1] = 9.0; // 2 becomes LRU; 1 carries an update
        let (k, row) = lru.evict_lru().unwrap();
        assert_eq!(k, 2);
        assert_eq!(row, vec![2.0, 2.0]);
        assert!(!lru.contains(2));
        assert!(lru.contains(1));
        assert_eq!(lru.evictions(), 1);
        // The freed slot is reusable without a further eviction.
        let (_, ev) = lru.get_or_insert_with(3, init_row(3.0));
        assert!(ev.is_none());
        assert_eq!(lru.get(1).unwrap(), &[1.0, 9.0]);
        lru.check_invariants().unwrap();
        let mut empty = LruStore::new(2, 1);
        assert!(empty.evict_lru().is_none());
    }

    #[test]
    fn hits_counter_tracks_resident_lookups() {
        let mut lru = LruStore::new(2, 1);
        assert_eq!(lru.hits(), 0);
        lru.get_or_insert_with(1, init_row(1.0)); // miss
        lru.get_or_insert_with(1, init_row(1.0)); // hit
        lru.get(1); // hit
        lru.get(99); // miss
        assert_eq!(lru.hits(), 2);
    }

    #[test]
    fn get_refreshes_recency() {
        let mut lru = LruStore::new(3, 1);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        lru.get_or_insert_with(3, init_row(3.0));
        assert_eq!(lru.keys_mru_order(), vec![3, 2, 1]);
        // Touch the coldest entries; they must move to the front.
        lru.get(1);
        lru.get(2);
        assert_eq!(lru.keys_mru_order(), vec![2, 1, 3]);
        // Now 3 is the LRU and must be the eviction victim.
        let (_, evicted) = lru.get_or_insert_with(4, init_row(4.0));
        assert_eq!(evicted, Some(3));
    }

    #[test]
    fn peek_does_not_refresh_recency() {
        let mut lru = LruStore::new(2, 1);
        lru.get_or_insert_with(1, init_row(1.0));
        lru.get_or_insert_with(2, init_row(2.0));
        assert_eq!(lru.peek(1).unwrap(), &[1.0]);
        // peek(1) must NOT have promoted 1: it is still the LRU victim.
        let (_, evicted) = lru.get_or_insert_with(3, init_row(3.0));
        assert_eq!(evicted, Some(1));
        assert_eq!(lru.keys_mru_order(), vec![3, 2]);
    }

    #[test]
    fn evicted_key_is_always_the_lru() {
        // Exhaustively: under a random get/insert stream, every eviction
        // victim equals the model's least-recently-used key at that moment.
        let cap = 6;
        let mut lru = LruStore::new(cap, 1);
        let mut model: Vec<u64> = Vec::new(); // front = MRU
        let mut rng = Rng::new(77);
        for _ in 0..3000 {
            let k = rng.below(24);
            let touch_only = rng.below(2) == 0 && model.contains(&k);
            if touch_only {
                assert!(lru.get(k).is_some());
            } else {
                let (_, evicted) = lru.get_or_insert_with(k, init_row(k as f32));
                if let Some(victim) = evicted {
                    assert_eq!(victim, *model.last().unwrap(), "evicted non-LRU key");
                    model.pop();
                }
            }
            if let Some(pos) = model.iter().position(|&x| x == k) {
                model.remove(pos);
            }
            model.insert(0, k);
            assert!(lru.len() <= cap, "capacity exceeded");
            assert_eq!(lru.keys_mru_order(), model);
        }
        lru.check_invariants().unwrap();
    }

    #[test]
    fn property_mixed_ops_with_removes_hold_invariants() {
        // Insert/get/remove streams: capacity bound, map/list agreement and
        // free-slot accounting all hold at every step.
        forall(
            53,
            40,
            |rng: &mut Rng| {
                let n = rng.range(1, 150) as usize;
                (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
            },
            |ops| {
                let cap = 5;
                let mut lru = LruStore::new(cap, 2);
                for &op in ops {
                    let k = (op >> 2) % 12;
                    match op % 3 {
                        0 => {
                            lru.get_or_insert_with(k, init_row(k as f32));
                        }
                        1 => {
                            lru.get(k);
                        }
                        _ => {
                            lru.remove(k);
                        }
                    }
                    if lru.len() > cap {
                        return false;
                    }
                    if lru.check_invariants().is_err() {
                        return false;
                    }
                }
                true
            },
        );
    }

    #[test]
    fn property_matches_reference_lru_model() {
        // Reference model: Vec-based LRU with explicit recency ordering.
        forall(
            51,
            60,
            |rng: &mut Rng| {
                let n = rng.range(1, 120) as usize;
                (0..n).map(|_| rng.below(30)).collect::<Vec<u64>>()
            },
            |ops| {
                let cap = 8;
                let mut lru = LruStore::new(cap, 1);
                let mut model: Vec<u64> = Vec::new(); // front = MRU
                for &k in ops {
                    lru.get_or_insert_with(k, init_row(k as f32));
                    if let Some(pos) = model.iter().position(|&x| x == k) {
                        model.remove(pos);
                    }
                    model.insert(0, k);
                    model.truncate(cap);
                }
                lru.check_invariants().unwrap();
                lru.keys_mru_order() == model
            },
        );
    }
}
