//! Embedding worker (paper Algorithm 1 + §4.2.1 buffering).
//!
//! Forward task: receive ID-type features from the data loader, mint a
//! sample ID (top byte = this worker's rank, footnote 3), buffer the features
//! in the *ID type feature hash-map*, fetch rows from the embedding PS,
//! pool per feature group, and ship the aggregated activation to the NN
//! worker. Backward task: receive the activation's gradient keyed by sample
//! ID, look up the buffered ID features, fan the gradient out to the rows and
//! `put` it to the PS. Both tasks run lock-free with respect to each other,
//! and the buffer lock is never held across a PS call — the PS sits behind
//! the [`PsBackend`] trait and may be a remote TCP server
//! ([`crate::service::RemotePs`]).
//!
//! PS traffic is *batched and deduplicated*: one `get_many` per pulled batch
//! and one `put_grads` per pushed batch, each carrying every unique
//! `(group, id)` exactly once with gradients pre-aggregated — the paper's
//! §4.2.3 index compression applied at the source, which is also what makes
//! the remote path one round-trip instead of thousands.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::netsim::{Link, NetSim};
use crate::config::{ModelConfig, Pooling};
use crate::data::sample::{make_sample_id, Batch, IdFeatures, SampleId};
use crate::service::PsBackend;
use crate::worker::cache::{CacheStats, EmbCache};

/// Monotonic traffic/dedup counters of one [`EmbeddingWorker`].
///
/// The flush-side counters (`samples_flushed`, `rows_put`, `grad_ids`) are
/// incremented only once the PS put **succeeds**: a batch whose put failed is
/// re-buffered for retry, and counting it per attempt would over-report both
/// the flush volume and — because the retry replays the identical dedup —
/// under-report the dedup ratio. Each sample therefore counts exactly once
/// per successful flush, no matter how many retries it took.
#[derive(Default)]
struct WorkerCounters {
    samples_registered: AtomicU64,
    batches_fetched: AtomicU64,
    ids_looked_up: AtomicU64,
    rows_fetched: AtomicU64,
    batches_flushed: AtomicU64,
    samples_flushed: AtomicU64,
    grad_ids: AtomicU64,
    rows_put: AtomicU64,
    put_failures: AtomicU64,
    rebuffered_samples: AtomicU64,
}

/// Point-in-time snapshot of an embedding worker's traffic statistics
/// (see [`EmbeddingWorker::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Samples ever buffered by [`EmbeddingWorker::register`].
    pub samples_registered: u64,
    /// Forward batches fetched from the PS.
    pub batches_fetched: u64,
    /// Total `(group, id)` occurrences across fetched batches (pre-dedup).
    pub ids_looked_up: u64,
    /// Unique rows actually requested from the PS (post-dedup).
    pub rows_fetched: u64,
    /// Gradient batches whose PS put succeeded.
    pub batches_flushed: u64,
    /// Samples released by a successful flush — counted once per successful
    /// flush, however many re-buffered retries preceded it.
    pub samples_flushed: u64,
    /// Total gradient id occurrences flushed (pre-dedup, success only).
    pub grad_ids: u64,
    /// Unique gradient rows put to the PS (post-dedup, success only).
    pub rows_put: u64,
    /// Failed PS puts (each one re-buffered its samples for retry).
    pub put_failures: u64,
    /// Samples returned to the buffer by failed puts (counts retries).
    pub rebuffered_samples: u64,
}

impl WorkerStats {
    /// Row fetches the §4.2.3 index compression avoided on the forward path
    /// (duplicate ids served from the deduplicated batch lookup).
    pub fn dedup_hits_forward(&self) -> u64 {
        self.ids_looked_up.saturating_sub(self.rows_fetched)
    }

    /// Gradient rows the pre-aggregation avoided on the backward path.
    pub fn dedup_hits_backward(&self) -> u64 {
        self.grad_ids.saturating_sub(self.rows_put)
    }
}

/// One embedding worker.
pub struct EmbeddingWorker {
    rank: u8,
    ps: Arc<dyn PsBackend>,
    n_groups: usize,
    dim_per_group: usize,
    pooling: Pooling,
    buffer: Mutex<HashMap<SampleId, IdFeatures>>,
    counter: AtomicU64,
    counters: WorkerCounters,
    net: Arc<NetSim>,
    /// Apply the §4.2.3 lossy value compression to activation/grad traffic.
    compress: bool,
    /// Bounded-staleness hot-row cache in front of `ps` on the training
    /// pull path (never on eval lookups). `None` = every fetch hits the PS
    /// (deterministic mode, `--ew-cache false`).
    cache: Option<Arc<EmbCache>>,
}

impl EmbeddingWorker {
    /// A worker of rank `rank` over `ps`, simulating its transfers on `net`
    /// (`compress` = §4.2.3 lossy value compression on the worker↔NN legs).
    pub fn new(
        rank: u8,
        ps: Arc<dyn PsBackend>,
        model: &ModelConfig,
        net: Arc<NetSim>,
        compress: bool,
    ) -> Self {
        assert_eq!(ps.dim(), model.emb_dim_per_group, "PS dim != model group dim");
        Self {
            rank,
            ps,
            n_groups: model.n_groups,
            dim_per_group: model.emb_dim_per_group,
            pooling: model.pooling,
            buffer: Mutex::new(HashMap::new()),
            counter: AtomicU64::new(0),
            counters: WorkerCounters::default(),
            net,
            compress,
            cache: None,
        }
    }

    /// Attach (or detach) the bounded-staleness hot-row cache. Builder
    /// style so the deterministic construction sites stay untouched.
    pub fn with_cache(mut self, cache: Option<Arc<EmbCache>>) -> Self {
        self.cache = cache;
        self
    }

    /// The attached cache, if any (flush hooks, stats plane).
    pub fn cache(&self) -> Option<&Arc<EmbCache>> {
        self.cache.as_ref()
    }

    /// Snapshot of the attached cache's counters (zeros when uncached, so
    /// the stats wire frame stays fixed-shape).
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    /// This worker's rank (the top byte of every sample id it mints).
    pub fn rank(&self) -> u8 {
        self.rank
    }

    /// Full activation width: `n_groups * dim_per_group`.
    pub fn emb_dim(&self) -> usize {
        self.n_groups * self.dim_per_group
    }

    /// Snapshot of the traffic/dedup counters.
    pub fn stats(&self) -> WorkerStats {
        let c = &self.counters;
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        WorkerStats {
            samples_registered: load(&c.samples_registered),
            batches_fetched: load(&c.batches_fetched),
            ids_looked_up: load(&c.ids_looked_up),
            rows_fetched: load(&c.rows_fetched),
            batches_flushed: load(&c.batches_flushed),
            samples_flushed: load(&c.samples_flushed),
            grad_ids: load(&c.grad_ids),
            rows_put: load(&c.rows_put),
            put_failures: load(&c.put_failures),
            rebuffered_samples: load(&c.rebuffered_samples),
        }
    }

    /// Step (1) of the training procedure: buffer ID features, mint sample
    /// ids to hand back to the data loader.
    pub fn register(&self, ids: Vec<IdFeatures>) -> Vec<SampleId> {
        self.counters.samples_registered.fetch_add(ids.len() as u64, Ordering::Relaxed);
        let mut buf = self.buffer.lock().unwrap();
        ids.into_iter()
            .map(|f| {
                let sid = make_sample_id(self.rank, self.counter.fetch_add(1, Ordering::Relaxed));
                buf.insert(sid, f);
                sid
            })
            .collect()
    }

    /// Every unique `(group, id)` across `feats` in first-occurrence order,
    /// plus the key -> slot index (deterministic, no hash-order dependence).
    fn unique_keys(
        &self,
        feats: &[IdFeatures],
    ) -> (Vec<(u32, u64)>, HashMap<(u32, u64), usize>) {
        let mut keys: Vec<(u32, u64)> = Vec::new();
        let mut index: HashMap<(u32, u64), usize> = HashMap::new();
        for f in feats {
            for (g, group) in f.groups.iter().enumerate() {
                for &id in group {
                    let k = (g as u32, id);
                    index.entry(k).or_insert_with(|| {
                        keys.push(k);
                        keys.len() - 1
                    });
                }
            }
        }
        (keys, index)
    }

    /// One batched PS fetch for `feats`, pooled per feature group into a
    /// `[feats.len(), emb_dim]` activation. Returns the pooled activations
    /// and the number of unique rows fetched **from the PS** (the wire
    /// traffic — with the cache on, rows served locally don't count).
    /// `use_cache` is false on the eval path: evaluation must read the
    /// freshest PS state, never a training-staleness-budget copy.
    fn fetch_pooled(&self, feats: &[IdFeatures], use_cache: bool) -> Result<(Vec<f32>, usize)> {
        let d = self.dim_per_group;
        let emb_dim = self.emb_dim();
        let (keys, index) = self.unique_keys(feats);
        let mut rows = vec![0.0f32; keys.len() * d];
        let fetched = match &self.cache {
            Some(c) if use_cache => c
                .fetch_through(self.ps.as_ref(), &keys, &mut rows)
                .context("embedding PS get (through worker cache)")?,
            _ => {
                self.ps.get_many(&keys, &mut rows).context("embedding PS get")?;
                keys.len()
            }
        };

        let mut out = vec![0.0f32; feats.len() * emb_dim];
        for (i, f) in feats.iter().enumerate() {
            for (g, group) in f.groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let dst = &mut out[i * emb_dim + g * d..i * emb_dim + (g + 1) * d];
                for &id in group {
                    let slot = index[&(g as u32, id)];
                    for (o, &x) in dst.iter_mut().zip(&rows[slot * d..(slot + 1) * d]) {
                        *o += x;
                    }
                }
                if self.pooling == Pooling::Mean {
                    let inv = 1.0 / group.len() as f32;
                    for o in dst.iter_mut() {
                        *o *= inv;
                    }
                }
            }
        }
        Ok((out, fetched))
    }

    /// Steps (3)-(4) up to (but excluding) the worker→NN transfer: fetch and
    /// pool the buffered samples' rows. Returns the **raw** pooled
    /// activations (`[B, emb_dim]` flattened) and the simulated seconds of
    /// the PS→worker leg only. This is the half an out-of-process embedding
    /// worker runs locally — the worker→NN leg then happens for real on the
    /// wire (see [`crate::service::embedding_worker`]) instead of being
    /// simulated here.
    pub fn pull_rows(&self, sample_ids: &[SampleId]) -> Result<(Vec<f32>, f64)> {
        // Snapshot the features under the lock; the PS round-trip (possibly
        // a real network call) runs with the lock released.
        let feats: Vec<IdFeatures> = {
            let buf = self.buffer.lock().unwrap();
            sample_ids
                .iter()
                .map(|sid| {
                    buf.get(sid).cloned().with_context(|| {
                        format!("sample {sid:#x} not buffered (worker {})", self.rank)
                    })
                })
                .collect::<Result<_>>()?
        };
        let total_ids: usize = feats.iter().map(|f| f.n_ids()).sum();
        let (out, unique_rows) = self.fetch_pooled(&feats, true)?;
        self.counters.batches_fetched.fetch_add(1, Ordering::Relaxed);
        self.counters.ids_looked_up.fetch_add(total_ids as u64, Ordering::Relaxed);
        self.counters.rows_fetched.fetch_add(unique_rows as u64, Ordering::Relaxed);
        // PS -> embedding worker: raw rows (unique keys only; cache hits
        // never reach this wire, so they are not charged).
        let sim = self.net.record(Link::PS_EW, unique_rows * self.dim_per_group * 4);
        Ok((out, sim))
    }

    /// Steps (3)-(4): the NN worker's pull. Returns the pooled activations
    /// (`[B, emb_dim]` flattened) and the simulated communication seconds
    /// (PS->worker rows + worker->NN activation transfer).
    pub fn pull(&self, sample_ids: &[SampleId]) -> Result<(Vec<f32>, f64)> {
        let (mut out, mut sim) = self.pull_rows(sample_ids)?;
        // embedding worker -> NN worker: pooled activations (fp16+scale when
        // compression is on; we run the real round-trip so the numeric effect
        // of the lossy path is part of training).
        let emb_dim = self.emb_dim();
        if self.compress {
            let c = CompressedValues::compress(&out, emb_dim);
            sim += self.net.record(Link::EW_NN, c.wire_bytes());
            c.decompress_into(&mut out);
        } else {
            sim += self.net.record(Link::EW_NN, out.len() * 4);
        }
        Ok((out, sim))
    }

    /// Eval-path lookup straight from a batch (no sample-id buffering).
    /// Always bypasses the worker cache: reported metrics must reflect the
    /// PS's current state, not a bounded-staleness copy.
    pub fn lookup_direct(&self, batch: &Batch) -> Result<(Vec<f32>, f64)> {
        let (out, unique_rows) = self.fetch_pooled(&batch.ids, false)?;
        let sim = self.net.record(Link::PS_EW, unique_rows * self.dim_per_group * 4);
        Ok((out, sim))
    }

    /// Steps (6)-(7): receive activation gradients, aggregate per unique
    /// row, put them to the PS in one batch, and release the buffer entries.
    /// Returns simulated comm secs.
    pub fn push_grads(&self, sample_ids: &[SampleId], grad_emb: &[f32]) -> Result<f64> {
        let emb_dim = self.emb_dim();
        anyhow::ensure!(grad_emb.len() == sample_ids.len() * emb_dim, "grad shape mismatch");
        // NN -> embedding worker transfer of the gradients (possibly lossy).
        let mut grads = grad_emb.to_vec();
        let mut sim = if self.compress {
            let c = CompressedValues::compress(&grads, emb_dim);
            let s = self.net.record(Link::EW_NN, c.wire_bytes());
            c.decompress_into(&mut grads);
            s
        } else {
            self.net.record(Link::EW_NN, grads.len() * 4)
        };
        sim += self.push_grads_raw(sample_ids, &grads)?;
        Ok(sim)
    }

    /// Steps (6)-(7) minus the NN→worker transfer: the gradients are already
    /// resident at the worker (an out-of-process deployment received them
    /// over the wire). Aggregates per unique row, puts one batch to the PS,
    /// and releases the buffer entries; returns the simulated seconds of the
    /// worker→PS leg. Re-buffers the samples on a failed put so the exact
    /// same push can be retried (§4.2.4 recovery).
    pub fn push_grads_raw(&self, sample_ids: &[SampleId], grads: &[f32]) -> Result<f64> {
        let emb_dim = self.emb_dim();
        anyhow::ensure!(grads.len() == sample_ids.len() * emb_dim, "grad shape mismatch");
        let d = self.dim_per_group;
        // Take the batch out of the buffer all-or-nothing: if any sid is
        // missing, the entries already removed go straight back, so a
        // partially-resolvable batch stays retryable instead of losing the
        // samples that happened to precede the missing one.
        let feats: Vec<IdFeatures> = {
            let mut buf = self.buffer.lock().unwrap();
            let mut taken: Vec<IdFeatures> = Vec::with_capacity(sample_ids.len());
            for sid in sample_ids {
                match buf.remove(sid) {
                    Some(f) => taken.push(f),
                    None => {
                        for (&s, f) in sample_ids.iter().zip(taken.drain(..)) {
                            buf.insert(s, f);
                        }
                        anyhow::bail!("sample {sid:#x} not buffered for backward");
                    }
                }
            }
            taken
        };

        // Aggregate gradients per unique key (first-occurrence order, same
        // dedup as the forward fetch) so each row crosses the wire and hits
        // its shard exactly once.
        let (keys, index) = self.unique_keys(&feats);
        let mut acc = vec![0.0f32; keys.len() * d];
        let mut scaled = vec![0.0f32; d];
        for (i, f) in feats.iter().enumerate() {
            for (g, group) in f.groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let gsl = &grads[i * emb_dim + g * d..i * emb_dim + (g + 1) * d];
                let src: &[f32] = if self.pooling == Pooling::Mean {
                    let inv = 1.0 / group.len() as f32;
                    for (s, &x) in scaled.iter_mut().zip(gsl) {
                        *s = x * inv;
                    }
                    &scaled
                } else {
                    gsl
                };
                for &id in group {
                    let slot = index[&(g as u32, id)];
                    for (o, &x) in acc[slot * d..(slot + 1) * d].iter_mut().zip(src) {
                        *o += x;
                    }
                }
            }
        }
        // A failed remote put must not lose the batch: the samples were
        // already removed from the buffer above, so put them back before
        // surfacing the error. The caller (or the trainer's gradient
        // applier) can then retry the exact same push — without this, one
        // dropped TCP connection permanently discarded the samples and the
        // batch became unretryable.
        if let Err(e) = self.ps.put_grads(&keys, &acc) {
            self.counters.put_failures.fetch_add(1, Ordering::Relaxed);
            self.counters
                .rebuffered_samples
                .fetch_add(sample_ids.len() as u64, Ordering::Relaxed);
            let mut buf = self.buffer.lock().unwrap();
            for (&sid, f) in sample_ids.iter().zip(feats) {
                buf.insert(sid, f);
            }
            return Err(e).context("embedding PS put (samples re-buffered for retry)");
        }
        // The PS accepted the batch — reconcile any cached copies of the
        // pushed rows (SGD mirrors the identical update in place; stateful
        // optimizers invalidate). Strictly after the successful put: a
        // failed put must leave the cache untouched so the retry path sees
        // the same world it left.
        if let Some(c) = &self.cache {
            c.push_applied(&keys, &acc);
        }
        // Flush statistics only count on success: a re-buffered batch will
        // come back through here, and counting it per attempt would tally
        // the same samples (and the same dedup savings) twice.
        let total_ids: usize = feats.iter().map(|f| f.n_ids()).sum();
        self.counters.batches_flushed.fetch_add(1, Ordering::Relaxed);
        self.counters.samples_flushed.fetch_add(sample_ids.len() as u64, Ordering::Relaxed);
        self.counters.grad_ids.fetch_add(total_ids as u64, Ordering::Relaxed);
        self.counters.rows_put.fetch_add(keys.len() as u64, Ordering::Relaxed);
        Ok(self.net.record(Link::PS_EW, keys.len() * d * 4))
    }

    /// Drop specific buffered samples (a gradient applier that has given up
    /// on a batch calls this so the entries `push_grads` re-buffered for
    /// retry don't accumulate forever — §4.2.4 tolerates the lost update,
    /// but the buffer must stay bounded).
    pub fn discard(&self, sample_ids: &[SampleId]) {
        let mut buf = self.buffer.lock().unwrap();
        for sid in sample_ids {
            buf.remove(sid);
        }
    }

    /// Buffered (in-flight) samples.
    pub fn buffered(&self) -> usize {
        self.buffer.lock().unwrap().len()
    }

    /// §4.2.4: "The embedding worker has no fault recovery schema — once a
    /// failure happens, the local buffer ... will be simply abandoned."
    pub fn abandon_buffer(&self) {
        self.buffer.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        EmbeddingConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };
    use crate::embedding::EmbeddingPs;

    use crate::data::SyntheticDataset;

    fn setup(pooling: Pooling, compress: bool) -> (Arc<EmbeddingPs>, EmbeddingWorker, ModelConfig) {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 1));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let w = EmbeddingWorker::new(3, ps.clone(), &model, net, compress);
        (ps, w, model)
    }

    fn feats(a: &[u64], b: &[u64]) -> IdFeatures {
        IdFeatures { groups: vec![a.to_vec(), b.to_vec()] }
    }

    #[test]
    fn register_mints_ranked_ids() {
        let (_, w, _) = setup(Pooling::Sum, false);
        let ids = w.register(vec![feats(&[1], &[2]), feats(&[3], &[4])]);
        assert_eq!(ids.len(), 2);
        assert_ne!(ids[0], ids[1]);
        assert!(ids.iter().all(|&sid| crate::data::sample::sample_id_rank(sid) == 3));
        assert_eq!(w.buffered(), 2);
    }

    #[test]
    fn pull_pools_sum_of_rows() {
        let (ps, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[10, 11], &[20])]);
        let (emb, _) = w.pull(&sids).unwrap();
        assert_eq!(emb.len(), 8);
        // Manual pooling.
        let mut want = vec![0.0f32; 8];
        let mut row = vec![0.0f32; 4];
        for id in [10u64, 11] {
            ps.get(0, id, &mut row);
            for (o, &x) in want[..4].iter_mut().zip(&row) {
                *o += x;
            }
        }
        ps.get(1, 20, &mut row);
        want[4..].copy_from_slice(&row);
        for (a, b) in emb.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_pooling_divides() {
        let (ps, w, _) = setup(Pooling::Mean, false);
        let sids = w.register(vec![feats(&[5, 5], &[7])]);
        let (emb, _) = w.pull(&sids).unwrap();
        let mut row = vec![0.0f32; 4];
        ps.get(0, 5, &mut row);
        for (a, b) in emb[..4].iter().zip(&row) {
            assert!((a - b).abs() < 1e-6, "mean of two equal rows is the row");
        }
    }

    #[test]
    fn push_grads_updates_ps_and_clears_buffer() {
        let (ps, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[42], &[43])]);
        let mut before = vec![0.0f32; 4];
        ps.get(0, 42, &mut before);
        let grad = vec![1.0f32; 8];
        w.push_grads(&sids, &grad).unwrap();
        let mut after = vec![0.0f32; 4];
        ps.get(0, 42, &mut after);
        // SGD lr 0.5, grad 1 => delta -0.5.
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6);
        }
        assert_eq!(w.buffered(), 0);
        // Double-push is an error (buffer entry consumed).
        assert!(w.push_grads(&sids, &grad).is_err());
    }

    #[test]
    fn duplicate_ids_aggregate_into_one_put() {
        // A sample containing the same id twice sends ONE row whose gradient
        // is the sum of both occurrences (index compression semantics).
        let (ps, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[9, 9], &[8])]);
        let mut before = vec![0.0f32; 4];
        ps.get(0, 9, &mut before);
        w.push_grads(&sids, &vec![1.0f32; 8]).unwrap();
        let mut after = vec![0.0f32; 4];
        ps.get(0, 9, &mut after);
        // Two occurrences, SGD lr 0.5, grad 1 each => one put of grad 2.
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 1.0 - a).abs() < 1e-6, "{b} vs {a}");
        }
    }

    #[test]
    fn failed_put_rebuffers_samples_so_push_can_be_retried() {
        use crate::service::{PsBackend, PsStats};
        use std::sync::atomic::{AtomicBool, Ordering};

        /// A PS whose puts can be switched to fail — a dropped TCP
        /// connection, in miniature.
        struct FlakyPs {
            inner: Arc<EmbeddingPs>,
            fail_puts: AtomicBool,
        }
        impl PsBackend for FlakyPs {
            fn dim(&self) -> usize {
                PsBackend::dim(self.inner.as_ref())
            }
            fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> anyhow::Result<()> {
                self.inner.get_many(keys, out);
                Ok(())
            }
            fn put_grads(&self, keys: &[(u32, u64)], grads: &[f32]) -> anyhow::Result<()> {
                anyhow::ensure!(!self.fail_puts.load(Ordering::SeqCst), "injected put failure");
                self.inner.put_grads(keys, grads);
                Ok(())
            }
            fn stats(&self) -> anyhow::Result<PsStats> {
                PsBackend::stats(self.inner.as_ref())
            }
        }

        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let inner = Arc::new(EmbeddingPs::new(&cfg, 4, 1));
        let flaky =
            Arc::new(FlakyPs { inner: inner.clone(), fail_puts: AtomicBool::new(true) });
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let w = EmbeddingWorker::new(0, flaky.clone(), &model, net, false);

        let sids = w.register(vec![feats(&[42], &[43])]);
        let mut before = vec![0.0f32; 4];
        inner.get(0, 42, &mut before);
        let grad = vec![1.0f32; 8];

        // Failing put: error surfaces AND the samples are back in the
        // buffer (they used to be gone for good).
        assert!(w.push_grads(&sids, &grad).is_err());
        assert_eq!(w.buffered(), 1, "failed put must re-buffer its samples");
        let s = w.stats();
        assert_eq!(s.put_failures, 1);
        assert_eq!(s.rebuffered_samples, 1);
        assert_eq!(s.samples_flushed, 0, "a failed flush must not count");
        assert_eq!(s.rows_put, 0);

        // The PS heals; the identical retry succeeds and applies once.
        flaky.fail_puts.store(false, Ordering::SeqCst);
        w.push_grads(&sids, &grad).unwrap();
        assert_eq!(w.buffered(), 0);
        let mut after = vec![0.0f32; 4];
        inner.get(0, 42, &mut after);
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6, "exactly one SGD step expected");
        }
        // The retried batch counts exactly once: one flush, one sample, and
        // the dedup tallies reflect a single replay of the batch — not one
        // per attempt.
        let s = w.stats();
        assert_eq!(s.batches_flushed, 1);
        assert_eq!(s.samples_flushed, 1, "each sample counts once per successful flush");
        assert_eq!(s.grad_ids, 2, "one occurrence per group, counted once");
        assert_eq!(s.rows_put, 2);
        assert_eq!(s.put_failures, 1);
    }

    #[test]
    fn stats_count_dedup_hits_once_per_flush() {
        let (_, w, _) = setup(Pooling::Sum, false);
        // 4 id occurrences in group 0 but only 2 unique rows; 2 unique in
        // group 1.
        let sids = w.register(vec![feats(&[9, 9], &[8]), feats(&[9, 7], &[6])]);
        assert_eq!(w.stats().samples_registered, 2);
        let (_, _) = w.pull(&sids).unwrap();
        let s = w.stats();
        assert_eq!(s.batches_fetched, 1);
        assert_eq!(s.ids_looked_up, 6);
        assert_eq!(s.rows_fetched, 4, "9 appears three times but is fetched once");
        assert_eq!(s.dedup_hits_forward(), 2);

        w.push_grads(&sids, &vec![1.0f32; 16]).unwrap();
        let s = w.stats();
        assert_eq!(s.samples_flushed, 2);
        assert_eq!(s.grad_ids, 6);
        assert_eq!(s.rows_put, 4);
        assert_eq!(s.dedup_hits_backward(), 2);
    }

    #[test]
    fn pull_rows_is_pull_without_the_nn_leg() {
        // With compression off the two entry points agree exactly; the raw
        // variant must not charge the EW→NN link (that leg happens on a real
        // wire in the out-of-process deployment).
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, 4, 1));
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let w = EmbeddingWorker::new(0, ps, &model, net.clone(), false);
        let sids = w.register(vec![feats(&[1, 2], &[3])]);
        let (raw, _) = w.pull_rows(&sids).unwrap();
        assert_eq!(net.link_bytes(Link::EW_NN), 0, "raw pull must not charge EW→NN");
        assert!(net.link_bytes(Link::PS_EW) > 0);
        let (full, _) = w.pull(&sids).unwrap();
        assert_eq!(raw, full);
        assert!(net.link_bytes(Link::EW_NN) > 0, "full pull charges EW→NN");
    }

    #[test]
    fn compressed_pull_is_close_to_exact() {
        let (_, w_exact, _) = setup(Pooling::Sum, false);
        let (_, w_comp, _) = setup(Pooling::Sum, true);
        let f = vec![feats(&[1, 2, 3], &[4, 5, 6])];
        let se = w_exact.register(f.clone());
        let sc = w_comp.register(f);
        let (a, _) = w_exact.pull(&se).unwrap();
        let (b, _) = w_comp.pull(&sc).unwrap();
        let norm = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= norm * 2.0f32.powi(-10) + 1e-6);
        }
    }

    #[test]
    fn lookup_direct_matches_pull() {
        let (_, w, model) = setup(Pooling::Sum, false);
        let ds = SyntheticDataset::new(&model, 1000, 1.0, 5);
        let batch = ds.test_batch(6);
        let (direct, _) = w.lookup_direct(&batch).unwrap();
        let sids = w.register(batch.ids.clone());
        let (pulled, _) = w.pull(&sids).unwrap();
        assert_eq!(direct, pulled);
    }

    #[test]
    fn abandon_buffer_drops_state() {
        let (_, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[1], &[2])]);
        w.abandon_buffer();
        assert_eq!(w.buffered(), 0);
        assert!(w.pull(&sids).is_err());
    }

    #[test]
    fn simulated_traffic_accounted() {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, 4, 1));
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let w = EmbeddingWorker::new(0, ps, &model, net.clone(), false);
        let sids = w.register(vec![feats(&[1, 2], &[3])]);
        let (_, sim) = w.pull(&sids).unwrap();
        assert!(sim > 0.0);
        assert!(net.total_bytes() > 0);
    }

    #[test]
    fn unknown_sample_id_is_error() {
        let (_, w, _) = setup(Pooling::Sum, false);
        assert!(w.pull(&[999]).is_err());
    }

    fn cached_worker(
        lr: f32,
    ) -> (Arc<EmbeddingPs>, EmbeddingWorker, Arc<EmbCache>, ModelConfig) {
        use crate::worker::cache::{EwCacheParams, PushPolicy};
        let (ps, _, model) = setup(Pooling::Sum, false);
        let cache = Arc::new(EmbCache::new(
            EwCacheParams {
                capacity: 64,
                staleness_ticks: 100,
                admit_threshold: 1,
                push: PushPolicy::MirrorSgd { lr },
            },
            model.emb_dim_per_group,
        ));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let w = EmbeddingWorker::new(1, ps.clone(), &model, net, false)
            .with_cache(Some(cache.clone()));
        (ps, w, cache, model)
    }

    #[test]
    fn cached_worker_hits_locally_and_mirrors_pushes_exactly() {
        // lr must match the PS's optimizer (setup uses SGD lr 0.5) for the
        // mirror to replay the identical update.
        let (ps, w, _cache, _) = cached_worker(0.5);

        // First pull fetches and admits; the repeat is served locally and
        // must be bitwise the same activation.
        let sids = w.register(vec![feats(&[10, 11], &[20])]);
        let (a, _) = w.pull(&sids).unwrap();
        let sids2 = w.register(vec![feats(&[10, 11], &[20])]);
        let (b, _) = w.pull(&sids2).unwrap();
        assert_eq!(a, b, "cached pull must equal the fetched pull bitwise");
        let s = w.stats();
        assert_eq!(s.rows_fetched, 3, "the repeat pull reached the PS for nothing");
        assert_eq!(w.cache_stats().hits, 3);

        // Push through the worker: the PS applies SGD and the cache mirrors
        // it, so a subsequent pull still hits AND matches the PS bitwise.
        w.push_grads(&sids2, &vec![1.0f32; 8]).unwrap();
        let sids3 = w.register(vec![feats(&[10], &[20])]);
        let (c, _) = w.pull(&sids3).unwrap();
        let mut want = vec![0.0f32; 4];
        ps.get(0, 10, &mut want);
        assert_eq!(&c[..4], &want[..], "mirrored row must equal the PS row bitwise");
        assert_eq!(w.stats().rows_fetched, 3, "the mirror kept the rows servable");
        assert!(w.cache_stats().updates >= 3);
    }

    #[test]
    fn eval_lookup_bypasses_the_cache() {
        let (_, w, _cache, model) = cached_worker(0.5);
        let sids = w.register(vec![feats(&[1, 2], &[3])]);
        w.pull(&sids).unwrap();
        let before = w.cache_stats();
        assert!(before.misses > 0, "warm-up went through the cache");
        let ds = SyntheticDataset::new(&model, 1000, 1.0, 5);
        let batch = ds.test_batch(4);
        w.lookup_direct(&batch).unwrap();
        assert_eq!(w.cache_stats(), before, "eval lookups never touch the cache");
    }
}
