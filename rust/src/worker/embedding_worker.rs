//! Embedding worker (paper Algorithm 1 + §4.2.1 buffering).
//!
//! Forward task: receive ID-type features from the data loader, mint a
//! sample ID (top byte = this worker's rank, footnote 3), buffer the features
//! in the *ID type feature hash-map*, fetch rows from the embedding PS,
//! pool per feature group, and ship the aggregated activation to the NN
//! worker. Backward task: receive the activation's gradient keyed by sample
//! ID, look up the buffered ID features, fan the gradient out to the rows and
//! `put` it to the PS. Both tasks run lock-free with respect to each other
//! (the buffer lock is per-operation, never held across PS calls).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::comm::compress::CompressedValues;
use crate::comm::netsim::{Link, NetSim};
use crate::config::{ModelConfig, Pooling};
use crate::data::sample::{make_sample_id, Batch, IdFeatures, SampleId};
use crate::embedding::EmbeddingPs;

/// One embedding worker.
pub struct EmbeddingWorker {
    rank: u8,
    ps: Arc<EmbeddingPs>,
    n_groups: usize,
    dim_per_group: usize,
    pooling: Pooling,
    buffer: Mutex<HashMap<SampleId, IdFeatures>>,
    counter: AtomicU64,
    net: Arc<NetSim>,
    /// Apply the §4.2.3 lossy value compression to activation/grad traffic.
    compress: bool,
}

impl EmbeddingWorker {
    pub fn new(
        rank: u8,
        ps: Arc<EmbeddingPs>,
        model: &ModelConfig,
        net: Arc<NetSim>,
        compress: bool,
    ) -> Self {
        assert_eq!(ps.dim(), model.emb_dim_per_group, "PS dim != model group dim");
        Self {
            rank,
            ps,
            n_groups: model.n_groups,
            dim_per_group: model.emb_dim_per_group,
            pooling: model.pooling,
            buffer: Mutex::new(HashMap::new()),
            counter: AtomicU64::new(0),
            net,
            compress,
        }
    }

    pub fn rank(&self) -> u8 {
        self.rank
    }

    pub fn emb_dim(&self) -> usize {
        self.n_groups * self.dim_per_group
    }

    /// Step (1) of the training procedure: buffer ID features, mint sample
    /// ids to hand back to the data loader.
    pub fn register(&self, ids: Vec<IdFeatures>) -> Vec<SampleId> {
        let mut buf = self.buffer.lock().unwrap();
        ids.into_iter()
            .map(|f| {
                let sid = make_sample_id(self.rank, self.counter.fetch_add(1, Ordering::Relaxed));
                buf.insert(sid, f);
                sid
            })
            .collect()
    }

    /// Pool one sample's groups into `out[emb_dim]`, fetching rows from PS.
    /// Allocation-free on the hot path: `row_buf` is a reusable scratch row
    /// and pooling accumulates directly from the shard (`get_into_acc`).
    fn pool_into(&self, feats: &IdFeatures, out: &mut [f32], row_buf: &mut Vec<f32>) -> usize {
        let d = self.dim_per_group;
        row_buf.resize(d, 0.0);
        let mut rows_fetched = 0;
        for (g, group) in feats.groups.iter().enumerate() {
            let dst = &mut out[g * d..(g + 1) * d];
            dst.fill(0.0);
            if group.is_empty() {
                continue;
            }
            for &id in group {
                self.ps.get(g as u32, id, row_buf);
                for (o, &x) in dst.iter_mut().zip(row_buf.iter()) {
                    *o += x;
                }
            }
            rows_fetched += group.len();
            if self.pooling == Pooling::Mean {
                let inv = 1.0 / group.len() as f32;
                for o in dst.iter_mut() {
                    *o *= inv;
                }
            }
        }
        rows_fetched
    }

    /// Steps (3)-(4): the NN worker's pull. Returns the pooled activations
    /// (`[B, emb_dim]` flattened) and the simulated communication seconds
    /// (PS->worker rows + worker->NN activation transfer).
    pub fn pull(&self, sample_ids: &[SampleId]) -> Result<(Vec<f32>, f64)> {
        let emb_dim = self.emb_dim();
        let mut out = vec![0.0f32; sample_ids.len() * emb_dim];
        let mut row_buf = Vec::new();
        let mut rows_fetched = 0usize;
        {
            let buf = self.buffer.lock().unwrap();
            for (i, sid) in sample_ids.iter().enumerate() {
                let feats = buf
                    .get(sid)
                    .with_context(|| format!("sample {sid:#x} not buffered (worker {})", self.rank))?;
                rows_fetched +=
                    self.pool_into(feats, &mut out[i * emb_dim..(i + 1) * emb_dim], &mut row_buf);
            }
        }
        // PS -> embedding worker: raw rows.
        let mut sim = self.net.record(Link::CpuCpu, rows_fetched * self.dim_per_group * 4);
        // embedding worker -> NN worker: pooled activations (fp16+scale when
        // compression is on; we run the real round-trip so the numeric effect
        // of the lossy path is part of training).
        if self.compress {
            let c = CompressedValues::compress(&out, emb_dim);
            sim += self.net.record(Link::CpuGpu, c.wire_bytes());
            c.decompress_into(&mut out);
        } else {
            sim += self.net.record(Link::CpuGpu, out.len() * 4);
        }
        Ok((out, sim))
    }

    /// Eval-path lookup straight from a batch (no sample-id buffering).
    pub fn lookup_direct(&self, batch: &Batch) -> (Vec<f32>, f64) {
        let emb_dim = self.emb_dim();
        let mut out = vec![0.0f32; batch.len() * emb_dim];
        let mut row_buf = Vec::new();
        let mut rows = 0;
        for (i, feats) in batch.ids.iter().enumerate() {
            rows += self.pool_into(feats, &mut out[i * emb_dim..(i + 1) * emb_dim], &mut row_buf);
        }
        let sim = self.net.record(Link::CpuCpu, rows * self.dim_per_group * 4);
        (out, sim)
    }

    /// Steps (6)-(7): receive activation gradients, fan out to rows, put to
    /// the PS, and release the buffer entries. Returns simulated comm secs.
    pub fn push_grads(&self, sample_ids: &[SampleId], grad_emb: &[f32]) -> Result<f64> {
        let emb_dim = self.emb_dim();
        anyhow::ensure!(grad_emb.len() == sample_ids.len() * emb_dim, "grad shape mismatch");
        // NN -> embedding worker transfer of the gradients (possibly lossy).
        let mut grads = grad_emb.to_vec();
        let mut sim = if self.compress {
            let c = CompressedValues::compress(&grads, emb_dim);
            let s = self.net.record(Link::CpuGpu, c.wire_bytes());
            c.decompress_into(&mut grads);
            s
        } else {
            self.net.record(Link::CpuGpu, grads.len() * 4)
        };

        let d = self.dim_per_group;
        let mut rows_put = 0usize;
        let mut taken: Vec<(usize, IdFeatures)> = Vec::with_capacity(sample_ids.len());
        {
            let mut buf = self.buffer.lock().unwrap();
            for (i, sid) in sample_ids.iter().enumerate() {
                let feats = buf
                    .remove(sid)
                    .with_context(|| format!("sample {sid:#x} not buffered for backward"))?;
                taken.push((i, feats));
            }
        }
        let mut scaled = vec![0.0f32; d];
        for (i, feats) in taken {
            for (g, group) in feats.groups.iter().enumerate() {
                if group.is_empty() {
                    continue;
                }
                let gsl = &grads[i * emb_dim + g * d..i * emb_dim + (g + 1) * d];
                let src: &[f32] = if self.pooling == Pooling::Mean {
                    let inv = 1.0 / group.len() as f32;
                    for (s, &x) in scaled.iter_mut().zip(gsl) {
                        *s = x * inv;
                    }
                    &scaled
                } else {
                    gsl
                };
                for &id in group {
                    self.ps.put_grad(g as u32, id, src);
                    rows_put += 1;
                }
            }
        }
        sim += self.net.record(Link::CpuCpu, rows_put * d * 4);
        Ok(sim)
    }

    /// Buffered (in-flight) samples.
    pub fn buffered(&self) -> usize {
        self.buffer.lock().unwrap().len()
    }

    /// §4.2.4: "The embedding worker has no fault recovery schema — once a
    /// failure happens, the local buffer ... will be simply abandoned."
    pub fn abandon_buffer(&self) {
        self.buffer.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{
        EmbeddingConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };
    
    use crate::data::SyntheticDataset;

    fn setup(pooling: Pooling, compress: bool) -> (Arc<EmbeddingPs>, EmbeddingWorker, ModelConfig) {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 1));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let w = EmbeddingWorker::new(3, ps.clone(), &model, net, compress);
        (ps, w, model)
    }

    fn feats(a: &[u64], b: &[u64]) -> IdFeatures {
        IdFeatures { groups: vec![a.to_vec(), b.to_vec()] }
    }

    #[test]
    fn register_mints_ranked_ids() {
        let (_, w, _) = setup(Pooling::Sum, false);
        let ids = w.register(vec![feats(&[1], &[2]), feats(&[3], &[4])]);
        assert_eq!(ids.len(), 2);
        assert_ne!(ids[0], ids[1]);
        assert!(ids.iter().all(|&sid| crate::data::sample::sample_id_rank(sid) == 3));
        assert_eq!(w.buffered(), 2);
    }

    #[test]
    fn pull_pools_sum_of_rows() {
        let (ps, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[10, 11], &[20])]);
        let (emb, _) = w.pull(&sids).unwrap();
        assert_eq!(emb.len(), 8);
        // Manual pooling.
        let mut want = vec![0.0f32; 8];
        let mut row = vec![0.0f32; 4];
        for id in [10u64, 11] {
            ps.get(0, id, &mut row);
            for (o, &x) in want[..4].iter_mut().zip(&row) {
                *o += x;
            }
        }
        ps.get(1, 20, &mut row);
        want[4..].copy_from_slice(&row);
        for (a, b) in emb.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn mean_pooling_divides() {
        let (ps, w, _) = setup(Pooling::Mean, false);
        let sids = w.register(vec![feats(&[5, 5], &[7])]);
        let (emb, _) = w.pull(&sids).unwrap();
        let mut row = vec![0.0f32; 4];
        ps.get(0, 5, &mut row);
        for (a, b) in emb[..4].iter().zip(&row) {
            assert!((a - b).abs() < 1e-6, "mean of two equal rows is the row");
        }
    }

    #[test]
    fn push_grads_updates_ps_and_clears_buffer() {
        let (ps, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[42], &[43])]);
        let mut before = vec![0.0f32; 4];
        ps.get(0, 42, &mut before);
        let grad = vec![1.0f32; 8];
        w.push_grads(&sids, &grad).unwrap();
        let mut after = vec![0.0f32; 4];
        ps.get(0, 42, &mut after);
        // SGD lr 0.5, grad 1 => delta -0.5.
        for (b, a) in before.iter().zip(&after) {
            assert!((b - 0.5 - a).abs() < 1e-6);
        }
        assert_eq!(w.buffered(), 0);
        // Double-push is an error (buffer entry consumed).
        assert!(w.push_grads(&sids, &grad).is_err());
    }

    #[test]
    fn compressed_pull_is_close_to_exact() {
        let (_, w_exact, _) = setup(Pooling::Sum, false);
        let (_, w_comp, _) = setup(Pooling::Sum, true);
        let f = vec![feats(&[1, 2, 3], &[4, 5, 6])];
        let se = w_exact.register(f.clone());
        let sc = w_comp.register(f);
        let (a, _) = w_exact.pull(&se).unwrap();
        let (b, _) = w_comp.pull(&sc).unwrap();
        let norm = a.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= norm * 2.0f32.powi(-10) + 1e-6);
        }
    }

    #[test]
    fn lookup_direct_matches_pull() {
        let (_, w, model) = setup(Pooling::Sum, false);
        let ds = SyntheticDataset::new(&model, 1000, 1.0, 5);
        let batch = ds.test_batch(6);
        let (direct, _) = w.lookup_direct(&batch);
        let sids = w.register(batch.ids.clone());
        let (pulled, _) = w.pull(&sids).unwrap();
        assert_eq!(direct, pulled);
    }

    #[test]
    fn abandon_buffer_drops_state() {
        let (_, w, _) = setup(Pooling::Sum, false);
        let sids = w.register(vec![feats(&[1], &[2])]);
        w.abandon_buffer();
        assert_eq!(w.buffered(), 0);
        assert!(w.pull(&sids).is_err());
    }

    #[test]
    fn simulated_traffic_accounted() {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 256,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.5,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, 4, 1));
        let net = Arc::new(NetSim::new(NetModelConfig::paper_like()));
        let w = EmbeddingWorker::new(0, ps, &model, net.clone(), false);
        let sids = w.register(vec![feats(&[1, 2], &[3])]);
        let (_, sim) = w.pull(&sids).unwrap();
        assert!(sim > 0.0);
        assert!(net.total_bytes() > 0);
    }

    #[test]
    fn unknown_sample_id_is_error() {
        let (_, w, _) = setup(Pooling::Sum, false);
        assert!(w.pull(&[999]).is_err());
    }
}
