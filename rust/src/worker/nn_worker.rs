//! NN worker state (paper Algorithm 2 + §4.2.1's input sample hash-map).
//!
//! Holds the *input sample hash-map* keyed by sample ID, valued by the
//! Non-ID features + label (what the data loader dispatches in step (2));
//! when the pooled embedding arrives from an embedding worker the entry is
//! popped and consumed into the mini-batch. The dense parameters always live
//! in this worker's memory (paper: "the parameter of the NN always locates
//! in the device RAM of the NN worker").
//!
//! `rank` is the worker's **global** ring rank: in the simulated cluster one
//! `NnWorker` exists per thread, while in the multi-process deployment
//! (`persia train-worker --rank R --world N`) each process owns exactly one,
//! carrying its `--rank`. The buffer is process-local either way — sample
//! IDs never cross the process boundary, only dense gradients do (via the
//! ring AllReduce) and embedding rows/gradients (via the shared PS).

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::data::sample::SampleId;

/// Buffered (nid, label) tuple.
struct Pending {
    nid: Vec<f32>,
    label: f32,
}

/// The NN-worker-side sample buffer.
pub struct NnWorker {
    rank: usize,
    buffer: Mutex<HashMap<SampleId, Pending>>,
    nid_dim: usize,
}

impl NnWorker {
    /// An empty input buffer for dense rank `rank` (`nid_dim`-wide rows).
    pub fn new(rank: usize, nid_dim: usize) -> Self {
        Self { rank, buffer: Mutex::new(HashMap::new()), nid_dim }
    }

    /// This worker's global ring rank.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Step (2): the loader dispatches the Non-ID features + label.
    pub fn receive(&self, sid: SampleId, nid: Vec<f32>, label: f32) {
        debug_assert_eq!(nid.len(), self.nid_dim);
        self.buffer.lock().unwrap().insert(sid, Pending { nid, label });
    }

    /// Bulk receive for a whole dispatched batch.
    pub fn receive_batch(&self, sids: &[SampleId], nid: &[f32], labels: &[f32]) {
        assert_eq!(nid.len(), sids.len() * self.nid_dim);
        assert_eq!(labels.len(), sids.len());
        let mut buf = self.buffer.lock().unwrap();
        for (i, &sid) in sids.iter().enumerate() {
            buf.insert(
                sid,
                Pending {
                    nid: nid[i * self.nid_dim..(i + 1) * self.nid_dim].to_vec(),
                    label: labels[i],
                },
            );
        }
    }

    /// Step (5): pop the buffered entries for an arrived embedding batch and
    /// assemble the mini-batch tensors (flat nid + labels, loader order).
    pub fn take(&self, sids: &[SampleId]) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut buf = self.buffer.lock().unwrap();
        let mut nid = Vec::with_capacity(sids.len() * self.nid_dim);
        let mut labels = Vec::with_capacity(sids.len());
        for sid in sids {
            let p = buf
                .remove(sid)
                .with_context(|| format!("sample {sid:#x} missing from input hash-map"))?;
            nid.extend_from_slice(&p.nid);
            labels.push(p.label);
        }
        Ok((nid, labels))
    }

    /// Pending (dispatched, not yet consumed) samples.
    pub fn buffered(&self) -> usize {
        self.buffer.lock().unwrap().len()
    }

    /// Fault path: drop all pending inputs (worker restart from checkpoint).
    pub fn clear(&self) {
        self.buffer.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn receive_take_roundtrip_preserves_order() {
        let w = NnWorker::new(0, 2);
        w.receive_batch(&[10, 11, 12], &[1., 2., 3., 4., 5., 6.], &[1.0, 0.0, 1.0]);
        assert_eq!(w.buffered(), 3);
        // Take in a different order than insertion.
        let (nid, labels) = w.take(&[12, 10]).unwrap();
        assert_eq!(nid, vec![5., 6., 1., 2.]);
        assert_eq!(labels, vec![1.0, 1.0]);
        assert_eq!(w.buffered(), 1);
    }

    #[test]
    fn take_missing_is_error() {
        let w = NnWorker::new(0, 1);
        w.receive(5, vec![0.5], 1.0);
        assert!(w.take(&[5, 6]).is_err());
    }

    #[test]
    fn clear_empties_buffer() {
        let w = NnWorker::new(1, 1);
        w.receive(1, vec![0.0], 0.0);
        w.clear();
        assert_eq!(w.buffered(), 0);
    }
}
