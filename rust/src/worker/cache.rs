//! Bounded-staleness hot-embedding cache at the embedding-worker tier.
//!
//! The hybrid algorithm (paper §4.2) already tolerates bounded staleness in
//! the embedding layer — a looked-up row may lag the freshest PS state by up
//! to τ optimization steps. Every lookup still paid a full PS round-trip,
//! even for the Zipf-hot head that dominates traffic. This module spends
//! that staleness budget on a per-worker cache instead: a hot row fetched
//! once may serve repeat lookups for up to `staleness` fetch ticks before it
//! must be refetched, absorbing the hot head's GET traffic entirely
//! worker-side (ScaleFreeCTR's MixCache applied at the worker tier).
//!
//! Correctness rules, in order of precedence:
//!
//! * **Deterministic mode never sees this cache.** The trainer refuses to
//!   construct one (`Trainer::ew_cache_params` returns `None`), so every
//!   bitwise-parity claim of the deterministic suites holds by construction.
//! * **Gradient pushes write through.** The PS is always updated first; the
//!   cached copy is then either mirrored (SGD: `w -= lr·g` is stateless, so
//!   the worker replays the *identical* f32 update on the cached row and the
//!   copy stays bitwise-coherent with the PS for single-writer keys) or
//!   invalidated (Adagrad/Adam keep optimizer state PS-side that the worker
//!   cannot see, so the entry is dropped instead).
//! * **Version tags gate every hit.** Entries carry
//!   `(routing_epoch, fetch_tick)`: a routing-epoch bump (live resharding, a
//!   NOT_OWNER-triggered refresh) flushes the whole cache before the next
//!   fetch proceeds, and an entry older than `staleness` ticks is refetched
//!   (counted as a stale refresh, the MixCache refresh path).
//! * **ADOPT_RANK flushes.** A worker taking over a dead peer's ranks
//!   splices streams mid-window; the prefetch pipeline drops the cache along
//!   with the replay rings.
//!
//! Admission uses the same frequency sketch as the tiered store
//! ([`crate::embedding::tiered`]): a power-of-two array of saturating byte
//! counters indexed by splitmix64, so one-touch tail keys never displace a
//! hot row.
//!
//! The cache also runs the cross-rank **single-flight** dedup: concurrent
//! stage-2 scatter-gathers from different NN ranks assigned to one worker
//! used to fetch co-hot keys once *per rank*; now the first rank to miss a
//! key becomes its leader and every concurrent rank waits for that one
//! fetch instead of issuing its own (`coalesced` in [`CacheStats`]).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::config::OptimizerKind;
use crate::embedding::store::DEFAULT_ADMIT_THRESHOLD;
use crate::service::PsBackend;

/// Minimum admission-sketch size (matches the tiered store: below this,
/// aliasing of one-touch tail keys would defeat the gate).
const MIN_SKETCH: usize = 1 << 16;
/// Maximum admission-sketch size (1 MiB of counters).
const MAX_SKETCH: usize = 1 << 20;
/// How long a coalesced rank waits for the leading rank's PS fetch before
/// falling back to its own fetch. Generous: a leader riding out a PS shard
/// restart can hold the flight for several retry windows, and the fallback
/// is always correct (just an extra GET).
const FLIGHT_WAIT: Duration = Duration::from_secs(10);

fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn key_hash((g, id): (u32, u64)) -> u64 {
    splitmix64((u64::from(g) << 48) ^ id)
}

/// User-facing cache knobs (`--ew-cache-capacity`, `--ew-cache-staleness`);
/// `Trainer::ew_cache` holds `Some(EwCacheConfig)` when `--ew-cache` is on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EwCacheConfig {
    /// Maximum cached rows per embedding worker.
    pub capacity: usize,
    /// Maximum age of a served row, in *steps*. `None` picks the run's own
    /// staleness bound τ — the hybrid algorithm's contract is the default.
    pub staleness: Option<u64>,
    /// Admission-sketch touch count at which a key may enter the cache
    /// (same gate as the tiered store's hot tier).
    pub admit_threshold: u8,
}

impl Default for EwCacheConfig {
    fn default() -> Self {
        Self { capacity: 65536, staleness: None, admit_threshold: DEFAULT_ADMIT_THRESHOLD }
    }
}

impl EwCacheConfig {
    /// Reject degenerate configurations loudly (a zero-capacity or
    /// zero-staleness cache silently behaving as "off" would mask typos).
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.capacity >= 1, "--ew-cache-capacity must be at least 1");
        if let Some(s) = self.staleness {
            anyhow::ensure!(s >= 1, "--ew-cache-staleness must be at least 1 step");
        }
        anyhow::ensure!(self.admit_threshold >= 1, "cache admit threshold must be >= 1");
        Ok(())
    }
}

/// What a gradient push does to a cached row (write-through to the PS
/// happens first in every case).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PushPolicy {
    /// SGD is stateless: replay `w -= lr·g` on the cached copy, bitwise
    /// identical to the PS update for single-writer keys.
    MirrorSgd {
        /// The row-wise learning rate the PS applies.
        lr: f32,
    },
    /// Stateful optimizers (Adagrad/Adam) keep per-row accumulators the
    /// worker cannot see: drop the entry and refetch on next use.
    Invalidate,
}

/// Fully resolved construction parameters for one worker's [`EmbCache`]:
/// staleness converted from steps to fetch ticks, push policy derived from
/// the run's optimizer.
#[derive(Clone, Copy, Debug)]
pub struct EwCacheParams {
    /// Maximum cached rows.
    pub capacity: usize,
    /// Maximum entry age in fetch ticks (each batched fetch through the
    /// cache advances the tick clock by one).
    pub staleness_ticks: u64,
    /// Admission-sketch threshold.
    pub admit_threshold: u8,
    /// Push-path behavior.
    pub push: PushPolicy,
}

impl EwCacheParams {
    /// Resolve user knobs against the run: `tau` is the mode's staleness
    /// bound (the default budget), `ranks_per_worker` how many NN-rank
    /// streams this worker serves — one global step costs the worker about
    /// that many fetch ticks, so a staleness of `s` steps becomes
    /// `s × ranks_per_worker` ticks (conservative: a worker serving its
    /// ranks unevenly expires entries *early*, never late).
    pub fn resolve(
        cfg: &EwCacheConfig,
        tau: u64,
        ranks_per_worker: usize,
        optimizer: OptimizerKind,
        lr: f32,
    ) -> Self {
        let steps = cfg.staleness.unwrap_or(tau).max(1);
        let push = match optimizer {
            OptimizerKind::Sgd => PushPolicy::MirrorSgd { lr },
            OptimizerKind::Adagrad | OptimizerKind::Adam => PushPolicy::Invalidate,
        };
        Self {
            capacity: cfg.capacity.max(1),
            staleness_ticks: steps.saturating_mul(ranks_per_worker.max(1) as u64).max(1),
            admit_threshold: cfg.admit_threshold.max(1),
            push,
        }
    }
}

/// Monotonic counters of one [`EmbCache`] — the third section of the EW
/// STATS wire frame (8 × u64, merged across the tier by the trainer).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups served from a valid cached row (no PS traffic).
    pub hits: u64,
    /// Lookups that went to the PS (cold key, not admitted, or refused).
    pub misses: u64,
    /// Misses whose entry existed but aged past the staleness bound — the
    /// refresh path, a subset of `misses`.
    pub stale_refreshes: u64,
    /// Entries dropped by a gradient push under [`PushPolicy::Invalidate`].
    pub invalidations: u64,
    /// Cached rows updated in place under [`PushPolicy::MirrorSgd`].
    pub updates: u64,
    /// Whole-cache flushes (routing-epoch bump, ADOPT take-over).
    pub flushes: u64,
    /// Lookups served by waiting on another rank's in-flight fetch of the
    /// same key (the cross-rank single-flight dedup).
    pub coalesced: u64,
    /// Entries dropped by capacity eviction.
    pub evictions: u64,
}

impl CacheStats {
    /// Accumulate `other` into `self` (merging a tier's workers).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.stale_refreshes += other.stale_refreshes;
        self.invalidations += other.invalidations;
        self.updates += other.updates;
        self.flushes += other.flushes;
        self.coalesced += other.coalesced;
        self.evictions += other.evictions;
    }

    /// PS GET bytes this cache absorbed (`hits × dim × 4`).
    pub fn bytes_saved(&self, dim: usize) -> u64 {
        (self.hits + self.coalesced) * dim as u64 * 4
    }

    /// Any activity at all (gates the end-of-run summary line).
    pub fn any(&self) -> bool {
        self.hits + self.misses + self.flushes != 0
    }
}

#[derive(Default)]
struct CacheCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    stale_refreshes: AtomicU64,
    invalidations: AtomicU64,
    updates: AtomicU64,
    flushes: AtomicU64,
    coalesced: AtomicU64,
    evictions: AtomicU64,
}

struct Entry {
    row: Vec<f32>,
    /// Routing epoch the row was fetched under (entries of an older epoch
    /// never survive — the epoch check flushes wholesale before lookup).
    epoch: u64,
    /// Fetch tick of the last PS read of this row — the staleness clock.
    /// Deliberately NOT advanced by local mirror updates: writers on other
    /// workers still drift the PS row, so age is measured from the last
    /// time this worker actually read the PS.
    fetched_at: u64,
    /// Fetch tick of the last lookup (capacity eviction keys on this).
    last_used: u64,
}

struct Inner {
    map: HashMap<(u32, u64), Entry>,
    /// Saturating per-key touch counters (aliased; power-of-two length).
    freq: Vec<u8>,
    freq_mask: u64,
    /// The routing epoch the cache contents were fetched under.
    seen_epoch: u64,
}

enum FlightState {
    Pending,
    /// `None`: the leading fetch failed; waiters fall back to their own GET.
    Done(Option<Vec<f32>>),
}

struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

/// The per-embedding-worker bounded-staleness hot-row cache. All methods
/// take `&self`; the row map lock is never held across a PS call.
pub struct EmbCache {
    dim: usize,
    capacity: usize,
    staleness: u64,
    admit_threshold: u8,
    push: PushPolicy,
    clock: AtomicU64,
    inner: Mutex<Inner>,
    flights: Mutex<HashMap<(u32, u64), Arc<Flight>>>,
    counters: CacheCounters,
}

impl EmbCache {
    /// A cache for `dim`-wide embedding rows under `params`.
    pub fn new(params: EwCacheParams, dim: usize) -> Self {
        let sketch = params
            .capacity
            .saturating_mul(8)
            .next_power_of_two()
            .clamp(MIN_SKETCH, MAX_SKETCH);
        Self {
            dim,
            capacity: params.capacity.max(1),
            staleness: params.staleness_ticks.max(1),
            admit_threshold: params.admit_threshold.max(1),
            push: params.push,
            clock: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                freq: vec![0; sketch],
                freq_mask: (sketch - 1) as u64,
                seen_epoch: 0,
            }),
            flights: Mutex::new(HashMap::new()),
            counters: CacheCounters::default(),
        }
    }

    fn lock_inner(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> CacheStats {
        let c = &self.counters;
        let load = |a: &AtomicU64| a.load(Ordering::Relaxed);
        CacheStats {
            hits: load(&c.hits),
            misses: load(&c.misses),
            stale_refreshes: load(&c.stale_refreshes),
            invalidations: load(&c.invalidations),
            updates: load(&c.updates),
            flushes: load(&c.flushes),
            coalesced: load(&c.coalesced),
            evictions: load(&c.evictions),
        }
    }

    /// Resident rows (tests and diagnostics).
    pub fn len(&self) -> usize {
        self.lock_inner().map.len()
    }

    /// True when no rows are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The current fetch tick (tests pin staleness arithmetic on this).
    pub fn now(&self) -> u64 {
        self.clock.load(Ordering::Relaxed)
    }

    /// Drop every cached row (ADOPT_RANK take-over, tests). `reason` is for
    /// the log line; epoch-bump flushes announce themselves from
    /// [`EmbCache::fetch_through`] instead.
    pub fn flush(&self, reason: &str) {
        let dropped = {
            let mut inner = self.lock_inner();
            let n = inner.map.len();
            inner.map.clear();
            n
        };
        self.counters.flushes.fetch_add(1, Ordering::Relaxed);
        if dropped > 0 {
            eprintln!("EW-CACHE: flushed {dropped} rows ({reason})");
        }
    }

    /// The batched lookup: serve every key of `keys` into `rows`
    /// (`keys.len() × dim`), reading from the cache where a valid row is
    /// resident and from `ps` otherwise. Returns the number of rows this
    /// call actually fetched from the PS — the wire traffic (coalesced rows
    /// served by another rank's in-flight fetch count as zero here; the
    /// leading rank already paid for them).
    ///
    /// The routing epoch is observed first: a bump flushes the whole cache
    /// before any key is served, so no row fetched under the old shard
    /// layout outlives a live reshard or a NOT_OWNER routing refresh.
    pub fn fetch_through(
        &self,
        ps: &dyn PsBackend,
        keys: &[(u32, u64)],
        rows: &mut [f32],
    ) -> Result<usize> {
        let d = self.dim;
        debug_assert_eq!(rows.len(), keys.len() * d);
        let epoch = ps.routing_epoch();
        let now = self.clock.fetch_add(1, Ordering::Relaxed) + 1;

        // Partition into hits (served under the lock) and misses.
        let mut miss_slots: Vec<usize> = Vec::new();
        let mut admit: Vec<bool> = Vec::new();
        {
            let mut inner = self.lock_inner();
            if inner.seen_epoch != epoch {
                let dropped = inner.map.len();
                let old = inner.seen_epoch;
                inner.map.clear();
                inner.seen_epoch = epoch;
                self.counters.flushes.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "EW-CACHE: flushed {dropped} rows (routing epoch {old} -> {epoch})"
                );
            }
            for (slot, &key) in keys.iter().enumerate() {
                let mut stale = false;
                match inner.map.get_mut(&key) {
                    Some(e)
                        if e.epoch == epoch && now.saturating_sub(e.fetched_at) <= self.staleness =>
                    {
                        rows[slot * d..(slot + 1) * d].copy_from_slice(&e.row);
                        e.last_used = now;
                        self.counters.hits.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    Some(_) => stale = true,
                    None => {}
                }
                if stale {
                    inner.map.remove(&key);
                    self.counters.stale_refreshes.fetch_add(1, Ordering::Relaxed);
                }
                let idx = (key_hash(key) & inner.freq_mask) as usize;
                inner.freq[idx] = inner.freq[idx].saturating_add(1);
                admit.push(inner.freq[idx] >= self.admit_threshold);
                miss_slots.push(slot);
                self.counters.misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        if miss_slots.is_empty() {
            // Cache-aware GET planning: a fully-hit batch issues NO PS call
            // at all (the sharded client's scatter-gather never starts).
            return Ok(0);
        }

        // Single-flight claim: the first rank to miss a key leads its fetch;
        // concurrent ranks wait on the leader instead of re-fetching.
        let mut lead: Vec<usize> = Vec::new(); // indexes into miss_slots
        let mut follow: Vec<(usize, Arc<Flight>)> = Vec::new();
        let mut claimed: Vec<(u32, u64)> = Vec::new();
        {
            let mut flights = self.flights.lock().unwrap_or_else(|p| p.into_inner());
            for (mi, &slot) in miss_slots.iter().enumerate() {
                let key = keys[slot];
                match flights.get(&key) {
                    Some(f) => follow.push((mi, f.clone())),
                    None => {
                        let f = Arc::new(Flight {
                            state: Mutex::new(FlightState::Pending),
                            cv: Condvar::new(),
                        });
                        flights.insert(key, f);
                        claimed.push(key);
                        lead.push(mi);
                    }
                }
            }
        }

        let mut fetched = 0usize;
        if !lead.is_empty() {
            let lead_keys: Vec<(u32, u64)> = lead.iter().map(|&mi| keys[miss_slots[mi]]).collect();
            let mut tmp = vec![0.0f32; lead_keys.len() * d];
            let got = ps.get_many(&lead_keys, &mut tmp);
            // Resolve the flights win or lose: waiters must never hang on a
            // failed leader (they fall back to their own GET).
            {
                let mut flights = self.flights.lock().unwrap_or_else(|p| p.into_inner());
                for (i, key) in claimed.iter().enumerate() {
                    if let Some(f) = flights.remove(key) {
                        let payload = got
                            .as_ref()
                            .ok()
                            .map(|_| tmp[i * d..(i + 1) * d].to_vec());
                        *f.state.lock().unwrap_or_else(|p| p.into_inner()) =
                            FlightState::Done(payload);
                        f.cv.notify_all();
                    }
                }
            }
            got?;
            fetched += lead_keys.len();
            let mut inner = self.lock_inner();
            for (i, &mi) in lead.iter().enumerate() {
                let slot = miss_slots[mi];
                rows[slot * d..(slot + 1) * d].copy_from_slice(&tmp[i * d..(i + 1) * d]);
                if admit[mi] {
                    Self::evict_for_room(&mut inner, &self.counters, self.capacity, self.staleness, now);
                    inner.map.insert(
                        keys[slot],
                        Entry {
                            row: tmp[i * d..(i + 1) * d].to_vec(),
                            epoch,
                            fetched_at: now,
                            last_used: now,
                        },
                    );
                }
            }
        }

        // Collect the coalesced keys; anything that timed out or rode a
        // failed leader is fetched directly (always correct, never stalls).
        let mut fallback: Vec<usize> = Vec::new(); // indexes into miss_slots
        for (mi, flight) in follow {
            let mut state = flight.state.lock().unwrap_or_else(|p| p.into_inner());
            let mut waited = Duration::ZERO;
            let done = loop {
                match &*state {
                    FlightState::Done(payload) => break payload.clone(),
                    FlightState::Pending if waited >= FLIGHT_WAIT => break None,
                    FlightState::Pending => {
                        let (s, timeout) = flight
                            .cv
                            .wait_timeout(state, FLIGHT_WAIT - waited)
                            .unwrap_or_else(|p| p.into_inner());
                        state = s;
                        if timeout.timed_out() {
                            waited = FLIGHT_WAIT;
                        } else {
                            waited += Duration::from_millis(1);
                        }
                    }
                }
            };
            drop(state);
            let slot = miss_slots[mi];
            match done {
                Some(row) => {
                    rows[slot * d..(slot + 1) * d].copy_from_slice(&row);
                    self.counters.coalesced.fetch_add(1, Ordering::Relaxed);
                    // Already counted as a miss above; correct the split.
                    self.counters.misses.fetch_sub(1, Ordering::Relaxed);
                }
                None => fallback.push(mi),
            }
        }
        if !fallback.is_empty() {
            let fb_keys: Vec<(u32, u64)> =
                fallback.iter().map(|&mi| keys[miss_slots[mi]]).collect();
            let mut tmp = vec![0.0f32; fb_keys.len() * d];
            ps.get_many(&fb_keys, &mut tmp)?;
            fetched += fb_keys.len();
            let mut inner = self.lock_inner();
            for (i, &mi) in fallback.iter().enumerate() {
                let slot = miss_slots[mi];
                rows[slot * d..(slot + 1) * d].copy_from_slice(&tmp[i * d..(i + 1) * d]);
                if admit[mi] {
                    Self::evict_for_room(&mut inner, &self.counters, self.capacity, self.staleness, now);
                    inner.map.insert(
                        keys[slot],
                        Entry {
                            row: tmp[i * d..(i + 1) * d].to_vec(),
                            epoch,
                            fetched_at: now,
                            last_used: now,
                        },
                    );
                }
            }
        }
        Ok(fetched)
    }

    /// Make room for one insertion when the map is at capacity: drop expired
    /// entries first, then (if still full) the least-recently-used half via
    /// a median split — O(n) amortized over the insertions that refilled it.
    fn evict_for_room(
        inner: &mut Inner,
        counters: &CacheCounters,
        capacity: usize,
        staleness: u64,
        now: u64,
    ) {
        if inner.map.len() < capacity {
            return;
        }
        let before = inner.map.len();
        inner.map.retain(|_, e| now.saturating_sub(e.fetched_at) <= staleness);
        if inner.map.len() >= capacity {
            let mut used: Vec<u64> = inner.map.values().map(|e| e.last_used).collect();
            let mid = used.len() / 2;
            let (_, median, _) = used.select_nth_unstable(mid);
            let median = *median;
            inner.map.retain(|_, e| e.last_used > median);
        }
        counters.evictions.fetch_add((before - inner.map.len()) as u64, Ordering::Relaxed);
    }

    /// The push-path hook: the PS put for `keys`/`agg_grads` (one aggregated
    /// gradient row per unique key) has already **succeeded**; reconcile the
    /// cached copies per the [`PushPolicy`].
    pub fn push_applied(&self, keys: &[(u32, u64)], agg_grads: &[f32]) {
        let d = self.dim;
        debug_assert_eq!(agg_grads.len(), keys.len() * d);
        let mut inner = self.lock_inner();
        match self.push {
            PushPolicy::MirrorSgd { lr } => {
                for (i, key) in keys.iter().enumerate() {
                    if let Some(e) = inner.map.get_mut(key) {
                        for (w, &g) in e.row.iter_mut().zip(&agg_grads[i * d..(i + 1) * d]) {
                            *w -= lr * g;
                        }
                        self.counters.updates.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            PushPolicy::Invalidate => {
                for key in keys {
                    if inner.map.remove(key).is_some() {
                        self.counters.invalidations.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::PsStats;
    use std::sync::atomic::AtomicU64 as Au64;

    /// A PS whose row for key `(g, id)` is `base + version` in every lane —
    /// bump `version` to model writers the cache cannot see; `epoch` models
    /// live resharding.
    struct FakePs {
        dim: usize,
        version: Au64,
        epoch: Au64,
        gets: Au64,
        rows_fetched: Au64,
    }

    impl FakePs {
        fn new(dim: usize) -> Self {
            Self {
                dim,
                version: Au64::new(0),
                epoch: Au64::new(0),
                gets: Au64::new(0),
                rows_fetched: Au64::new(0),
            }
        }
        fn value(&self, (g, id): (u32, u64)) -> f32 {
            (u64::from(g) * 1_000_000 + id * 1_000) as f32
                + self.version.load(Ordering::SeqCst) as f32
        }
    }

    impl PsBackend for FakePs {
        fn dim(&self) -> usize {
            self.dim
        }
        fn get_many(&self, keys: &[(u32, u64)], out: &mut [f32]) -> Result<()> {
            self.gets.fetch_add(1, Ordering::SeqCst);
            self.rows_fetched.fetch_add(keys.len() as u64, Ordering::SeqCst);
            for (i, &k) in keys.iter().enumerate() {
                let v = self.value(k);
                out[i * self.dim..(i + 1) * self.dim].fill(v);
            }
            Ok(())
        }
        fn put_grads(&self, _keys: &[(u32, u64)], _grads: &[f32]) -> Result<()> {
            Ok(())
        }
        fn stats(&self) -> Result<PsStats> {
            Ok(PsStats::default())
        }
        fn routing_epoch(&self) -> u64 {
            self.epoch.load(Ordering::SeqCst)
        }
    }

    fn params(capacity: usize, staleness: u64) -> EwCacheParams {
        EwCacheParams {
            capacity,
            staleness_ticks: staleness,
            admit_threshold: 1,
            push: PushPolicy::Invalidate,
        }
    }

    fn fetch(cache: &EmbCache, ps: &FakePs, keys: &[(u32, u64)]) -> (Vec<f32>, usize) {
        let mut rows = vec![0.0f32; keys.len() * ps.dim];
        let fetched = cache.fetch_through(ps, keys, &mut rows).unwrap();
        (rows, fetched)
    }

    #[test]
    fn second_lookup_hits_and_skips_the_ps() {
        let ps = FakePs::new(4);
        let cache = EmbCache::new(params(64, 10), 4);
        let keys = [(0u32, 1u64), (0, 2)];
        let (_, fetched) = fetch(&cache, &ps, &keys);
        assert_eq!(fetched, 2);
        let (rows, fetched) = fetch(&cache, &ps, &keys);
        assert_eq!(fetched, 0, "warm lookup must not touch the PS");
        assert_eq!(ps.gets.load(Ordering::SeqCst), 1, "fully-hit batch issues no GET");
        assert_eq!(rows[0], ps.value((0, 1)));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
    }

    #[test]
    fn admission_gate_blocks_one_touch_keys() {
        let ps = FakePs::new(2);
        let p = EwCacheParams { admit_threshold: 2, ..params(64, 100) };
        let cache = EmbCache::new(p, 2);
        fetch(&cache, &ps, &[(0, 7)]);
        assert_eq!(cache.len(), 0, "first touch must not admit");
        fetch(&cache, &ps, &[(0, 7)]);
        assert_eq!(cache.len(), 1, "second touch admits");
        let (_, fetched) = fetch(&cache, &ps, &[(0, 7)]);
        assert_eq!(fetched, 0);
    }

    #[test]
    fn stale_rows_are_refetched_within_the_bound() {
        let ps = FakePs::new(2);
        let cache = EmbCache::new(params(64, 3), 2);
        fetch(&cache, &ps, &[(0, 1)]); // tick 1, fetched_at = 1
        ps.version.store(50, Ordering::SeqCst); // an unseen writer
        // Ticks 2..=4: age <= 3, served stale — the bounded-staleness
        // window at work (value still the old one).
        for _ in 0..3 {
            let (rows, fetched) = fetch(&cache, &ps, &[(0, 1)]);
            assert_eq!(fetched, 0);
            assert_eq!(rows[0], 1_000.0, "within the bound the old row serves");
        }
        // Tick 5: age 4 > 3 — must refetch and see the new value.
        let (rows, fetched) = fetch(&cache, &ps, &[(0, 1)]);
        assert_eq!(fetched, 1);
        assert_eq!(rows[0], 1_050.0, "past the bound the fresh row serves");
        assert_eq!(cache.stats().stale_refreshes, 1);
    }

    #[test]
    fn routing_epoch_bump_flushes_everything() {
        let ps = FakePs::new(2);
        let cache = EmbCache::new(params(64, 1000), 2);
        fetch(&cache, &ps, &[(0, 1), (0, 2), (1, 3)]);
        assert_eq!(cache.len(), 3);
        ps.epoch.store(1, Ordering::SeqCst);
        ps.version.store(9, Ordering::SeqCst);
        let (rows, fetched) = fetch(&cache, &ps, &[(0, 1)]);
        assert_eq!(fetched, 1, "post-reshard lookup must refetch");
        assert_eq!(rows[0], 1_009.0);
        assert_eq!(cache.len(), 1, "old-epoch rows are gone");
        assert_eq!(cache.stats().flushes, 1);
    }

    #[test]
    fn explicit_flush_drops_rows() {
        let ps = FakePs::new(2);
        let cache = EmbCache::new(params(64, 1000), 2);
        fetch(&cache, &ps, &[(0, 1), (0, 2)]);
        cache.flush("adopt");
        assert!(cache.is_empty());
        let (_, fetched) = fetch(&cache, &ps, &[(0, 1)]);
        assert_eq!(fetched, 1);
    }

    #[test]
    fn capacity_is_respected() {
        let ps = FakePs::new(2);
        let cache = EmbCache::new(params(8, 1000), 2);
        for id in 0..100u64 {
            fetch(&cache, &ps, &[(0, id)]);
        }
        assert!(cache.len() <= 8, "resident {} > capacity 8", cache.len());
        assert!(cache.stats().evictions > 0);
    }

    #[test]
    fn invalidate_policy_drops_pushed_rows() {
        let ps = FakePs::new(2);
        let cache = EmbCache::new(params(64, 1000), 2);
        fetch(&cache, &ps, &[(0, 1), (0, 2)]);
        cache.push_applied(&[(0, 1)], &[1.0, 1.0]);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().invalidations, 1);
        let (_, fetched) = fetch(&cache, &ps, &[(0, 1), (0, 2)]);
        assert_eq!(fetched, 1, "pushed key refetches, untouched key hits");
    }

    #[test]
    fn sgd_mirror_keeps_row_bitwise_coherent() {
        let ps = FakePs::new(2);
        let p = EwCacheParams { push: PushPolicy::MirrorSgd { lr: 0.5 }, ..params(64, 1000) };
        let cache = EmbCache::new(p, 2);
        let (rows, _) = fetch(&cache, &ps, &[(0, 1)]);
        let want: Vec<f32> = rows.iter().map(|w| w - 0.5 * 2.0).collect();
        cache.push_applied(&[(0, 1)], &[2.0, 2.0]);
        let (rows, fetched) = fetch(&cache, &ps, &[(0, 1)]);
        assert_eq!(fetched, 0, "mirrored row still serves");
        assert_eq!(rows, want, "mirror must replay the exact SGD update");
        assert_eq!(cache.stats().updates, 1);
    }

    #[test]
    fn concurrent_ranks_coalesce_on_one_flight() {
        use std::sync::Barrier;
        // A PS that blocks inside get_many until both threads have entered
        // fetch_through would deadlock under double-fetch; with
        // single-flight the follower waits on the leader instead. We assert
        // the weaker, schedule-independent property: total PS rows fetched
        // for N concurrent identical lookups is at most N (and with any
        // coalescing, less than 2N for the 2-thread case over many rounds).
        let ps = Arc::new(FakePs::new(2));
        let cache = Arc::new(EmbCache::new(params(1, 0), 2)); // nothing ever valid
        let rounds = 50;
        let barrier = Arc::new(Barrier::new(2));
        let spawn = |ps: Arc<FakePs>, cache: Arc<EmbCache>, barrier: Arc<Barrier>| {
            std::thread::spawn(move || {
                for _ in 0..rounds {
                    barrier.wait();
                    let mut rows = vec![0.0f32; 2];
                    cache.fetch_through(ps.as_ref(), &[(0, 42)], &mut rows).unwrap();
                    assert!(rows[0] >= 42_000.0);
                }
            })
        };
        let h1 = spawn(ps.clone(), cache.clone(), barrier.clone());
        let h2 = spawn(ps.clone(), cache.clone(), barrier);
        h1.join().unwrap();
        h2.join().unwrap();
        let s = cache.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(
            s.coalesced + s.misses,
            2 * rounds,
            "every lookup is a miss or a coalesced wait"
        );
        assert_eq!(
            ps.rows_fetched.load(Ordering::SeqCst),
            s.misses,
            "only non-coalesced misses reach the PS"
        );
    }

    #[test]
    fn params_resolve_staleness_and_policy() {
        let cfg = EwCacheConfig::default();
        let p = EwCacheParams::resolve(&cfg, 4, 2, OptimizerKind::Sgd, 0.05);
        assert_eq!(p.staleness_ticks, 8, "tau steps x ranks-per-worker ticks");
        assert_eq!(p.push, PushPolicy::MirrorSgd { lr: 0.05 });
        let cfg = EwCacheConfig { staleness: Some(10), ..cfg };
        let p = EwCacheParams::resolve(&cfg, 4, 1, OptimizerKind::Adagrad, 0.05);
        assert_eq!(p.staleness_ticks, 10);
        assert_eq!(p.push, PushPolicy::Invalidate);
    }

    #[test]
    fn config_validation_rejects_degenerates() {
        assert!(EwCacheConfig::default().validate().is_ok());
        assert!(EwCacheConfig { capacity: 0, ..Default::default() }.validate().is_err());
        assert!(
            EwCacheConfig { staleness: Some(0), ..Default::default() }.validate().is_err()
        );
    }
}
