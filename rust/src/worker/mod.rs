//! The two worker roles of Fig. 4: embedding workers (CPU side of Alg. 1)
//! and NN workers (GPU side of Alg. 2), with their sample-ID-keyed buffers
//! (§4.2.1 "Fill the Async/Sync Gap").
//!
//! * [`embedding_worker`] — the buffering/dedup/pooling core, deployable
//!   in-process or behind `persia serve-embedding-worker`.
//! * [`nn_worker`] — the input sample hash-map of one dense rank.
//! * [`pipeline`] — stages 1–2 of the embedding pipeline ([`BatchPrep`])
//!   plus the bounded prefetcher ([`PrefetchPipeline`]) the out-of-process
//!   tier runs so PS latency hides behind dense compute.
//! * [`emb_comm`] — the [`EmbComm`] seam the trainer programs against
//!   (mirroring [`DenseComm`](crate::hybrid::dense_comm::DenseComm)), with
//!   the in-process [`LocalEmbTier`] implementation; the remote tier lives
//!   in [`crate::service::embedding_worker`].
//! * [`cache`] — the bounded-staleness hot-embedding cache each worker may
//!   run in front of the (sharded) PS, spending the hybrid algorithm's
//!   staleness budget τ on the Zipf-hot head instead of refetching it.

pub mod cache;
pub mod emb_comm;
pub mod embedding_worker;
pub mod nn_worker;
pub mod pipeline;

pub use cache::{CacheStats, EmbCache, EwCacheConfig, EwCacheParams, PushPolicy};
pub use emb_comm::{elastic_assign, EmbComm, LocalEmbTier};
pub use embedding_worker::{EmbeddingWorker, WorkerStats};
pub use nn_worker::NnWorker;
pub use pipeline::{AssignMode, BatchPrep, PrefetchPipeline, PreparedBatch};
