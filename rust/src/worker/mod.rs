//! The two worker roles of Fig. 4: embedding workers (CPU side of Alg. 1)
//! and NN workers (GPU side of Alg. 2), with their sample-ID-keyed buffers
//! (§4.2.1 "Fill the Async/Sync Gap").

pub mod embedding_worker;
pub mod nn_worker;

pub use embedding_worker::EmbeddingWorker;
pub use nn_worker::NnWorker;
