//! The embedding-side batch pipeline (paper §4.1 steps (1)-(5), run as a
//! prefetcher so PS latency hides behind dense compute).
//!
//! Both embedding-worker deployments share one implementation of "turn the
//! sample stream into embedding-complete batches":
//!
//! * **Stage 1** ([`BatchPrep::draw`]) pulls the next mini-batch of one NN
//!   rank's arrival stream from the data source.
//! * **Stage 2** ([`BatchPrep::assemble`]) buffers the ID features, runs the
//!   deduplicated scatter-gather lookup against the (possibly sharded,
//!   possibly remote) embedding PS, pools per feature group, and assembles
//!   the activation/NID/label tensors. When the resident worker runs the
//!   bounded-staleness cache ([`crate::worker::cache`]), the lookup first
//!   drains against it — and because each rank's assemble runs on its own
//!   stage-2 thread, the cache's single-flight table dedups co-hot keys
//!   *across* the ranks assigned to one worker: historically each rank's
//!   scatter-gather deduplicated only within itself and N ranks fetched the
//!   same hot row N times per window; now the first rank to miss leads one
//!   fetch and the rest coalesce onto it.
//! * **Stage 3** serves the assembled [`PreparedBatch`]es to NN ranks — the
//!   in-process trainer keeps its own τ-deep lookahead and calls the fused
//!   [`BatchPrep::prepare`] on demand, while the `serve-embedding-worker`
//!   process runs stages 1 and 2 on their own threads behind a bounded queue
//!   ([`PrefetchPipeline`]) so the *next* batches' PS round-trips overlap
//!   with the NN ranks' dense compute — the paper's hybrid-pipeline claim,
//!   measured by `benches/ew_pipeline.rs`.
//!
//! Determinism: batches are drawn from a per-rank RNG in strict step order,
//! so a pipeline of any depth produces the *same batch sequence*; depth only
//! changes *when* the PS reads happen relative to gradient writes. Bitwise
//! parity with the inline path therefore requires depth 1 (lookups happen on
//! demand, after all earlier puts), which is what deterministic mode forces.
//!
//! Cold-tier latency: when the PS shards run the tiered storage engine
//! (`serve-ps --cold-dir`), a batch whose working set spills past the hot
//! LRU pays disk reads (cold hits) and writes (demotions) inside stage 2's
//! scatter-gather — orders of magnitude slower than the all-hot path. No
//! code here knows or cares: that latency lands in exactly the same place
//! as PS network latency, so the same `--pipeline-depth` lookahead that
//! hides round-trips hides cold I/O. Stage 2 runs up to `depth` batches
//! ahead of the consuming NN rank, so as long as the *average* prepare time
//! (including cold misses) stays under the dense step time times depth, the
//! NN ring never stalls — Zipf-distributed key streams concentrate hot keys
//! in RAM, so cold hits cluster on the first touches of tail keys and the
//! steady state approaches all-hot throughput (see
//! `benches/fig9_capacity.rs`'s across-the-boundary sweep). Sizing rule of
//! thumb: raise `--pipeline-depth` until throughput plateaus; each extra
//! unit buys one more batch of cold I/O overlapped with dense compute, at
//! the cost of one batch of extra staleness (deterministic mode still
//! forces depth 1 and simply eats the cold latency inline).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::data::sample::{Batch, SampleId};
use crate::data::SyntheticDataset;
use crate::util::Rng;

use super::embedding_worker::EmbeddingWorker;
use super::nn_worker::NnWorker;

/// One embedding-complete mini-batch, ready for a dense train step.
#[derive(Clone, Debug)]
pub struct PreparedBatch {
    /// Position in the owning rank's stream (strictly sequential from 0).
    pub step: usize,
    /// Index of the embedding worker that prepared it (gradients must be
    /// pushed back to the same worker — it holds the sample buffer).
    pub ew: usize,
    /// Sample ids minted by the embedding worker, batch order.
    pub sids: Vec<SampleId>,
    /// Pooled activations, `[batch, emb_dim]` flattened.
    pub emb: Vec<f32>,
    /// Non-ID features, `[batch, nid_dim]` flattened.
    pub nid: Vec<f32>,
    /// Binary labels, batch order.
    pub labels: Vec<f32>,
    /// Simulated + real seconds spent preparing it (PS fetch, pooling, and —
    /// for the in-process deployment — the simulated worker→NN transfer).
    pub sim_prep: f64,
}

/// How NN ranks map onto the embedding workers of one deployment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignMode {
    /// In-process cluster: batch `step` of rank `r` goes to worker
    /// `(r + step) % n_workers` (spreads every rank over every worker, the
    /// historical simulated-cluster policy).
    PerStepRoundRobin,
    /// One `serve-embedding-worker` process: every batch this process
    /// prepares uses its single resident worker.
    Fixed(usize),
}

/// Per-rank stream state: the arrival-order RNG plus the next step index.
struct RankStream {
    rng: Rng,
    next_step: usize,
}

/// Stages 1–2 of the embedding pipeline, shared by the in-process tier and
/// the `serve-embedding-worker` process (the trait-seam analogue of
/// [`DenseComm`](crate::hybrid::dense_comm::DenseComm)'s two ring
/// implementations sharing one schedule).
pub struct BatchPrep {
    dataset: SyntheticDataset,
    workers: Vec<Arc<EmbeddingWorker>>,
    batch_size: usize,
    nid_dim: usize,
    assign: AssignMode,
    /// Serve raw (pre worker→NN leg) activations: the out-of-process server
    /// sets this so the worker→NN transfer happens on the real wire instead
    /// of being simulated by [`EmbeddingWorker::pull`].
    serve_raw: bool,
    ranks: Vec<Mutex<RankStream>>,
}

impl BatchPrep {
    /// Build the preparation state for `n_ranks` NN ranks over `workers`.
    /// Rank `r`'s stream is `dataset.train_rng(r)` in strict arrival order —
    /// identical across deployments, which is what makes remote-vs-inline
    /// parity possible at all.
    pub fn new(
        dataset: SyntheticDataset,
        workers: Vec<Arc<EmbeddingWorker>>,
        batch_size: usize,
        nid_dim: usize,
        n_ranks: usize,
        assign: AssignMode,
        serve_raw: bool,
    ) -> Self {
        assert!(!workers.is_empty(), "need at least one embedding worker");
        let ranks = (0..n_ranks)
            .map(|r| {
                Mutex::new(RankStream { rng: dataset.train_rng(r as u64), next_step: 0 })
            })
            .collect();
        Self { dataset, workers, batch_size, nid_dim, assign, serve_raw, ranks }
    }

    /// Number of embedding workers behind this preparation state.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// The `i`-th resident embedding worker.
    pub fn worker(&self, i: usize) -> &Arc<EmbeddingWorker> {
        &self.workers[i]
    }

    /// The data source (eval paths build their test batches from it).
    pub fn dataset(&self) -> &SyntheticDataset {
        &self.dataset
    }

    /// Samples per drawn batch.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Non-ID feature width of assembled batches.
    pub fn nid_dim(&self) -> usize {
        self.nid_dim
    }

    /// Which worker prepares batch `step` of `rank` under this deployment's
    /// assignment policy.
    pub fn assign(&self, rank: usize, step: usize) -> usize {
        match self.assign {
            AssignMode::PerStepRoundRobin => (rank + step) % self.workers.len(),
            AssignMode::Fixed(i) => i,
        }
    }

    /// Fast-forward `rank`'s stream to `step` by drawing and discarding
    /// batches — pure loader-RNG advancement, no buffering, no PS traffic.
    /// This is the resume path (`--resume-from` / `--start-step`): the
    /// deterministic streams make "redraw and discard" exactly equivalent
    /// to having trained through those steps, as far as the loader is
    /// concerned. Errors if the stream already advanced past `step`.
    pub fn skip_to(&self, rank: usize, step: usize) -> Result<()> {
        let slot = self
            .ranks
            .get(rank)
            .with_context(|| format!("rank {rank} out of range ({} ranks)", self.ranks.len()))?;
        let mut s = slot.lock().unwrap();
        anyhow::ensure!(
            s.next_step <= step,
            "cannot fast-forward rank {rank} to step {step}: stream already at {}",
            s.next_step
        );
        while s.next_step < step {
            let _ = self.dataset.batch(&mut s.rng, self.batch_size);
            s.next_step += 1;
        }
        Ok(())
    }

    /// Stage 1: draw the next mini-batch of `rank`'s arrival stream.
    /// Returns the step index the batch belongs to.
    pub fn draw(&self, rank: usize) -> Result<(usize, Batch)> {
        let slot = self
            .ranks
            .get(rank)
            .with_context(|| format!("rank {rank} out of range ({} ranks)", self.ranks.len()))?;
        let mut s = slot.lock().unwrap();
        let step = s.next_step;
        s.next_step += 1;
        let batch = self.dataset.batch(&mut s.rng, self.batch_size);
        Ok((step, batch))
    }

    /// Stage 2: buffer the ID features with the assigned embedding worker,
    /// run the deduplicated PS lookup, and assemble the batch tensors.
    pub fn assemble(&self, rank: usize, step: usize, batch: Batch) -> Result<PreparedBatch> {
        let ew_idx = self.assign(rank, step);
        let ew = &self.workers[ew_idx];
        let t0 = std::time::Instant::now();
        let sids = ew.register(batch.ids);
        // Round-trip through the NN worker's input sample hash-map (paper
        // steps (2) and (5)) so both deployments exercise the same flow.
        let nn = NnWorker::new(rank, self.nid_dim);
        nn.receive_batch(&sids, &batch.nid, &batch.labels);
        let (emb, sim) =
            if self.serve_raw { ew.pull_rows(&sids)? } else { ew.pull(&sids)? };
        let (nid, labels) = nn.take(&sids)?;
        // In-process, the assemble wall time is the rank's visible prep cost
        // and is folded in here. When serving raw (out-of-process), the
        // consumer measures its own RPC wall time — which already contains
        // this assemble when the pipeline runs on demand — so only the
        // *simulated* seconds ride along, never counted twice.
        let wall = if self.serve_raw { 0.0 } else { t0.elapsed().as_secs_f64() };
        Ok(PreparedBatch {
            step,
            ew: ew_idx,
            sids,
            emb,
            nid,
            labels,
            sim_prep: sim + wall,
        })
    }

    /// Stages 1+2 fused: the inline (pipeline-depth-1) path.
    pub fn prepare(&self, rank: usize) -> Result<PreparedBatch> {
        let (step, batch) = self.draw(rank)?;
        self.assemble(rank, step, batch)
    }
}

/// One NN rank's two-stage prefetcher: draw and assemble threads joined by
/// bounded channels, consumed by stage 3 (the RPC handler).
struct RankPipe {
    /// Assembled batches, in step order. `Receiver` is not `Sync`, so stage
    /// 3 consumers serialize on this inner lock (per rank, not globally).
    rx: Mutex<Receiver<Result<PreparedBatch>>>,
    /// Tells stage 1 to stop drawing — [`PrefetchPipeline::adopt`] raises it
    /// before quiescing, so the rank's loader stream freezes at a known
    /// position instead of racing the fast-forward.
    stop: Arc<AtomicBool>,
    /// Stage thread handles, joined by [`PrefetchPipeline::adopt`] when the
    /// pipe is torn down; simply dropped (detaching the threads, which exit
    /// once the channels close) when the whole pipeline drops.
    stages: Mutex<Vec<JoinHandle<()>>>,
}

/// The bounded prefetcher of one `serve-embedding-worker` process: up to
/// `depth` batches per rank in flight across stages 1–3.
///
/// Depth 1 degenerates to on-demand preparation (no threads, no readahead) —
/// the configuration deterministic mode forces, because readahead reorders
/// PS reads relative to gradient writes and breaks bitwise parity. Depth ≥ 2
/// is where the tier earns its keep: while an NN rank crunches batch `s`,
/// this process is already scatter-gathering batches `s+1..s+depth` from the
/// PS shards.
pub struct PrefetchPipeline {
    prep: Arc<BatchPrep>,
    depth: usize,
    ranks: Mutex<HashMap<usize, Arc<RankPipe>>>,
}

impl PrefetchPipeline {
    /// Wrap `prep` in a prefetcher with `depth` in-flight batches per rank.
    pub fn new(prep: Arc<BatchPrep>, depth: usize) -> Self {
        assert!(depth >= 1, "pipeline depth must be >= 1");
        Self { prep, depth, ranks: Mutex::new(HashMap::new()) }
    }

    /// The configured in-flight bound.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// The shared stage-1/2 implementation.
    pub fn prep(&self) -> &Arc<BatchPrep> {
        &self.prep
    }

    /// Get or lazily create rank `r`'s stage threads + queues.
    fn pipe_for(&self, rank: usize) -> Result<Arc<RankPipe>> {
        let mut map = self.ranks.lock().unwrap();
        if let Some(pipe) = map.get(&rank) {
            return Ok(pipe.clone());
        }
        let (raw_tx, raw_rx) = sync_channel::<Result<(usize, Batch)>>(self.depth);
        let (out_tx, out_rx) = sync_channel::<Result<PreparedBatch>>(self.depth);
        let prep = self.prep.clone();
        let stop = Arc::new(AtomicBool::new(false));
        let stop1 = stop.clone();
        let stage1 = std::thread::Builder::new()
            .name(format!("ew-draw-r{rank}"))
            .spawn(move || loop {
                if stop1.load(Ordering::Acquire) {
                    return;
                }
                let item = prep.draw(rank);
                let halt = item.is_err();
                // A closed channel (pipeline dropped) or a drawn error both
                // end the stream; the error is forwarded first.
                if raw_tx.send(item).is_err() || halt {
                    return;
                }
            })
            .context("spawning prefetch draw stage")?;
        let prep = self.prep.clone();
        let stage2 = std::thread::Builder::new()
            .name(format!("ew-assemble-r{rank}"))
            .spawn(move || {
                while let Ok(item) = raw_rx.recv() {
                    let out = match item {
                        Ok((step, batch)) => prep.assemble(rank, step, batch),
                        Err(e) => Err(e),
                    };
                    let stop = out.is_err();
                    if out_tx.send(out).is_err() || stop {
                        return;
                    }
                }
            })
            .context("spawning prefetch assemble stage")?;
        let pipe = Arc::new(RankPipe {
            rx: Mutex::new(out_rx),
            stop,
            stages: Mutex::new(vec![stage1, stage2]),
        });
        map.insert(rank, pipe.clone());
        Ok(pipe)
    }

    /// Take ownership of `rank`'s stream at `next_step` — the `ADOPT_RANK`
    /// path of elastic failover: a trainer whose previous embedding worker
    /// died asks this process to serve the rank from `next_step` on.
    ///
    /// Any existing pipe for the rank is fully quiesced first (stop flag,
    /// drain, join) so no stage thread races the fast-forward; its drained
    /// batches are discarded from the worker buffer (their in-flight samples
    /// are re-drawn by the new stream — the §4.2.4 re-buffering policy).
    /// Errors if the rank's stream already advanced past `next_step`
    /// (adopting *backwards* would require un-drawing batches).
    pub fn adopt(&self, rank: usize, next_step: usize) -> Result<()> {
        let existing = self.ranks.lock().unwrap().remove(&rank);
        if let Some(pipe) = existing {
            pipe.stop.store(true, Ordering::Release);
            let mut handles = std::mem::take(&mut *pipe.stages.lock().unwrap());
            let rx = pipe.rx.lock().unwrap();
            // Keep draining while the stages wind down: a stage blocked on a
            // full channel only unblocks when the consumer side empties it.
            loop {
                while let Ok(item) = rx.try_recv() {
                    self.discard_drained(rank, item);
                }
                if handles.iter().all(|h| h.is_finished()) {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            for h in handles.drain(..) {
                let _ = h.join();
            }
            while let Ok(item) = rx.try_recv() {
                self.discard_drained(rank, item);
            }
        }
        // A take-over splices a foreign rank's stream into this process
        // mid-window: the dead worker's unflushed pushes are lost and the
        // trainer replays the window, so locally cached rows may disagree
        // with what the replay is about to write. Drop them all — the cache
        // is a perf artifact and refills on the first post-adopt fetches.
        for i in 0..self.prep.n_workers() {
            if let Some(c) = self.prep.worker(i).cache() {
                c.flush("ADOPT_RANK take-over");
            }
        }
        self.prep.skip_to(rank, next_step)
    }

    /// Release the worker-side sample buffer of a batch drained (not served)
    /// during [`adopt`](Self::adopt), so re-buffered entries don't leak.
    fn discard_drained(&self, rank: usize, item: Result<PreparedBatch>) {
        if let Ok(pb) = item {
            self.prep.worker(self.prep.assign(rank, pb.step)).discard(&pb.sids);
        }
    }

    /// Stage 3: the next prepared batch of `rank`, which must be `step`.
    /// Requests must be strictly sequential per rank — a skipped or repeated
    /// step means client and server desynchronized (e.g. a NEXT_BATCH
    /// response was lost), and the mismatch is surfaced loudly instead of
    /// silently training on the wrong data.
    pub fn next(&self, rank: usize, step: usize) -> Result<PreparedBatch> {
        let pb = if self.depth <= 1 {
            self.prep.prepare(rank)?
        } else {
            let pipe = self.pipe_for(rank)?;
            let rx = pipe.rx.lock().unwrap();
            rx.recv()
                .map_err(|_| {
                    anyhow::anyhow!("prefetch pipeline for rank {rank} ended (earlier error)")
                })??
        };
        anyhow::ensure!(
            pb.step == step,
            "embedding prefetch out of sync for rank {rank}: asked for step {step}, \
             pipeline is at step {} — NEXT_BATCH must be called strictly in step order",
            pb.step
        );
        Ok(pb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetSim;
    use crate::config::{
        EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };
    use crate::embedding::EmbeddingPs;

    fn model() -> ModelConfig {
        ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 3,
            pooling: Pooling::Sum,
        }
    }

    fn prep(n_workers: usize, n_ranks: usize, assign: AssignMode, serve_raw: bool) -> BatchPrep {
        let model = model();
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 4096,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 7));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let workers = (0..n_workers)
            .map(|r| {
                Arc::new(EmbeddingWorker::new(r as u8, ps.clone(), &model, net.clone(), false))
            })
            .collect();
        let dataset = SyntheticDataset::new(&model, 1000, 1.05, 7);
        BatchPrep::new(dataset, workers, 8, model().nid_dim, n_ranks, assign, serve_raw)
    }

    #[test]
    fn prepare_yields_sequential_steps_with_batch_shapes() {
        let p = prep(2, 1, AssignMode::PerStepRoundRobin, false);
        for want in 0..3 {
            let pb = p.prepare(0).unwrap();
            assert_eq!(pb.step, want);
            assert_eq!(pb.ew, want % 2);
            assert_eq!(pb.sids.len(), 8);
            assert_eq!(pb.emb.len(), 8 * 8);
            assert_eq!(pb.nid.len(), 8 * 4);
            assert_eq!(pb.labels.len(), 8);
        }
    }

    #[test]
    fn fixed_assignment_always_uses_the_resident_worker() {
        let p = prep(1, 2, AssignMode::Fixed(0), true);
        for rank in 0..2 {
            for _ in 0..2 {
                assert_eq!(p.prepare(rank).unwrap().ew, 0);
            }
        }
        assert_eq!(p.assign(1, 17), 0);
    }

    #[test]
    fn streams_match_the_trainer_reference_draw() {
        // The batch content for (rank, step) must equal drawing the same
        // dataset stream by hand — the property every parity test rests on.
        let p = prep(1, 2, AssignMode::Fixed(0), false);
        let ds = SyntheticDataset::new(&model(), 1000, 1.05, 7);
        for rank in 0..2u64 {
            let mut rng = ds.train_rng(rank);
            for _ in 0..3 {
                let want = ds.batch(&mut rng, 8);
                let got = p.prepare(rank as usize).unwrap();
                assert_eq!(got.labels, want.labels);
                assert_eq!(got.nid, want.nid);
            }
        }
    }

    #[test]
    fn pipelined_and_inline_serve_identical_streams() {
        // Same PS seed on both sides and no writes in between: any depth
        // must serve byte-identical batches in the same order.
        let inline = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 1);
        let deep = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 3);
        for step in 0..5 {
            let a = inline.next(0, step).unwrap();
            let b = deep.next(0, step).unwrap();
            assert_eq!(a.step, b.step);
            assert_eq!(a.emb, b.emb);
            assert_eq!(a.nid, b.nid);
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn skip_to_is_equivalent_to_drawing_and_discarding() {
        let p = prep(1, 1, AssignMode::Fixed(0), true);
        let q = prep(1, 1, AssignMode::Fixed(0), true);
        for _ in 0..3 {
            p.prepare(0).unwrap();
        }
        q.skip_to(0, 3).unwrap();
        let a = p.prepare(0).unwrap();
        let b = q.prepare(0).unwrap();
        assert_eq!((a.step, b.step), (3, 3));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.nid, b.nid);
        // Streams only move forward.
        assert!(q.skip_to(0, 2).is_err());
        assert!(q.skip_to(9, 5).is_err(), "unknown rank must error");
    }

    #[test]
    fn out_of_order_step_is_rejected() {
        let pipe = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 2);
        pipe.next(0, 0).unwrap();
        let err = pipe.next(0, 5).unwrap_err();
        assert!(format!("{err:#}").contains("out of sync"), "{err:#}");
    }

    #[test]
    fn unknown_rank_is_an_error_not_a_panic() {
        let pipe = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 1);
        assert!(pipe.next(7, 0).is_err());
    }

    #[test]
    fn adopt_fast_forwards_a_fresh_rank_to_the_requested_step() {
        // The common failover shape: this server never touched the rank, a
        // reference stream says what batch lives at the adopted step.
        let reference = prep(1, 1, AssignMode::Fixed(0), true);
        for _ in 0..4 {
            reference.prepare(0).unwrap();
        }
        let want = reference.prepare(0).unwrap();

        let pipe = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 3);
        pipe.adopt(0, 4).unwrap();
        let got = pipe.next(0, 4).unwrap();
        assert_eq!(got.step, 4);
        assert_eq!(got.labels, want.labels);
        assert_eq!(got.nid, want.nid);
        // The stream continues strictly sequentially from there.
        assert_eq!(pipe.next(0, 5).unwrap().step, 5);
    }

    #[test]
    fn adopt_quiesces_a_running_pipe_and_leaks_no_buffered_samples() {
        let p = Arc::new(prep(1, 1, AssignMode::Fixed(0), true));
        let pipe = PrefetchPipeline::new(p.clone(), 3);
        // Serve a couple of steps so the prefetcher is warm and has batches
        // in flight beyond what was served.
        let served0 = pipe.next(0, 0).unwrap();
        let served1 = pipe.next(0, 1).unwrap();
        p.worker(0).discard(&served0.sids);
        p.worker(0).discard(&served1.sids);
        // Adopt far ahead: the old pipe must quiesce, its drained in-flight
        // batches must be discarded from the worker buffer, and the stream
        // must land exactly on the requested step.
        pipe.adopt(0, 16).unwrap();
        assert_eq!(p.worker(0).buffered(), 0, "drained in-flight samples leaked");
        assert_eq!(pipe.next(0, 16).unwrap().step, 16);
    }

    #[test]
    fn adopt_flushes_the_worker_cache() {
        use crate::worker::cache::{EmbCache, EwCacheParams, PushPolicy};
        let model = model();
        let cfg = EmbeddingConfig {
            rows_per_group: 1000,
            shard_capacity: 4096,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let ps = Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 7));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let cache = Arc::new(EmbCache::new(
            EwCacheParams {
                capacity: 1024,
                staleness_ticks: 64,
                admit_threshold: 1,
                push: PushPolicy::MirrorSgd { lr: 0.1 },
            },
            model.emb_dim_per_group,
        ));
        let worker = Arc::new(
            EmbeddingWorker::new(0, ps, &model, net, false).with_cache(Some(cache.clone())),
        );
        let dataset = SyntheticDataset::new(&model, 1000, 1.05, 7);
        let prep = Arc::new(BatchPrep::new(
            dataset,
            vec![worker],
            8,
            model.nid_dim,
            1,
            AssignMode::Fixed(0),
            true,
        ));
        let pipe = PrefetchPipeline::new(prep, 2);
        pipe.next(0, 0).unwrap();
        pipe.next(0, 1).unwrap();
        assert!(!cache.is_empty(), "warm pulls populated the cache");
        pipe.adopt(0, 8).unwrap();
        assert!(cache.is_empty(), "adopt must flush the cache");
        assert!(cache.stats().flushes >= 1);
    }

    #[test]
    fn adopt_behind_the_stream_is_rejected() {
        let pipe = PrefetchPipeline::new(Arc::new(prep(1, 1, AssignMode::Fixed(0), true)), 1);
        pipe.next(0, 0).unwrap();
        pipe.next(0, 1).unwrap();
        let err = pipe.adopt(0, 1).unwrap_err();
        assert!(format!("{err:#}").contains("cannot fast-forward"), "{err:#}");
        // The no-op adopt at exactly the stream head is fine.
        pipe.adopt(0, 2).unwrap();
        assert_eq!(pipe.next(0, 2).unwrap().step, 2);
    }
}
