//! The embedding-tier seam: how an NN worker reaches its embedding workers.
//!
//! Mirrors [`DenseComm`](crate::hybrid::dense_comm::DenseComm), the seam for
//! the dense AllReduce fabric. The trainer's worker loop programs against
//! [`EmbComm`] for everything embedding-shaped — next prepared batch,
//! gradient push-back, eval lookup, PS statistics — so all four train modes
//! run unchanged whether the embedding workers live in this process
//! ([`LocalEmbTier`]) or as their own OS processes
//! (`persia serve-embedding-worker`, reached through
//! [`RemoteEmbTier`](crate::service::embedding_worker::RemoteEmbTier)).
//!
//! The assignment policy is part of the seam: the in-process tier spreads a
//! rank's batches over every worker per step, while the remote tier pins
//! each NN rank to one worker process — home worker `rank % M`, linearly
//! probed past dead members by [`elastic_assign`] when `--ew-failover` is
//! on — so the rank's whole sample stream lives in a single process at a
//! time. Neither choice affects numerics (the workers share one PS and run
//! identical dedup and pooling), which is what the remote-vs-inline parity
//! suite proves.

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::comm::NetSim;
use crate::config::ModelConfig;
use crate::data::sample::SampleId;
use crate::data::SyntheticDataset;
use crate::service::{PsBackend, PsStats};

use super::cache::{CacheStats, EmbCache, EwCacheParams};
use super::embedding_worker::EmbeddingWorker;
use super::pipeline::{AssignMode, BatchPrep, PreparedBatch};

/// The elastic rank→worker assignment of the remote embedding tier: the
/// first *live* worker at or after the rank's home slot `rank % n_workers`,
/// probing linearly with wraparound. `dead[i]` marks worker `i` dead;
/// `None` iff every worker is dead (`dead` shorter than `n_workers` treats
/// the missing tail as live).
///
/// The three properties failover correctness rests on, proven exhaustively
/// by `rust/tests/property_failover.rs`:
///
/// * **total** — some worker is assigned whenever any worker is live;
/// * **deterministic** — a pure function of `(rank, n_workers, dead)`, so
///   every trainer rank independently computes the same adopter with no
///   coordination round;
/// * **minimal movement** — marking one worker dead moves *only* the ranks
///   that were assigned to it; every other rank keeps its worker (a rehash
///   over the survivor list would reshuffle unrelated ranks, forcing
///   needless `ADOPT_RANK` stream fast-forwards).
///
/// With `dead` all-false this is exactly the pre-elastic pinning `rank % n`,
/// which is why the failover-off path cannot change behavior.
pub fn elastic_assign(rank: usize, n_workers: usize, dead: &[bool]) -> Option<usize> {
    if n_workers == 0 {
        return None;
    }
    let home = rank % n_workers;
    (0..n_workers)
        .map(|probe| (home + probe) % n_workers)
        .find(|&w| !dead.get(w).copied().unwrap_or(false))
}

/// Batched access to the embedding-worker tier of one deployment.
///
/// Implementations are shared by every NN-worker thread of a process plus
/// its gradient-applier threads, hence `&self` methods and `Send + Sync`.
pub trait EmbComm: Send + Sync {
    /// Number of embedding workers in the tier.
    fn n_workers(&self) -> usize;

    /// Which worker serves batch `step` of NN rank `rank`.
    fn assign(&self, rank: usize, step: usize) -> usize;

    /// The embedding-complete batch for `(rank, step)`. Steps must be
    /// requested strictly in order per rank.
    fn next_batch(&self, rank: usize, step: usize) -> Result<PreparedBatch>;

    /// Push a batch's activation gradients back to worker `ew` (which holds
    /// the samples' ID-feature buffer). Returns simulated comm seconds. On
    /// failure the samples are re-buffered worker-side, so the identical
    /// call can be retried.
    fn push_grads(&self, ew: usize, sids: &[SampleId], grads: &[f32]) -> Result<f64>;

    /// Drop buffered samples on worker `ew` — a gradient applier that gave
    /// up on a batch calls this so re-buffered entries don't leak (§4.2.4
    /// tolerates the lost update, not the leak). Best-effort.
    fn discard(&self, ew: usize, sids: &[SampleId]);

    /// Pooled activations of the deterministic held-out test batch
    /// (`rows` samples) against the live PS state, plus simulated seconds.
    fn eval_lookup(&self, rows: usize) -> Result<(Vec<f32>, f64)>;

    /// Statistics of the embedding PS behind this tier.
    fn ps_stats(&self) -> Result<PsStats>;

    /// Error unless the tier was built for exactly this trainer config
    /// (compared via
    /// [`config_fingerprint`](crate::hybrid::Trainer::config_fingerprint)).
    /// In-process tiers are compatible by construction; the remote tier
    /// compares against each server's INFO handshake.
    fn check_compat(&self, _fingerprint: u64) -> Result<()> {
        Ok(())
    }

    /// Cut checkpoint epoch `step` on the embedding PS behind this tier
    /// (the two-phase protocol of [`crate::recovery::coordinator`]). The
    /// trainer's rank 0 drives this at step boundaries; `dir` is the
    /// checkpoint root for tiers whose PS writes locally. Tiers without
    /// checkpoint support error at the first epoch.
    fn checkpoint_epoch(&self, _dir: &Path, _step: u64) -> Result<()> {
        anyhow::bail!("this embedding tier does not support coordinated checkpoint epochs")
    }

    /// Drive one live resharding round on the embedding PS behind this
    /// tier when per-node traffic imbalance exceeds `threshold` (see
    /// [`PsBackend::maybe_reshard`]). Returns the committed routing epoch,
    /// or `Ok(None)` when balanced or unsupported. The default is a no-op:
    /// the *remote* embedding-worker tier cannot reshard from the trainer
    /// side yet (the EW processes own the PS connections) — a documented
    /// limit of this PR.
    fn maybe_reshard(&self, _threshold: f64) -> Result<Option<u64>> {
        Ok(None)
    }

    /// The committed routing epoch of the PS behind this tier (0 = initial
    /// layout), recorded into the [`crate::recovery::GlobalManifest`] so
    /// resume restores the post-migration layout.
    fn routing_epoch(&self) -> u64 {
        0
    }

    /// Fast-forward rank `rank`'s batch stream to `step` without touching
    /// the PS — the resume path: a run restarting from a checkpoint epoch
    /// asks for its first batch at the epoch's boundary, and the strictly
    /// sequential streams must already stand there. The default is a no-op
    /// because the *remote* tier's streams live in the worker processes,
    /// which fast-forward themselves via `--start-step` (a mismatch is
    /// caught loudly by the strict NEXT_BATCH step check).
    fn fast_forward(&self, _rank: usize, _step: usize) -> Result<()> {
        Ok(())
    }

    /// Merged bounded-staleness-cache counters across this tier's workers
    /// ([`crate::worker::cache`]), or `None` when no worker runs the cache
    /// (deterministic mode, `--ew-cache false`, or a tier that predates
    /// it). The trainer prints the merged line at run end.
    fn cache_stats(&self) -> Option<CacheStats> {
        None
    }
}

/// In-process embedding-worker tier: the simulated-cluster default, where
/// the workers are plain structs sharing the trainer's address space and the
/// worker→NN transfer is simulated on [`NetSim`].
pub struct LocalEmbTier {
    prep: BatchPrep,
    backend: Arc<dyn PsBackend>,
}

impl LocalEmbTier {
    /// Build `n_emb_workers` in-process workers over `backend` and the
    /// per-rank batch streams for `n_ranks` NN workers. `cache` attaches a
    /// per-worker bounded-staleness hot-row cache (resolved by
    /// [`crate::hybrid::Trainer::ew_cache_params`], which returns `None` in
    /// deterministic mode so this tier stays bitwise-identical there).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        dataset: SyntheticDataset,
        model: &ModelConfig,
        backend: Arc<dyn PsBackend>,
        net: Arc<NetSim>,
        compress: bool,
        n_emb_workers: usize,
        n_ranks: usize,
        batch_size: usize,
        cache: Option<EwCacheParams>,
    ) -> Self {
        let workers = (0..n_emb_workers)
            .map(|r| {
                // Per-worker caches: workers never share rows, so sharing a
                // cache would only share a lock.
                let c = cache.map(|p| Arc::new(EmbCache::new(p, model.emb_dim_per_group)));
                Arc::new(
                    EmbeddingWorker::new(r as u8, backend.clone(), model, net.clone(), compress)
                        .with_cache(c),
                )
            })
            .collect();
        let prep = BatchPrep::new(
            dataset,
            workers,
            batch_size,
            model.nid_dim,
            n_ranks,
            AssignMode::PerStepRoundRobin,
            false,
        );
        Self { prep, backend }
    }

    /// The resident workers (tests inspect their buffers/stats).
    pub fn worker(&self, i: usize) -> &Arc<EmbeddingWorker> {
        self.prep.worker(i)
    }
}

impl EmbComm for LocalEmbTier {
    fn n_workers(&self) -> usize {
        self.prep.n_workers()
    }

    fn assign(&self, rank: usize, step: usize) -> usize {
        self.prep.assign(rank, step)
    }

    fn next_batch(&self, rank: usize, step: usize) -> Result<PreparedBatch> {
        let pb = self.prep.prepare(rank)?;
        anyhow::ensure!(
            pb.step == step,
            "local embedding tier out of sync for rank {rank}: asked for step {step}, \
             stream is at step {}",
            pb.step
        );
        Ok(pb)
    }

    fn push_grads(&self, ew: usize, sids: &[SampleId], grads: &[f32]) -> Result<f64> {
        self.prep.worker(ew).push_grads(sids, grads)
    }

    fn discard(&self, ew: usize, sids: &[SampleId]) {
        self.prep.worker(ew).discard(sids);
    }

    fn eval_lookup(&self, rows: usize) -> Result<(Vec<f32>, f64)> {
        let batch = self.prep.dataset().test_batch(rows);
        self.prep.worker(0).lookup_direct(&batch)
    }

    fn ps_stats(&self) -> Result<PsStats> {
        self.backend.stats()
    }

    fn checkpoint_epoch(&self, dir: &Path, step: u64) -> Result<()> {
        self.backend.checkpoint_epoch(dir, step)
    }

    fn maybe_reshard(&self, threshold: f64) -> Result<Option<u64>> {
        self.backend.maybe_reshard(threshold)
    }

    fn routing_epoch(&self) -> u64 {
        self.backend.routing_epoch()
    }

    fn fast_forward(&self, rank: usize, step: usize) -> Result<()> {
        self.prep.skip_to(rank, step)
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        let mut any = false;
        let mut total = CacheStats::default();
        for i in 0..self.prep.n_workers() {
            if let Some(c) = self.prep.worker(i).cache() {
                any = true;
                total.merge(&c.stats());
            }
        }
        any.then_some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetSim;
    use crate::config::{
        EmbeddingConfig, NetModelConfig, OptimizerKind, PartitionPolicy, Pooling,
    };
    use crate::embedding::EmbeddingPs;

    fn tier(n_ew: usize, n_ranks: usize) -> LocalEmbTier {
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 500,
            shard_capacity: 2048,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let ps: Arc<dyn PsBackend> =
            Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 3));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let dataset = SyntheticDataset::new(&model, 500, 1.05, 3);
        LocalEmbTier::new(dataset, &model, ps, net, false, n_ew, n_ranks, 8, None)
    }

    #[test]
    fn uncached_tier_reports_no_cache_stats() {
        assert!(tier(2, 1).cache_stats().is_none());
    }

    #[test]
    fn cached_tier_merges_worker_stats() {
        use crate::worker::cache::PushPolicy;
        let model = ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 2,
            emb_dim_per_group: 4,
            nid_dim: 4,
            hidden: vec![8],
            ids_per_group: 2,
            pooling: Pooling::Sum,
        };
        let cfg = EmbeddingConfig {
            rows_per_group: 500,
            shard_capacity: 2048,
            n_nodes: 2,
            shards_per_node: 2,
            optimizer: OptimizerKind::Sgd,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.1,
        };
        let ps: Arc<dyn PsBackend> =
            Arc::new(EmbeddingPs::new(&cfg, model.emb_dim_per_group, 3));
        let net = Arc::new(NetSim::new(NetModelConfig::disabled()));
        let dataset = SyntheticDataset::new(&model, 500, 1.05, 3);
        let params = EwCacheParams {
            capacity: 256,
            staleness_ticks: 16,
            admit_threshold: 1,
            push: PushPolicy::MirrorSgd { lr: 0.1 },
        };
        let t =
            LocalEmbTier::new(dataset, &model, ps, net, false, 2, 1, 8, Some(params));
        t.next_batch(0, 0).unwrap();
        t.next_batch(0, 1).unwrap();
        let s = t.cache_stats().expect("cached tier must report stats");
        assert!(s.misses > 0, "first pulls miss through to the PS");
    }

    #[test]
    fn full_cycle_next_push_eval() {
        let t = tier(2, 1);
        assert_eq!(t.n_workers(), 2);
        let pb = t.next_batch(0, 0).unwrap();
        assert_eq!(pb.ew, t.assign(0, 0));
        let grads = vec![0.1f32; pb.sids.len() * 8];
        t.push_grads(pb.ew, &pb.sids, &grads).unwrap();
        assert_eq!(t.worker(pb.ew).buffered(), 0);
        let (emb, _) = t.eval_lookup(16).unwrap();
        assert_eq!(emb.len(), 16 * 8);
        assert!(t.ps_stats().unwrap().total_rows > 0);
    }

    #[test]
    fn out_of_order_next_batch_is_rejected() {
        let t = tier(1, 1);
        t.next_batch(0, 0).unwrap();
        assert!(t.next_batch(0, 2).is_err());
    }

    #[test]
    fn discard_releases_buffered_samples() {
        let t = tier(1, 1);
        let pb = t.next_batch(0, 0).unwrap();
        assert_eq!(t.worker(0).buffered(), pb.sids.len());
        t.discard(0, &pb.sids);
        assert_eq!(t.worker(0).buffered(), 0);
    }

    #[test]
    fn elastic_assign_matches_modulo_when_all_live() {
        for n in 1..5 {
            for rank in 0..12 {
                assert_eq!(elastic_assign(rank, n, &vec![false; n]), Some(rank % n));
                // A short (even empty) dead slice treats the tail as live.
                assert_eq!(elastic_assign(rank, n, &[]), Some(rank % n));
            }
        }
    }

    #[test]
    fn elastic_assign_probes_past_dead_workers() {
        // Home 1 dead: rank 1 probes to 2; rank 5 (home 1) likewise.
        let dead = [false, true, false, false];
        assert_eq!(elastic_assign(1, 4, &dead), Some(2));
        assert_eq!(elastic_assign(5, 4, &dead), Some(2));
        // Wraparound: home 3 dead too -> rank 3 lands on 0.
        let dead = [false, true, false, true];
        assert_eq!(elastic_assign(3, 4, &dead), Some(0));
        // Survivors keep their home.
        assert_eq!(elastic_assign(0, 4, &dead), Some(0));
        assert_eq!(elastic_assign(2, 4, &dead), Some(2));
    }

    #[test]
    fn elastic_assign_degenerate_memberships() {
        assert_eq!(elastic_assign(0, 0, &[]), None);
        assert_eq!(elastic_assign(7, 3, &[true, true, true]), None);
        assert_eq!(elastic_assign(7, 1, &[true]), None);
    }
}
