//! Typed wrappers over the train/forward artifacts + the engine abstraction.
//!
//! `DenseEngine` is what NN workers program against: either the AOT-compiled
//! PJRT executables (production path — the L2/L1 stack) or the pure-Rust
//! reference tower (fallback + cross-check). Both implement the same
//! (params, emb, nid, y) -> (loss, dense grads, emb grads) contract in the
//! flat artifact ordering.

use std::sync::Mutex;

use anyhow::{ensure, Result};

use crate::dense::DenseModel;

use super::manifest::{ArtifactManifest, PresetInfo};
use super::pjrt::{Executable, PjRtRuntime};

/// One train-step's outputs.
#[derive(Clone, Debug)]
pub struct TrainStepOut {
    pub loss: f32,
    /// Dense gradients flattened in (w0, b0, w1, b1, ...) order.
    pub grad_flat: Vec<f32>,
    /// `[B, emb_dim]` gradient wrt the pooled embedding activations.
    pub grad_emb: Vec<f32>,
}

/// Compiled `train_<preset>` artifact.
///
/// Param literals are cached and refilled in place each step
/// (`copy_raw_from`) instead of re-allocated — the execute-boundary
/// optimization recorded in EXPERIMENTS.md §Perf.
pub struct TrainStepExec {
    exe: Executable,
    info: PresetInfo,
    lit_cache: Mutex<Option<Vec<xla::Literal>>>,
}

impl TrainStepExec {
    pub fn load(rt: &PjRtRuntime, manifest: &ArtifactManifest, preset: &str) -> Result<Self> {
        let info = manifest.preset(preset)?.clone();
        let exe = rt.load_hlo_text(manifest.train_path(&info))?;
        Ok(Self { exe, info, lit_cache: Mutex::new(None) })
    }

    pub fn batch(&self) -> usize {
        self.info.batch
    }

    pub fn info(&self) -> &PresetInfo {
        &self.info
    }

    fn param_literals(info: &PresetInfo, params_flat: &[f32]) -> Result<Vec<xla::Literal>> {
        let mut args = Vec::with_capacity(2 * info.n_layers() + 3);
        let mut off = 0;
        for i in 0..info.n_layers() {
            let (di, dj) = (info.dims[i], info.dims[i + 1]);
            args.push(PjRtRuntime::literal_f32(&[di, dj], &params_flat[off..off + di * dj])?);
            off += di * dj;
            args.push(PjRtRuntime::literal_f32(&[dj], &params_flat[off..off + dj])?);
            off += dj;
        }
        ensure!(off == params_flat.len(), "params_flat length mismatch");
        Ok(args)
    }

    /// Run one train step. `emb: [B*emb_dim]`, `nid: [B*nid_dim]`, `y: [B]`
    /// with `B == self.batch()`.
    pub fn run(
        &self,
        params_flat: &[f32],
        emb: &[f32],
        nid: &[f32],
        labels: &[f32],
    ) -> Result<TrainStepOut> {
        let info = &self.info;
        let b = info.batch;
        ensure!(labels.len() == b, "batch mismatch: {} != {}", labels.len(), b);
        ensure!(emb.len() == b * info.emb_dim && nid.len() == b * info.nid_dim);
        // Reuse the literal set across steps: refill in place.
        let mut cache = self.lit_cache.lock().unwrap();
        if cache.is_none() {
            let mut lits = Self::param_literals(info, params_flat)?;
            lits.push(PjRtRuntime::literal_f32(&[b, info.emb_dim], emb)?);
            lits.push(PjRtRuntime::literal_f32(&[b, info.nid_dim], nid)?);
            lits.push(PjRtRuntime::literal_f32(&[b], labels)?);
            *cache = Some(lits);
        } else {
            let lits = cache.as_mut().unwrap();
            let mut off = 0;
            let n_layers = info.n_layers();
            for i in 0..n_layers {
                let (di, dj) = (info.dims[i], info.dims[i + 1]);
                lits[2 * i]
                    .copy_raw_from(&params_flat[off..off + di * dj])
                    .map_err(|e| anyhow::anyhow!("xla: {e}"))?;
                off += di * dj;
                lits[2 * i + 1]
                    .copy_raw_from(&params_flat[off..off + dj])
                    .map_err(|e| anyhow::anyhow!("xla: {e}"))?;
                off += dj;
            }
            ensure!(off == params_flat.len(), "params_flat length mismatch");
            lits[2 * n_layers].copy_raw_from(emb).map_err(|e| anyhow::anyhow!("xla: {e}"))?;
            lits[2 * n_layers + 1].copy_raw_from(nid).map_err(|e| anyhow::anyhow!("xla: {e}"))?;
            lits[2 * n_layers + 2].copy_raw_from(labels).map_err(|e| anyhow::anyhow!("xla: {e}"))?;
        }
        let args = cache.as_ref().unwrap();

        let out = self.exe.run(args)?;
        ensure!(out.len() == 2 * info.n_layers() + 2, "unexpected output arity {}", out.len());
        let loss = out[0].get_first_element::<f32>().map_err(|e| anyhow::anyhow!("xla: {e}"))?;
        let mut grad_flat = Vec::with_capacity(params_flat.len());
        for i in 0..info.n_layers() {
            grad_flat.extend(PjRtRuntime::literal_to_f32(&out[1 + 2 * i])?);
            grad_flat.extend(PjRtRuntime::literal_to_f32(&out[2 + 2 * i])?);
        }
        let grad_emb = PjRtRuntime::literal_to_f32(&out[1 + 2 * info.n_layers()])?;
        ensure!(grad_emb.len() == b * info.emb_dim);
        Ok(TrainStepOut { loss, grad_flat, grad_emb })
    }
}

/// Compiled `fwd_<preset>` artifact (eval path).
pub struct ForwardExec {
    exe: Executable,
    info: PresetInfo,
}

impl ForwardExec {
    pub fn load(rt: &PjRtRuntime, manifest: &ArtifactManifest, preset: &str) -> Result<Self> {
        let info = manifest.preset(preset)?.clone();
        let exe = rt.load_hlo_text(manifest.fwd_path(&info))?;
        Ok(Self { exe, info })
    }

    /// Predict probabilities for exactly one artifact batch.
    fn run_one(&self, params_flat: &[f32], emb: &[f32], nid: &[f32]) -> Result<Vec<f32>> {
        let info = &self.info;
        let mut args = TrainStepExec::param_literals(info, params_flat)?;
        args.push(PjRtRuntime::literal_f32(&[info.batch, info.emb_dim], emb)?);
        args.push(PjRtRuntime::literal_f32(&[info.batch, info.nid_dim], nid)?);
        let out = self.exe.run(&args)?;
        PjRtRuntime::literal_to_f32(&out[0])
    }

    /// Predict for any number of rows (pads the trailing chunk).
    pub fn run(&self, params_flat: &[f32], emb: &[f32], nid: &[f32], rows: usize) -> Result<Vec<f32>> {
        let info = &self.info;
        ensure!(emb.len() == rows * info.emb_dim && nid.len() == rows * info.nid_dim);
        let b = info.batch;
        let mut probs = Vec::with_capacity(rows);
        let mut r = 0;
        while r < rows {
            let take = b.min(rows - r);
            let mut e = emb[r * info.emb_dim..(r + take) * info.emb_dim].to_vec();
            let mut n = nid[r * info.nid_dim..(r + take) * info.nid_dim].to_vec();
            e.resize(b * info.emb_dim, 0.0);
            n.resize(b * info.nid_dim, 0.0);
            let chunk = self.run_one(params_flat, &e, &n)?;
            probs.extend_from_slice(&chunk[..take]);
            r += take;
        }
        Ok(probs)
    }
}

/// The dense compute engine NN workers drive.
pub enum DenseEngine {
    /// AOT artifacts via PJRT (L2/L1 on the hot path).
    Pjrt { train: TrainStepExec, fwd: ForwardExec },
    /// Pure-Rust reference tower.
    Rust { model: Mutex<DenseModel> },
}

impl DenseEngine {
    /// Load the PJRT engine for an artifact preset.
    pub fn pjrt(rt: &PjRtRuntime, manifest: &ArtifactManifest, preset: &str) -> Result<Self> {
        Ok(DenseEngine::Pjrt {
            train: TrainStepExec::load(rt, manifest, preset)?,
            fwd: ForwardExec::load(rt, manifest, preset)?,
        })
    }

    /// Pure-Rust engine over a template model (its params are overwritten by
    /// `params_flat` on every call).
    pub fn rust(model: DenseModel) -> Self {
        DenseEngine::Rust { model: Mutex::new(model) }
    }

    /// Fixed train batch of the engine (None = any).
    pub fn train_batch(&self) -> Option<usize> {
        match self {
            DenseEngine::Pjrt { train, .. } => Some(train.batch()),
            DenseEngine::Rust { .. } => None,
        }
    }

    pub fn is_pjrt(&self) -> bool {
        matches!(self, DenseEngine::Pjrt { .. })
    }

    /// One train step over a batch of `rows` samples.
    pub fn train_step(
        &self,
        params_flat: &[f32],
        emb: &[f32],
        nid: &[f32],
        labels: &[f32],
    ) -> Result<TrainStepOut> {
        match self {
            DenseEngine::Pjrt { train, .. } => train.run(params_flat, emb, nid, labels),
            DenseEngine::Rust { model } => {
                let mut m = model.lock().unwrap();
                m.set_params_flat(params_flat);
                let b = labels.len();
                let (loss, grads) = m.train_step(emb, nid, labels, b);
                let mut grad_flat = Vec::with_capacity(params_flat.len());
                for (gw, gb) in grads.weights.iter().zip(&grads.biases) {
                    grad_flat.extend_from_slice(gw.data());
                    grad_flat.extend_from_slice(gb.data());
                }
                Ok(TrainStepOut { loss, grad_flat, grad_emb: grads.emb.into_vec() })
            }
        }
    }

    /// Predict probabilities.
    pub fn forward(
        &self,
        params_flat: &[f32],
        emb: &[f32],
        nid: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        match self {
            DenseEngine::Pjrt { fwd, .. } => fwd.run(params_flat, emb, nid, rows),
            DenseEngine::Rust { model } => {
                let mut m = model.lock().unwrap();
                m.set_params_flat(params_flat);
                Ok(m.forward(emb, nid, rows))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn artifacts() -> Option<ArtifactManifest> {
        let dir = ArtifactManifest::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(ArtifactManifest::load(dir).unwrap())
        } else {
            None
        }
    }

    /// The central L2-vs-L3 numeric cross-check: the AOT artifact and the
    /// pure-Rust tower must agree on loss and every gradient.
    #[test]
    fn pjrt_and_rust_engines_agree() {
        let Some(m) = artifacts() else { return };
        let rt = PjRtRuntime::cpu().unwrap();
        let info = m.preset("tiny").unwrap().clone();
        let pjrt = DenseEngine::pjrt(&rt, &m, "tiny").unwrap();

        let mut rng = Rng::new(11);
        let model = DenseModel::new(&info.dims, info.emb_dim, info.nid_dim, &mut rng);
        let params = model.params_flat();
        let rust = DenseEngine::rust(model);

        let b = info.batch;
        let emb = rng.normal_vec(b * info.emb_dim);
        let nid = rng.normal_vec(b * info.nid_dim);
        let labels: Vec<f32> =
            (0..b).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();

        let a = pjrt.train_step(&params, &emb, &nid, &labels).unwrap();
        let r = rust.train_step(&params, &emb, &nid, &labels).unwrap();
        assert!((a.loss - r.loss).abs() < 1e-4, "loss {} vs {}", a.loss, r.loss);
        assert_eq!(a.grad_flat.len(), r.grad_flat.len());
        for (x, y) in a.grad_flat.iter().zip(&r.grad_flat) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
        for (x, y) in a.grad_emb.iter().zip(&r.grad_emb) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }

        let pa = pjrt.forward(&params, &emb, &nid, b).unwrap();
        let pr = rust.forward(&params, &emb, &nid, b).unwrap();
        for (x, y) in pa.iter().zip(&pr) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn literal_cache_refill_path_is_correct() {
        // Two successive steps with different params must match the Rust
        // engine on both (exercises the copy_raw_from refill branch).
        let Some(m) = artifacts() else { return };
        let rt = PjRtRuntime::cpu().unwrap();
        let info = m.preset("tiny").unwrap().clone();
        let pjrt = DenseEngine::pjrt(&rt, &m, "tiny").unwrap();
        let mut rng = Rng::new(21);
        let model = DenseModel::new(&info.dims, info.emb_dim, info.nid_dim, &mut rng);
        let rust = DenseEngine::rust(model.clone());
        let b = info.batch;
        let emb = rng.normal_vec(b * info.emb_dim);
        let nid = rng.normal_vec(b * info.nid_dim);
        let y: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        let mut params = model.params_flat();
        for step in 0..3 {
            let a = pjrt.train_step(&params, &emb, &nid, &y).unwrap();
            let r = rust.train_step(&params, &emb, &nid, &y).unwrap();
            assert!((a.loss - r.loss).abs() < 1e-4, "step {step}: {} vs {}", a.loss, r.loss);
            for (x, yv) in a.grad_flat.iter().zip(&r.grad_flat) {
                assert!((x - yv).abs() < 1e-4);
            }
            // SGD update so the next step sees different params.
            for (p, g) in params.iter_mut().zip(&a.grad_flat) {
                *p -= 0.1 * g;
            }
        }
    }

    #[test]
    fn forward_pads_partial_batches() {
        let Some(m) = artifacts() else { return };
        let rt = PjRtRuntime::cpu().unwrap();
        let info = m.preset("tiny").unwrap().clone();
        let pjrt = DenseEngine::pjrt(&rt, &m, "tiny").unwrap();
        let mut rng = Rng::new(3);
        let rows = info.batch + 7; // forces a padded second chunk
        let emb = rng.normal_vec(rows * info.emb_dim);
        let nid = rng.normal_vec(rows * info.nid_dim);
        let params = {
            let model = DenseModel::new(&info.dims, info.emb_dim, info.nid_dim, &mut rng);
            model.params_flat()
        };
        let probs = pjrt.forward(&params, &emb, &nid, rows).unwrap();
        assert_eq!(probs.len(), rows);
        assert!(probs.iter().all(|&p| p > 0.0 && p < 1.0));
    }

    #[test]
    fn batch_mismatch_is_error() {
        let Some(m) = artifacts() else { return };
        let rt = PjRtRuntime::cpu().unwrap();
        let pjrt = DenseEngine::pjrt(&rt, &m, "tiny").unwrap();
        let info = m.preset("tiny").unwrap();
        let params = vec![0.0; info.dense_params];
        // One row short.
        let b = info.batch - 1;
        let res = pjrt.train_step(
            &params,
            &vec![0.0; b * info.emb_dim],
            &vec![0.0; b * info.nid_dim],
            &vec![0.0; b],
        );
        assert!(res.is_err());
    }
}
