//! PJRT runtime: load and execute the AOT artifacts from the Rust hot path.
//!
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` → `compile` →
//! `execute` — the exact path demonstrated by /opt/xla-example/load_hlo.
//! HLO *text* is the interchange format (see python/compile/aot.py).

pub mod manifest;
pub mod pjrt;
pub mod trainstep;

pub use manifest::{ArtifactManifest, PresetInfo};
pub use pjrt::{Executable, PjRtRuntime};
pub use trainstep::{DenseEngine, ForwardExec, TrainStepExec, TrainStepOut};
