//! Thin, safe wrapper over the `xla` crate's PJRT CPU client.

use std::path::Path;

use anyhow::{Context, Result};

/// Process-wide PJRT client (compiling is per-executable).
pub struct PjRtRuntime {
    client: xla::PjRtClient,
}

impl PjRtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(anyhow_xla)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(anyhow_xla)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }

    /// Build an f32 literal from host data.
    pub fn literal_f32(dims: &[usize], data: &[f32]) -> Result<xla::Literal> {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
            .map_err(anyhow_xla)
    }

    /// Read an f32 literal back to a Vec.
    pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(anyhow_xla)
    }
}

fn anyhow_xla(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

/// A compiled artifact. All our artifacts return a single tuple
/// (lowered with `return_tuple=True`), which `run` decomposes.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args).map_err(anyhow_xla)?;
        let lit = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
        lit.to_tuple().map_err(anyhow_xla)
    }

    /// Execute and return raw output buffers (no host copy) — used when the
    /// caller chains executions device-side.
    pub fn run_buffers(&self, args: &[xla::Literal]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        self.exe.execute::<xla::Literal>(args).map_err(anyhow_xla)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ArtifactManifest;

    fn artifacts() -> Option<ArtifactManifest> {
        let dir = ArtifactManifest::default_dir();
        if dir.join("manifest.txt").exists() {
            Some(ArtifactManifest::load(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn cpu_client_boots() {
        // With the vendored xla stub there is no PJRT behind the API; the
        // client must fail fast with a diagnosable error instead of booting.
        match PjRtRuntime::cpu() {
            Ok(rt) => assert!(rt.platform().to_lowercase().contains("cpu"), "{}", rt.platform()),
            Err(e) => assert!(format!("{e:#}").contains("xla"), "unexpected error: {e:#}"),
        }
    }

    #[test]
    fn literal_f32_roundtrip() {
        let lit = PjRtRuntime::literal_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(PjRtRuntime::literal_to_f32(&lit).unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn literal_f32_shape_mismatch_errors() {
        assert!(PjRtRuntime::literal_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn tiny_forward_artifact_runs() {
        let Some(m) = artifacts() else { return };
        let rt = PjRtRuntime::cpu().unwrap();
        let p = m.preset("tiny").unwrap();
        let exe = rt.load_hlo_text(m.fwd_path(p)).unwrap();
        // Zero params + zero inputs => logits 0 => probs 0.5.
        let mut args = Vec::new();
        for i in 0..p.n_layers() {
            args.push(PjRtRuntime::literal_f32(
                &[p.dims[i], p.dims[i + 1]],
                &vec![0.0; p.dims[i] * p.dims[i + 1]],
            )
            .unwrap());
            args.push(PjRtRuntime::literal_f32(&[p.dims[i + 1]], &vec![0.0; p.dims[i + 1]]).unwrap());
        }
        args.push(
            PjRtRuntime::literal_f32(&[p.batch, p.emb_dim], &vec![0.0; p.batch * p.emb_dim])
                .unwrap(),
        );
        args.push(
            PjRtRuntime::literal_f32(&[p.batch, p.nid_dim], &vec![0.0; p.batch * p.nid_dim])
                .unwrap(),
        );
        let out = exe.run(&args).unwrap();
        assert_eq!(out.len(), 1);
        let probs = PjRtRuntime::literal_to_f32(&out[0]).unwrap();
        assert_eq!(probs.len(), p.batch);
        assert!(probs.iter().all(|&x| (x - 0.5).abs() < 1e-6));
    }
}
