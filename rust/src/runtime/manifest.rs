//! Parse `artifacts/manifest.txt` emitted by `python -m compile.aot`.

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::config::IniDoc;

/// One exported dense-tower preset.
#[derive(Clone, Debug)]
pub struct PresetInfo {
    pub name: String,
    pub train_file: String,
    pub fwd_file: String,
    pub batch: usize,
    pub n_groups: usize,
    pub emb_dim_per_group: usize,
    pub emb_dim: usize,
    pub nid_dim: usize,
    /// Layer dims including input and output 1.
    pub dims: Vec<usize>,
    pub dense_params: usize,
}

impl PresetInfo {
    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub presets: Vec<PresetInfo>,
}

impl ArtifactManifest {
    /// Load from an artifacts directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let doc = IniDoc::load(dir.join("manifest.txt"))
            .context("artifacts/manifest.txt missing — run `make artifacts`")?;
        ensure!(doc.get_u64("", "format_version")? == 1, "unsupported manifest version");
        let mut presets = Vec::new();
        for section in doc.sections() {
            if section == "kernels" {
                continue;
            }
            let dims = doc.get_usize_list(section, "dims")?;
            ensure!(dims.len() >= 3 && *dims.last().unwrap() == 1, "bad dims in {section}");
            presets.push(PresetInfo {
                name: section.to_string(),
                train_file: doc.get_str(section, "train_file")?.to_string(),
                fwd_file: doc.get_str(section, "fwd_file")?.to_string(),
                batch: doc.get_usize(section, "batch")?,
                n_groups: doc.get_usize(section, "n_groups")?,
                emb_dim_per_group: doc.get_usize(section, "emb_dim_per_group")?,
                emb_dim: doc.get_usize(section, "emb_dim")?,
                nid_dim: doc.get_usize(section, "nid_dim")?,
                dims,
                dense_params: doc.get_usize(section, "dense_params")?,
            });
        }
        ensure!(!presets.is_empty(), "manifest lists no presets");
        Ok(Self { dir, presets })
    }

    /// Default artifacts directory (repo-root/artifacts, overridable).
    pub fn default_dir() -> PathBuf {
        std::env::var_os("PERSIA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn preset(&self, name: &str) -> Result<&PresetInfo> {
        self.presets
            .iter()
            .find(|p| p.name == name)
            .with_context(|| format!("preset {name:?} not in manifest"))
    }

    pub fn train_path(&self, preset: &PresetInfo) -> PathBuf {
        self.dir.join(&preset.train_file)
    }

    pub fn fwd_path(&self, preset: &PresetInfo) -> PathBuf {
        self.dir.join(&preset.fwd_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
format_version = 1

[tiny]
train_file = train_tiny.hlo.txt
fwd_file = fwd_tiny.hlo.txt
batch = 32
n_groups = 4
emb_dim_per_group = 8
emb_dim = 32
nid_dim = 8
dims = 40,32,16,1
dense_params = 1873

[kernels]
bag_file = bag.hlo.txt
bag_shape = 256,32,16
compress_file = compress.hlo.txt
decompress_file = decompress.hlo.txt
compress_shape = 1024,16
"#;

    fn write_sample() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("persia_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_presets_and_skips_kernels() {
        let dir = write_sample();
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.presets.len(), 1);
        let p = m.preset("tiny").unwrap();
        assert_eq!(p.batch, 32);
        assert_eq!(p.dims, vec![40, 32, 16, 1]);
        assert_eq!(p.n_layers(), 3);
        assert!(m.preset("nope").is_err());
        assert!(m.train_path(p).ends_with("train_tiny.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_context_error() {
        let err = ArtifactManifest::load("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn real_manifest_parses_when_built() {
        // Opportunistic: only runs when `make artifacts` has been run.
        let dir = ArtifactManifest::default_dir();
        if dir.join("manifest.txt").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            for name in ["tiny", "small", "paper"] {
                let p = m.preset(name).unwrap();
                assert_eq!(p.emb_dim, p.n_groups * p.emb_dim_per_group);
                assert!(m.train_path(p).exists());
                assert!(m.fwd_path(p).exists());
            }
        }
    }
}
