//! `persia` — CLI launcher for the hybrid recommender training system.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!   train        run a training job (preset, mode, workers, steps, ...);
//!                add --remote-ps host:port[,host:port...] to train against
//!                TCP embedding-PS shard processes, or --embedding-workers
//!                host:port[,...] to train through an out-of-process
//!                embedding-worker tier (the full three-tier topology)
//!   train-worker run ONE NN-worker rank as its own OS process: rank 0
//!                hosts the ring rendezvous, peers dial it, and the dense
//!                AllReduce runs over loopback/network TCP instead of
//!                in-process channels (world > 1 requires --remote-ps or
//!                --embedding-workers)
//!   serve-ps     run the embedding PS (or one --node-range slice of it) as
//!                a standalone TCP server
//!   serve-embedding-worker
//!                run ONE embedding worker as its own OS process: it owns
//!                the data-loader streams of the NN ranks assigned to it,
//!                prefetches batches against the PS (--remote-ps list, or a
//!                private in-process PS), and serves them over TCP
//!   gantt        print the Fig.-3 phase timelines for all four modes
//!   table1       print the Table-1 model-scale presets
//!   capacity     Fig.-9 style capacity sweep (virtualized tables)
//!   modes        convergence comparison across modes (Fig. 7 / Table 2)

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use persia::allreduce::RingRendezvous;
use persia::config::{
    BenchPreset, ClusterConfig, EmbWorkerConfig, EwFailoverConfig, NetModelConfig,
    OptimizerKind, RecoveryConfig, RingConfig, ServiceConfig, TrainConfig, TrainMode,
};
use persia::comm::NetSim;
use persia::data::SyntheticDataset;
use persia::embedding::{CheckpointManager, EmbeddingPs, StoreConfig};
use persia::hybrid::{DenseComm, PjrtEngineFactory, ResumeState, Trainer};
use persia::worker::EwCacheConfig;
use persia::recovery::{latest_epoch, load_manifest, EpochConfig};
use persia::runtime::ArtifactManifest;
use persia::service::{
    reshard, EmbeddingWorkerServer, EwExpect, PsBackend, PsBindOpts, PsServer, RemoteEmbTier,
    ShardedRemotePs,
};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                i += 1;
                args[i].clone()
            } else {
                "true".to_string()
            };
            out.insert(key.to_string(), val);
        }
        i += 1;
    }
    out
}

fn flag<'a>(flags: &'a HashMap<String, String>, key: &str, default: &'a str) -> &'a str {
    flags.get(key).map(|s| s.as_str()).unwrap_or(default)
}

/// The preset-derived pieces `train` and `serve-ps` must agree on for a
/// remote PS to be interchangeable with the in-process one: same model
/// geometry, same embedding storage config, same materialization seed.
struct PresetSetup {
    preset: BenchPreset,
    model: persia::config::ModelConfig,
    emb_cfg: persia::config::EmbeddingConfig,
    seed: u64,
}

fn preset_setup(flags: &HashMap<String, String>) -> Result<PresetSetup> {
    let preset_name = flag(flags, "preset", "taobao");
    let preset = BenchPreset::by_name(preset_name)
        .with_context(|| format!("unknown preset {preset_name}"))?;
    let model = preset.model(flag(flags, "dense", "small"));
    let mut emb_cfg = preset.embedding(&model, flag(flags, "shard-capacity", "65536").parse()?);
    // --nodes overrides the preset's PS node count (it rides in the config
    // fingerprint, so every process of a deployment must agree). A finer
    // node grid gives live resharding more split points to migrate.
    if let Some(s) = flags.get("nodes") {
        emb_cfg.n_nodes = s.parse().context("--nodes")?;
        anyhow::ensure!(emb_cfg.n_nodes >= 1, "--nodes must be at least 1");
    }
    // --optimizer overrides the preset's row-wise embedding optimizer (it
    // rides the fingerprint too, so every process must agree). SGD keeps no
    // PS-side row state, which is what lets the worker-side embedding cache
    // mirror gradient pushes locally instead of invalidating on every push.
    if let Some(s) = flags.get("optimizer") {
        emb_cfg.optimizer = OptimizerKind::parse(s).context("--optimizer")?;
    }
    let seed = flag(flags, "seed", "42").parse()?;
    Ok(PresetSetup { preset, model, emb_cfg, seed })
}

/// Parse the storage-engine flags into a [`StoreConfig`]. `--cold-dir DIR`
/// selects the tiered engine; `--hot-capacity N` (default: the full
/// `shard_capacity`, i.e. the cold tier only absorbs overflow) and
/// `--admit-threshold T` tune it. The tuning flags without `--cold-dir` are
/// rejected — silently ignoring them would look like a working cold tier.
fn store_config(
    flags: &HashMap<String, String>,
    shard_capacity: usize,
) -> Result<StoreConfig> {
    let Some(dir) = flags.get("cold-dir") else {
        anyhow::ensure!(
            !flags.contains_key("hot-capacity") && !flags.contains_key("admit-threshold"),
            "--hot-capacity/--admit-threshold require --cold-dir (they tune the \
             tiered storage engine; without a cold tier the hot capacity IS \
             --shard-capacity)"
        );
        return Ok(StoreConfig::Hot);
    };
    let hot_capacity: usize = match flags.get("hot-capacity") {
        Some(s) => s.parse().context("--hot-capacity")?,
        None => shard_capacity,
    };
    anyhow::ensure!(hot_capacity >= 1, "--hot-capacity must be at least 1");
    let admit_threshold: u8 = match flags.get("admit-threshold") {
        Some(s) => s.parse().context("--admit-threshold")?,
        None => persia::embedding::store::DEFAULT_ADMIT_THRESHOLD,
    };
    anyhow::ensure!(admit_threshold >= 1, "--admit-threshold must be at least 1");
    Ok(StoreConfig::Tiered {
        hot_capacity,
        cold_dir: std::path::PathBuf::from(dir),
        admit_threshold,
    })
}

/// Parse the worker-side hot-embedding cache flags. The cache is on by
/// default (`--ew-cache false` disables it; deterministic mode force-
/// disables it regardless). The geometry flags without the cache are
/// rejected — silently ignoring them would look like a tuned cache.
fn ew_cache_config(flags: &HashMap<String, String>) -> Result<Option<EwCacheConfig>> {
    if flag(flags, "ew-cache", "true") != "true" {
        anyhow::ensure!(
            !flags.contains_key("ew-cache-capacity")
                && !flags.contains_key("ew-cache-staleness"),
            "--ew-cache-capacity/--ew-cache-staleness require --ew-cache true (they \
             tune the worker-side embedding cache; with --ew-cache false no cache \
             exists)"
        );
        return Ok(None);
    }
    let mut cfg = EwCacheConfig::default();
    if let Some(s) = flags.get("ew-cache-capacity") {
        cfg.capacity = s.parse().context("--ew-cache-capacity")?;
    }
    if let Some(s) = flags.get("ew-cache-staleness") {
        cfg.staleness = Some(s.parse().context("--ew-cache-staleness")?);
    }
    cfg.validate()?;
    Ok(Some(cfg))
}

fn build_trainer(flags: &HashMap<String, String>) -> Result<Trainer> {
    let PresetSetup { preset, model, emb_cfg, seed } = preset_setup(flags)?;
    let dense = flag(flags, "dense", "small");
    let cluster = ClusterConfig {
        n_nn_workers: flag(flags, "nn-workers", "4").parse()?,
        n_emb_workers: flag(flags, "emb-workers", "2").parse()?,
        net: if flag(flags, "netsim", "true") == "true" {
            NetModelConfig::paper_like()
        } else {
            NetModelConfig::disabled()
        },
    };
    // PJRT artifacts fix the batch per preset; read it from the manifest.
    // Default "auto": PJRT when artifacts exist, pure-Rust tower otherwise
    // (the offline build ships a stub xla crate with no executor).
    let use_pjrt = match flag(flags, "engine", "auto") {
        "pjrt" => true,
        "rust" => false,
        _ => ArtifactManifest::default_dir().join("manifest.txt").exists(),
    };
    let batch: usize = if use_pjrt {
        let manifest = ArtifactManifest::load(ArtifactManifest::default_dir())?;
        manifest.preset(dense)?.batch
    } else {
        flag(flags, "batch", "64").parse()?
    };
    let train = TrainConfig {
        mode: TrainMode::parse(flag(flags, "mode", "hybrid"))?,
        batch_size: batch,
        lr: flag(flags, "lr", "0.05").parse()?,
        staleness_bound: flag(flags, "tau", "4").parse()?,
        steps: flag(flags, "steps", "200").parse()?,
        eval_every: flag(flags, "eval-every", "50").parse()?,
        seed,
        use_pjrt,
        compress: flag(flags, "compress", "true") == "true",
    };
    let dataset = SyntheticDataset::new(
        &model,
        preset.embedding(&model, 1).rows_per_group,
        preset.zipf_exponent,
        train.seed,
    );
    let mut trainer = Trainer::new(model, emb_cfg, cluster, train, dataset);
    trainer.store = store_config(flags, trainer.emb_cfg.shard_capacity)?;
    trainer.deterministic = flag(flags, "deterministic", "false") == "true";
    trainer.gossip_period =
        flag(flags, "gossip-period", "64").parse().context("--gossip-period")?;
    trainer.ew_cache = ew_cache_config(flags)?;
    // Kept past the connect so --resume-from can interrogate the shards'
    // restored epochs.
    let mut remote_ps: Option<Arc<ShardedRemotePs>> = None;
    if let Some(addr) = flags.get("remote-ps") {
        let svc = ServiceConfig {
            addr: addr.clone(),
            client_conns: flag(flags, "ps-conns", "4").parse()?,
            inflight_window: flag(flags, "inflight-window", "32").parse()?,
            wire_compress: flag(flags, "ps-wire-compress", "false") == "true",
            recovery: RecoveryConfig {
                attempts: flag(flags, "ps-retries", "4").parse()?,
                backoff_ms: flag(flags, "ps-retry-ms", "50").parse()?,
                io_timeout_ms: flag(flags, "io-timeout-ms", "30000").parse()?,
                replay_puts: flag(flags, "ps-replay", "false") == "true",
                replay_cap: flag(flags, "ps-replay-cap", "4096").parse()?,
                // NN ranks put directly, so the ring rank is the put owner.
                replay_owner: flag(flags, "rank", "0").parse()?,
            },
        };
        // One client regardless of shard count: a single full-range
        // serve-ps is just the 1-shard case. Connect-time validation proves
        // the shard processes agree with each other and cover every node.
        let remote = Arc::new(
            ShardedRemotePs::connect(&svc)
                .with_context(|| format!("connecting to remote PS shard(s) at {addr}"))?,
        );
        println!(
            "remote PS: {} shard process(es), dim={} nodes={} shards/node={}",
            remote.n_shard_processes(),
            PsBackend::dim(remote.as_ref()),
            remote.n_nodes(),
            remote.shards_per_node()
        );
        trainer.ps_backend = Some(remote.clone());
        remote_ps = Some(remote);
    }
    if let Some(addrs) = flags.get("embedding-workers") {
        anyhow::ensure!(
            !flags.contains_key("remote-ps"),
            "--embedding-workers and --remote-ps are mutually exclusive: with an \
             embedding-worker tier only the workers talk to the PS — give the \
             --remote-ps list to serve-embedding-worker instead"
        );
        let svc = ServiceConfig {
            addr: addrs.clone(),
            client_conns: flag(flags, "ew-conns", "2").parse()?,
            inflight_window: flag(flags, "inflight-window", "32").parse()?,
            wire_compress: false,
            recovery: RecoveryConfig {
                attempts: flag(flags, "ew-retries", "4").parse()?,
                backoff_ms: flag(flags, "ew-retry-ms", "50").parse()?,
                io_timeout_ms: flag(flags, "io-timeout-ms", "30000").parse()?,
                ..RecoveryConfig::default()
            },
        };
        svc.validate()?;
        let failover = EwFailoverConfig {
            enabled: flag(flags, "ew-failover", "false") == "true",
            rejoin: flag(flags, "ew-rejoin", "true") == "true",
            rejoin_ms: flag(flags, "ew-rejoin-ms", "500").parse()?,
        };
        // The tier IS the embedding-worker cluster: its process count
        // replaces --emb-workers (and rides in the fingerprint, so every
        // process must agree on it).
        trainer.cluster.n_emb_workers = svc.shard_addrs().len();
        let expect = EwExpect {
            fingerprint: trainer.config_fingerprint(),
            emb_dim: trainer.model.emb_dim(),
            nid_dim: trainer.model.nid_dim,
            batch_size: trainer.train.batch_size,
        };
        let net = Arc::new(NetSim::new(trainer.cluster.net));
        let tier =
            RemoteEmbTier::connect_elastic(&svc, expect, trainer.train.compress, net, failover)
                .with_context(|| format!("connecting to embedding worker(s) at {addrs}"))?;
        println!(
            "embedding-worker tier: {} process(es), pipeline depth {}{}",
            tier.n_processes(),
            tier.pipeline_depth(),
            if failover.enabled { ", elastic failover on" } else { "" }
        );
        trainer.emb_comm = Some(Arc::new(tier));
    }

    // --- live resharding: rank 0 probes the fleet at this cadence ---
    match flags.get("reshard-every") {
        Some(s) => {
            let every: usize = s.parse().context("--reshard-every")?;
            if every > 0 {
                anyhow::ensure!(
                    remote_ps.is_some(),
                    "--reshard-every needs --remote-ps: live resharding moves nodes \
                     between serve-ps processes (an in-process or embedding-worker \
                     deployment has no shard fleet to rebalance)"
                );
                trainer.reshard = Some(reshard::ReshardConfig {
                    every,
                    threshold: flag(flags, "reshard-threshold", "1.25")
                        .parse()
                        .context("--reshard-threshold")?,
                });
            }
        }
        None => anyhow::ensure!(
            !flags.contains_key("reshard-threshold"),
            "--reshard-threshold requires --reshard-every (it tunes the live \
             resharding probe; without a cadence no probe ever runs)"
        ),
    }

    // --- the recovery layer's CLI: coordinated epochs + resume ---
    if let Some(dir) = flags.get("checkpoint-dir") {
        let every: usize =
            flag(flags, "checkpoint-every", "0").parse().context("--checkpoint-every")?;
        if every > 0 {
            trainer.checkpoint =
                Some(EpochConfig { dir: std::path::PathBuf::from(dir), every });
        }
    }
    if let Some(dir) = flags.get("resume-from") {
        let root = std::path::Path::new(dir.as_str());
        let step = match flags.get("resume-step") {
            Some(s) => s.parse::<u64>().context("--resume-step")?,
            None => latest_epoch(root)
                .with_context(|| format!("no committed checkpoint epoch under {dir}"))?,
        };
        let manifest = load_manifest(root, step)
            .with_context(|| format!("loading epoch {step} manifest from {dir}"))?;
        anyhow::ensure!(
            manifest.fingerprint == trainer.config_fingerprint(),
            "--resume-from epoch {step} was written by a run with different numeric \
             flags (fingerprint {:#x} != this trainer's {:#x}) — resume with the \
             exact flags of the checkpointed run",
            manifest.fingerprint,
            trainer.config_fingerprint()
        );
        anyhow::ensure!(
            manifest.world == trainer.cluster.n_nn_workers,
            "--resume-from epoch {step} recorded {} NN worker(s), this run has {}",
            manifest.world,
            trainer.cluster.n_nn_workers
        );
        // Where does the embedding state come from?
        let ps_restore = if let Some(remote) = &remote_ps {
            // The shards restored themselves at startup; every one must
            // stand at exactly the resume epoch, or the run would splice
            // embedding states from different steps (mixed-epoch).
            let restored = remote.restored_steps();
            anyhow::ensure!(
                restored.iter().all(|&s| s == step),
                "PS shards report restored epochs {restored:?}, resume needs every \
                 shard at epoch {step} — restart each serve-ps with \
                 --checkpoint-dir DIR --restore-epoch {step}"
            );
            None
        } else if flags.contains_key("embedding-workers") {
            // The embedding workers own the PS connections; their
            // --start-step and the shards' --restore-epoch carry the
            // restore (a mismatch fails loudly at the first NEXT_BATCH).
            None
        } else {
            // In-process PS: the trainer restores it from the epoch files.
            Some(std::path::PathBuf::from(dir))
        };
        trainer.start_step = step as usize;
        trainer.resume = Some(ResumeState::from_manifest(&manifest, ps_restore));
        println!("resuming from committed checkpoint epoch {step} under {dir}");
    }
    Ok(trainer)
}

/// Parse `--node-range START..END` (end-exclusive, like Rust ranges).
fn parse_node_range(s: &str, n_nodes: usize) -> Result<std::ops::Range<usize>> {
    let parsed = match s.split_once("..") {
        Some((a, b)) => match (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
            (Ok(start), Ok(end)) => Some(start..end),
            _ => None,
        },
        None => None,
    };
    let range = parsed.with_context(|| format!("--node-range {s:?} must be START..END"))?;
    anyhow::ensure!(
        range.start < range.end && range.end <= n_nodes,
        "--node-range {s} invalid for a {n_nodes}-node PS"
    );
    Ok(range)
}

/// Build the PS exactly as `train` would for the same preset flags — or one
/// `--node-range` slice of it — then serve it over TCP until a SHUTDOWN RPC
/// arrives. With `--checkpoint-dir`, owned nodes are restored from existing
/// checkpoint files at startup (the §4.2.4 process-restart recovery path)
/// and saved again on graceful shutdown. `--join` starts the process as an
/// idle spare that owns nothing until a live reshard migrates nodes onto
/// it; a persisted `ROUTING` table under the checkpoint dir re-enters a
/// restarted shard at the committed post-migration layout.
fn cmd_serve_ps(flags: HashMap<String, String>) -> Result<()> {
    let PresetSetup { preset, model, emb_cfg, seed } = preset_setup(&flags)?;
    let svc = ServiceConfig::at(flag(&flags, "addr", "127.0.0.1:7700"));
    svc.validate()?;
    anyhow::ensure!(
        svc.shard_addrs().len() == 1,
        "serve-ps takes a single --addr; run one process per shard"
    );
    let join = flag(&flags, "join", "false") == "true";
    anyhow::ensure!(
        !(join && flags.contains_key("node-range")),
        "--join and --node-range are mutually exclusive: a spare materializes \
         the full node range and owns nothing until a reshard commits nodes over"
    );
    let range = match flags.get("node-range") {
        Some(s) => parse_node_range(s, emb_cfg.n_nodes)?,
        None => 0..emb_cfg.n_nodes,
    };

    let store = store_config(&flags, emb_cfg.shard_capacity)?;
    let ps = Arc::new(
        EmbeddingPs::new_range_with_store(
            &emb_cfg,
            model.emb_dim_per_group,
            seed,
            range.clone(),
            &store,
        )
        .context("building the embedding PS storage engine")?,
    );

    // A persisted ROUTING table (written at every reshard commit) overrides
    // the static layout: a restarted shard re-enters the deployment owning
    // whatever the committed table assigns to its --addr.
    let routing: Option<(reshard::RoutingTable, usize)> = match flags.get("checkpoint-dir") {
        Some(dir) => match reshard::load_routing(std::path::Path::new(dir))? {
            Some(table) => {
                anyhow::ensure!(
                    table.n_nodes == emb_cfg.n_nodes,
                    "ROUTING table covers {} nodes, this deployment has {}",
                    table.n_nodes,
                    emb_cfg.n_nodes
                );
                let self_idx =
                    table.addrs.iter().position(|a| a == &svc.addr).with_context(|| {
                        format!(
                            "ROUTING table at epoch {} does not list this shard's \
                             --addr {} (addresses: {:?}) — restart each shard with \
                             the exact addr the deployment knows it by",
                            table.epoch, svc.addr, table.addrs
                        )
                    })?;
                println!(
                    "ROUTING: committed epoch {} assigns this shard nodes {:?}",
                    table.epoch,
                    table.owned_range(self_idx)?
                );
                Some((table, self_idx))
            }
            None => None,
        },
        None => None,
    };
    // The node range this process will actually serve — what restore targets.
    let owned = match &routing {
        Some((table, self_idx)) => table.owned_range(*self_idx)?,
        None if join => 0..0,
        None => range.clone(),
    };

    let mut restored_step = 0u64;
    let ckpt = match flags.get("checkpoint-dir") {
        Some(dir) => {
            let mgr = Arc::new(CheckpointManager::new(dir)?);
            // Committed checkpoint epochs are the preferred restore source:
            // they are coordinated step-boundary states, which both the
            // resume semantics and the mid-run recovery replay require.
            // --restore-epoch pins a specific epoch (resume orchestration);
            // otherwise the newest fully committed one wins. Legacy flat
            // per-node files remain the fallback.
            let epoch = match flags.get("restore-epoch") {
                Some(s) => Some(s.parse::<u64>().context("--restore-epoch")?),
                None => mgr.latest_committed_epoch(&owned),
            };
            match epoch {
                Some(step) => {
                    mgr.restore_epoch_range(&ps, step, owned.clone()).with_context(|| {
                        format!("restoring nodes {owned:?} from epoch {step} in {dir}")
                    })?;
                    restored_step = step;
                    println!("restored nodes {owned:?} from committed epoch step-{step}");
                }
                None => {
                    for node in owned.clone() {
                        if mgr.exists(node) {
                            mgr.restore_node(&ps, node)
                                .with_context(|| format!("restoring node {node} from {dir}"))?;
                            println!("restored node {node} from checkpoint");
                        }
                    }
                }
            }
            Some(mgr)
        }
        None => None,
    };
    let server = PsServer::bind_with_opts(
        ps.clone(),
        &svc.addr,
        &emb_cfg,
        seed,
        PsBindOpts {
            ckpt: ckpt.clone(),
            restored_step,
            join,
            routing,
            routing_dir: flags.get("checkpoint-dir").map(std::path::PathBuf::from),
        },
    )?;
    let storage_desc = match &store {
        StoreConfig::Hot => format!("all-hot capacity={}/shard", emb_cfg.shard_capacity),
        StoreConfig::Tiered { hot_capacity, cold_dir, admit_threshold } => format!(
            "tiered hot={hot_capacity}/shard cold-dir={} admit-threshold={admit_threshold}",
            cold_dir.display()
        ),
    };
    println!(
        "persia serve-ps: preset={} dim={} nodes={} (serving {}..{}{}) shards/node={} \
         {storage_desc} seed={}",
        preset.name,
        model.emb_dim_per_group,
        emb_cfg.n_nodes,
        owned.start,
        owned.end,
        if join { ", --join spare" } else { "" },
        emb_cfg.shards_per_node,
        seed,
    );
    println!("listening on {} (stop with a SHUTDOWN RPC)", server.local_addr()?);
    // Orchestrators (and the multi-process integration test) read the
    // listening line through a pipe, where stdout is block-buffered.
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    server.serve_forever()?;
    if let Some(mgr) = ckpt {
        // The legacy flat per-node save assumes the static layout (physical
        // range == served range). Once live resharding is in play — a
        // spare, or a committed ROUTING table — ownership may have moved
        // mid-run, and a full-range flat save would clobber other shards'
        // fallback files with wiped or stale rows; committed checkpoint
        // epochs are the durable state there.
        let resharded = flags
            .get("checkpoint-dir")
            .map(|d| reshard::routing_path(std::path::Path::new(d)).exists())
            .unwrap_or(false);
        if join || resharded {
            println!("skipping flat-file save on shutdown (resharding deployment)");
        } else {
            mgr.save(&ps)?;
            println!("checkpointed nodes {:?} on shutdown", ps.node_range());
        }
    }
    Ok(())
}

fn run_trainer(trainer: &Trainer, flags: &HashMap<String, String>) -> Result<()> {
    let out = if trainer.train.use_pjrt {
        let factory = PjrtEngineFactory {
            artifacts_dir: ArtifactManifest::default_dir(),
            preset: trainer.model.artifact_preset.clone(),
        };
        trainer.run(&factory)?
    } else {
        trainer.run_rust()?
    };
    out.report.print_row();
    if flag(flags, "parity-lines", "false") == "true" {
        // Machine-readable lines for the parity harnesses (integration
        // tests + examples) — same format train-worker rank 0 prints.
        let losses: Vec<String> =
            out.tracker.losses.iter().map(|(s, l)| format!("{s}:{l:.9e}")).collect();
        println!("LOSSES {}", losses.join(","));
        println!(
            "PARITY final_loss={:.9e} final_auc={}",
            out.report.final_loss,
            out.report
                .final_auc
                .map(|a| format!("{a:.12e}"))
                .unwrap_or_else(|| "nan".to_string()),
        );
    }
    if flag(flags, "verbose", "false") == "true" {
        for (name, hist) in out.tracker.phases() {
            println!("  phase {name:<12} {}", hist.summary());
        }
        println!("  ps imbalance: {:.2}", out.ps_imbalance);
    }
    Ok(())
}

/// One embedding worker as its own OS process (the paper's middle tier).
/// Builds the exact trainer the NN ranks build — the fingerprint served in
/// the INFO handshake is how mismatched trainers get rejected — then runs
/// the pipelined prefetcher between the PS (the --remote-ps fleet, or a
/// private in-process PS) and the NN ranks until a SHUTDOWN RPC arrives.
///
/// Flags must be IDENTICAL to the trainers' (same preset/train knobs, with
/// --emb-workers = the tier's process count and --nn-workers / --world = the
/// NN world size); --ew-rank gives this process its sample-id byte,
/// --pipeline-depth bounds the in-flight batches per rank (deterministic
/// mode forces 1).
fn cmd_serve_embedding_worker(flags: HashMap<String, String>) -> Result<()> {
    anyhow::ensure!(
        !flags.contains_key("embedding-workers"),
        "serve-embedding-worker IS the embedding-worker tier; point it at the \
         PS with --remote-ps instead"
    );
    // Accept --world as an alias for --nn-workers so three-tier train-worker
    // deployments can reuse one flag set verbatim.
    let mut flags = flags;
    if let Some(world) = flags.get("world").cloned() {
        flags.insert("nn-workers".to_string(), world);
    }
    // This process's gradient puts are owned by its EW rank: the trainer
    // builder stamps --rank into the PS put-replay log's owner tag, and on
    // this tier the embedding worker (not an NN rank) is the putter.
    if !flags.contains_key("rank") {
        if let Some(ew_rank) = flags.get("ew-rank").cloned() {
            flags.insert("rank".to_string(), ew_rank);
        }
    }
    let trainer = build_trainer(&flags)?;
    let ew_cfg = EmbWorkerConfig {
        addr: flag(&flags, "addr", "127.0.0.1:7900").to_string(),
        ew_rank: flag(&flags, "ew-rank", "0").parse().context("--ew-rank")?,
        pipeline_depth: match flags.get("pipeline-depth") {
            Some(s) => Some(s.parse().context("--pipeline-depth")?),
            None => None,
        },
        replay_depth: flag(&flags, "replay-depth", "4").parse().context("--replay-depth")?,
        // A resumed deployment (--resume-from on this process, or an
        // explicit --start-step) serves its first batches at the epoch
        // boundary the NN ranks will ask for.
        start_step: match flags.get("start-step") {
            Some(s) => s.parse().context("--start-step")?,
            None => trainer.start_step,
        },
        // The worker-side hot-embedding cache lives in THIS process — the
        // same --ew-cache* flags the trainers parse configure it here
        // (deterministic mode force-disables it inside for_trainer).
        ew_cache: flag(&flags, "ew-cache", "true") == "true",
        ew_cache_capacity: flag(&flags, "ew-cache-capacity", "65536")
            .parse()
            .context("--ew-cache-capacity")?,
        ew_cache_staleness: match flags.get("ew-cache-staleness") {
            Some(s) => Some(s.parse().context("--ew-cache-staleness")?),
            None => None,
        },
    };
    ew_cfg.validate()?;
    let ps_deployment = flags.get("remote-ps").map(|s| s.as_str());
    let ps_wire_compress = flag(&flags, "ps-wire-compress", "false") == "true";
    let ckpt_dir = flags.get("checkpoint-dir").map(|s| s.as_str());
    let server = EmbeddingWorkerServer::for_trainer(
        &trainer,
        &ew_cfg,
        ps_deployment,
        ps_wire_compress,
        ckpt_dir,
    )?;
    println!(
        "persia serve-embedding-worker: rank {} preset={} mode={} batch={} ranks={} \
         emb-workers={} deterministic={} ps={}",
        ew_cfg.ew_rank,
        flag(&flags, "preset", "taobao"),
        trainer.train.mode.name(),
        trainer.train.batch_size,
        trainer.cluster.n_nn_workers,
        trainer.cluster.n_emb_workers,
        trainer.deterministic,
        ps_deployment.unwrap_or("in-process"),
    );
    println!(
        "embedding worker listening on {} (stop with a SHUTDOWN RPC)",
        server.local_addr()?
    );
    // Orchestrators (and the integration test) read the listening line
    // through a pipe, where stdout is block-buffered.
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    server.serve_forever()
}

fn cmd_train(flags: HashMap<String, String>) -> Result<()> {
    let trainer = build_trainer(&flags)?;
    println!(
        "persia train: preset={} dense={} mode={} engine={} workers={} batch={} steps={}",
        flag(&flags, "preset", "taobao"),
        trainer.model.artifact_preset,
        trainer.train.mode.name(),
        if trainer.train.use_pjrt { "pjrt" } else { "rust" },
        trainer.cluster.n_nn_workers,
        trainer.train.batch_size,
        trainer.train.steps,
    );
    run_trainer(&trainer, &flags)
}

/// One NN-worker rank as its own OS process (paper §4.1: every role is a
/// process). Builds the exact trainer `train` would, joins the TCP ring
/// through the rank-0 rendezvous — which rejects world-size or
/// config-fingerprint mismatches at connect time — and runs only this
/// rank's worker loop; the dense AllReduce crosses real sockets.
fn cmd_train_worker(flags: HashMap<String, String>) -> Result<()> {
    use std::io::Write as _;
    let rank: usize = flag(&flags, "rank", "0").parse().context("--rank")?;
    let world: usize = flag(&flags, "world", "1").parse().context("--world")?;
    let ring_cfg = RingConfig {
        rendezvous: flag(&flags, "rendezvous", "127.0.0.1:7800").to_string(),
        rank,
        world,
        bind_host: flag(&flags, "listen-host", "127.0.0.1").to_string(),
        timeout_ms: flag(&flags, "ring-timeout-ms", "30000")
            .parse()
            .context("--ring-timeout-ms")?,
        compress: flag(&flags, "ring-compress", "false") == "true",
    };
    ring_cfg.validate()?;
    anyhow::ensure!(
        world == 1 || flags.contains_key("remote-ps") || flags.contains_key("embedding-workers"),
        "train-worker with --world > 1 needs --remote-ps or --embedding-workers: \
         separate worker processes must share one embedding deployment \
         (start serve-ps / serve-embedding-worker first)"
    );
    // The ring IS the worker cluster: fold --world into --nn-workers before
    // the trainer (and its config fingerprint) is built, so connect-time
    // handshakes — the embedding-worker tier's INFO, the ring rendezvous —
    // all see the real world size.
    let mut flags = flags;
    flags.insert("nn-workers".to_string(), world.to_string());
    // A rank riding out a PS shard restart (reconnect-with-retry) stalls
    // for up to retries × backoff without touching the ring; peers would
    // declare it dead once the ring timeout elapses. Warn about the
    // coupling instead of letting the §4.2.4 recovery drill abort the ring.
    let ps_outage_ms: u64 = flag(&flags, "ps-retries", "4").parse::<u64>().unwrap_or(4)
        * flag(&flags, "ps-retry-ms", "50").parse::<u64>().unwrap_or(50);
    if world > 1 && ring_cfg.timeout_ms <= ps_outage_ms {
        eprintln!(
            "warning: --ring-timeout-ms {} is not above the worst-case PS recovery \
             window of {}ms (--ps-retries x --ps-retry-ms); a peer riding out a PS \
             shard restart may be declared dead by the ring",
            ring_cfg.timeout_ms, ps_outage_ms
        );
    }

    // Bind before the (potentially retried) PS connect so orchestrators can
    // read the rendezvous address immediately; peer HELLOs queue in the
    // listener backlog until this worker is ready to run.
    let rz = RingRendezvous::bind(&ring_cfg)?;
    if rank == 0 && world > 1 {
        println!("rendezvous listening on {}", rz.rendezvous_addr()?);
        std::io::stdout().flush().ok();
    }

    let trainer = build_trainer(&flags)?;
    debug_assert_eq!(trainer.cluster.n_nn_workers, world);
    println!(
        "persia train-worker: rank {rank}/{world} preset={} mode={} engine={} batch={} steps={}",
        flag(&flags, "preset", "taobao"),
        trainer.train.mode.name(),
        if trainer.train.use_pjrt { "pjrt" } else { "rust" },
        trainer.train.batch_size,
        trainer.train.steps,
    );
    std::io::stdout().flush().ok();

    // --ring-compress and --ps-wire-compress live outside the Trainer
    // config but change numerics (lossy fp16 on AllReduce chunks / PS
    // traffic): fold both into the rendezvous fingerprint so a mismatch is
    // rejected at connect time like every other numeric knob. The
    // checkpoint cadence and resume step are folded in too — in ordered
    // deterministic mode the epoch drive is a collective ordered section,
    // so ranks disagreeing on either would desynchronize the ring tokens.
    let ps_wire_compress = flag(&flags, "ps-wire-compress", "false") == "true";
    let ckpt_every: u64 =
        trainer.checkpoint.as_ref().map(|c| c.every as u64).unwrap_or(0);
    // The reshard cadence rides along for the same reason as the checkpoint
    // cadence: its drive is a collective ordered section in deterministic
    // mode, so disagreeing ranks would desynchronize the ring tokens.
    let reshard_every: u64 =
        trainer.reshard.as_ref().map(|r| r.every as u64).unwrap_or(0);
    let fingerprint = (trainer.config_fingerprint()
        ^ u64::from(ring_cfg.compress)
        ^ (u64::from(ps_wire_compress) << 1)
        ^ (ckpt_every << 2)
        ^ (reshard_every << 3)
        ^ ((trainer.start_step as u64) << 20)
        ^ trainer.gossip_period.rotate_left(44))
        .wrapping_mul(0x0000_0100_0000_01b3);
    let make_comm = move |net: Arc<NetSim>| -> Result<Box<dyn DenseComm>> {
        let member = rz.connect(fingerprint, net)?;
        println!("ring connected: rank {rank}/{world}");
        std::io::stdout().flush().ok();
        Ok(Box::new(member) as Box<dyn DenseComm>)
    };
    let out = if trainer.train.use_pjrt {
        let factory = PjrtEngineFactory {
            artifacts_dir: ArtifactManifest::default_dir(),
            preset: trainer.model.artifact_preset.clone(),
        };
        trainer.run_rank(&factory, make_comm)?
    } else {
        trainer.run_rank(&trainer.rust_engine_factory(), make_comm)?
    };
    if rank == 0 {
        out.report.print_row();
        // Machine-readable lines for the parity harness (tests + example).
        let losses: Vec<String> =
            out.tracker.losses.iter().map(|(s, l)| format!("{s}:{l:.9e}")).collect();
        println!("LOSSES {}", losses.join(","));
        println!(
            "PARITY final_loss={:.9e} final_auc={}",
            out.report.final_loss,
            out.report
                .final_auc
                .map(|a| format!("{a:.12e}"))
                .unwrap_or_else(|| "nan".to_string()),
        );
    } else {
        println!("rank {rank}/{world} finished {} steps", out.report.steps);
    }
    Ok(())
}

fn cmd_gantt(flags: HashMap<String, String>) -> Result<()> {
    for mode in TrainMode::ALL {
        let mut f = flags.clone();
        f.insert("mode".into(), mode.name().into());
        f.insert("steps".into(), flag(&flags, "steps", "6").to_string());
        f.insert("engine".into(), flag(&flags, "engine", "rust").to_string());
        f.insert("eval-every".into(), "0".into());
        let mut trainer = build_trainer(&f)?;
        trainer.record_gantt = true;
        let out = trainer.run_rust()?;
        println!(
            "\n### mode = {} (overlap fraction {:.2}) ###",
            mode.name(),
            out.gantt.overlap_fraction()
        );
        print!("{}", out.gantt.render_ascii(100));
    }
    Ok(())
}

fn cmd_table1() -> Result<()> {
    println!("{:<14} {:>18} {:>14}", "benchmark", "sparse params", "dense params");
    for p in BenchPreset::all() {
        println!("{:<14} {:>18} {:>14}", p.name, p.sparse_params, p.dense_params_paper);
    }
    Ok(())
}

fn cmd_capacity(flags: HashMap<String, String>) -> Result<()> {
    println!("capacity sweep (virtualized tables, LRU-bounded physical memory)");
    for p in BenchPreset::capacity_sweep() {
        let mut f = flags.clone();
        f.insert("preset".into(), p.name.into());
        f.insert("engine".into(), flag(&flags, "engine", "rust").to_string());
        f.insert("steps".into(), flag(&flags, "steps", "60").to_string());
        f.insert("eval-every".into(), "0".into());
        let trainer = build_trainer(&f)?;
        print!("{:<14} sparse={:>20} ", p.name, p.sparse_params);
        run_trainer(&trainer, &f)?;
    }
    Ok(())
}

fn cmd_modes(flags: HashMap<String, String>) -> Result<()> {
    for mode in TrainMode::ALL {
        let mut f = flags.clone();
        f.insert("mode".into(), mode.name().into());
        f.insert("engine".into(), flag(&flags, "engine", "rust").to_string());
        let trainer = build_trainer(&f)?;
        run_trainer(&trainer, &f)?;
    }
    Ok(())
}

fn usage() -> ! {
    eprintln!(
        "usage: persia <train|train-worker|serve-ps|serve-embedding-worker|gantt|table1|\
         capacity|modes> \
         [--preset taobao] \
         [--mode hybrid] [--engine pjrt|rust] [--dense tiny|small|paper] [--nn-workers N] \
         [--emb-workers N] [--steps N] [--batch N] [--tau N] [--seed N] [--netsim true|false] \
         [--verbose true] [--deterministic true] [--gossip-period N] \
         [--optimizer sgd|adagrad|adam]\n\
         worker-side embedding cache (on by default): [--ew-cache true|false] \
         [--ew-cache-capacity N] [--ew-cache-staleness S] keeps a bounded-staleness \
         cache of hot rows at each embedding worker — cached rows serve repeat \
         lookups for up to S steps (default: the mode's own staleness bound tau) \
         without touching the PS; gradient pushes write through (SGD mirrors the \
         update locally, Adagrad/Adam invalidate); flushed whole on every routing-\
         epoch bump and rank adoption; force-disabled under --deterministic\n\
         sharded PS: persia serve-ps [--addr 127.0.0.1:7700] [--node-range A..B] \
         [--checkpoint-dir DIR] — one process per shard — then \
         persia train --remote-ps addr1[,addr2,...] [--ps-conns N] [--ps-wire-compress true] \
         [--ps-retries N] [--ps-retry-ms MS] [--inflight-window N] [--io-timeout-ms MS] \
         (same --preset/--dense/--shard-capacity/--seed on every process; \
         the --node-range slices must partition the PS nodes exactly)\n\
         embedding-worker tier: persia serve-embedding-worker [--addr 127.0.0.1:7900] \
         [--ew-rank R] [--pipeline-depth D] --remote-ps addr1[,addr2,...] — one process per \
         worker, identical train flags (--emb-workers = worker-process count, \
         --nn-workers/--world = NN world size) — then \
         persia train --embedding-workers addr1[,addr2,...] [--ew-conns N] [--ew-retries N] \
         [--ew-retry-ms MS] [--inflight-window N] [--io-timeout-ms MS] (NN ranks are \
         assigned round-robin, rank mod M); --ew-failover true makes the tier elastic — \
         a dead worker's ranks are adopted by survivors (linear probing from rank mod M) \
         and a restarted worker takes them back ([--ew-rejoin true] [--ew-rejoin-ms MS] \
         throttle the rejoin probe)\n\
         multi-process NN workers: persia train-worker --rank R --world N \
         [--rendezvous 127.0.0.1:7800] [--listen-host HOST] [--ring-timeout-ms MS] \
         [--ring-compress true] --remote-ps|--embedding-workers addr1[,addr2,...] — one \
         process per rank, identical flags everywhere (the rendezvous rejects config \
         mismatches); rank 0 prints 'rendezvous listening on ADDR' for orchestrators\n\
         fault tolerance (recovery layer): train[-worker] --checkpoint-dir DIR \
         --checkpoint-every N cuts committed checkpoint epochs (two-phase across all \
         PS shards + a global manifest); --resume-from DIR [--resume-step N] restarts \
         a killed run from the last committed epoch (serve-ps reloads with \
         --checkpoint-dir DIR [--restore-epoch N], serve-embedding-worker with \
         --start-step N); train/serve-embedding-worker --ps-replay true \
         [--ps-replay-cap N] keeps a gradient replay log so a SIGKILLed shard \
         rejoins mid-run with exact state; serve-embedding-worker [--replay-depth D] \
         sizes the NEXT_BATCH/PUSH_GRADS response replay rings\n\
         tiered storage (bigger-than-RAM tables): serve-ps/train --cold-dir DIR \
         [--hot-capacity N] [--admit-threshold T] keeps a hot LRU of N rows per \
         shard (default: --shard-capacity) over a disk-backed cold tier under DIR; \
         eviction demotes the exact row bytes and a cold hit promotes them back, so \
         numerics are bitwise identical to an all-hot run of the same \
         --shard-capacity; checkpoint epochs persist both tiers (ps_node_N.cold)\n\
         live resharding (grow a deployment mid-run): start a spare with \
         serve-ps --join (same preset flags, no --node-range; it materializes the \
         full node range but owns nothing), list it LAST in every process's \
         --remote-ps, and train with --reshard-every N [--reshard-threshold T] \
         [--nodes N]: rank 0 merges per-node traffic at each N-step boundary and, \
         when the per-process imbalance reaches T (default 1.25), migrates the hot \
         shard's tail nodes onto the spare behind a PREPARE/MIGRATE/COMMIT barrier \
         (no update lost; abort on any failure keeps the old layout); commits \
         persist a ROUTING table under --checkpoint-dir so restarted shards \
         re-enter at the committed layout; make --reshard-every a multiple of \
         --checkpoint-every so each migration is checkpointed at the same boundary"
    );
    std::process::exit(2)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "train" => cmd_train(flags),
        "train-worker" => cmd_train_worker(flags),
        "serve-ps" => cmd_serve_ps(flags),
        "serve-embedding-worker" => cmd_serve_embedding_worker(flags),
        "gantt" => cmd_gantt(flags),
        "table1" => cmd_table1(),
        "capacity" => cmd_capacity(flags),
        "modes" => cmd_modes(flags),
        _ => usage(),
    }
}
