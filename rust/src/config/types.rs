//! Configuration types for model, embedding storage, cluster and training.

use anyhow::{bail, Result};

/// Pooling applied by embedding workers per feature group (paper §4.1 (4)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pooling {
    Sum,
    Mean,
}

/// Row-wise optimizer for the embedding PS (paper Alg. 1's Ω^emb).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Adagrad,
    Adam,
}

impl OptimizerKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sgd" => OptimizerKind::Sgd,
            "adagrad" => OptimizerKind::Adagrad,
            "adam" => OptimizerKind::Adam,
            _ => bail!("unknown optimizer: {s} (sgd|adagrad|adam)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adagrad => "adagrad",
            OptimizerKind::Adam => "adam",
        }
    }
}

/// How embedding rows are placed across PS nodes (paper §4.2.3,
/// "Workload balance of embedding PS").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Naive: each feature group owned by a sub-group of PS nodes. Congests
    /// under skewed traffic — kept as the ablation baseline.
    FeatureGroup,
    /// Persia's fix: ids shuffled (hashed) uniformly across all PS nodes.
    ShuffledUniform,
}

/// Training synchronization mode (paper Fig. 3 right, 4 Gantt rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainMode {
    /// Fully synchronous (XDL-sync-like): all five stages sequential.
    FullSync,
    /// Fully asynchronous (XDL-async-like): no barriers, unbounded staleness,
    /// dense updates drift across workers too.
    FullAsync,
    /// Persia: async embeddings (bounded staleness) + sync dense AllReduce,
    /// without overlap of the dense sync with backward ("raw hybrid").
    HybridRaw,
    /// Persia + overlapping dense AllReduce with backward computation
    /// ("optimized hybrid", the shipping configuration).
    Hybrid,
}

impl TrainMode {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "sync" | "full-sync" => TrainMode::FullSync,
            "async" | "full-async" => TrainMode::FullAsync,
            "hybrid-raw" => TrainMode::HybridRaw,
            "hybrid" => TrainMode::Hybrid,
            _ => bail!("unknown train mode: {s} (sync|async|hybrid-raw|hybrid)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainMode::FullSync => "sync",
            TrainMode::FullAsync => "async",
            TrainMode::HybridRaw => "hybrid-raw",
            TrainMode::Hybrid => "hybrid",
        }
    }

    pub const ALL: [TrainMode; 4] =
        [TrainMode::FullSync, TrainMode::FullAsync, TrainMode::HybridRaw, TrainMode::Hybrid];
}

/// Dense-tower + feature geometry. Must agree with an AOT artifact preset
/// (artifacts/manifest.txt) when the PJRT path is used.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// Name of the AOT preset this maps to ("tiny" | "small" | "paper").
    pub artifact_preset: String,
    /// Number of ID feature groups (VideoIDs, LocIDs, ... in §2.1).
    pub n_groups: usize,
    /// Embedding dimension per group.
    pub emb_dim_per_group: usize,
    /// Non-ID dense feature dimension.
    pub nid_dim: usize,
    /// Hidden layer widths of the FFNN tower.
    pub hidden: Vec<usize>,
    /// IDs per feature group per sample (bag size before pooling).
    pub ids_per_group: usize,
    pub pooling: Pooling,
}

impl ModelConfig {
    pub fn emb_dim(&self) -> usize {
        self.n_groups * self.emb_dim_per_group
    }

    /// Layer dims including input and output: [in, hidden..., 1].
    pub fn dims(&self) -> Vec<usize> {
        let mut d = vec![self.emb_dim() + self.nid_dim];
        d.extend_from_slice(&self.hidden);
        d.push(1);
        d
    }

    pub fn dense_param_count(&self) -> usize {
        let d = self.dims();
        (0..d.len() - 1).map(|i| d[i] * d[i + 1] + d[i + 1]).sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_groups == 0 || self.emb_dim_per_group == 0 {
            bail!("embedding geometry must be non-zero");
        }
        if self.hidden.is_empty() {
            bail!("need at least one hidden layer");
        }
        if self.ids_per_group == 0 {
            bail!("ids_per_group must be >= 1");
        }
        Ok(())
    }
}

/// Embedding-PS storage geometry.
#[derive(Clone, Debug)]
pub struct EmbeddingConfig {
    /// Virtual rows per feature group (can be in the trillions; rows are
    /// materialized on first access — the 100T capacity substitution).
    pub rows_per_group: u64,
    /// Physical LRU capacity (rows) per shard; beyond this, LRU eviction.
    pub shard_capacity: usize,
    /// PS node count.
    pub n_nodes: usize,
    /// Lock-striped sub-shards per node (paper: one thread per sub-map).
    pub shards_per_node: usize,
    pub optimizer: OptimizerKind,
    pub partition: PartitionPolicy,
    /// Row-wise learning rate for the embedding optimizer.
    pub lr: f32,
}

impl EmbeddingConfig {
    /// Total virtual sparse parameter count for a model config.
    pub fn virtual_params(&self, model: &ModelConfig) -> u128 {
        self.rows_per_group as u128
            * model.n_groups as u128
            * model.emb_dim_per_group as u128
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_nodes == 0 || self.shards_per_node == 0 {
            bail!("need >=1 PS node and shard");
        }
        if self.shard_capacity == 0 {
            bail!("shard_capacity must be positive");
        }
        if self.rows_per_group == 0 {
            bail!("rows_per_group must be positive");
        }
        Ok(())
    }
}

/// Simulated network cost model (see DESIGN.md substitutions). All zero =
/// no injected costs (pure in-process speed).
#[derive(Clone, Copy, Debug)]
pub struct NetModelConfig {
    /// GPU<->GPU AllReduce bandwidth (bytes/s) — GPUDirect-class links.
    pub gpu_gpu_bw: f64,
    /// CPU<->GPU link bandwidth (bytes/s) — PCIe/Ethernet-class (paper: 10x slower).
    pub cpu_gpu_bw: f64,
    /// Per-message latency (seconds).
    pub latency_s: f64,
}

impl NetModelConfig {
    pub fn disabled() -> Self {
        Self { gpu_gpu_bw: 0.0, cpu_gpu_bw: 0.0, latency_s: 0.0 }
    }

    /// Defaults mirroring the paper's production cluster ratios
    /// (100 Gbps fabric; GPU-GPU 10x the CPU-GPU effective bandwidth),
    /// scaled down so simulated time structure is visible at laptop scale.
    pub fn paper_like() -> Self {
        Self { gpu_gpu_bw: 12.5e9, cpu_gpu_bw: 1.25e9, latency_s: 50e-6 }
    }

    pub fn enabled(&self) -> bool {
        self.gpu_gpu_bw > 0.0 || self.cpu_gpu_bw > 0.0 || self.latency_s > 0.0
    }
}

/// Cluster geometry: how many logical nodes of each role.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub n_nn_workers: usize,
    pub n_emb_workers: usize,
    pub net: NetModelConfig,
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if self.n_nn_workers == 0 || self.n_emb_workers == 0 {
            bail!("need >=1 NN worker and >=1 embedding worker");
        }
        Ok(())
    }
}

/// Training loop parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub mode: TrainMode,
    pub batch_size: usize,
    /// Dense-side learning rate.
    pub lr: f32,
    /// Bounded staleness τ for the hybrid mode (papers says τ < 5 typical).
    pub staleness_bound: usize,
    pub steps: usize,
    /// Evaluate test AUC every this many steps (0 = never).
    pub eval_every: usize,
    pub seed: u64,
    /// Use the PJRT artifact for dense compute (else pure-Rust tower).
    pub use_pjrt: bool,
    /// Compress embedding/gradient traffic (paper §4.2.3).
    pub compress: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            mode: TrainMode::Hybrid,
            batch_size: 32,
            lr: 0.05,
            staleness_bound: 4,
            steps: 200,
            eval_every: 0,
            seed: 42,
            use_pjrt: false,
            compress: true,
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<()> {
        if self.batch_size == 0 || self.steps == 0 {
            bail!("batch_size and steps must be positive");
        }
        // Paper §4.2.3: uint16 sample indices require batch <= 65535.
        if self.batch_size > 65535 {
            bail!("batch_size must be <= 65535 (uint16 index compression)");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelConfig {
        ModelConfig {
            artifact_preset: "tiny".into(),
            n_groups: 4,
            emb_dim_per_group: 8,
            nid_dim: 8,
            hidden: vec![32, 16],
            ids_per_group: 4,
            pooling: Pooling::Sum,
        }
    }

    #[test]
    fn dims_and_param_count() {
        let m = model();
        assert_eq!(m.emb_dim(), 32);
        assert_eq!(m.dims(), vec![40, 32, 16, 1]);
        assert_eq!(m.dense_param_count(), 40 * 32 + 32 + 32 * 16 + 16 + 16 + 1);
    }

    #[test]
    fn virtual_params_hits_100t() {
        let m = ModelConfig { n_groups: 8, emb_dim_per_group: 16, ..model() };
        // 100T total => rows_per_group = 100e12 / (8*16)
        let e = EmbeddingConfig {
            rows_per_group: 781_250_000_000,
            shard_capacity: 1000,
            n_nodes: 30,
            shards_per_node: 8,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.05,
        };
        assert_eq!(e.virtual_params(&m), 100_000_000_000_000u128);
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in TrainMode::ALL {
            assert_eq!(TrainMode::parse(m.name()).unwrap(), m);
        }
        assert!(TrainMode::parse("bogus").is_err());
    }

    #[test]
    fn validation_catches_bad_configs() {
        let mut m = model();
        m.hidden.clear();
        assert!(m.validate().is_err());
        let mut t = TrainConfig::default();
        t.batch_size = 70_000;
        assert!(t.validate().is_err());
        let c = ClusterConfig { n_nn_workers: 0, n_emb_workers: 1, net: NetModelConfig::disabled() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn netmodel_flags() {
        assert!(!NetModelConfig::disabled().enabled());
        assert!(NetModelConfig::paper_like().enabled());
        // Paper: GPU-GPU links ~10x CPU-GPU.
        let n = NetModelConfig::paper_like();
        assert!((n.gpu_gpu_bw / n.cpu_gpu_bw - 10.0).abs() < 1e-6);
    }
}
