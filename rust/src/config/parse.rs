//! Minimal INI/TOML-subset parser (serde/toml unavailable offline).
//!
//! Grammar: `[section]` headers, `key = value` pairs, `#` comments. Values
//! are accessed typed (`get_u64`, `get_f64`, `get_str`, `get_usize_list`).
//! Used for the AOT artifact manifest and for user config files.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed document: section -> key -> raw string value.
#[derive(Clone, Debug, Default)]
pub struct IniDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl IniDoc {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = IniDoc::default();
        let mut current = String::new(); // "" = top-level section
        doc.sections.insert(String::new(), BTreeMap::new());
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            doc.sections
                .get_mut(&current)
                .unwrap()
                .insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        Ok(doc)
    }

    /// Parse a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Self> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<&str> {
        self.sections
            .get(section)
            .and_then(|kv| kv.get(key))
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing [{section}] {key}"))
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<u64> {
        let s = self.get_str(section, key)?;
        s.replace('_', "").parse().with_context(|| format!("[{section}] {key} = {s}: not a u64"))
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<usize> {
        Ok(self.get_u64(section, key)? as usize)
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<f64> {
        let s = self.get_str(section, key)?;
        s.parse().with_context(|| format!("[{section}] {key} = {s}: not a f64"))
    }

    /// Comma-separated usize list (e.g. `dims = 40,32,16,1`).
    pub fn get_usize_list(&self, section: &str, key: &str) -> Result<Vec<usize>> {
        let s = self.get_str(section, key)?;
        s.split(',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .with_context(|| format!("[{section}] {key}: bad element {t:?}"))
            })
            .collect()
    }

    /// Optional string lookup.
    pub fn get_opt(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section).and_then(|kv| kv.get(key)).map(|s| s.as_str())
    }

    /// Set a value (used by tests and config synthesis).
    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    /// Serialize back to text.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (name, kv) in &self.sections {
            if kv.is_empty() && name.is_empty() {
                continue;
            }
            if !name.is_empty() {
                out.push_str(&format!("[{name}]\n"));
            }
            for (k, v) in kv {
                out.push_str(&format!("{k} = {v}\n"));
            }
            out.push('\n');
        }
        out
    }
}

/// Validate that a string is a known artifact preset name.
pub fn validate_preset_name(name: &str) -> Result<()> {
    match name {
        "tiny" | "small" | "paper" => Ok(()),
        _ => bail!("unknown artifact preset {name:?} (tiny|small|paper)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# comment
top = 1

[model]
name = "tiny"
dims = 40, 32, 16, 1
lr = 0.05
big = 781_250_000_000
"#;

    #[test]
    fn parses_sections_and_types() {
        let doc = IniDoc::parse(DOC).unwrap();
        assert_eq!(doc.get_u64("", "top").unwrap(), 1);
        assert_eq!(doc.get_str("model", "name").unwrap(), "tiny");
        assert_eq!(doc.get_usize_list("model", "dims").unwrap(), vec![40, 32, 16, 1]);
        assert!((doc.get_f64("model", "lr").unwrap() - 0.05).abs() < 1e-12);
        assert_eq!(doc.get_u64("model", "big").unwrap(), 781_250_000_000);
    }

    #[test]
    fn missing_keys_error() {
        let doc = IniDoc::parse(DOC).unwrap();
        assert!(doc.get_str("model", "nope").is_err());
        assert!(doc.get_str("nosection", "x").is_err());
        assert!(doc.get_opt("model", "nope").is_none());
    }

    #[test]
    fn bad_syntax_errors() {
        assert!(IniDoc::parse("[unterminated").is_err());
        assert!(IniDoc::parse("no equals sign here").is_err());
    }

    #[test]
    fn roundtrip_text() {
        let mut doc = IniDoc::default();
        doc.set("a", "x", "1");
        doc.set("a", "y", "2");
        let text = doc.to_text();
        let doc2 = IniDoc::parse(&text).unwrap();
        assert_eq!(doc2.get_u64("a", "x").unwrap(), 1);
        assert_eq!(doc2.get_u64("a", "y").unwrap(), 2);
    }

    #[test]
    fn preset_name_validation() {
        assert!(validate_preset_name("tiny").is_ok());
        assert!(validate_preset_name("huge").is_err());
    }
}
