//! Configuration for the TCP service mode (`persia serve-ps` /
//! `persia train --remote-ps`).

use anyhow::{bail, Result};

/// How a trainer process reaches (or a PS process exposes) the embedding
/// parameter server over TCP.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Listen address for `serve-ps`, server address for clients
    /// (`host:port`; port 0 picks an ephemeral port when binding).
    pub addr: String,
    /// TCP connections in the client pool. Each connection carries one
    /// request at a time, so this bounds in-flight PS requests per process;
    /// the trainer's NN-worker threads and gradient appliers share the pool.
    pub client_conns: usize,
    /// Apply the §4.2.3 lossy fp16 value compression to row/gradient
    /// payloads on the PS wire (index compression — unique keys only — is
    /// always on). Off by default so the remote PS is bit-identical to the
    /// in-process one.
    pub wire_compress: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7700".to_string(), client_conns: 4, wire_compress: false }
    }
}

impl ServiceConfig {
    /// A config pointing at `addr` with defaults otherwise.
    pub fn at(addr: impl Into<String>) -> Self {
        Self { addr: addr.into(), ..Self::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.addr.contains(':') {
            bail!("service addr {:?} must be host:port", self.addr);
        }
        if self.client_conns == 0 {
            bail!("client_conns must be >= 1");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        let cfg = ServiceConfig::default();
        cfg.validate().unwrap();
        assert!(!cfg.wire_compress);
    }

    #[test]
    fn at_overrides_addr_only() {
        let cfg = ServiceConfig::at("0.0.0.0:0");
        assert_eq!(cfg.addr, "0.0.0.0:0");
        assert_eq!(cfg.client_conns, ServiceConfig::default().client_conns);
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_values() {
        assert!(ServiceConfig::at("nocolon").validate().is_err());
        let cfg = ServiceConfig { client_conns: 0, ..ServiceConfig::default() };
        assert!(cfg.validate().is_err());
    }
}
