//! Configuration for the TCP service mode (`persia serve-ps`,
//! `persia serve-embedding-worker`, `persia train --remote-ps` /
//! `--embedding-workers`) and the multi-process NN-worker ring
//! (`persia train-worker`).

use anyhow::{bail, Context, Result};

/// The one failure-handling policy every wire client shares (paper §4.2.4,
/// deployed by `rust/src/recovery/`): how hard a pooled connection tries to
/// come back, and whether the client keeps a gradient-put replay log so a
/// PS shard restarted from an older checkpoint epoch can be brought back to
/// the exact pre-crash state.
///
/// One struct, one meaning, three wire clients: the PS pool
/// ([`RemotePs`](crate::service::RemotePs) /
/// [`ShardedRemotePs`](crate::service::ShardedRemotePs)), the
/// embedding-worker pool
/// ([`RemoteEmbeddingWorker`](crate::service::RemoteEmbeddingWorker)), and
/// the grad appliers' bounded put retry all build their
/// [`RetryPolicy`](crate::recovery::RetryPolicy) from here.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RecoveryConfig {
    /// How many times a failed call re-dials its pooled connection before
    /// giving up (0 = fail on first error). Each retry re-runs the INFO
    /// handshake and insists the server's config fingerprint is unchanged —
    /// this is what lets a PS shard process killed and restarted from its
    /// checkpoint epoch rejoin a run mid-flight (§4.2.4).
    pub attempts: u32,
    /// Base reconnect delay in milliseconds: retry `r` sleeps about
    /// `backoff_ms · 2^(r-1)`, capped and deterministically jittered per
    /// client (see [`RetryPolicy::delay`](crate::recovery::RetryPolicy::delay))
    /// so a restarted shard is not hit by every client at once.
    pub backoff_ms: u64,
    /// Per-call I/O deadline in milliseconds (`--io-timeout-ms`): bounds
    /// every socket write and every response wait on the pooled
    /// connections, so a server that accepts and then wedges trips the
    /// retry path instead of hanging the trainer forever. 0 disables the
    /// deadline (the pre-PR-6 wait-forever behavior).
    pub io_timeout_ms: u64,
    /// Keep a per-shard log of successfully applied gradient puts since the
    /// last committed checkpoint epoch, and replay it into a shard that
    /// comes back restored from that epoch (detected via the INFO boot
    /// nonce). Off by default: the log costs memory proportional to the
    /// checkpoint cadence. Entries are scoped by `replay_owner` and the PS
    /// boot nonce, so a multi-owner replay (a dead embedding worker's delta
    /// adopted by a survivor) stays exact instead of silently assuming one
    /// process owns all puts. See `recovery::PutReplayLog`.
    pub replay_puts: bool,
    /// Maximum put batches retained in the replay log. When the cap is
    /// exceeded the oldest entries are dropped and a later replay is
    /// best-effort (it warns about the lost prefix instead of failing).
    pub replay_cap: usize,
    /// Identity stamped on this process's replay-log entries (`--ew-rank`
    /// for an embedding worker, the NN rank for a direct-`--remote-ps`
    /// trainer). Purely a tag for multi-owner replay bookkeeping — it never
    /// affects what gets replayed, only how hand-offs are attributed.
    pub replay_owner: u64,
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        Self {
            attempts: 4,
            backoff_ms: 50,
            io_timeout_ms: 30_000,
            replay_puts: false,
            replay_cap: 4096,
            replay_owner: 0,
        }
    }
}

impl RecoveryConfig {
    /// Error on a configuration that cannot work.
    pub fn validate(&self) -> Result<()> {
        if self.replay_puts && self.replay_cap == 0 {
            bail!("recovery replay_cap must be >= 1 when replay_puts is on");
        }
        Ok(())
    }

    /// The per-call I/O deadline as a [`std::time::Duration`] (`None` when
    /// disabled with 0) — the form the RPC clients consume.
    pub fn io_timeout(&self) -> Option<std::time::Duration> {
        (self.io_timeout_ms > 0).then(|| std::time::Duration::from_millis(self.io_timeout_ms))
    }
}

/// How a trainer process reaches (or a PS process exposes) the embedding
/// parameter server over TCP.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServiceConfig {
    /// Listen address for `serve-ps` (`host:port`; port 0 picks an
    /// ephemeral port when binding). For clients: one address, or a
    /// comma-separated list of shard-process addresses
    /// (`host:port,host:port,...`) that jointly cover the PS node space —
    /// see [`ShardedRemotePs`](crate::service::ShardedRemotePs).
    pub addr: String,
    /// TCP connections in the client pool *per shard process*. Connections
    /// are pipelined (see `inflight_window`), so this is about spreading
    /// load across sockets, not about concurrency alone; the trainer's
    /// NN-worker threads and gradient appliers share the pool.
    pub client_conns: usize,
    /// Requests in flight per pooled connection (`--inflight-window`):
    /// sends are sequence-tagged and responses demuxed by correlation id,
    /// so scatter-gather GET/PUT across shards overlaps on one socket
    /// instead of paying a round-trip per request. 1 degrades to the old
    /// lock-step call/response.
    pub inflight_window: usize,
    /// Apply the §4.2.3 lossy fp16 value compression to row/gradient
    /// payloads on the PS wire (index compression — unique keys only — is
    /// always on). Off by default so the remote PS is bit-identical to the
    /// in-process one.
    pub wire_compress: bool,
    /// Reconnect/retry/replay policy of this client's connection pools —
    /// the shared `recovery` layer's configuration.
    pub recovery: RecoveryConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7700".to_string(),
            client_conns: 4,
            inflight_window: 32,
            wire_compress: false,
            recovery: RecoveryConfig::default(),
        }
    }
}

impl ServiceConfig {
    /// A config pointing at `addr` with defaults otherwise.
    ///
    /// ```
    /// use persia::config::ServiceConfig;
    /// let cfg = ServiceConfig::at("127.0.0.1:7700, 127.0.0.1:7701");
    /// cfg.validate().unwrap();
    /// assert_eq!(cfg.shard_addrs(), vec!["127.0.0.1:7700", "127.0.0.1:7701"]);
    /// ```
    pub fn at(addr: impl Into<String>) -> Self {
        Self { addr: addr.into(), ..Self::default() }
    }

    /// The (one or more) shard-process addresses in `addr`.
    pub fn shard_addrs(&self) -> Vec<String> {
        self.addr
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    pub fn validate(&self) -> Result<()> {
        let addrs = self.shard_addrs();
        if addrs.is_empty() {
            bail!("service addr list {:?} is empty", self.addr);
        }
        for addr in &addrs {
            validate_addr(addr)?;
        }
        if self.client_conns == 0 {
            bail!("client_conns must be >= 1");
        }
        if self.inflight_window == 0 {
            bail!("inflight_window must be >= 1 (1 = lock-step call/response)");
        }
        self.recovery.validate()?;
        Ok(())
    }
}

/// How one `persia serve-embedding-worker` process presents itself: where
/// it listens and how deep its prefetch pipeline runs. The client-side
/// knobs (pool size, retry policy) reuse [`ServiceConfig`], with the
/// comma-separated `--embedding-workers` list riding in
/// [`ServiceConfig::addr`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EmbWorkerConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port, printed
    /// for orchestrators).
    pub addr: String,
    /// This process's embedding-worker rank (top byte of the sample ids it
    /// mints; purely an identifier, not numerics).
    pub ew_rank: u8,
    /// In-flight batches per NN rank across the draw/assemble/serve stages.
    /// `None` = auto: 1 in deterministic mode (bitwise parity needs
    /// on-demand lookups with ordered puts), else the train mode's own
    /// pipeline depth — on-demand for FullSync (zero staleness is its
    /// contract), τ for the hybrid modes, 2τ for FullAsync — so PS latency
    /// hides behind dense compute exactly where the mode allows staleness.
    pub pipeline_depth: Option<usize>,
    /// Depth of the per-rank NEXT_BATCH response replay ring (`--replay-depth`).
    /// A reconnecting NN rank may re-ask for any of the last `replay_depth`
    /// served steps and get the cached response; deeper rings survive longer
    /// bursts of lost responses (the PR-4 one-deep cache desynced after two
    /// in a row). The PUSH_GRADS ack cache is sized `4 × replay_depth`.
    pub replay_depth: usize,
    /// First step index of every rank's stream (`--start-step`). A resumed
    /// three-tier run (`train --resume-from`) starts its NN ranks at the
    /// checkpoint epoch's step; the worker must fast-forward its loader
    /// streams to the same point or the strictly-sequential NEXT_BATCH
    /// protocol rejects the first request.
    pub start_step: usize,
    /// Run the bounded-staleness hot-embedding cache in front of the PS
    /// (`--ew-cache`, on by default). Forced off in deterministic mode
    /// regardless of this flag — the cache is a strict no-op there, which
    /// is what keeps every bitwise-parity claim intact.
    pub ew_cache: bool,
    /// Maximum cached rows (`--ew-cache-capacity`).
    pub ew_cache_capacity: usize,
    /// Maximum age of a served row in steps (`--ew-cache-staleness`).
    /// `None` = the run's own staleness bound τ.
    pub ew_cache_staleness: Option<u64>,
}

impl Default for EmbWorkerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7900".to_string(),
            ew_rank: 0,
            pipeline_depth: None,
            replay_depth: 4,
            start_step: 0,
            ew_cache: true,
            ew_cache_capacity: 65536,
            ew_cache_staleness: None,
        }
    }
}

impl EmbWorkerConfig {
    /// Error on malformed listen addresses, a zero pipeline/replay depth,
    /// or a degenerate cache geometry.
    pub fn validate(&self) -> Result<()> {
        validate_addr(&self.addr)?;
        if self.pipeline_depth == Some(0) {
            bail!("--pipeline-depth must be >= 1 (1 = on-demand, no readahead)");
        }
        if self.replay_depth == 0 {
            bail!("--replay-depth must be >= 1 (1 = the PR-4 one-deep cache)");
        }
        if self.ew_cache {
            if self.ew_cache_capacity == 0 {
                bail!("--ew-cache-capacity must be >= 1 (or pass --ew-cache false)");
            }
            if self.ew_cache_staleness == Some(0) {
                bail!("--ew-cache-staleness must be >= 1 step (or pass --ew-cache false)");
            }
        }
        Ok(())
    }
}

/// Elastic-membership policy of a trainer's remote embedding tier
/// (`--ew-failover` and friends): what happens when one
/// `serve-embedding-worker` process stops answering within its retry
/// budget, and whether a restarted process may take its ranks back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EwFailoverConfig {
    /// Reassign a dead worker's NN ranks to survivors (`--ew-failover`).
    /// Off by default: the pre-PR-8 behavior — an exhausted retry budget
    /// against any embedding worker is fatal — is preserved bit-for-bit.
    pub enabled: bool,
    /// Probe dead workers' addresses in the background and, when a
    /// restarted process comes back with a matching deployment, return its
    /// home ranks to it at the next step boundary (`--ew-rejoin`, on by
    /// default when failover is enabled).
    pub rejoin: bool,
    /// Minimum milliseconds between rejoin probes of dead addresses
    /// (`--ew-rejoin-ms`). Keeps the probe off the training hot path.
    pub rejoin_ms: u64,
}

impl Default for EwFailoverConfig {
    fn default() -> Self {
        Self { enabled: false, rejoin: true, rejoin_ms: 500 }
    }
}

impl EwFailoverConfig {
    /// Error on a configuration that cannot work.
    pub fn validate(&self) -> Result<()> {
        if self.rejoin && self.rejoin_ms == 0 {
            bail!("--ew-rejoin-ms must be >= 1 when rejoin is on");
        }
        Ok(())
    }
}

/// How one `persia train-worker` process joins the dense AllReduce ring
/// (paper §4.2.3, "Optimized communication among NN workers", deployed as
/// one OS process per NN-worker rank).
///
/// Rank 0 listens on `rendezvous`; every other rank dials it, presents its
/// `(rank, world, config fingerprint)` — exactly the PS INFO handshake
/// policy — and receives the full ring address table back. Mismatched
/// world sizes or fingerprints are rejected at connect time, before any
/// AllReduce step can desynchronize.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RingConfig {
    /// Rank 0's rendezvous listen/dial address (`host:port`; port 0 lets
    /// rank 0 pick an ephemeral port, printed for orchestrators).
    pub rendezvous: String,
    /// This process's rank in `0..world`.
    pub rank: usize,
    /// Total NN-worker processes in the ring.
    pub world: usize,
    /// Host this process binds its ring-inbound listener on (the address
    /// advertised to its ring predecessor).
    pub bind_host: String,
    /// Rendezvous deadline AND per-receive timeout on the established ring,
    /// so a dead peer surfaces as an error instead of a hang. This bounds
    /// how long any rank may stall without touching the ring — set it above
    /// the worst-case PS recovery window (`--ps-retries` ×
    /// `--ps-retry-ms`) or a peer riding out a PS shard restart will be
    /// declared dead mid-drill (`train-worker` warns about this coupling).
    pub timeout_ms: u64,
    /// Apply the §4.2.3 lossy fp16 value compression to AllReduce chunks.
    /// Off by default: the TCP ring is then bit-identical to the
    /// in-process threaded ring.
    pub compress: bool,
}

impl Default for RingConfig {
    fn default() -> Self {
        Self {
            rendezvous: "127.0.0.1:7800".to_string(),
            rank: 0,
            world: 1,
            bind_host: "127.0.0.1".to_string(),
            timeout_ms: 30_000,
            compress: false,
        }
    }
}

impl RingConfig {
    pub fn validate(&self) -> Result<()> {
        if self.world == 0 {
            bail!("ring world size must be >= 1");
        }
        if self.rank >= self.world {
            bail!("ring rank {} out of range for world {}", self.rank, self.world);
        }
        if self.bind_host.is_empty() {
            bail!("ring bind host must be non-empty");
        }
        if self.timeout_ms == 0 {
            bail!("ring timeout must be positive");
        }
        validate_addr(&self.rendezvous)?;
        Ok(())
    }
}

/// Check one `host:port` address: non-empty host AND a port that actually
/// parses as a u16 — `"host:"`, `":7700"`, and `"host:http"` are all
/// config typos that used to slip through and fail much later with an
/// unhelpful connect/bind error.
fn validate_addr(addr: &str) -> Result<()> {
    let Some((host, port)) = addr.rsplit_once(':') else {
        bail!("service addr {addr:?} must be host:port");
    };
    if host.is_empty() {
        bail!("service addr {addr:?} has an empty host");
    }
    port.parse::<u16>()
        .with_context(|| format!("service addr {addr:?} has invalid port {port:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        let cfg = ServiceConfig::default();
        cfg.validate().unwrap();
        assert!(!cfg.wire_compress);
        assert_eq!(cfg.shard_addrs(), vec!["127.0.0.1:7700".to_string()]);
    }

    #[test]
    fn at_overrides_addr_only() {
        let cfg = ServiceConfig::at("0.0.0.0:0");
        assert_eq!(cfg.addr, "0.0.0.0:0");
        assert_eq!(cfg.client_conns, ServiceConfig::default().client_conns);
        cfg.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_values() {
        assert!(ServiceConfig::at("nocolon").validate().is_err());
        // Malformed host/port halves that the old contains(':') check let
        // through.
        assert!(ServiceConfig::at("host:").validate().is_err());
        assert!(ServiceConfig::at(":7700").validate().is_err());
        assert!(ServiceConfig::at("host:http").validate().is_err());
        assert!(ServiceConfig::at("host:70000").validate().is_err());
        assert!(ServiceConfig::at("host:-1").validate().is_err());
        assert!(ServiceConfig::at("").validate().is_err());
        let cfg = ServiceConfig { client_conns: 0, ..ServiceConfig::default() };
        assert!(cfg.validate().is_err());
        let cfg = ServiceConfig { inflight_window: 0, ..ServiceConfig::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn io_timeout_zero_means_disabled() {
        let cfg = RecoveryConfig { io_timeout_ms: 0, ..RecoveryConfig::default() };
        assert_eq!(cfg.io_timeout(), None);
        let cfg = RecoveryConfig { io_timeout_ms: 1500, ..RecoveryConfig::default() };
        assert_eq!(cfg.io_timeout(), Some(std::time::Duration::from_millis(1500)));
        // The default deadline is on: hangs must be opt-in, not opt-out.
        assert!(RecoveryConfig::default().io_timeout().is_some());
    }

    #[test]
    fn shard_lists_parse_and_validate() {
        let cfg = ServiceConfig::at("127.0.0.1:7700, 127.0.0.1:7701,127.0.0.1:7702");
        assert_eq!(
            cfg.shard_addrs(),
            vec!["127.0.0.1:7700", "127.0.0.1:7701", "127.0.0.1:7702"]
        );
        cfg.validate().unwrap();
        // One bad entry poisons the whole list.
        assert!(ServiceConfig::at("127.0.0.1:7700,host:").validate().is_err());
        assert!(ServiceConfig::at(",").validate().is_err());
    }

    #[test]
    fn port_zero_is_legal_for_ephemeral_binds() {
        ServiceConfig::at("127.0.0.1:0").validate().unwrap();
    }

    #[test]
    fn emb_worker_config_validation() {
        EmbWorkerConfig::default().validate().unwrap();
        let ok = EmbWorkerConfig {
            addr: "0.0.0.0:0".into(),
            ew_rank: 3,
            pipeline_depth: Some(4),
            replay_depth: 2,
            start_step: 10,
            ..EmbWorkerConfig::default()
        };
        ok.validate().unwrap();
        assert!(EmbWorkerConfig { pipeline_depth: Some(0), ..EmbWorkerConfig::default() }
            .validate()
            .is_err());
        assert!(EmbWorkerConfig { replay_depth: 0, ..EmbWorkerConfig::default() }
            .validate()
            .is_err());
        assert!(EmbWorkerConfig { addr: "nocolon".into(), ..EmbWorkerConfig::default() }
            .validate()
            .is_err());
        // Cache geometry: zero capacity/staleness only legal with the cache off.
        assert!(EmbWorkerConfig { ew_cache_capacity: 0, ..EmbWorkerConfig::default() }
            .validate()
            .is_err());
        assert!(EmbWorkerConfig { ew_cache_staleness: Some(0), ..EmbWorkerConfig::default() }
            .validate()
            .is_err());
        EmbWorkerConfig { ew_cache: false, ew_cache_capacity: 0, ..EmbWorkerConfig::default() }
            .validate()
            .unwrap();
    }

    #[test]
    fn recovery_config_validation() {
        RecoveryConfig::default().validate().unwrap();
        // Replay needs at least one retained entry.
        let bad = RecoveryConfig { replay_puts: true, replay_cap: 0, ..RecoveryConfig::default() };
        assert!(bad.validate().is_err());
        // A zero-cap log is fine while replay is off.
        let ok = RecoveryConfig { replay_cap: 0, ..RecoveryConfig::default() };
        ok.validate().unwrap();
        // A bad recovery block poisons the owning ServiceConfig.
        let svc = ServiceConfig {
            recovery: RecoveryConfig { replay_puts: true, replay_cap: 0, ..Default::default() },
            ..ServiceConfig::default()
        };
        assert!(svc.validate().is_err());
    }

    #[test]
    fn ew_failover_config_validation() {
        let def = EwFailoverConfig::default();
        assert!(!def.enabled, "failover must be opt-in");
        def.validate().unwrap();
        EwFailoverConfig { enabled: true, ..Default::default() }.validate().unwrap();
        // Rejoin with no probe interval cannot work.
        let bad = EwFailoverConfig { enabled: true, rejoin: true, rejoin_ms: 0 };
        assert!(bad.validate().is_err());
        // ...but rejoin off tolerates any interval.
        EwFailoverConfig { enabled: true, rejoin: false, rejoin_ms: 0 }.validate().unwrap();
    }

    #[test]
    fn ring_config_validation() {
        RingConfig::default().validate().unwrap();
        let ok = RingConfig { rank: 2, world: 3, ..RingConfig::default() };
        ok.validate().unwrap();
        assert!(RingConfig { world: 0, ..RingConfig::default() }.validate().is_err());
        assert!(RingConfig { rank: 2, world: 2, ..RingConfig::default() }.validate().is_err());
        assert!(RingConfig { timeout_ms: 0, ..RingConfig::default() }.validate().is_err());
        assert!(RingConfig { bind_host: String::new(), ..RingConfig::default() }
            .validate()
            .is_err());
        assert!(RingConfig { rendezvous: "nocolon".into(), ..RingConfig::default() }
            .validate()
            .is_err());
    }
}
