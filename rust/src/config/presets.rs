//! Benchmark presets mirroring the paper's Table 1 model scales.
//!
//! | benchmark   | sparse params | dense params |
//! |-------------|---------------|--------------|
//! | Taobao-Ad   | 29 M          | 12 M         |
//! | Avazu-Ad    | 134 M         | 12 M         |
//! | Criteo-Ad   | 540 M         | 12 M         |
//! | Kwai-Video  | 2 T           | 34 M         |
//! | Criteo-Syn1 | 6.25 T        | 12 M         |
//! | Criteo-Syn2 | 12.5 T        | 12 M         |
//! | Criteo-Syn3 | 25 T          | 12 M         |
//! | Criteo-Syn4 | 50 T          | 12 M         |
//! | Criteo-Syn5 | 100 T         | 12 M         |
//!
//! The sparse side is *virtual* (rows materialize on first access — see
//! DESIGN.md substitutions); the dense side runs the `small` artifact by
//! default for wallclock reasons and the `paper` (~12 M dense) artifact when
//! `--dense paper` is requested.

use super::types::*;

/// One Table-1 row plus the workload knobs the experiments need.
#[derive(Clone, Debug)]
pub struct BenchPreset {
    pub name: &'static str,
    /// Paper-reported sparse (embedding) parameter count.
    pub sparse_params: u128,
    /// Paper-reported dense parameter count.
    pub dense_params_paper: u64,
    /// Records in the real dataset (drives synthetic stream length ratios).
    pub records: u64,
    /// Zipf skew of the synthetic ID traffic.
    pub zipf_exponent: f64,
    /// Target test AUC for time-to-AUC runs (paper Fig. 6 / Table 2 scale).
    pub target_auc: f64,
}

pub const PRESET_NAMES: [&str; 9] = [
    "taobao", "avazu", "criteo", "kwai", "criteo-syn1", "criteo-syn2", "criteo-syn3",
    "criteo-syn4", "criteo-syn5",
];

impl BenchPreset {
    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<BenchPreset> {
        let p = |name, sparse, dense, records, zipf, auc| BenchPreset {
            name,
            sparse_params: sparse,
            dense_params_paper: dense,
            records,
            zipf_exponent: zipf,
            target_auc: auc,
        };
        Some(match name {
            "taobao" => p("taobao", 29_000_000, 12_000_000, 26_000_000, 1.05, 0.63),
            "avazu" => p("avazu", 134_000_000, 12_000_000, 32_000_000, 1.05, 0.62),
            "criteo" => p("criteo", 540_000_000, 12_000_000, 44_000_000, 1.05, 0.66),
            "kwai" => p("kwai", 2_000_000_000_000, 34_000_000, 3_000_000_000, 1.1, 0.66),
            "criteo-syn1" => p("criteo-syn1", 6_250_000_000_000, 12_000_000, 44_000_000, 1.05, 0.0),
            "criteo-syn2" => p("criteo-syn2", 12_500_000_000_000, 12_000_000, 44_000_000, 1.05, 0.0),
            "criteo-syn3" => p("criteo-syn3", 25_000_000_000_000, 12_000_000, 44_000_000, 1.05, 0.0),
            "criteo-syn4" => p("criteo-syn4", 50_000_000_000_000, 12_000_000, 44_000_000, 1.05, 0.0),
            "criteo-syn5" => p("criteo-syn5", 100_000_000_000_000, 12_000_000, 44_000_000, 1.05, 0.0),
            _ => return None,
        })
    }

    /// All presets in Table-1 order.
    pub fn all() -> Vec<BenchPreset> {
        PRESET_NAMES.iter().map(|n| Self::by_name(n).unwrap()).collect()
    }

    /// The capacity-sweep subset (Fig. 9): criteo-syn1..5.
    pub fn capacity_sweep() -> Vec<BenchPreset> {
        PRESET_NAMES[4..].iter().map(|n| Self::by_name(n).unwrap()).collect()
    }

    /// The convergence subset (Fig. 6/7, Table 2): the four real benchmarks.
    pub fn convergence_set() -> Vec<BenchPreset> {
        PRESET_NAMES[..4].iter().map(|n| Self::by_name(n).unwrap()).collect()
    }

    /// Runnable model geometry. `dense`: "tiny" | "small" | "paper"
    /// (must match an AOT artifact preset).
    pub fn model(&self, dense: &str) -> ModelConfig {
        let (n_groups, dim, nid, hidden, ids) = match dense {
            "tiny" => (4, 8, 8, vec![32, 16], 4),
            "small" => (8, 16, 16, vec![256, 128, 64], 8),
            // ~12M dense params: hidden 4096/2048/1024/512/256 (paper FFNN).
            "paper" => (8, 16, 64, vec![4096, 2048, 1024, 512, 256], 8),
            other => panic!("unknown dense preset {other:?}"),
        };
        ModelConfig {
            artifact_preset: dense.to_string(),
            n_groups,
            emb_dim_per_group: dim,
            nid_dim: nid,
            hidden,
            ids_per_group: ids,
            pooling: Pooling::Sum,
        }
    }

    /// Embedding storage config: virtual rows sized so that
    /// `rows_per_group * n_groups * dim == sparse_params` of this preset.
    pub fn embedding(&self, model: &ModelConfig, shard_capacity: usize) -> EmbeddingConfig {
        let denom = (model.n_groups * model.emb_dim_per_group) as u128;
        let rows = (self.sparse_params / denom).max(1) as u64;
        EmbeddingConfig {
            rows_per_group: rows,
            shard_capacity,
            n_nodes: 4,
            shards_per_node: 4,
            optimizer: OptimizerKind::Adagrad,
            partition: PartitionPolicy::ShuffledUniform,
            lr: 0.05,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_name_resolves() {
        for name in PRESET_NAMES {
            let p = BenchPreset::by_name(name).unwrap();
            assert_eq!(p.name, name);
        }
        assert!(BenchPreset::by_name("nope").is_none());
    }

    #[test]
    fn table1_scales_match_paper() {
        assert_eq!(BenchPreset::by_name("taobao").unwrap().sparse_params, 29_000_000);
        assert_eq!(BenchPreset::by_name("kwai").unwrap().sparse_params, 2_000_000_000_000);
        assert_eq!(
            BenchPreset::by_name("criteo-syn5").unwrap().sparse_params,
            100_000_000_000_000
        );
        assert_eq!(BenchPreset::by_name("kwai").unwrap().dense_params_paper, 34_000_000);
    }

    #[test]
    fn virtual_rows_reconstruct_sparse_params() {
        for p in BenchPreset::all() {
            let m = p.model("small");
            let e = p.embedding(&m, 1000);
            let virt = e.virtual_params(&m);
            // Integer division loses < one row's worth per group.
            let err = p.sparse_params.abs_diff(virt);
            assert!(err < (m.n_groups * m.emb_dim_per_group) as u128 * 2);
        }
    }

    #[test]
    fn paper_dense_preset_is_about_12m() {
        let m = BenchPreset::by_name("criteo").unwrap().model("paper");
        let n = m.dense_param_count();
        assert!(n > 11_000_000 && n < 13_000_000, "{n}");
    }

    #[test]
    fn sweep_subsets() {
        assert_eq!(BenchPreset::capacity_sweep().len(), 5);
        assert_eq!(BenchPreset::convergence_set().len(), 4);
    }
}
