//! Typed configuration + the paper's Table-1 benchmark presets.

pub mod parse;
pub mod presets;
pub mod service;
pub mod types;

pub use parse::IniDoc;
pub use presets::{BenchPreset, PRESET_NAMES};
pub use service::{EmbWorkerConfig, EwFailoverConfig, RecoveryConfig, RingConfig, ServiceConfig};
pub use types::{
    ClusterConfig, EmbeddingConfig, ModelConfig, NetModelConfig, OptimizerKind, PartitionPolicy,
    Pooling, TrainConfig, TrainMode,
};
