//! Thin safe wrapper over `poll(2)` — the readiness primitive behind the
//! event-driven service core.
//!
//! The vendored dependency closure has no `libc`/`mio`, so the one syscall
//! the readiness loop needs is declared here directly. Everything above
//! this module works with [`PollFd`] slices and plain [`std::net`] sockets
//! in non-blocking mode: the [`crate::service`] accept loop multiplexes its
//! listener + connections through [`poll_fds`], and the ring rendezvous
//! replaces its sleep-polling accept loops with [`poll_readable`].

use std::io;
use std::os::unix::io::RawFd;
use std::time::Duration;

/// One descriptor's interest set and readiness result (mirrors
/// `struct pollfd`).
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    /// The descriptor to watch (negative entries are ignored by the
    /// kernel, which is how unused slots are skipped).
    pub fd: RawFd,
    /// Requested events ([`POLLIN`] | [`POLLOUT`]).
    pub events: i16,
    /// Kernel-reported readiness (includes error/hangup bits even when not
    /// requested).
    pub revents: i16,
}

impl PollFd {
    /// Interest entry for `fd` with `events`.
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Did the kernel report `fd` readable (or in an error/hangup state a
    /// read will surface)?
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
    }

    /// Did the kernel report `fd` writable (or errored, which a write will
    /// surface)?
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0
    }
}

/// Readable-data event bit.
pub const POLLIN: i16 = 0x001;
/// Writable-without-blocking event bit.
pub const POLLOUT: i16 = 0x004;
/// Error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (revents only).
pub const POLLHUP: i16 = 0x010;
/// Invalid descriptor (revents only).
pub const POLLNVAL: i16 = 0x020;

#[cfg(target_os = "macos")]
type NfdsT = u32;
#[cfg(not(target_os = "macos"))]
type NfdsT = std::os::raw::c_ulong;

extern "C" {
    fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
}

/// Wait up to `timeout` for readiness on any entry of `fds`; returns how
/// many entries have non-zero `revents`. `None` blocks indefinitely.
/// `EINTR` is retried internally.
pub fn poll_fds(fds: &mut [PollFd], timeout: Option<Duration>) -> io::Result<usize> {
    let timeout_ms: i32 = match timeout {
        None => -1,
        // Round up so a non-zero timeout never becomes a busy-spin 0.
        Some(d) => d.as_millis().min(i32::MAX as u128).max(u128::from(!d.is_zero())) as i32,
    };
    loop {
        let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
        if rc >= 0 {
            return Ok(rc as usize);
        }
        let err = io::Error::last_os_error();
        if err.kind() != io::ErrorKind::Interrupted {
            return Err(err);
        }
    }
}

/// Wait up to `timeout` for `fd` to become readable. Returns `false` on
/// timeout — the caller decides whether that is an error.
pub fn poll_readable(fd: RawFd, timeout: Duration) -> io::Result<bool> {
    let mut fds = [PollFd::new(fd, POLLIN)];
    Ok(poll_fds(&mut fds, Some(timeout))? > 0 && fds[0].readable())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn listener_becomes_readable_on_connect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        assert!(
            !poll_readable(listener.as_raw_fd(), Duration::from_millis(10)).unwrap(),
            "no pending connection yet"
        );
        let _client = TcpStream::connect(addr).unwrap();
        assert!(
            poll_readable(listener.as_raw_fd(), Duration::from_secs(5)).unwrap(),
            "pending connection must mark the listener readable"
        );
    }

    #[test]
    fn stream_becomes_readable_on_data() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        assert!(!poll_readable(server.as_raw_fd(), Duration::from_millis(10)).unwrap());
        client.write_all(b"x").unwrap();
        assert!(poll_readable(server.as_raw_fd(), Duration::from_secs(5)).unwrap());
    }

    #[test]
    fn poll_fds_reports_writable_stream() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let mut fds = [PollFd::new(client.as_raw_fd(), POLLIN | POLLOUT)];
        let n = poll_fds(&mut fds, Some(Duration::from_secs(5))).unwrap();
        assert!(n > 0 && fds[0].writable(), "idle stream must be writable");
        assert!(!fds[0].readable(), "nothing was sent, so not readable");
    }
}
